"""Trace-import throughput: the traceio pipeline must stay O(events).

Workload: synthetic 4-worker trace sets (``repro.traceio.synthetic``)
written as native JSONL — 50k events total (the ISSUE's 4-worker x ~12.5k
events/worker set) against a 10k-event control.  Timed end-to-end through
``load_trace_dir`` (parse + clock alignment + per-worker graph
reconstruction) and through ``ClusterGraph.from_traces`` (collective
matching + global wiring).

Acceptance (wired into CI):

* scaling gate: per-event import cost at 50k events is <= 2.5x the
  per-event cost at 10k events — a super-linear (O(n^2)) regression in
  parsing, flow binding, alignment, or matching blows straight past that;
* floor gate: import sustains >= 10k events/s (parse-bound; catches
  accidentally quadratic hot loops even if both sizes regress together).

CSV: stage,workers,events,seconds,events_per_sec,per_event_vs_small
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.core import ClusterGraph, CostModel
from repro.traceio import load_trace_dir, write_synthetic_trace_dir

from benchmarks.common import fmt_csv

WORKERS = 4
# events per worker = 4*layers + 2  =>  totals of 10_000 and 50_000
SIZES = {"small": 624, "large": 3124}
SCALING_GATE = 2.5
FLOOR_EVENTS_PER_SEC = 10_000.0


def _events_total(layers: int) -> int:
    return WORKERS * (4 * layers + 2)


def _time_import(trace_dir: str):
    t0 = time.perf_counter()
    imp = load_trace_dir(trace_dir)
    t_load = time.perf_counter() - t0
    t0 = time.perf_counter()
    cg = ClusterGraph.from_traces(imp, cost=CostModel())
    t_build = time.perf_counter() - t0
    return t_load, t_build, imp, cg


def run() -> str:
    rows = []
    per_event = {}
    with tempfile.TemporaryDirectory() as tmp:
        for name, layers in SIZES.items():
            d = os.path.join(tmp, name)
            write_synthetic_trace_dir(
                d, WORKERS, layers=layers,
                compute_scales=[1.5, 1.0, 1.0, 1.0],
                clock_offsets=[0.0, 0.05, -0.03, 0.01])
            events = _events_total(layers)
            # best of 2 so shared-machine load drift cancels out
            t_load, t_build, imp, _ = _time_import(d)
            t2_load, t2_build, _, _ = _time_import(d)
            t_load, t_build = min(t_load, t2_load), min(t_build, t2_build)
            assert imp.num_workers == WORKERS
            per_event[name] = t_load / events
            rows.append(["load_trace_dir", WORKERS, events, f"{t_load:.3f}",
                         f"{events / t_load:.0f}",
                         f"{per_event[name] / per_event['small']:.2f}"])
            rows.append(["from_traces", WORKERS, events, f"{t_build:.3f}",
                         f"{events / t_build:.0f}", ""])
    ratio = per_event["large"] / per_event["small"]
    assert ratio <= SCALING_GATE, (
        f"trace import is super-linear: 50k-event per-event cost is "
        f"{ratio:.2f}x the 10k-event cost (acceptance: <= {SCALING_GATE}x)")
    throughput = 1.0 / per_event["large"]
    assert throughput >= FLOOR_EVENTS_PER_SEC, (
        f"trace import sustains only {throughput:.0f} events/s "
        f"(acceptance: >= {FLOOR_EVENTS_PER_SEC:.0f})")
    return fmt_csv(rows, ["stage", "workers", "events", "seconds",
                          "events_per_sec", "per_event_vs_small"])


if __name__ == "__main__":
    print(run())
