"""Paper Fig. 5 + Fig. 6: AMP speedup predictions + runtime breakdown.

Fig. 5 analogue: per-arch predicted AMP (bf16->fp8-class MXU + halved HBM
bytes) speedups from the Daydream graph.  Fig. 6 analogue: host-only /
device-only / parallel breakdown of the simulated baseline vs AMP.
"""

from __future__ import annotations

from repro.core import whatif, simulate

from .common import BENCH_ARCHS, traced_train, fmt_csv


def run() -> str:
    rows = []
    for arch in BENCH_ARCHS:
        bundle = traced_train(arch)
        base = bundle.simulate()
        amp = whatif.what_if_amp(bundle.graph).simulate()
        rows.append([
            "fig5_amp", arch,
            f"{base.makespan*1e3:.3f}", f"{amp.makespan*1e3:.3f}",
            f"{base.makespan/amp.makespan:.3f}",
        ])
        for tag, res in (("base", base), ("amp", amp)):
            b = res.breakdown
            rows.append([
                "fig6_breakdown", f"{arch}:{tag}",
                f"{b['host_only_s']*1e3:.3f}", f"{b['device_only_s']*1e3:.3f}",
                f"{b['parallel_s']*1e3:.3f}",
            ])
    return fmt_csv(rows, ["bench", "arch", "baseline_ms_or_host",
                          "opt_ms_or_device", "speedup_or_parallel"])
