"""Serving-simulator throughput: graph build must stay O(requests·tokens).

Workload: seeded Poisson traffic lowered under continuous batching with
chunked prefill (the densest policy — per-step gates, slot lanes, chunk
tasks) at two sizes, then simulated.  Timed stages:

* ``build`` — :func:`repro.serving.build_serving_graph` (the policy loop
  plays the workload forward and emits the graph);
* ``simulate`` — the event engine over the generated graph.

Acceptance (wired into CI):

* scaling gate: per-task build cost at the large size <= 2.5x the small
  size — a super-linear regression in admission, slot bookkeeping, or
  gate wiring blows past it (this is the O(requests·tokens) guard);
* floor gate: simulation sustains >= 20k simulated events/s on the
  serving graph (it is lane-heavy: slots + sched + device + arrivals);
* correctness smoke: token conservation and the static drain-time
  invariant, asserted here so a broken build cannot post numbers.

CSV: stage,requests,tasks,seconds,tasks_per_sec,per_task_vs_small
"""

from __future__ import annotations

import time

from repro.core import simulate
from repro.serving import (ServingCostModel, ServingPolicy,
                           build_serving_graph, explicit_workload,
                           poisson_workload)

from benchmarks.common import fmt_csv

COST = ServingCostModel()
POLICY = ServingPolicy(mode="continuous", slots=16, prefill_chunk=64)
# rate scales the request count at fixed duration; output_mean scales the
# decode-token count per request
SIZES = {"small": 100.0, "large": 500.0}
DURATION = 1.0
SCALING_GATE = 2.5
FLOOR_EVENTS_PER_SEC = 20_000.0


def _time_stage(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run() -> str:
    # ---- correctness smoke: drain invariant + token conservation ------
    slots, prompt, budget = 4, 100, 16
    wl0 = explicit_workload([(0.0, prompt, budget)] * slots)
    sg0 = build_serving_graph(wl0, COST,
                              ServingPolicy(mode="static", slots=slots))
    kv = slots * (prompt + budget)
    analytic = slots * COST.prefill_time(prompt) \
        + budget * COST.decode_step_time(slots, kv)
    got = simulate(sg0.graph).makespan
    assert abs(got - analytic) <= 1e-12 * analytic, (
        f"static drain invariant broken: {got} vs {analytic}")

    rows = []
    per_task = {}
    for name, rate in SIZES.items():
        wl = poisson_workload(rate, DURATION, seed=7, prompt_mean=128,
                              output_mean=64)
        t_build, sg = min((_time_stage(
            lambda: build_serving_graph(wl, COST, POLICY))
            for _ in range(2)), key=lambda p: p[0])
        assert sg.tokens_emitted == {r.rid: r.output_tokens
                                     for r in wl.requests}, \
            "token conservation broken"
        tasks = len(sg.graph.tasks())
        per_task[name] = t_build / tasks
        rows.append(["build", len(wl), tasks, f"{t_build:.3f}",
                     f"{tasks / t_build:.0f}",
                     f"{per_task[name] / per_task['small']:.2f}"])
        t_sim, res = min((_time_stage(lambda: simulate(sg.graph))
                          for _ in range(2)), key=lambda p: p[0])
        rows.append(["simulate", len(wl), tasks, f"{t_sim:.3f}",
                     f"{tasks / t_sim:.0f}", ""])
        assert tasks / t_sim >= FLOOR_EVENTS_PER_SEC, (
            f"serving simulation at {tasks / t_sim:.0f} events/s "
            f"(floor: {FLOOR_EVENTS_PER_SEC:.0f})")

    ratio = per_task["large"] / per_task["small"]
    assert ratio <= SCALING_GATE, (
        f"serving graph build scales super-linearly: per-task cost ratio "
        f"{ratio:.2f} (gate: {SCALING_GATE})")

    return fmt_csv(
        rows, ["stage", "requests", "tasks", "seconds", "tasks_per_sec",
               "per_task_vs_small"])


if __name__ == "__main__":
    print(run())
