"""Shared benchmark plumbing: traced bundles of reduced-arch train steps.

Benchmarks trace *unrolled* (scan_layers=False, remat=none) reduced configs so
every layer gets its own named scope -> per-layer Daydream tasks, matching the
paper's per-layer what-if recipes.  Durations are analytical (TPU-v5e model);
the ground-truth benches (fusedadam, amp) re-pin durations to CPU wall-clock
via trace_measured.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import TraceBundle, trace_compiled
from repro.data import make_batch
from repro.models import build_model, init_params, make_train_step
from repro.optim import AdamW

BENCH_ARCHS = ["tinyllama-1.1b", "llama3.2-1b", "moonshot-v1-16b-a3b",
               "mamba2-2.7b", "recurrentgemma-9b"]


@functools.lru_cache(maxsize=16)
def traced_train(arch: str, seq: int = 64, batch: int = 4) -> TraceBundle:
    cfg = get_smoke_config(arch).with_(scan_layers=False, remat="none")
    opt = AdamW(lr=1e-3)
    step = make_train_step(cfg, opt)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    b = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, seq_len=seq, batch=batch, step=0).items()}
    return trace_compiled(step, state, b, max_tasks=40_000)


def layer_grad_bytes(arch: str) -> Dict[str, float]:
    """Per-layer gradient payloads from the reduced config's param tree."""
    cfg = get_smoke_config(arch)
    spec = init_params(cfg, None)
    n_layers = max(1, cfg.n_layers)
    blocks = spec.get("blocks") or spec.get("decoder")
    per_layer = 0.0
    if blocks is not None:
        for leaf in jax.tree.leaves(
                blocks, is_leaf=lambda x: hasattr(x, "logical")):
            n = 1
            for d in leaf.shape[1:]:
                n *= d
            per_layer += n * jnp.dtype(leaf.dtype).itemsize
        n_layers = jax.tree.leaves(
            blocks, is_leaf=lambda x: hasattr(x, "logical"))[0].shape[0]
    return {f"layer{i}": float(per_layer) for i in range(n_layers)}


def fmt_csv(rows, header) -> str:
    out = [",".join(header)]
    for r in rows:
        out.append(",".join(str(x) for x in r))
    return "\n".join(out)
