"""Paper Table 1 coverage: every modeled optimization, one line each.

Runs all ten what-if recipes (5 evaluated + 5 modeled, paper §5) plus the
beyond-paper what-ifs on one traced arch and reports the predicted speedup
(>1: helps; <1: overhead — e.g. Gist/vDNN trade time for memory, matching the
paper's framing that Daydream also identifies optimizations that DON'T pay).
"""

from __future__ import annotations

from repro.core import whatif, simulate

from .common import traced_train, layer_grad_bytes, fmt_csv


def run() -> str:
    arch = "tinyllama-1.1b"
    bundle = traced_train(arch)
    grads = layer_grad_bytes(arch)
    acts = {l: 2e6 for l in grads}
    base = bundle.simulate().makespan
    g = bundle.graph

    dist = whatif.what_if_distributed(g, grads, 16).graph
    dist_base = simulate(dist).makespan

    recipes = {
        "amp": lambda: whatif.what_if_amp(g),
        "fused_optimizer": lambda: whatif.what_if_fused_optimizer(g),
        "fused_norm": lambda: whatif.what_if_fused_norm(g),
        "metaflow_scale_attn_0.7": lambda: whatif.what_if_scale_layer(
            g, "attn", 0.7),
        "gist": lambda: whatif.what_if_gist(g, "layer", acts),
        "vdnn_offload": lambda: whatif.what_if_offload(g, "layer", acts),
    }
    dist_recipes = {
        "distributed_16w": lambda: None,
        "p3": lambda: whatif.what_if_p3(g, grads, 16, bandwidth=5e9),
        "blueconnect": lambda: whatif.what_if_blueconnect(
            dist, [("data", 4), ("model", 4)]),
        "dgc_1pct": lambda: whatif.what_if_dgc(dist, compression=0.01),
        "zero": lambda: whatif.what_if_zero(dist, 16),
        "overlap_collectives": lambda: whatif.what_if_overlap_collectives(
            dist),
        "straggler_1.5x": lambda: whatif.what_if_straggler(dist),
        "bandwidth_2x": lambda: whatif.what_if_bandwidth(dist, 2.0),
        "grad_accum_4": lambda: whatif.what_if_grad_accum(dist, 4),
    }

    rows = []
    for name, fn in recipes.items():
        ms = fn().simulate().makespan
        rows.append(["table1_coverage", name, f"{base*1e3:.3f}",
                     f"{ms*1e3:.3f}", f"{base/ms:.3f}"])
    for name, fn in dist_recipes.items():
        if name == "distributed_16w":
            ms = dist_base
            ref = base
        else:
            ms = fn().simulate().makespan
            ref = dist_base
        rows.append(["table1_coverage", name, f"{ref*1e3:.3f}",
                     f"{ms*1e3:.3f}", f"{ref/ms:.3f}"])
    return fmt_csv(rows, ["bench", "optimization", "baseline_ms",
                          "predicted_ms", "predicted_speedup"])
