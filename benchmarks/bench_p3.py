"""Paper Fig. 10: Priority-Based Parameter Propagation across bandwidths.

Baseline parameter-server (no slicing, no priority) vs P3 (sliced +
priority-scheduled) predictions per bandwidth, reproducing the paper's trend:
P3's win grows as bandwidth shrinks.
"""

from __future__ import annotations

import math

from repro.core import whatif

from .common import traced_train, layer_grad_bytes, fmt_csv

GBPS = 1e9 / 8


def run() -> str:
    rows = []
    for arch in ["tinyllama-1.1b", "llama3.2-1b"]:
        bundle = traced_train(arch)
        grads = layer_grad_bytes(arch)
        for gbps in (5, 10, 15, 20):
            bw = gbps * GBPS
            base = whatif.what_if_p3(bundle.graph, grads, 4, bandwidth=bw,
                                     slice_bytes=math.inf,
                                     priority=False).simulate().makespan
            p3 = whatif.what_if_p3(bundle.graph, grads, 4, bandwidth=bw,
                                   priority=True).simulate().makespan
            rows.append(["fig10_p3", arch, gbps, f"{base*1e3:.3f}",
                         f"{p3*1e3:.3f}", f"{base/p3:.3f}"])
    return fmt_csv(rows, ["bench", "arch", "gbps", "baseline_ms",
                          "p3_ms", "p3_speedup"])
