"""Goodput-simulator throughput: O(fault events) cost + steady-state reuse.

The renewal engine advances checkpoint blocks in closed form, so simulating
a month of training must cost O(fault events), *not* O(steps): a 30-day
horizon at 20 steps/s is ~50M steps but only a few thousand fault episodes.
And a fault-policy sweep (checkpoint interval, elastic, hot spares) over a
FaultScenario must reuse ONE steady-state cluster evaluation — only the
cheap goodput re-simulation varies per point.

Timed stages:

* ``sim`` — :func:`repro.faults.simulate_goodput` on seeded failure
  timelines at two event densities (8x apart) over the same horizon;
* ``policy_sweep`` — 8-point checkpoint-interval sweep on one shared
  :class:`repro.faults.FaultScenario` (steady-state cache hit per point)
  vs naive fresh-scenario-per-point rebuilds.

Acceptance (wired into CI):

* scaling gate: per-event sim cost at the dense size <= 3x the sparse
  size — an O(steps) regression in the block advance blows past it by
  orders of magnitude;
* reuse gate: shared-scenario sweep >= 3x faster than per-point rebuilds,
  with bit-identical goodput per point.

CSV: case,unit,count,seconds,per_unit_us,vs_baseline
"""

from __future__ import annotations

import time

from repro.core import WorkerSpec
from repro.faults import (FaultScenario, RecoveryModel, exponential_failures,
                          simulate_goodput)

from benchmarks.bench_sweep import LAYERS, step_graph
from benchmarks.common import fmt_csv

HORIZON_S = 30 * 86400.0            # one month
STEP_S = 0.05                       # 20 steps/s -> ~52M steps simulated
SIM_WORKERS = 8
SIM_SIZES = {"sparse": 24.0, "dense": 3.0}      # per-worker MTBF, hours
SCALING_GATE = 3.0
REUSE_GATE = 3.0
SWEEP_KS = [50, 100, 200, 400, 800, 1600, 3200, 6400]

gate_margins = None


def _best_of(fn, n=3):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run() -> str:
    global gate_margins
    rows = []

    # ---- stage 1: raw engine cost scales with fault events ------------
    rec = RecoveryModel(checkpoint_bytes=16e9)
    per_event = {}
    for name, mtbf_h in SIM_SIZES.items():
        tl = exponential_failures(SIM_WORKERS, mtbf_h * 3600.0, HORIZON_S,
                                  seed=11)
        t, rep = _best_of(lambda: simulate_goodput(
            n_workers=SIM_WORKERS, horizon_s=HORIZON_S, timeline=tl,
            recovery=rec, ckpt_interval_steps=500, step_s=STEP_S))
        # failures of an already-down worker coalesce into the in-flight
        # repair, so the count can run slightly under the event count
        assert 0 < rep.failures <= len(tl), "engine dropped fault events"
        per_event[name] = t / max(1, len(tl))
        rows.append(["sim", "events", len(tl), f"{t:.4f}",
                     f"{per_event[name] * 1e6:.1f}",
                     f"{per_event[name] / per_event['sparse']:.2f}"])
    ratio = per_event["dense"] / per_event["sparse"]
    assert ratio <= SCALING_GATE, (
        f"goodput sim cost is not O(events): per-event cost ratio "
        f"{ratio:.2f} at 8x density (gate: {SCALING_GATE}) — the closed-"
        f"form block advance has regressed to per-step work")

    # ---- stage 2: policy sweep reuses the steady-state evaluation -----
    # cluster route (ring-wired 16-worker DDP graph, ~12k tasks): the
    # steady-state evaluation is the expensive part the cache must amortize
    def _make():
        return FaultScenario(
            graph=step_graph(),
            layer_grad_bytes={f"l{i}": 40e6 for i in range(LAYERS)},
            workers=[WorkerSpec() for _ in range(16)],
            mtbf_s=6 * 3600.0, horizon_s=86400.0, seed=1)

    shared = _make()

    def _sweep_shared():
        return [shared.predict(f"ddp,ckpt_interval:steps={k}").goodput
                for k in SWEEP_KS]

    def _sweep_fresh():
        return [_make().predict(f"ddp,ckpt_interval:steps={k}").goodput
                for k in SWEEP_KS]

    t_shared, g_shared = _best_of(_sweep_shared, n=2)
    t_fresh, g_fresh = _best_of(_sweep_fresh, n=2)
    assert g_shared == g_fresh, (
        "steady-state reuse changed the goodput predictions")
    assert len(shared._steady_cache) == 1, (
        f"ckpt-interval sweep should hit ONE cached steady state, found "
        f"{len(shared._steady_cache)} entries")
    speedup = t_fresh / t_shared
    rows.append(["policy_sweep", "points", len(SWEEP_KS), f"{t_shared:.3f}",
                 f"{t_shared / len(SWEEP_KS) * 1e6:.0f}",
                 f"{speedup:.1f}x_vs_fresh"])
    rows.append(["policy_sweep_fresh", "points", len(SWEEP_KS),
                 f"{t_fresh:.3f}",
                 f"{t_fresh / len(SWEEP_KS) * 1e6:.0f}", "1.0"])
    assert speedup >= REUSE_GATE, (
        f"fault-policy sweep only {speedup:.2f}x faster than per-point "
        f"scenario rebuilds (acceptance: >= {REUSE_GATE}x)")

    gate_margins = {
        "per_event_cost_ratio": {"value": round(ratio, 2),
                                 "limit": SCALING_GATE},
        "steady_reuse_speedup": {"value": round(speedup, 2),
                                 "floor": REUSE_GATE},
        "steady_cache_entries": {"value": len(shared._steady_cache),
                                 "limit": 1},
    }
    return fmt_csv(rows, ["case", "unit", "count", "seconds", "per_unit_us",
                          "vs_baseline"])


if __name__ == "__main__":
    print(run())
