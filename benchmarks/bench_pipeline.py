"""Pipeline-plan build cost: O(S*M) placement + partition-cached sweeps.

Two CI gates for the PR-4 plan layer (repro.parallel.plan):

1. **Plan size is O(S * M), independent of profile size** — placing a plan
   partitions the profile once into S scalar stage profiles; the placed
   global graph contains only schedule tasks (S*dp microbatch lanes, hop
   legs, per-stage rings, updates), never clones of the profile's tasks.
   Gate: the placed task count equals the closed-form count exactly, for a
   ~38k-task profile.

2. **Sweep reuse >= 3x over per-point rebuilds on a microbatch grid** —
   ``Scenario.sweep`` caches the stage partition per (pre-stack, stages),
   so a microbatch/schedule grid point skips the O(V) profile copy + scan
   and only rebuilds the O(S*M) schedule graph.  ``reuse=False`` repays
   the full partition per point; predictions must match exactly.

CSV: bench,profile_tasks,plan_tasks,points,mode,seconds,speedup_vs_rebuild
"""

from __future__ import annotations

import time

from repro.core import (DependencyGraph, Scenario, Task, TaskKind,
                        DEVICE_STREAM, HOST_THREAD)
from repro.core.optimize import PipelineParallel
from repro.parallel import ParallelPlan

from benchmarks.common import fmt_csv

LAYERS = 96
TASKS_PER_PHASE = 100           # per layer: 100 fwd + 100 bwd ops
STAGES = 4
DP = 2
MICROBATCHES = 16
POINTS = 8


def big_profile(layers: int = LAYERS) -> DependencyGraph:
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    for i in range(layers):
        for k in range(TASKS_PER_PHASE):
            t = g.add_task(Task(f"fwd:l{i}:{k}", TaskKind.COMPUTE,
                                DEVICE_STREAM, 1e-5, layer=f"l{i}",
                                phase="fwd"))
            if i == 0 and k == 0:
                g.add_edge(h, t)
    for i in reversed(range(layers)):
        for k in range(TASKS_PER_PHASE):
            g.add_task(Task(f"bwd:l{i}:{k}", TaskKind.COMPUTE,
                            DEVICE_STREAM, 2e-5, layer=f"l{i}",
                            phase="bwd"))
    for i in range(layers):
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 5e-6,
                        layer=f"l{i}", phase="update"))
    return g


def expected_plan_tasks(S: int, M: int, dp: int) -> int:
    """Closed-form task count of a placed plan (the O(S*M) gate)."""
    n = 0
    for s in range(S):
        per_worker = 2 * M + 1                       # F, B, update
        per_worker += M if s < S - 1 else 0          # act sends
        per_worker += M if s > 0 else 0              # grad sends
        per_worker += 2 * (dp - 1) if dp > 1 else 0  # stage ring legs
        n += dp * per_worker
    return n


def run() -> str:
    g = big_profile()
    grads = {f"l{i}": 40e6 for i in range(LAYERS)}
    acts = {f"l{i}": 4e6 for i in range(LAYERS)}
    scenario = Scenario(g, layer_grad_bytes=grads, activation_bytes=acts)

    # gate 1: plan task count is exactly O(S*M), profile-size-independent
    plan = ParallelPlan.from_profile(g, STAGES, MICROBATCHES, dp=DP,
                                     activation_bytes=acts,
                                     layer_grad_bytes=grads)
    cg = plan.place()
    want = expected_plan_tasks(STAGES, MICROBATCHES, DP)
    assert len(cg.graph) == want, (
        f"placed plan has {len(cg.graph)} tasks, expected the closed-form "
        f"{want} (S={STAGES}, M={MICROBATCHES}, dp={DP}) — placement must "
        f"not scale with the {len(g)}-task profile")

    # gate 2: microbatch-grid sweep reuses the cached partition
    opt = PipelineParallel(stages=STAGES, dp=DP)
    grid = {"microbatches": [2 * (i + 1) for i in range(POINTS)]}

    def timed(reuse: bool):
        t0 = time.perf_counter()
        preds = scenario.sweep(opt, grid, reuse=reuse)
        return time.perf_counter() - t0, [p.predicted for p in preds]

    t_reuse, p_reuse = timed(True)
    t_rebuild, p_rebuild = timed(False)
    t_reuse = min(t_reuse, timed(True)[0])
    t_rebuild = min(t_rebuild, timed(False)[0])
    assert p_reuse == p_rebuild, (
        "partition-cached sweep diverged from per-point rebuilds")
    speedup = t_rebuild / t_reuse
    assert speedup >= 3.0, (
        f"pipeline sweep reuse only {speedup:.2f}x faster than per-point "
        f"rebuilds (acceptance: >=3x)")

    rows = [
        ["plan_size", len(g), len(cg.graph), 1, "place", "-", "-"],
        ["microbatch_sweep", len(g), len(cg.graph), POINTS, "reuse",
         f"{t_reuse:.3f}", f"{speedup:.1f}"],
        ["microbatch_sweep", len(g), len(cg.graph), POINTS, "rebuild",
         f"{t_rebuild:.3f}", "1.0"],
    ]
    return fmt_csv(rows, ["bench", "profile_tasks", "plan_tasks", "points",
                          "mode", "seconds", "speedup_vs_rebuild"])


if __name__ == "__main__":
    print(run())
