"""Paper Fig. 9: collective-primitive model vs theoretical formula vs measured.

Our CollectiveModel implements the NCCL-tests ring formulas [56] on the ICI
topology.  This bench reports, per payload: the theoretical ring time, the
hierarchical (BlueConnect-style) decomposition over (data, model) axes, and —
when >1 local XLA device is available — a measured all-reduce (calibrate.py).
On the 1-device container the measured column is marked n/a.
"""

from __future__ import annotations

import jax

from repro.core import CollectiveModel, MeshTopology
from repro.core.task import TPU_V5E

from .common import fmt_csv


def run() -> str:
    topo = MeshTopology.multi_pod(2, 16, 16)
    coll = CollectiveModel(TPU_V5E, topo)
    rows = []
    for mb in (1, 8, 64, 256):
        payload = mb * 1024 * 1024
        flat = coll.axis_time("all-reduce", payload, 256, "ici")
        hier = coll.hierarchical_all_reduce(payload, ["model", "data"])
        cross = coll.hierarchical_all_reduce(payload,
                                             ["model", "data", "pod"])
        rows.append(["fig9_collectives", f"{mb}MB",
                     f"{flat*1e6:.1f}", f"{hier*1e6:.1f}",
                     f"{cross*1e6:.1f}"])
    measured = "n/a"
    if len(jax.devices()) > 1:
        from repro.core.calibrate import measure_collective_bandwidth
        measured = f"{measure_collective_bandwidth()/1e9:.2f}GB/s"
    rows.append(["fig9_collectives", "local_measured_bw", measured, "", ""])
    return fmt_csv(rows, ["bench", "payload", "flat_ring_us",
                          "hierarchical_us", "with_pod_axis_us"])
