"""Diagnosis-subsystem throughput: critical path + diff must stay O(V+E).

Workload: the ISSUE's 4-worker x ~12.5k-events/worker synthetic cluster
(50k events total, straggler + clock skew) against a 10k-event control —
the same trace sets ``bench_traceio`` imports.  Timed stages:

* ``critical_path`` — ``simulate(record_binding=True)`` over the global
  graph plus the chain walk and attribution
  (:func:`repro.analysis.cluster_critical_path`);
* ``diff`` — predicted per-worker timelines rendered and matched
  task-by-task against the captured trace set
  (:func:`repro.analysis.diff_cluster`).

Acceptance (wired into CI):

* scaling gate: per-event cost at 50k events <= 2.5x the 10k-event cost
  for both stages — a super-linear regression in the binding walk, the
  event collapse, or the occurrence matching blows past it (this is the
  real O(V+E) guard);
* floor gate: critical path sustains >= 10k events/s, diff >= 5k (diff
  renders both timelines, runs the staleness guard pass, and matches
  twice — the lower absolute floor keeps the gate meaningful without
  flaking under shared-machine load);
* correctness smoke: the path's breakdown sums to the makespan and the
  self-diff reports ~zero error (the cheap ends of the test-suite
  invariants, asserted here so a broken build cannot post numbers);
* calibration gate: fitting a perturbed CostModel to a 4-worker capture
  (:func:`repro.analysis.calibrate.calibrate_scenario`) stays within its
  simulator-call budget — ``1 + rounds x constants x probes`` — while
  landing every per-kind WAPE under 5% with a monotone loss history.
  The loop's cost *is* simulator calls, so the budget is the scaling
  gate for the calibrate CLI.

CSV: stage,workers,events,seconds,events_per_sec,per_event_vs_small
(the ``calibrate`` row reports ``sim_calls/budget`` in the last column)
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.analysis import cluster_critical_path, diff_cluster
from repro.core import ClusterGraph, CostModel, Scenario
from repro.traceio import load_trace_dir, write_synthetic_trace_dir

from benchmarks.common import fmt_csv

WORKERS = 4
# events per worker = 4*layers + 2  =>  totals of 10_000 and 50_000
SIZES = {"small": 624, "large": 3124}
SCALING_GATE = 2.5
FLOOR_EVENTS_PER_SEC = {"critical_path": 10_000.0, "diff": 5_000.0}


def _events_total(layers: int) -> int:
    return WORKERS * (4 * layers + 2)


def _time_stage(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run() -> str:
    rows = []
    per_event = {"critical_path": {}, "diff": {}}
    with tempfile.TemporaryDirectory() as tmp:
        for name, layers in SIZES.items():
            d = os.path.join(tmp, name)
            write_synthetic_trace_dir(
                d, WORKERS, layers=layers,
                compute_scales=[1.5, 1.0, 1.0, 1.0],
                clock_offsets=[0.0, 0.05, -0.03, 0.01])
            events = _events_total(layers)
            imp = load_trace_dir(d)
            cg = ClusterGraph.from_traces(imp, cost=CostModel())

            def cp_stage():
                res = cg.simulate(record_binding=True)
                return res, cluster_critical_path(cg, res)

            t_cp, (res, cp) = min(
                (_time_stage(cp_stage) for _ in range(2)),
                key=lambda p: p[0])
            bd = cp.breakdown()
            assert abs(sum(bd.values()) - cp.makespan) <= \
                1e-9 * max(cp.makespan, 1.0), "critical path lost time"

            t_diff, diff = min((_time_stage(
                lambda: diff_cluster(cg, res, imp)) for _ in range(2)),
                key=lambda p: p[0])
            assert not diff.unmatched_predicted and \
                not diff.unmatched_captured, "self-diff failed to match"
            assert diff.max_abs_error() <= 1e-9, "self-diff is not ~zero"

            for stage, t in (("critical_path", t_cp), ("diff", t_diff)):
                per_event[stage][name] = t / events
                rows.append([stage, WORKERS, events, f"{t:.3f}",
                             f"{events / t:.0f}",
                             f"{per_event[stage][name] / per_event[stage]['small']:.2f}"])

        # ---- calibration convergence-cost gate (ISSUE PR 6) ----
        cal_dir = os.path.join(tmp, "calibrate")
        cal_layers = 100
        write_synthetic_trace_dir(cal_dir, WORKERS, layers=cal_layers,
                                  cost=CostModel())
        scn = Scenario(trace_dir=cal_dir,
                       cost=CostModel(kind_scales={"compute": 1.4},
                                      ici_factor=0.6))
        rounds, probes = 6, 6
        t_cal, (_, rep) = _time_stage(
            lambda: scn.calibrate(max_rounds=rounds,
                                  probes_per_constant=probes))
        budget = 1 + rounds * len(rep.fitted) * probes
        assert rep.sim_calls <= budget, (
            f"calibration burned {rep.sim_calls} simulator calls for "
            f"{len(rep.fitted)} constant(s) (budget: {budget})")
        assert all(b <= a + 1e-15 for a, b in
                   zip(rep.loss_history, rep.loss_history[1:])), \
            "calibration loss history is not monotone"
        for kind, st in rep.after.per_kind().items():
            assert st.wape < 0.05, (
                f"calibrated {kind} WAPE {st.wape:.1%} (acceptance: <5%)")
        cal_events = _events_total(cal_layers)
        rows.append(["calibrate", WORKERS, cal_events, f"{t_cal:.3f}",
                     f"{cal_events / t_cal:.0f}",
                     f"{rep.sim_calls}/{budget}"])
    for stage, pe in per_event.items():
        ratio = pe["large"] / pe["small"]
        assert ratio <= SCALING_GATE, (
            f"{stage} is super-linear: 50k-event per-event cost is "
            f"{ratio:.2f}x the 10k-event cost (acceptance: <= "
            f"{SCALING_GATE}x)")
        throughput = 1.0 / pe["large"]
        assert throughput >= FLOOR_EVENTS_PER_SEC[stage], (
            f"{stage} sustains only {throughput:.0f} events/s "
            f"(acceptance: >= {FLOOR_EVENTS_PER_SEC[stage]:.0f})")
    return fmt_csv(rows, ["stage", "workers", "events", "seconds",
                          "events_per_sec", "per_event_vs_small"])


if __name__ == "__main__":
    print(run())
