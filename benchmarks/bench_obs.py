"""Observability overhead gates: O(events) timeline build, free disabled spans.

Two contracts, both asserted so CI fails on regression:

* **Timeline build is O(events)** — :func:`repro.obs.compute_timelines`
  walks the graph once (busy/utilization/queue/comm/memory deltas) and
  sorts per-series change points.  Per-event cost on a ~50k-task wide
  graph must stay within 2.5x of a ~10k-task graph (a superlinear scan
  or per-task re-walk blows well past that).
* **Disabled spans are free** — ``repro.obs.span()`` with telemetry off
  must cost <= 1.05x on a span-per-iteration simulate loop (the
  ``Scenario.sweep``/``ClusterGraph.retune`` wiring pattern).  Paired
  interleaved timings with the GC paused, same discipline as
  ``bench_sim.py``'s binding gate.

Also smoke-checks the enabled path: spans configured at a JSONL sink
actually land there, nested, with attrs.

CSV: metric,events,seconds,per_event_us,gate
"""

from __future__ import annotations

import gc
import json
import os
import tempfile
import time

from repro.core import simulate
from repro.obs import compute_timelines, span
from repro.obs import spans as _spans

from benchmarks.bench_sim import wide_graph
from benchmarks.common import fmt_csv

gate_margins = None     # populated by run(); surfaced by run.py --json


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _timeline_cost(n_lanes: int, per_lane: int):
    g = wide_graph(n_lanes=n_lanes, per_lane=per_lane)
    res = simulate(g)
    acts = {f"l{i}": 1e6 for i in range(n_lanes)}
    t = min(_time(lambda: compute_timelines(
        g, res, activation_bytes=acts)) for _ in range(3))
    return len(g), t


def run() -> str:
    global gate_margins
    rows = []

    # -------------------------------------------- O(events) timeline gate
    n_small, t_small = _timeline_cost(96, 104)      # ~10k tasks
    n_big, t_big = _timeline_cost(96, 520)          # ~50k tasks
    per_small = t_small / n_small
    per_big = t_big / n_big
    ratio = per_big / per_small
    assert ratio <= 2.5, (
        f"timeline build per-event cost grew {ratio:.2f}x from {n_small} "
        f"to {n_big} events (acceptance: <= 2.5x — compute_timelines must "
        f"stay a single O(V+E) walk plus per-series sorts)")
    rows.append(["timeline_build", n_small, f"{t_small:.4f}",
                 f"{per_small * 1e6:.3f}", ""])
    rows.append(["timeline_build", n_big, f"{t_big:.4f}",
                 f"{per_big * 1e6:.3f}", f"ratio={ratio:.2f}x<=2.5x"])

    # ----------------------------------------- disabled-span overhead gate
    assert not _spans.enabled(), (
        "span telemetry is enabled (REPRO_TELEMETRY set?) — the disabled-"
        "overhead gate must run with it off")
    g = wide_graph(n_lanes=24, per_lane=104)        # ~2.5k tasks, ~ms sim

    def plain():
        simulate(g)

    def spanned():
        with span("bench.iteration", tasks=len(g)):
            simulate(g)

    plain(); spanned()                              # warm
    gc.collect()
    gc.disable()
    try:
        t_plain, t_span = [], []
        for _ in range(7):
            t_plain.append(_time(plain))
            t_span.append(_time(spanned))
    finally:
        gc.enable()
    overhead = min(t_span) / min(t_plain)
    assert overhead <= 1.05, (
        f"disabled span() costs {overhead:.3f}x the bare loop "
        f"(acceptance: <= 1.05x — the off path must stay one module-"
        f"global None check returning the shared no-op)")
    n = len(g)
    rows.append(["span_disabled", n, f"{min(t_span):.4f}",
                 f"{min(t_span) / n * 1e6:.3f}",
                 f"overhead={overhead:.3f}x<=1.05x"])

    # ------------------------------------------------- enabled-path smoke
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        _spans.configure(path)
        with span("bench.outer", depth=1):
            with span("bench.inner", depth=2):
                pass
        _spans.configure(None)
        with open(path) as f:
            recs = [json.loads(line) for line in f]
    finally:
        _spans.configure(None)
        os.unlink(path)
    assert [r["span"] for r in recs] == \
        ["bench.outer.bench.inner", "bench.outer"], (
        f"enabled spans mis-stacked: {recs}")
    rows.append(["span_enabled_smoke", len(recs), "", "", "nested-ok"])

    gate_margins = {
        "timeline_per_event_ratio": {"value": round(ratio, 3),
                                     "limit": 2.5},
        "span_disabled_overhead": {"value": round(overhead, 4),
                                   "limit": 1.05},
    }
    return fmt_csv(rows, ["metric", "events", "seconds", "per_event_us",
                          "gate"])


if __name__ == "__main__":
    print(run())
