"""Paper Fig. 8: distributed-training prediction from a single-worker profile.

Sweeps workers x bandwidth, inserting bucketed wait-free-backprop all-reduce
tasks (paper Algorithm 6) into the single-device trace.  Ground truth at fleet
scale needs a fleet; the validation here is the paper's *single-GPU-profile*
methodology plus exactness checks against the analytic ring model (and the
multi-host-device measured path in core/calibrate.py).
"""

from __future__ import annotations

from repro.core import whatif, simulate

from .common import traced_train, layer_grad_bytes, fmt_csv

GBPS = 1e9 / 8


def run() -> str:
    rows = []
    for arch in ["tinyllama-1.1b", "llama3.2-1b"]:
        bundle = traced_train(arch)
        grads = layer_grad_bytes(arch)
        base = bundle.simulate().makespan
        for workers in (4, 8, 16, 32):
            for gbps in (10, 20, 40):
                tf = whatif.what_if_distributed(
                    bundle.graph, grads, workers,
                    bandwidth=gbps * GBPS)
                ms = tf.simulate().makespan
                rows.append(["fig8_distributed", arch, workers, gbps,
                             f"{base*1e3:.3f}", f"{ms*1e3:.3f}",
                             f"{ms/base:.3f}"])
    return fmt_csv(rows, ["bench", "arch", "workers", "gbps",
                          "single_ms", "predicted_ms", "slowdown"])
