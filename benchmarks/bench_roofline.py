"""§Roofline table: render the dry-run artifacts (experiments/dryrun/*.json).

Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

from __future__ import annotations

import glob
import json
import os

from .common import fmt_csv

DEFAULT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
OPT_DIR = os.environ.get("REPRO_DRYRUN_OPT_DIR", "experiments/dryrun_opt")


def _rows(dryrun_dir: str, variant: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if "__" not in os.path.basename(path) or rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append(["roofline", variant, rec["arch"], rec["shape"],
                             rec["mesh"], "SKIP", "", "", "", "", ""])
            continue
        r = rec["roofline"]
        rows.append([
            "roofline", variant, rec["arch"], rec["shape"], rec["mesh"],
            r["bound"],
            f"{r['compute_s']*1e3:.2f}", f"{r['memory_s']*1e3:.2f}",
            f"{r['collective_s']*1e3:.2f}",
            f"{r['useful_compute_ratio']:.3f}",
            f"{r['roofline_fraction']:.3f}",
        ])
    return rows


def run(dryrun_dir: str = DEFAULT_DIR) -> str:
    rows = _rows(dryrun_dir, "baseline") + _rows(OPT_DIR, "optimized")
    if not rows:
        rows.append(["roofline", "", "(no dry-run artifacts found — run "
                     "python -m repro.launch.dryrun --all)", "", "", "", "",
                     "", "", "", ""])
    return fmt_csv(rows, ["bench", "variant", "arch", "shape", "mesh",
                          "bound", "compute_ms", "memory_ms",
                          "collective_ms", "useful_ratio", "roofline_frac"])
