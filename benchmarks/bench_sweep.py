"""Sweep-engine throughput: Scenario.sweep with vs without ClusterGraph reuse.

The ROADMAP's batched-what-if item: a parameter sweep (bandwidth scales,
straggler slowdowns) over an N-worker cluster should reuse ONE ClusterGraph
build — per point only the scaled durations change (``ClusterGraph.retune``
recomputes them from recorded base values, bit-identically), so rebuilding
the replicated global graph per point is pure waste.

Workload: a 16-worker DDP cluster graph from a 24-layer step profile
(ring-leg collectives, ~12k tasks), swept over a 10-point uniform link
bandwidth grid and a 10-point straggler slowdown grid.

Acceptance (wired into CI): reuse >= 3x rebuild on the bandwidth sweep, with
identical predictions point-for-point.

CSV: sweep,points,tasks,mode,seconds,points_per_sec,speedup_vs_rebuild
"""

from __future__ import annotations

import time

from repro.core import (DependencyGraph, Scenario, Task, TaskKind,
                        WorkerSpec, DEVICE_STREAM, HOST_THREAD)
from repro.core.optimize import straggler_specs, uniform_bandwidth_specs

from benchmarks.common import fmt_csv

WORKERS = 16
LAYERS = 24
POINTS = 10


def step_graph(layers: int = LAYERS) -> DependencyGraph:
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM,
                            1e-3, layer=f"l{i}", phase="fwd"))
        if i == 0:
            g.add_edge(h, t)
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 2e-3,
                        layer=f"l{i}", phase="bwd"))
    for i in range(layers):
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 5e-4,
                        layer=f"l{i}", phase="update"))
    return g


def bench_scenario() -> Scenario:
    g = step_graph()
    grads = {f"l{i}": 40e6 for i in range(LAYERS)}
    return Scenario(g, layer_grad_bytes=grads,
                    workers=[WorkerSpec() for _ in range(WORKERS)])


def _run_sweep(scenario: Scenario, grid, *, reuse: bool):
    t0 = time.perf_counter()
    preds = scenario.sweep("ddp", grid, reuse=reuse)
    return time.perf_counter() - t0, [p.predicted for p in preds]


def run() -> str:
    rows = []
    scenario = bench_scenario()
    ntasks = len(scenario.predict("ddp").cluster.global_result.start)

    sweeps = {
        "bandwidth": {"workers": uniform_bandwidth_specs(
            WORKERS, [0.25 + 0.25 * i for i in range(POINTS)])},
        "straggler": {"workers": straggler_specs(
            WORKERS, [1.0 + 0.2 * i for i in range(POINTS)])},
    }
    for name, grid in sweeps.items():
        # interleave modes and keep the best of 2 so shared-machine load
        # drift cancels out of the ratio
        t_reuse, p_reuse = _run_sweep(scenario, grid, reuse=True)
        t_rebuild, p_rebuild = _run_sweep(scenario, grid, reuse=False)
        t_reuse = min(t_reuse, _run_sweep(scenario, grid, reuse=True)[0])
        t_rebuild = min(t_rebuild,
                        _run_sweep(scenario, grid, reuse=False)[0])
        assert p_reuse == p_rebuild, (
            f"{name}: reused sweep diverged from per-point rebuilds")
        rows.append([name, POINTS, ntasks, "reuse", f"{t_reuse:.3f}",
                     f"{POINTS / t_reuse:.1f}",
                     f"{t_rebuild / t_reuse:.1f}"])
        rows.append([name, POINTS, ntasks, "rebuild", f"{t_rebuild:.3f}",
                     f"{POINTS / t_rebuild:.1f}", "1.0"])
        if name == "bandwidth":
            assert t_rebuild / t_reuse >= 3.0, (
                f"sweep reuse only {t_rebuild / t_reuse:.2f}x faster than "
                f"per-point rebuilds (acceptance: >=3x)")
    return fmt_csv(rows, ["sweep", "points", "tasks", "mode", "seconds",
                          "points_per_sec", "speedup_vs_rebuild"])


if __name__ == "__main__":
    print(run())
