"""Benchmark driver — one bench per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,roofline]

Prints ``name,...`` CSV blocks and writes each to experiments/bench/.
"""

from __future__ import annotations

import argparse
import os
import time

BENCHES = {
    "fig5_fig6_amp_breakdown": "benchmarks.bench_amp",
    "fig7_fusedadam": "benchmarks.bench_fusedadam",
    "fig8_distributed": "benchmarks.bench_distributed",
    "fig9_collectives": "benchmarks.bench_collectives",
    "fig10_p3": "benchmarks.bench_p3",
    "table1_coverage": "benchmarks.bench_coverage",
    "roofline": "benchmarks.bench_roofline",
    "sim_engine": "benchmarks.bench_sim",
    "sweep_reuse": "benchmarks.bench_sweep",
    "traceio_import": "benchmarks.bench_traceio",
    "pipeline_plan": "benchmarks.bench_pipeline",
    "analysis_diag": "benchmarks.bench_analysis",
    "serving_sim": "benchmarks.bench_serving",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    os.makedirs(args.out, exist_ok=True)

    import importlib
    failures = []
    for name, modname in BENCHES.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        try:
            mod = importlib.import_module(modname)
            csv = mod.run()
        except Exception as e:  # report and continue
            failures.append((name, repr(e)))
            print(f"FAILED: {e!r}", flush=True)
            continue
        print(csv, flush=True)
        with open(os.path.join(args.out, f"{name}.csv"), "w") as f:
            f.write(csv + "\n")
        print(f"-- {name} done in {time.time()-t0:.1f}s --\n", flush=True)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
