"""Benchmark driver — one bench per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,roofline] [--json]

Prints ``name,...`` CSV blocks and writes each to experiments/bench/.
``--json`` additionally writes (merging into, so per-bench ``--only`` CI
steps accumulate) a ``BENCH_<UTC-date>.json`` perf-trajectory snapshot:
per-bench wall time, parsed CSV rows, and each bench's ``gate_margins``
(how close the asserted perf gates ran to their limits) — the artifact CI
uploads so regressions are visible as a trend, not just a red X.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import time

BENCHES = {
    "fig5_fig6_amp_breakdown": "benchmarks.bench_amp",
    "fig7_fusedadam": "benchmarks.bench_fusedadam",
    "fig8_distributed": "benchmarks.bench_distributed",
    "fig9_collectives": "benchmarks.bench_collectives",
    "fig10_p3": "benchmarks.bench_p3",
    "table1_coverage": "benchmarks.bench_coverage",
    "roofline": "benchmarks.bench_roofline",
    "sim_engine": "benchmarks.bench_sim",
    "sweep_reuse": "benchmarks.bench_sweep",
    "traceio_import": "benchmarks.bench_traceio",
    "pipeline_plan": "benchmarks.bench_pipeline",
    "analysis_diag": "benchmarks.bench_analysis",
    "serving_sim": "benchmarks.bench_serving",
    "obs_telemetry": "benchmarks.bench_obs",
    "cluster_scale": "benchmarks.bench_scale",
    "faults_goodput": "benchmarks.bench_faults",
}


def _csv_rows(csv: str) -> list:
    """Parse a bench's CSV block into row dicts (values stay strings)."""
    lines = [ln for ln in csv.strip().splitlines() if ln.strip()]
    if len(lines) < 2:
        return []
    header = [h.strip() for h in lines[0].split(",")]
    return [dict(zip(header, [c.strip() for c in ln.split(",")]))
            for ln in lines[1:]]


def _write_snapshot(out_dir: str, results: dict) -> str:
    """Merge ``results`` into today's ``BENCH_<UTC-date>.json``."""
    date = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")
    path = os.path.join(out_dir, f"BENCH_{date}.json")
    snap = {"schema": 1, "date": date, "benches": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("benches"), dict):
                snap["benches"] = prev["benches"]
        except (json.JSONDecodeError, OSError):
            pass        # unreadable snapshot: start fresh, don't fail CI
    snap["benches"].update(results)
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--json", action="store_true",
                    help="write/merge a BENCH_<UTC-date>.json perf-"
                         "trajectory snapshot (per-bench metrics + gate "
                         "margins) into --out")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    os.makedirs(args.out, exist_ok=True)

    import importlib
    failures = []
    results = {}
    for name, modname in BENCHES.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        try:
            mod = importlib.import_module(modname)
            csv = mod.run()
        except Exception as e:  # report and continue
            failures.append((name, repr(e)))
            print(f"FAILED: {e!r}", flush=True)
            results[name] = {"ok": False, "error": repr(e),
                             "seconds": round(time.time() - t0, 3)}
            continue
        dt = time.time() - t0
        print(csv, flush=True)
        with open(os.path.join(args.out, f"{name}.csv"), "w") as f:
            f.write(csv + "\n")
        results[name] = {"ok": True, "seconds": round(dt, 3),
                         "rows": _csv_rows(csv),
                         "gate_margins": getattr(mod, "gate_margins", None)}
        print(f"-- {name} done in {dt:.1f}s --\n", flush=True)
    if args.json and results:
        path = _write_snapshot(args.out, results)
        print(f"perf snapshot: {path}", flush=True)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
