"""Cluster-scale what-ifs: symmetry folding + incremental re-simulation.

ROADMAP item 4: predict/sweep surfaces must stay interactive where
production users live — thousands of workers — without giving up the
simulator's exactness.  Two engines under test:

* **Symmetry folding** (``repro.core.fold``): partition workers into
  equivalence classes, materialize one representative per class, close
  collectives algebraically over class sizes.  Gate: folded makespan is
  *identical* (``==``, not approx) to the fully materialized build on a
  mixed workload — uniform ring, pod-uniform hierarchical, straggler
  fused — and a 10-point what-if sweep over a 4096-worker hybrid PP×DP
  plan completes in < 10 s wall-clock.
* **Incremental cone re-simulation** (``simulate_incremental``): after
  ``retune``, replay only the dirty downstream cone.  Gate: >= 3x over a
  full replay on sweeps touching < 10% of tasks, timeline-identical.

CSV: case,workers,classes,tasks,mode,seconds,note
"""

from __future__ import annotations

import time

from repro.core import (ClusterGraph, WorkerSpec, fold_cluster, whatif)
from repro.core.optimize import straggler_specs, uniform_bandwidth_specs
from repro.parallel.plan import ParallelPlan, StageProfile

from benchmarks.bench_sweep import step_graph
from benchmarks.common import fmt_csv

WORKERS = 64
LAYERS = 24
POINTS = 10
PLAN_STAGES = 8
PLAN_DP = 512                   # 8 stages x 512 replicas = 4096 workers

gate_margins = None     # populated by run(); surfaced by run.py --json


def _ddp_graph(layers: int = LAYERS, bucket_bytes: float = 26214400):
    g = step_graph(layers)
    grads = {f"l{i}": 40e6 for i in range(layers)}
    return whatif.what_if_distributed(g, grads, num_workers=WORKERS,
                                      bucket_bytes=bucket_bytes).graph


def _deep_step_graph(layers: int):
    """Deep fwd/bwd chains + ONE fused-optimizer update: the incremental
    regime — a bandwidth what-if dirties only the (late) collectives, the
    compute prefix replays from the frozen boundary."""
    from repro.core import (DependencyGraph, Task, TaskKind, DEVICE_STREAM,
                            HOST_THREAD)
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM,
                            1e-3, layer=f"l{i}", phase="fwd"))
        if i == 0:
            g.add_edge(h, t)
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 2e-3,
                        layer=f"l{i}", phase="bwd"))
    g.add_task(Task("upd:fused", TaskKind.COMPUTE, DEVICE_STREAM, 2e-3,
                    phase="update"))
    return g


def _hybrid_plan() -> ParallelPlan:
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=2e-3,
                               bwd_s=4e-3, update_s=1e-3, act_bytes=16e6,
                               grad_bytes=64e6) for s in range(PLAN_STAGES))
    return ParallelPlan(profs, 8, "gpipe", PLAN_DP)


def run() -> str:
    global gate_margins
    rows = []
    base = _ddp_graph()

    # ---- gate 1: folded == materialized, exact, on a mixed workload ----
    mixed = [
        ("uniform_ring", "ring",
         [WorkerSpec() for _ in range(WORKERS)]),
        ("pod_hierarchical", "hierarchical",
         [WorkerSpec(pod=i // 16) for i in range(WORKERS)]),
        ("straggler_fused", "fused",
         straggler_specs(WORKERS, [2.0])[0]),
    ]
    worst_err = 0.0
    for name, mode, specs in mixed:
        t_fold = time.perf_counter()
        fg = fold_cluster(base, specs, collective_mode=mode)
        rf = fg.simulate()
        t_fold = time.perf_counter() - t_fold
        t_mat = time.perf_counter()
        cg = ClusterGraph.build(base, specs, collective_mode=mode)
        rm = cg.simulate()
        t_mat = time.perf_counter() - t_mat
        err = abs(rf.makespan - rm.makespan)
        worst_err = max(worst_err, err)
        assert rf.makespan == rm.makespan, (
            f"{name}: folded makespan {rf.makespan} != materialized "
            f"{rm.makespan} (acceptance: identical)")
        rows.append([name, WORKERS, fg.num_classes, len(fg.graph), "fold",
                     f"{t_fold:.3f}", f"mat={t_mat:.3f}s "
                     f"tasks_mat={len(cg.graph)}"])

    # ---- gate 2: 10-point sweep over a 4096-worker hybrid PP x DP ----
    plan = _hybrid_plan()
    bw_points = [0.25 + 0.25 * i for i in range(POINTS)]
    t0 = time.perf_counter()
    fg = plan.fold_place()
    assert fg is not None and fg.num_classes == PLAN_STAGES
    prev = fg.simulate()
    makespans = [prev.makespan]
    n_inc = 0
    for bw in bw_points[1:]:
        fg.retune([WorkerSpec(bandwidth_scale=bw)] * plan.num_workers)
        res = fg.simulate_incremental(prev)
        if res is not None:
            n_inc += 1
        else:
            res = fg.simulate()
        makespans.append(res.makespan)
        prev = res
    t_sweep = time.perf_counter() - t0
    assert len(set(f"{m:.9e}" for m in makespans)) > 1, \
        "sweep points did not vary — bandwidth retune is dead"
    assert t_sweep < 10.0, (
        f"10-point sweep over {plan.num_workers}-worker hybrid plan took "
        f"{t_sweep:.1f}s (acceptance: < 10 s)")
    rows.append(["hybrid_4k_sweep", plan.num_workers, fg.num_classes,
                 len(fg.graph), "fold", f"{t_sweep:.3f}",
                 f"points={POINTS} incremental={n_inc}"])

    # ---- gate 3: incremental >= 3x full replay, < 10% of tasks dirty ----
    # coarse gradient buckets + fused mode keep the dirty set to a
    # handful of per-worker collective tasks — the realistic
    # interconnect-what-if axis where only the collectives change and
    # the compute prefix is untouched
    deep = _deep_step_graph(144)
    grads = {f"l{i}": 40e6 for i in range(144)}
    sparse = whatif.what_if_distributed(deep, grads, num_workers=WORKERS,
                                        bucket_bytes=500e6).graph
    cg = ClusterGraph.build(sparse, [WorkerSpec() for _ in range(WORKERS)],
                            collective_mode="fused")
    ntasks = len(cg.graph)
    prev = cg.simulate()
    t_inc = t_full = 0.0
    max_dirty = 0
    for bw in bw_points:
        cg.retune(uniform_bandwidth_specs(WORKERS, [bw])[0])
        max_dirty = max(max_dirty, len(cg.last_retune_dirty))
        # time the calls whose results the sweep actually consumes — one
        # incremental, one full — exactly the Scenario.sweep access
        # pattern (its cres carry chains incremental results)
        t0 = time.perf_counter()
        inc = cg.simulate_incremental(prev)
        t_inc += time.perf_counter() - t0
        t0 = time.perf_counter()
        full = cg.simulate()
        t_full += time.perf_counter() - t0
        assert inc is not None, "incremental route bailed on a tiny cone"
        assert inc.global_result.makespan == full.global_result.makespan
        assert inc.global_result.finish == full.global_result.finish
        prev = inc
    dirty_frac = max_dirty / ntasks
    speedup = t_full / t_inc
    assert dirty_frac < 0.10, (
        f"perturbation touches {dirty_frac:.1%} of tasks — not the "
        f"sparse-sweep regime this gate is about")
    assert speedup >= 3.0, (
        f"incremental re-simulation only {speedup:.2f}x over full replay "
        f"(acceptance: >= 3x at {dirty_frac:.1%} dirty)")
    rows.append(["incremental_resim", WORKERS, "-", ntasks, "fused",
                 f"{t_inc:.3f}",
                 f"full={t_full:.3f}s speedup={speedup:.1f}x "
                 f"dirty={dirty_frac:.1%}"])

    gate_margins = {
        "fold_exactness_err": {"value": worst_err, "limit": 0.0},
        "hybrid_4k_sweep_seconds": {"value": round(t_sweep, 3),
                                    "limit": 10.0},
        "incremental_speedup": {"value": round(speedup, 2), "floor": 3.0},
        "incremental_dirty_frac": {"value": round(dirty_frac, 4),
                                   "limit": 0.10},
    }
    return fmt_csv(rows, ["case", "workers", "classes", "tasks", "mode",
                          "seconds", "note"])


if __name__ == "__main__":
    print(run())
