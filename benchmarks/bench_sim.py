"""Simulator-engine throughput: event-driven heap vs legacy frontier scan.

Two workloads:

* ``wide``  — a synthetic 50k-task graph with ~100 parallel lanes and
  cross-lane edges.  This is the regime the legacy O(V·F) loop dies in
  (frontier ~= lane count, scanned per step) and the heap engine's
  O(E log V) shrugs at; the ISSUE's acceptance bar is >=5x here.
* ``cluster`` — a 64-worker ClusterGraph built from a DDP-transformed step
  graph (ring-leg collectives), i.e. the shape the cluster what-ifs
  actually simulate.  Event-driven engine only (the legacy loop is run
  once on a smaller replica count for reference).

Also gates the diagnosis subsystem's zero-cost contract: binding-
predecessor recording (``simulate(record_binding=True)``, what
``repro.analysis`` walks for critical paths) must keep the instrumented
run within 10% of the plain run on the 50k-task wide graph — asserted,
so a recording change that leaks cost into the hot loop fails CI.

CSV: workload,tasks,engine,seconds,tasks_per_sec,speedup_vs_legacy
"""

from __future__ import annotations

import random
import time

from repro.core import (ClusterGraph, DependencyGraph, Task, TaskKind,
                        simulate, simulate_reference, whatif,
                        DEVICE_STREAM, HOST_THREAD)

from benchmarks.common import fmt_csv

gate_margins = None     # populated by run(); surfaced by run.py --json


def wide_graph(n_lanes: int = 96, per_lane: int = 520,
               seed: int = 0) -> DependencyGraph:
    rng = random.Random(seed)
    g = DependencyGraph()
    lanes = []
    for ln in range(n_lanes):
        th = f"lane{ln}"
        lanes.append([g.add_task(Task(f"{th}:{i}", TaskKind.COMPUTE, th,
                                      duration=rng.uniform(0.5, 2.0) * 1e-3))
                      for i in range(per_lane)])
    # cross-lane edges: every 8th task depends on the neighbour lane's
    # previous task (keeps the frontier wide but the graph connected)
    for ln in range(n_lanes):
        for i in range(8, per_lane, 8):
            g.add_edge(lanes[(ln + 1) % n_lanes][i - 8], lanes[ln][i])
    return g


def cluster_graph(workers: int = 64):
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    layers = 24
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM,
                            1e-3, layer=f"l{i}", phase="fwd"))
        if i == 0:
            g.add_edge(h, t)
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 2e-3,
                        layer=f"l{i}", phase="bwd"))
    for i in range(layers):
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 5e-4,
                        layer=f"l{i}", phase="update"))
    grads = {f"l{i}": 40e6 for i in range(layers)}
    tf = whatif.what_if_distributed(g, grads, num_workers=workers)
    return ClusterGraph.build(tf.graph, workers)


def _time(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def run() -> str:
    global gate_margins
    rows = []

    g = wide_graph()
    n = len(g)
    t_fast = min(_time(simulate, g) for _ in range(3))
    t_slow = _time(simulate_reference, g)
    r_fast = simulate(g)
    r_slow = simulate_reference(g)
    assert abs(r_fast.makespan - r_slow.makespan) < 1e-9, "engines disagree"
    wide_speedup = t_slow / t_fast
    rows.append(["wide", n, "event", f"{t_fast:.3f}", f"{n / t_fast:.0f}",
                 f"{wide_speedup:.1f}"])
    rows.append(["wide", n, "legacy", f"{t_slow:.3f}", f"{n / t_slow:.0f}",
                 "1.0"])

    # binding-recording overhead gate: the instrumented run must stay
    # within 10% of the plain run.  Interleaved pairs cancel machine-load
    # drift, and the GC is paused across the timed region — each simulate
    # allocates ~100k objects, and with the legacy run's results still
    # live a gen-2 collection landing inside one timed call skews a
    # single-digit-percent comparison by 2-3x.
    import gc
    r_rec = simulate(g, record_binding=True)
    assert r_rec.makespan == r_fast.makespan, "recording changed the timeline"
    assert len(r_rec.binding) == n, "recording missed tasks"
    del r_rec, r_slow
    gc.collect()
    gc.disable()
    try:
        t_plain, t_rec = [], []
        for _ in range(5):
            t_plain.append(_time(simulate, g))
            t_rec.append(_time(lambda gg: simulate(gg, record_binding=True),
                               g))
    finally:
        gc.enable()
    overhead = min(t_rec) / min(t_plain)
    assert overhead <= 1.10, (
        f"binding recording costs {overhead:.2f}x the plain run "
        f"(acceptance: <= 1.10x — keep the disabled path byte-identical "
        f"and the enabled path out of the hot loop)")
    rows.append(["wide", n, "event+binding",
                 f"{min(t_rec):.3f}", f"{n / min(t_rec):.0f}",
                 f"overhead={overhead:.2f}x"])

    cg = cluster_graph()
    n = len(cg.graph)
    t_fast = min(_time(cg.simulate) for _ in range(3))
    rows.append(["cluster64", n, "event", f"{t_fast:.3f}",
                 f"{n / t_fast:.0f}", ""])
    small = cluster_graph(workers=8)
    ns = len(small.graph)
    t_f8 = _time(simulate, small.graph)
    t_s8 = _time(simulate_reference, small.graph)
    rows.append(["cluster8", ns, "event", f"{t_f8:.3f}", f"{ns / t_f8:.0f}",
                 f"{t_s8 / t_f8:.1f}"])
    rows.append(["cluster8", ns, "legacy", f"{t_s8:.3f}", f"{ns / t_s8:.0f}",
                 "1.0"])

    gate_margins = {
        "binding_overhead": {"value": round(overhead, 4), "limit": 1.10},
        "engine_speedup_wide": {"value": round(wide_speedup, 2),
                                "floor": 5.0},
    }
    return fmt_csv(rows, ["workload", "tasks", "engine", "seconds",
                          "tasks_per_sec", "speedup_vs_legacy"])


if __name__ == "__main__":
    print(run())
