"""Paper Fig. 7: FusedAdam — predicted vs CPU-measured ground truth.

Implements the paper's predict -> implement -> measure loop with a runnable
ground truth: the unfused per-chunk Adam chain vs the single fused update,
measured on this container's CPU backend; Daydream predicts from the unfused
trace (durations pinned to wall-clock by trace_measured).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import trace_measured, measure_wallclock
from repro.core.transform import GraphTransform, on_device

from .common import fmt_csv


def _make_chains(n: int, chunks: int):
    def unfused(p, g, m, v):
        outs = []
        for c in range(chunks):
            sl = slice(c * n // chunks, (c + 1) * n // chunks)
            mm = 0.9 * m[sl] + 0.1 * g[sl]
            vv = 0.95 * v[sl] + 0.05 * g[sl] * g[sl]
            outs.append(p[sl] - 1e-3 * (mm / (jnp.sqrt(vv) + 1e-8)))
        return jnp.concatenate(outs)

    def fused(p, g, m, v):
        mm = 0.9 * m + 0.1 * g
        vv = 0.95 * v + 0.05 * g * g
        return p - 1e-3 * (mm / (jnp.sqrt(vv) + 1e-8))

    return unfused, fused


def run() -> str:
    rows = []
    key = jax.random.PRNGKey(0)
    for n, chunks in [(1 << 17, 32), (1 << 18, 64), (1 << 20, 64)]:
        args = [jax.random.normal(jax.random.fold_in(key, i), (n,))
                for i in range(4)]
        unfused, fused = _make_chains(n, chunks)
        bundle = trace_measured(unfused, *args, iters=12)
        base = bundle.simulate().makespan
        tf = GraphTransform(bundle.graph)
        dev = tf.select(on_device)
        flops = sum(t.flops for t in dev)
        byts = 7 * n * 4.0        # fused kernel traffic: read p,g,m,v; write
        for t in dev[1:]:
            tf.remove(t)
        keep = tf.select(on_device)[0]
        keep.duration = bundle.cost.compute_time(flops, byts)
        pred_speedup = base / tf.simulate().makespan
        t_unf = measure_wallclock(unfused, *args, iters=12)
        t_fus = measure_wallclock(fused, *args, iters=12)
        true_speedup = t_unf / t_fus
        err = abs(pred_speedup - true_speedup) / true_speedup
        rows.append(["fig7_fusedadam", f"n={n}:chunks={chunks}",
                     f"{pred_speedup:.3f}", f"{true_speedup:.3f}",
                     f"{err*100:.1f}%"])
    return fmt_csv(rows, ["bench", "config", "predicted_speedup",
                          "measured_speedup", "rel_error"])
