"""Diagnosis subsystem (repro.analysis) acceptance tests.

The ISSUE's acceptance criteria live here:

* **Critical-path invariants**: the extracted path's segment durations
  (+gaps) sum to the makespan to float precision, on single-worker graphs
  and on cluster graphs in every collective mode; the chain is contiguous
  (each segment starts exactly when its binder completes); the path's
  composition and attribution fractions on the seed graph are pinned by
  ``tests/golden/critical_path.json``.
* **Trace-diff round trip**: diffing a prediction against its *own*
  exported trace set reports ~zero error for every task — including
  point-to-point pipeline hops, which round-trip via provenance since this
  PR — and a perturbed capture surfaces exactly the perturbed task at the
  top of the mispredicted list.
* **Opportunity bounds**: for every registered (default-constructible)
  optimization on the seed scenario, the Amdahl bound through the real
  simulator is >= the realized speedup (golden-tested for the headline
  candidates).
"""

import json
import math
import os

import pytest

from repro.core import (ClusterGraph, CostModel, Scenario, Task, TaskKind,
                        WorkerSpec, simulate, simulate_reference, whatif,
                        DEVICE_STREAM)
from repro.core.optimize import default_candidates
from repro import traceio
from repro.analysis import (TaskDiff, TraceDiff, cluster_critical_path,
                            diff_cluster, diff_graph, extract_critical_path,
                            format_opportunity_table, opportunity_bound,
                            rank_opportunities, searchable_candidates)
from synthgraphs import random_dag, training_step_graph

LAYERS = 6
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}
ACTS = {f"l{i}": 10e6 for i in range(LAYERS)}
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "critical_path.json")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def seed_scenario(workers=4):
    return Scenario(training_step_graph(layers=LAYERS),
                    layer_grad_bytes=dict(GRADS),
                    activation_bytes=dict(ACTS), workers=workers)


# ============================================================ binding record
class TestBindingRecording:
    def test_disabled_by_default(self):
        assert simulate(training_step_graph()).binding is None

    def test_recording_does_not_change_the_timeline(self):
        g = training_step_graph(layers=LAYERS)
        plain = simulate(g)
        rec = simulate(g, record_binding=True)
        assert rec.makespan == plain.makespan
        assert rec.start == plain.start
        assert set(rec.binding) == set(rec.start)

    @pytest.mark.parametrize("seed", range(12))
    def test_chain_continuity_on_random_dags(self, seed):
        """Every bound task starts exactly when its binder completes;
        unbound tasks start at t=0 — the property that makes path sums
        exact."""
        g = random_dag(seed)
        for engine in (simulate, simulate_reference):
            res = engine(g, record_binding=True)
            for uid, b in res.binding.items():
                if b is None:
                    assert res.start[uid] == 0.0
                else:
                    assert res.finish[b] + g.get(b).gap == res.start[uid]

    def test_engines_agree_on_binding(self):
        g = training_step_graph(layers=LAYERS)
        assert simulate(g, record_binding=True).binding == \
            simulate_reference(g, record_binding=True).binding

    def test_cluster_simulate_passthrough(self):
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS, num_workers=4)
        cg = ClusterGraph.build(tf.graph, 4)
        assert cg.simulate().global_result.binding is None
        res = cg.simulate(record_binding=True)
        assert len(res.global_result.binding) == len(cg.graph)


# ============================================================= critical path
class TestCriticalPath:
    def test_segments_sum_to_makespan_single(self):
        g = training_step_graph(layers=LAYERS)
        cp = extract_critical_path(g)
        assert sum(cp.breakdown().values()) == \
            pytest.approx(cp.makespan, rel=1e-12)
        assert cp.makespan == pytest.approx(simulate(g).makespan, rel=1e-12)
        # contiguity: origin at 0, each segment starts at its binder's end
        assert cp.segments[0].start == 0.0
        for a, b in zip(cp.segments, cp.segments[1:]):
            assert b.start == pytest.approx(a.end, rel=1e-12)

    @pytest.mark.parametrize("mode,specs", [
        ("ring", 4),
        ("fused", 4),
        ("hierarchical", [WorkerSpec(pod=i // 2) for i in range(4)]),
    ])
    def test_segments_sum_to_makespan_cluster(self, mode, specs):
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS, num_workers=4)
        cg = ClusterGraph.build(tf.graph, specs, cost=CostModel(),
                                collective_mode=mode)
        res = cg.simulate(record_binding=True)
        cp = cluster_critical_path(cg, res)
        assert sum(cp.breakdown().values()) == \
            pytest.approx(res.makespan, rel=1e-12)
        assert set(cp.per_worker()) <= set(range(4)) | {None}

    def test_straggler_path_runs_through_the_slow_worker(self):
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS, num_workers=4)
        specs = [WorkerSpec(compute_scale=3.0 if i == 2 else 1.0)
                 for i in range(4)]
        cp = cluster_critical_path(ClusterGraph.build(tf.graph, specs))
        pw = cp.per_worker()
        assert max((w for w in pw if w is not None), key=lambda w: pw[w]) == 2

    def test_random_dags_sum_exact(self):
        for seed in range(8):
            g = random_dag(seed)
            cp = extract_critical_path(g)
            assert sum(cp.breakdown().values()) == \
                pytest.approx(cp.makespan, rel=1e-12)

    def test_extract_resimulates_without_recording(self):
        g = training_step_graph(layers=LAYERS)
        res = simulate(g)                      # no binding recorded
        cp = extract_critical_path(g, res)
        assert cp.makespan == pytest.approx(res.makespan, rel=1e-12)

    def test_golden_composition(self, golden):
        """Path composition + attribution fractions on the seed graph —
        re-freeze tests/golden/critical_path.json via the commands in the
        file when an intentional engine/model change moves them."""
        want = golden["single"]
        cp = extract_critical_path(training_step_graph(layers=LAYERS))
        assert cp.makespan == pytest.approx(want["makespan_s"],
                                            rel=want["rtol"])
        assert len(cp.segments) == want["segments"]
        for cat, frac in want["fractions"].items():
            assert cp.fractions()[cat] == pytest.approx(
                frac, rel=want["rtol"], abs=1e-12)

    def test_golden_cluster_composition(self, golden):
        want = golden["cluster_ring"]
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS,
                                        num_workers=golden["workers"])
        cg = ClusterGraph.build(tf.graph, golden["workers"],
                                cost=CostModel())
        cp = cluster_critical_path(cg)
        assert cp.makespan == pytest.approx(want["makespan_s"],
                                            rel=want["rtol"])
        for cat, frac in want["fractions"].items():
            assert cp.fractions()[cat] == pytest.approx(
                frac, rel=want["rtol"], abs=1e-12)

    def test_format_smoke(self):
        txt = extract_critical_path(training_step_graph()).format()
        assert "critical path" in txt and "compute" in txt


# ================================================================== diffing
class TestTraceDiff:
    def _exported_cluster(self, tmp_path, mode="ring"):
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS, num_workers=4)
        cost = CostModel()
        cg = ClusterGraph.build(tf.graph, 4, cost=cost,
                                collective_mode=mode)
        res = cg.simulate()
        traceio.export_cluster_traces(cg, res, str(tmp_path))
        return cg, res

    def test_self_diff_reports_zero_error(self, tmp_path):
        cg, res = self._exported_cluster(tmp_path)
        diff = diff_cluster(cg, res, str(tmp_path))
        assert not diff.unmatched_predicted and not diff.unmatched_captured
        assert diff.max_abs_error() <= 1e-9
        assert diff.makespan_rel_error == pytest.approx(0.0, abs=1e-9)
        assert all(st.wape <= 1e-9 for st in diff.per_kind().values())

    def test_self_diff_includes_pipeline_p2p_hops(self, tmp_path):
        """p2p hop legs must match leg-for-leg (exported provenance) and
        report zero error — the PR-4 caveat closed."""
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate(
            "pipeline:stages=2,microbatches=4")
        traceio.export_cluster_traces(cg, pred.cluster, str(tmp_path))
        diff = diff_cluster(cg, pred.cluster, str(tmp_path))
        hops = [d for d in diff.tasks if d.kind == TaskKind.COMM.value]
        assert hops, "pipeline placement exported no hop legs"
        assert not diff.unmatched_predicted and not diff.unmatched_captured
        assert diff.max_abs_error() <= 1e-9

    def test_perturbed_capture_tops_the_mispredicted_list(self, tmp_path):
        cg, res = self._exported_cluster(tmp_path)
        # stretch one compute task in worker 2's captured trace by 2x
        path = os.path.join(str(tmp_path), "worker2.trace.json")
        with open(path) as f:
            data = json.load(f)
        victim = next(ev for ev in data["traceEvents"]
                      if ev.get("ph") == "X" and ev["name"] == "bwd:l3")
        delta_us = victim["dur"]
        victim["dur"] *= 2.0
        with open(path, "w") as f:
            json.dump(data, f)
        diff = diff_cluster(cg, res, str(tmp_path))
        top = diff.top_mispredicted(1)[0]
        assert top.name == "bwd:l3" and top.worker == 2
        assert abs(top.dur_error) == pytest.approx(delta_us / 1e6, rel=1e-9)
        assert diff.per_kind()["compute"].max_abs_err_s == \
            pytest.approx(delta_us / 1e6, rel=1e-9)

    def test_single_graph_diff(self, tmp_path):
        g = training_step_graph(layers=LAYERS)
        res = simulate(g)
        path = str(tmp_path / "step.trace.json")
        traceio.export_graph_trace(g, res, path)
        diff = diff_graph(g, res, path)
        assert not diff.unmatched_predicted and not diff.unmatched_captured
        assert diff.max_abs_error() <= 1e-9

    def test_worker_count_mismatch_raises(self, tmp_path):
        cg, res = self._exported_cluster(tmp_path)
        os.remove(os.path.join(str(tmp_path), "worker3.trace.json"))
        with pytest.raises(ValueError, match="worker"):
            diff_cluster(cg, res, str(tmp_path))

    def test_scenario_diff_against(self, tmp_path):
        """The API surface: a trace scenario diffs its own (noop)
        prediction against the capture it was built from with ~zero
        duration error (uniform synthetic capture == analytical model)."""
        traceio.write_synthetic_trace_dir(str(tmp_path), 4, layers=LAYERS)
        scn = Scenario(trace_dir=str(tmp_path))
        diff = scn.diff_against(str(tmp_path))
        assert not diff.unmatched_predicted and not diff.unmatched_captured
        assert diff.makespan_rel_error == pytest.approx(0.0, abs=1e-6)
        assert diff.max_abs_error() <= 1e-6
        assert "predicted vs captured" in diff.format()

    def test_zero_duration_kind_renders_na(self):
        """Satellite bugfix: a kind whose captured durations are all zero
        makes WAPE (and a zero captured makespan makes the relative
        makespan error) ``inf`` — the report must render ``n/a``, not a
        garbled ``inf%``, and the top-K ranking must stay finite."""
        def td(name, kind, pred_dur, cap_dur):
            return TaskDiff(worker=0, thread="device", name=name,
                            occurrence=0, kind=kind,
                            predicted_start=0.0, predicted_dur=pred_dur,
                            captured_start=0.0, captured_dur=cap_dur)
        diff = TraceDiff(
            tasks=[td("marker", "host", 1e-3, 0.0),       # wape -> inf
                   td("mm", "compute", 2e-3, 1e-3)],
            unmatched_predicted=[], unmatched_captured=[],
            predicted_makespan=3e-3, captured_makespan=0.0)
        assert math.isinf(diff.per_kind()["host"].wape)
        assert math.isinf(diff.makespan_rel_error)
        out = diff.format()
        assert "n/a" in out
        assert "inf" not in out and "nan" not in out
        # finite rows still render as percentages
        assert "100.00%" in out
        # the ranking is by finite |error| only
        assert all(math.isfinite(d.abs_error)
                   for d in diff.top_mispredicted(10))

    def test_all_zero_capture_stays_renderable(self):
        """Degenerate but reachable: every captured duration zero."""
        diff = TraceDiff(
            tasks=[TaskDiff(worker=0, thread="device", name="x",
                            occurrence=0, kind="compute",
                            predicted_start=0.0, predicted_dur=1e-3,
                            captured_start=0.0, captured_dur=0.0)],
            unmatched_predicted=[], unmatched_captured=[],
            predicted_makespan=1e-3, captured_makespan=0.0)
        out = diff.format()
        assert "inf" not in out
        assert out.count("n/a") >= 2          # makespan line + kind row


# ======================================================= p2p hop round trip
class TestP2PRoundTrip:
    def test_pipeline_hops_survive_reimport(self, tmp_path):
        """The PR-4 export caveat, closed: a pipeline placement's exported
        per-worker traces re-import through ClusterGraph.from_traces with
        the cross-stage hops re-wired, reproducing the predicted makespan."""
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate(
            "pipeline:stages=2,microbatches=4")
        traceio.export_cluster_traces(cg, pred.cluster, str(tmp_path))
        re = ClusterGraph.from_traces(str(tmp_path),
                                      cost=scn.cost).simulate()
        assert re.makespan == pytest.approx(pred.predicted, rel=1e-9)
        # the re-imported hops regained their cross-worker coupling
        wired = [t for t in ClusterGraph.from_traces(
            str(tmp_path), cost=scn.cost).graph.tasks()
            if t.kind == TaskKind.COMM and "p2p_gid" in t.attrs]
        assert wired

    def test_exported_hops_carry_provenance(self, tmp_path):
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate("pipeline:stages=2,microbatches=2")
        paths = traceio.export_cluster_traces(cg, pred.cluster,
                                              str(tmp_path))
        with open(paths[0]) as f:
            evs = json.load(f)["traceEvents"]
        hops = [ev for ev in evs if ev.get("ph") == "X"
                and ev.get("args", {}).get("p2p")]
        assert hops, "hop legs exported without args.p2p provenance"
        for ev in hops:
            assert "p2p_gid" in ev["args"]
            src, dst = ev["args"]["p2p"]
            assert (src, dst) == (0, 1)

    def test_hybrid_dp_ring_roundtrip(self, tmp_path):
        """Hybrid PP x DP: per-stage gradient rings live on a worker
        *subset*, which (name, occurrence) matching cannot re-import —
        gid-based matching wires them back over exactly their stage's
        workers, and the collapsed export carries the true group payload
        (not the cluster-wide inflation)."""
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate(
            "pipeline:stages=2,microbatches=2,dp=2")
        paths = traceio.export_cluster_traces(cg, pred.cluster,
                                              str(tmp_path))
        with open(paths[0]) as f:
            evs = json.load(f)["traceEvents"]
        ring = next(ev for ev in evs if ev.get("ph") == "X"
                    and ev.get("args", {}).get("collective") == "all-reduce")
        assert ring["args"]["group_size"] == 2      # the stage's dp ring
        assert ring["args"]["comm_bytes"] == pytest.approx(
            sum(GRADS.values()) / 2)                # per-stage grads
        re = ClusterGraph.from_traces(str(tmp_path),
                                      cost=scn.cost).simulate()
        assert re.makespan == pytest.approx(pred.predicted, rel=1e-9)

    def test_double_roundtrip_is_stable(self, tmp_path):
        """export -> import -> export -> import keeps the makespan and
        does not grow provenance lists or collide gids."""
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate("pipeline:stages=2,microbatches=3")
        d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
        traceio.export_cluster_traces(cg, pred.cluster, d1)
        cg2 = ClusterGraph.from_traces(d1, cost=scn.cost)
        res2 = cg2.simulate()
        traceio.export_cluster_traces(cg2, res2, d2)
        cg3 = ClusterGraph.from_traces(d2, cost=scn.cost)
        assert cg3.simulate().makespan == pytest.approx(pred.predicted,
                                                        rel=1e-9)
        for t in cg3.graph.tasks():
            assert len(t.attrs.get("p2p_in", ())) <= 1

    def test_stale_gid_cannot_collide_with_fresh_wiring(self, tmp_path):
        """An unmatched imported hop (receiver stripped from the capture)
        keeps its stale gid as a plain local event; freshly wired gids are
        seeded above every imported gid, so no two legs share one."""
        scn = seed_scenario(workers=1)
        pred, tf, cg = scn.evaluate("pipeline:stages=3,microbatches=2")
        traceio.export_cluster_traces(cg, pred.cluster, str(tmp_path))
        # strip one receiver's p2p_in so its hop cannot re-match
        path = os.path.join(str(tmp_path), "worker1.trace.json")
        with open(path) as f:
            data = json.load(f)
        victim = next(ev for ev in data["traceEvents"]
                      if ev.get("ph") == "X"
                      and ev.get("args", {}).get("p2p_in"))
        stale = victim["args"].pop("p2p_in")
        with open(path, "w") as f:
            json.dump(data, f)
        cg2 = ClusterGraph.from_traces(str(tmp_path), cost=scn.cost)
        gids = [t.attrs["p2p_gid"] for t in cg2.graph.tasks()
                if "p2p_gid" in t.attrs]
        assert len(gids) == len(set(gids)), "colliding p2p gids"
        # the stale leg stayed a plain local event (old behavior), the
        # rest re-wired
        wired = [t for t in cg2.graph.tasks()
                 if t.attrs.get("p2p_gid") not in stale
                 and "p2p_gid" in t.attrs and t.attrs["p2p_gid"] > max(
                     stale)]
        assert wired

    def test_replicate_equivalence_unaffected(self, tmp_path):
        """No p2p in a DDP export: re-import must still match the build
        path exactly (regression guard for the new wiring pass)."""
        g = training_step_graph(layers=LAYERS)
        tf = whatif.what_if_distributed(g, GRADS, num_workers=3)
        cost = CostModel()
        cg = ClusterGraph.build(tf.graph, 3, cost=cost)
        res = cg.simulate()
        traceio.export_cluster_traces(cg, res, str(tmp_path))
        re = ClusterGraph.from_traces(str(tmp_path), cost=cost).simulate()
        assert re.makespan == pytest.approx(res.makespan, rel=1e-9)


# ========================================================= opportunity rank
class TestOpportunity:
    def test_bounds_dominate_realized_for_whole_registry(self):
        """ISSUE acceptance: bound >= realized speedup for every
        registered (default-constructible) optimization on the seed
        scenario."""
        scn = seed_scenario(workers=4)
        opps = rank_opportunities(scn, realize=True)
        assert opps, "no candidates ranked"
        checked = 0
        for o in opps:
            if o.realized is None or math.isinf(o.bound):
                continue
            assert o.bound >= o.realized - 1e-9, (
                f"{o.optimization.spec()}: bound {o.bound} < realized "
                f"{o.realized}")
            checked += 1
        assert checked >= 8     # the registry's default-constructible core

    def test_bounds_golden(self, golden):
        scn = seed_scenario(workers=golden["workers"])
        for name, want in golden["opportunity_bounds"].items():
            if name == "rtol":
                continue
            got = opportunity_bound(scn, next(
                c for c in default_candidates(scn) if c.name == name))
            assert got == pytest.approx(want, rel=golden[
                "opportunity_bounds"]["rtol"]), (
                f"{name}: bound {got} vs golden {want} — re-freeze "
                f"tests/golden/critical_path.json if intentional")

    def test_insertion_only_candidates_bound_at_one(self):
        scn = seed_scenario(workers=4)
        by_name = {c.name: c for c in default_candidates(scn)}
        for name in ("ddp", "noop", "straggler", "grad_accum"):
            assert opportunity_bound(scn, by_name[name]) == \
                pytest.approx(1.0)

    def test_pipeline_is_unbounded(self):
        scn = seed_scenario(workers=4)
        by_name = {c.name: c for c in default_candidates(scn)}
        assert math.isinf(opportunity_bound(scn, by_name["pipeline"]))

    def test_ranking_sorted_and_searchable_filtered(self):
        scn = seed_scenario(workers=4)
        opps = rank_opportunities(scn)
        bounds = [o.bound for o in opps]
        assert bounds == sorted(bounds, reverse=True)
        kept = searchable_candidates(opps)
        assert all(not o.skipped or o.optimization not in kept
                   for o in opps)
        assert any(o.optimization.name == "amp" for o in opps)
        txt = format_opportunity_table(opps)
        assert "amp" in txt and "bound" in txt

    def test_stack_headroom_is_member_union(self):
        from repro.core.optimize import Stack
        scn = seed_scenario(workers=1)
        by_name = {c.name: c for c in default_candidates(scn)}
        amp_bound = opportunity_bound(scn, by_name["amp"])
        stacked = opportunity_bound(
            scn, Stack(by_name["amp"], by_name["fused_optimizer"]))
        assert stacked >= amp_bound - 1e-9
        # a stack containing an unbounded member is unbounded
        assert math.isinf(opportunity_bound(
            scn, Stack(by_name["amp"], by_name["pipeline"])))

    def test_prediction_critical_path_property(self):
        scn = seed_scenario(workers=4)
        pred = scn.predict("amp")
        cp = pred.critical_path
        assert cp is pred.critical_path          # cached
        assert sum(cp.breakdown().values()) == \
            pytest.approx(pred.predicted, rel=1e-12)

    def test_stale_results_refused_everywhere(self, tmp_path):
        """Every diagnosis surface refuses a result whose graph was
        retuned afterwards (sweep reuse shares one build) — silently
        mixing two points' timelines is the failure mode."""
        from repro.core.optimize import uniform_bandwidth_specs
        from repro.analysis import extract_critical_path
        scn = Scenario(training_step_graph(layers=LAYERS),
                       layer_grad_bytes=dict(GRADS),
                       workers=[WorkerSpec() for _ in range(4)])
        pred, tf, cg = scn.evaluate("ddp")
        rec = cg.simulate(record_binding=True)
        _ = rec.global_result.binding          # materialize pre-retune
        cg.retune(uniform_bandwidth_specs(4, [0.25])[0])
        with pytest.raises(RuntimeError, match="retuned"):
            cluster_critical_path(cg, pred.cluster)     # re-derive path
        with pytest.raises(RuntimeError, match="discontiguous"):
            cluster_critical_path(cg, rec)              # recorded path
        with pytest.raises(ValueError, match="stale"):
            traceio.predicted_worker_events(cg, pred.cluster)
        fresh = cg.simulate(record_binding=True)
        assert sum(cluster_critical_path(cg, fresh).breakdown().values()) \
            == pytest.approx(fresh.makespan, rel=1e-12)

    def test_stale_sweep_prediction_refuses_critical_path(self):
        """Sweep points share one retuned-in-place build: an earlier
        point's critical_path must raise instead of silently reporting a
        later point's timeline (the last point still diagnoses fine)."""
        from repro.core.optimize import OptimizationError, \
            uniform_bandwidth_specs
        scn = seed_scenario(workers=4)
        preds = scn.sweep("ddp",
                          {"workers": uniform_bandwidth_specs(
                              4, [1.0, 0.5, 0.25])})
        last = preds[-1].critical_path
        assert sum(last.breakdown().values()) == \
            pytest.approx(preds[-1].predicted, rel=1e-12)
        with pytest.raises(OptimizationError, match="retuned"):
            _ = preds[0].critical_path

    def test_greedy_search_round1_seed_matches_unseeded(self):
        from repro.core.optimize import greedy_search
        scn = seed_scenario(workers=1)
        opps = rank_opportunities(scn, realize=True)
        kept = searchable_candidates(opps)
        round1 = {id(o.optimization): o.prediction
                  for o in opps if o.prediction is not None}
        best_a, trail_a = greedy_search(scn, max_depth=2, candidates=kept,
                                        round1=round1)
        best_b, trail_b = greedy_search(scn, max_depth=2, candidates=kept)
        assert [p.predicted for p in trail_a] == \
            [p.predicted for p in trail_b]
        assert (best_a is None) == (best_b is None)
        if best_a is not None:
            assert best_a.spec() == best_b.spec()
