"""HLO parsing + cost aggregation against real compiled modules."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (parse_hlo_module, aggregate_costs, extract_graph,
                        CostModel, simulate, split_op_name)
from repro.core.hlo import _shape_bytes, _shape_elems


def test_shape_helpers():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2], s8[3])") == 11
    assert _shape_elems("pred[2,2]") == 4


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matmul_flops_counted():
    n = 64
    c = _compile(lambda a, b: a @ b,
                 jnp.ones((n, n), jnp.float32), jnp.ones((n, n), jnp.float32))
    m = parse_hlo_module(c.as_text())
    agg = aggregate_costs(m)
    assert agg["flops"] == pytest.approx(2 * n ** 3, rel=0.01)


def test_scan_trip_count_expansion():
    """XLA's cost_analysis visits while bodies once; ours multiplies by the
    known trip count — verify against the analytic total."""
    n, steps = 32, 10

    def f(x):
        def body(c, _):
            return c @ c * 1e-3, None
        y, _ = jax.lax.scan(body, x, None, length=steps)
        return y

    c = _compile(f, jnp.eye(n, dtype=jnp.float32))
    m = parse_hlo_module(c.as_text())
    agg = aggregate_costs(m)
    want = 2 * n ** 3 * steps
    assert agg["flops"] == pytest.approx(want, rel=0.2)
    from repro.compat import cost_analysis_dict
    xla = cost_analysis_dict(c).get("flops", 0.0)
    assert xla < want * 0.5          # demonstrates the undercount we fix


def test_graph_extraction_and_simulation():
    def f(a, b):
        return jnp.tanh(a @ b).sum()

    c = _compile(f, jnp.ones((32, 32), jnp.float32),
                 jnp.ones((32, 32), jnp.float32))
    m = parse_hlo_module(c.as_text())
    g = extract_graph(m, CostModel())
    g.validate()
    r = simulate(g)
    assert r.makespan > 0
    assert any(t.flops > 0 for t in g.tasks())


def test_layer_mapping_from_named_scope():
    def f(x):
        with jax.named_scope("blk0"):
            with jax.named_scope("mlp"):
                x = x * 2.0
        return x

    c = _compile(f, jnp.ones((128, 128), jnp.float32))
    m = parse_hlo_module(c.as_text())
    g = extract_graph(m, CostModel())
    layers = {t.layer for t in g.tasks() if t.layer}
    assert any("blk0" in (l or "") for l in layers)


def test_split_op_name_phases():
    layer, phase = split_op_name("jit(f)/jvp(loss)/blk/mlp/dot_general")
    assert phase == "fwd"
    layer, phase = split_op_name(
        "jit(f)/transpose(jvp(loss))/blk/mlp/dot_general")
    assert phase == "bwd"


def test_collective_payload_parsing():
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("d",))
    # single-device psum still lowers to an all-reduce-free graph; craft text
    text = """
HloModule m, is_scheduled=true, num_partitions=4

ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  ROOT %ar = f32[128]{0} all-reduce(%p0), replica_groups=[2,2]<=[4], to_apply=%add
}
"""
    m = parse_hlo_module(text)
    agg = aggregate_costs(m)
    assert agg["collective_bytes"] == pytest.approx(512)
    assert agg["bytes_all-reduce"] == pytest.approx(512)
