"""Hypothesis property tests for pipeline-schedule invariants.

Optional-dependency module (``pytest.importorskip``) like the other
property suites.  The invariants:

* balanced-stage GPipe bubble is exactly ``(S - 1) / (M + S - 1)`` (and the
  makespan ``(M + S - 1) * t_stage``) for any S, M, t — the simulator
  reproduces the closed form, it is not baked in;
* 1F1B never loses to GPipe on the same (arbitrary, unbalanced) stage
  split when hops are free — it schedules backwards strictly earlier.
  (With costly hops the two orders overlap communication differently and
  either can win; the bounded unit tests cover that regime.)
* a one-stage plan is exactly the replicate path: placing S=1 x dp
  replicas equals ``ClusterGraph.build`` of the stage template — the p2p /
  scoped-group wiring degenerates to the classic DDP build;
* ``retune`` on a placed plan is bit-identical to a fresh placement.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from repro.core import ClusterGraph, CostModel, WorkerSpec  # noqa: E402
from repro.parallel import ParallelPlan, StageProfile  # noqa: E402

times = st.floats(min_value=1e-5, max_value=1e-1, allow_nan=False,
                  allow_infinity=False)


def plan_of(fwd, bwd, M, schedule, dp=1, act=0.0, grad=0.0):
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=f,
                               bwd_s=b, act_bytes=act, grad_bytes=grad)
                  for s, (f, b) in enumerate(zip(fwd, bwd)))
    return ParallelPlan(profs, M, schedule, dp)


@settings(max_examples=40, deadline=None)
@given(S=st.integers(1, 8), M=st.integers(1, 16), t=times)
def test_balanced_gpipe_bubble_closed_form(S, M, t):
    plan = plan_of([t] * S, [2 * t] * S, M, "gpipe")
    makespan = plan.place().simulate().makespan
    t_mb = 3 * t / M
    assert makespan == pytest.approx((M + S - 1) * t_mb, rel=1e-9)
    ideal = M * t_mb
    bubble = 1 - ideal / makespan
    assert bubble == pytest.approx((S - 1) / (M + S - 1), abs=1e-9)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_1f1b_never_loses_to_gpipe_without_hops(data):
    S = data.draw(st.integers(1, 6), label="S")
    M = data.draw(st.integers(1, 12), label="M")
    fwd = data.draw(st.lists(times, min_size=S, max_size=S), label="fwd")
    bwd = data.draw(st.lists(times, min_size=S, max_size=S), label="bwd")
    g = plan_of(fwd, bwd, M, "gpipe").place().simulate().makespan
    f = plan_of(fwd, bwd, M, "1f1b").place().simulate().makespan
    assert f <= g * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(M=st.integers(1, 8), dp=st.integers(1, 6), t=times,
       grad=st.floats(min_value=0, max_value=1e9))
def test_single_stage_plan_is_replicate_path(M, dp, t, grad):
    plan = plan_of([t], [2 * t], M, "gpipe", dp=dp, grad=grad)
    placed = plan.place().simulate()
    tmpl = plan.stage_templates(CostModel())[0]
    replicated = ClusterGraph.build(tmpl, dp).simulate()
    assert placed.makespan == pytest.approx(replicated.makespan, rel=1e-12)
    assert placed.worker_makespans() == \
        pytest.approx(replicated.worker_makespans(), rel=1e-12)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_plan_retune_matches_fresh_place(data):
    S = data.draw(st.integers(1, 4), label="S")
    M = data.draw(st.integers(1, 6), label="M")
    dp = data.draw(st.integers(1, 3), label="dp")
    sched = data.draw(st.sampled_from(["gpipe", "1f1b"]), label="sched")
    t = data.draw(times, label="t")
    plan = plan_of([t] * S, [2 * t] * S, M, sched, dp=dp,
                   act=64e6, grad=128e6)
    n = plan.num_workers
    scales = st.floats(min_value=0.1, max_value=4.0)
    specs = [WorkerSpec(compute_scale=data.draw(scales),
                        bandwidth_scale=data.draw(scales))
             for _ in range(n)]
    retuned = plan.place().retune(specs).simulate()
    fresh = plan.place(specs).simulate()
    assert retuned.makespan == fresh.makespan
    assert retuned.worker_makespans() == fresh.worker_makespans()
