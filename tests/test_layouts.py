"""Sharding layout resolution logic (baseline / v2 / dp), mesh-independent.

``mesh_axis_sizes`` is monkeypatched so the rules' pure logic is testable
without multi-device processes; the end-to-end sharded lowering is covered by
tests/test_system.py's subprocess dry-run.
"""

import pytest

import repro.sharding.rules as R
from repro.sharding import ShardingRules


@pytest.fixture
def pod_mesh(monkeypatch):
    sizes = {"data": 16, "model": 16}
    monkeypatch.setattr(R, "mesh_axis_sizes", lambda: sizes)
    return sizes


@pytest.fixture
def multi_mesh(monkeypatch):
    sizes = {"pod": 2, "data": 16, "model": 16}
    monkeypatch.setattr(R, "mesh_axis_sizes", lambda: sizes)
    return sizes


class TestBaseline:
    def test_fsdp_on_contraction(self, pod_mesh):
        r = ShardingRules(layout="baseline")
        assert r.physical("fsdp", dim_size=2048) == ("data",)
        assert r.physical("ff_mega", dim_size=5632) == ("model",)

    def test_out_fsdp_is_data(self, pod_mesh):
        r = ShardingRules(layout="baseline")
        assert r.physical("out_fsdp", dim_size=2048) == ("data",)


class TestV2:
    def test_contraction_unsharded(self, pod_mesh):
        r = ShardingRules(layout="v2")
        assert r.physical("fsdp", dim_size=2048) is None

    def test_output_dims_sharded(self, pod_mesh):
        r = ShardingRules(layout="v2")
        assert r.physical("out_fsdp", dim_size=64) == ("data",)
        # ff stays model-only (2D variant refuted in §Perf iter 1)
        assert r.physical("ff_mega", dim_size=5632) == ("model",)

    def test_indivisible_head_dim_degrades(self, pod_mesh):
        r = ShardingRules(layout="v2")
        assert r.physical("out_fsdp", dim_size=10) is None


class TestDP:
    def test_no_model_axis_use(self, pod_mesh):
        r = ShardingRules(layout="dp")
        assert r.physical("heads", dim_size=32) is None
        assert r.physical("ff", dim_size=5632) is None

    def test_batch_spans_whole_mesh(self, pod_mesh):
        r = ShardingRules(layout="dp")
        assert r.physical("batch", dim_size=256) == ("data", "model")

    def test_batch_fallback_to_data(self, pod_mesh):
        r = ShardingRules(layout="dp")
        # 32 doesn't divide 256 -> fall back to the data axis only
        assert r.physical("batch", dim_size=32) == ("data",)

    def test_storage_fully_sharded(self, pod_mesh):
        r = ShardingRules(layout="dp")
        assert r.physical("ff_mega", dim_size=5632) == ("data", "model")


class TestMultiPod:
    def test_pod_is_data_parallel(self, multi_mesh):
        r = ShardingRules(layout="v2")
        assert r.physical("batch", dim_size=256) == ("pod", "data")

    def test_spec_dedups_axes(self, multi_mesh):
        r = ShardingRules(layout="v2")
        spec = r.spec("batch", None, "heads", dim_sizes=[256, 4096, 16])
        flat = []
        for e in spec:
            if isinstance(e, tuple):
                flat.extend(e)
            elif e:
                flat.append(e)
        assert len(flat) == len(set(flat))


def test_adaptive_layout_in_cell(monkeypatch):
    """dp degrades to v2 when the global batch can't cover the mesh."""
    from repro.configs import get_config, registry
    cfg = get_config("tinyllama-1.1b")
    assert cfg.layout == "dp"

    class FakeDevices:
        size = 512

    class FakeMesh:
        devices = FakeDevices()

    # replicate build_cell's resolution logic without lowering
    shape = registry.SHAPES["train_4k"]          # global_batch 256
    layout = cfg.layout
    if layout == "dp" and shape.global_batch % FakeMesh.devices.size != 0:
        layout = "v2"
    assert layout == "v2"
