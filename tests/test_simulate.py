"""Daydream Algorithm 1 simulation semantics.

Hypothesis-based property tests live in ``test_simulate_properties.py``
(guarded by ``pytest.importorskip``); engine-equivalence randomized tests —
which need no optional dependency — live in ``test_engine_equivalence.py``.
"""

import pytest

from repro.core import (DependencyGraph, Task, TaskKind, simulate,
                        make_priority_schedule, DEVICE_STREAM, HOST_THREAD,
                        ici_channel)


def mk(name, thread=DEVICE_STREAM, dur=1.0, gap=0.0, **kw):
    return Task(name=name, kind=kw.pop("kind", TaskKind.COMPUTE),
                thread=thread, duration=dur, gap=gap, **kw)


def test_serial_lane():
    g = DependencyGraph()
    for i in range(3):
        g.add_task(mk(f"t{i}", dur=2.0))
    assert simulate(g).makespan == pytest.approx(6.0)


def test_gap_advances_thread_progress():
    """Paper §4.2.1 'Gap': untraced host time occupies the thread."""
    g = DependencyGraph()
    g.add_task(mk("a", HOST_THREAD, dur=1.0, gap=3.0))
    g.add_task(mk("b", HOST_THREAD, dur=1.0))
    r = simulate(g)
    assert r.start[g.tasks()[1].uid] == pytest.approx(4.0)
    assert r.makespan == pytest.approx(5.0)


def test_parallel_threads_overlap():
    g = DependencyGraph()
    g.add_task(mk("d", DEVICE_STREAM, dur=5.0))
    g.add_task(mk("h", HOST_THREAD, dur=3.0))
    r = simulate(g)
    assert r.makespan == pytest.approx(5.0)
    assert r.breakdown["parallel_s"] == pytest.approx(3.0)
    assert r.breakdown["device_only_s"] == pytest.approx(2.0)


def test_dependency_delays_start():
    g = DependencyGraph()
    h = g.add_task(mk("h", HOST_THREAD, dur=2.0))
    d = g.add_task(mk("d", DEVICE_STREAM, dur=1.0))
    g.add_edge(h, d)
    r = simulate(g)
    assert r.start[d.uid] == pytest.approx(2.0)


def test_priority_schedule_reorders():
    """P3-style: among ready tasks on one channel, highest priority first."""
    g = DependencyGraph()
    lo = g.add_task(mk("lo", ici_channel("send"), dur=4.0,
                       attrs={"priority": 0}), link_lane=False)
    hi = g.add_task(mk("hi", ici_channel("send"), dur=1.0,
                       attrs={"priority": 9}), link_lane=False)
    sched = make_priority_schedule(lambda t: t.attrs.get("priority", -1))
    r = simulate(g, sched)
    assert r.start[hi.uid] < r.start[lo.uid]


def test_makespan_at_least_critical_path():
    g = DependencyGraph()
    a = g.add_task(mk("a", dur=1.0))
    b = g.add_task(mk("b", HOST_THREAD, dur=2.0))
    g.add_edge(a, b)
    r = simulate(g)
    assert r.makespan >= g.critical_path() - 1e-9


