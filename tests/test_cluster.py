"""Cluster simulation subsystem (repro.core.cluster) acceptance tests.

The ISSUE's acceptance criteria live here:

* a uniform cluster's global simulation matches the single-graph DDP what-if
  prediction within 5% (they agree to float precision by construction —
  ring legs telescope to the analytical collective time);
* a 2x-slower straggler shifts the makespan as the analytical
  ring-all-reduce model predicts (everyone waits for the straggler).
"""

import pytest

from repro.core import (ClusterGraph, ClusterResult, CostModel, WorkerSpec,
                        DependencyGraph, Task, TaskKind, simulate, whatif,
                        DEVICE_STREAM, HOST_THREAD, worker_thread,
                        split_worker_thread)
from synthgraphs import training_step_graph

LAYERS = 6
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}


@pytest.fixture()
def step_graph():
    return training_step_graph(layers=LAYERS)


def test_worker_thread_roundtrip():
    assert worker_thread(3, "device") == "w3/device"
    assert split_worker_thread("w3/device") == (3, "device")
    assert split_worker_thread("device") == (None, "device")
    assert split_worker_thread("w3x/device") == (None, "w3x/device")


class TestUniformEquivalence:
    def test_matches_single_graph_ddp(self, step_graph):
        """Acceptance: uniform ClusterGraph == single-graph DDP within 5%."""
        cost = CostModel()
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=8,
                                        cost=cost)
        single = tf.simulate().makespan
        res = ClusterGraph.build(tf.graph, 8, cost=cost).simulate()
        assert res.makespan == pytest.approx(single, rel=0.05)
        # in fact the ring legs telescope exactly
        assert res.makespan == pytest.approx(single, rel=1e-9)
        # every worker sees the same local makespan
        for m in res.worker_makespans():
            assert m == pytest.approx(res.makespan, rel=1e-9)

    def test_wrapper_matches_build(self, step_graph):
        r1 = whatif.cluster_what_if_distributed(step_graph, GRADS, 4)
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=4)
        r2 = ClusterGraph.build(tf.graph, 4).simulate()
        assert r1.makespan == pytest.approx(r2.makespan, rel=1e-12)

    def test_fused_mode_matches_ring_for_uniform(self, step_graph):
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=8)
        ring = ClusterGraph.build(tf.graph, 8).simulate()
        fused = ClusterGraph.build(tf.graph, 8,
                                   collective_mode="fused").simulate()
        assert fused.makespan == pytest.approx(ring.makespan, rel=1e-9)


class TestStraggler:
    def test_straggler_shift_matches_analytical(self, step_graph):
        """Acceptance: 2x straggler shifts makespan by its extra compute.

        Synchronous ring semantics: every ring leg waits for the straggler's
        gradients, so global makespan ~= uniform makespan + (slowdown-1) *
        straggler device compute (the collective time itself is unchanged).
        """
        slowdown = 2.0
        uniform = whatif.cluster_what_if_distributed(step_graph, GRADS, 8)
        strag = whatif.cluster_what_if_straggler(step_graph, GRADS, 8,
                                                 straggler=0,
                                                 slowdown=slowdown)
        device_compute = sum(t.duration
                             for t in step_graph.lane_tasks(DEVICE_STREAM))
        expected = uniform.makespan + (slowdown - 1.0) * device_compute
        assert strag.makespan == pytest.approx(expected, rel=0.02)
        assert strag.straggler() == 0

    def test_straggler_slows_everyone(self, step_graph):
        """dPRO's point: the delay propagates to every worker through the
        ring edges, not just the slow replica."""
        res = whatif.cluster_what_if_straggler(step_graph, GRADS, 8,
                                               straggler=3, slowdown=2.0)
        uniform = whatif.cluster_what_if_distributed(step_graph, GRADS, 8)
        for i, m in enumerate(res.worker_makespans()):
            assert m > uniform.worker_makespans()[i] * 1.2
        assert res.straggler() == 3

    def test_per_worker_breakdown_shows_idle_skew(self, step_graph):
        """Fast workers idle while waiting for the straggler's gradients."""
        res = whatif.cluster_what_if_straggler(step_graph, GRADS, 8,
                                               straggler=0, slowdown=2.0)
        fast = res.per_worker[4]
        slow = res.per_worker[0]
        assert slow.thread_busy["device"] > fast.thread_busy["device"] * 1.8
        assert fast.breakdown["idle_s"] > slow.breakdown["idle_s"]


class TestHeterogeneity:
    def test_bandwidth_skew_slows_ring(self, step_graph):
        uniform = whatif.cluster_what_if_distributed(step_graph, GRADS, 4)
        skew = whatif.cluster_what_if_bandwidth(
            step_graph, GRADS, 4, scales=[1.0, 1.0, 0.25, 1.0])
        assert skew.makespan > uniform.makespan
        with pytest.raises(ValueError):
            whatif.cluster_what_if_bandwidth(step_graph, GRADS, 4,
                                             scales=[1.0])

    def test_dead_link_models_not_crashes(self, step_graph):
        """bandwidth_scale=0 (dead NIC) must model an astronomically slow
        link, not raise ZeroDivisionError."""
        res = whatif.cluster_what_if_bandwidth(
            step_graph, GRADS, 4, scales=[0.0, 1.0, 1.0, 1.0])
        uniform = whatif.cluster_what_if_distributed(step_graph, GRADS, 4)
        assert res.makespan > uniform.makespan * 100

    def test_mixed_generations(self, step_graph):
        """Half the fleet 1.5x slower: makespan tracks the slow generation."""
        specs = [WorkerSpec(compute_scale=1.5 if i % 2 else 1.0)
                 for i in range(4)]
        res = whatif.cluster_what_if_distributed(step_graph, GRADS, specs)
        uniform = whatif.cluster_what_if_distributed(step_graph, GRADS, 4)
        slow_uniform = whatif.cluster_what_if_distributed(
            step_graph, GRADS, [WorkerSpec(compute_scale=1.5)] * 4)
        assert uniform.makespan < res.makespan <= slow_uniform.makespan + 1e-12

    def test_cross_pod_ring_slower_than_single_pod(self, step_graph):
        single = whatif.cluster_what_if_distributed(step_graph, GRADS, 8)
        pods = [WorkerSpec(pod=i // 4) for i in range(8)]
        multi = whatif.cluster_what_if_distributed(step_graph, GRADS, pods)
        assert multi.makespan > single.makespan    # two DCN hops in the ring

    def test_hierarchical_beats_flat_ring_across_pods(self, step_graph):
        """BlueConnect's reason to exist: only the shard crosses the DCN."""
        pods = [WorkerSpec(pod=i // 4) for i in range(8)]
        flat = whatif.cluster_what_if_distributed(step_graph, GRADS, pods)
        hier = whatif.cluster_what_if_distributed(step_graph, GRADS, pods,
                                                  collective_mode="hierarchical")
        assert hier.makespan < flat.makespan

    def test_hierarchical_single_pod_close_to_ring(self, step_graph):
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=8)
        ring = ClusterGraph.build(tf.graph, 8).simulate()
        hier = ClusterGraph.build(tf.graph, 8,
                                  collective_mode="hierarchical").simulate()
        # same total bytes over the same links; only hop/barrier bookkeeping
        # differs between one 2(n-1)-step ring and rs+ag stages
        assert hier.makespan == pytest.approx(ring.makespan, rel=0.05)


class TestRoutedWhatIfs:
    def test_zero_routes_and_speeds_update(self, step_graph):
        ddp = whatif.cluster_what_if_distributed(step_graph, GRADS, 8)
        zero = whatif.cluster_what_if_zero(step_graph, GRADS, 8)
        assert isinstance(zero, ClusterResult)
        # sharded update: each worker's update lane busy time drops ~8x
        upd_ddp = ddp.per_worker[0].thread_busy["device"]
        upd_zero = zero.per_worker[0].thread_busy["device"]
        assert upd_zero < upd_ddp

    def test_hierarchical_mode_is_op_aware(self, step_graph):
        """BlueConnect decomposition applies to all-reduces only; ZeRO's
        bare reduce-scatter / all-gather keep their single-stage ring legs
        (a past bug costed them as full three-stage all-reduces)."""
        ring = whatif.cluster_what_if_zero(step_graph, GRADS, 8)
        hier = whatif.cluster_what_if_zero(step_graph, GRADS, 8,
                                           collective_mode="hierarchical")
        assert hier.makespan == pytest.approx(ring.makespan, rel=1e-9)

    def test_p3_cluster_runs_with_priority(self, step_graph):
        res = whatif.cluster_what_if_p3(step_graph, GRADS, 4, bandwidth=5e9)
        assert isinstance(res, ClusterResult)
        assert res.makespan > 0
        assert len(res.per_worker) == 4
        # pulls run on every worker's recv channel
        for i in range(4):
            assert res.per_worker[i].thread_busy.get("ici:recv", 0.0) > 0

    def test_p3_pulls_gate_on_global_pushes(self, step_graph):
        """A straggler's late pushes delay every worker's pulls (PS
        aggregation semantics), not just its own."""
        specs = [WorkerSpec(compute_scale=2.0 if i == 0 else 1.0)
                 for i in range(4)]
        tf = whatif.what_if_p3(step_graph, GRADS, 4, bandwidth=5e9)
        uni = ClusterGraph.build(tf.graph, 4, schedule=tf.schedule).simulate()
        strag = ClusterGraph.build(tf.graph, specs,
                                   schedule=tf.schedule).simulate()
        # worker 3 is full-speed in both runs, yet finishes later with the
        # straggler in the fleet
        assert strag.per_worker[3].makespan > uni.per_worker[3].makespan

    def test_transform_cluster_convenience(self, step_graph):
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=4)
        res = tf.cluster(4).simulate()
        assert res.makespan == pytest.approx(tf.simulate().makespan, rel=1e-9)


class TestBuildInvariants:
    def test_graph_validates_and_scales(self, step_graph):
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=4)
        cg = ClusterGraph.build(tf.graph, 4)
        cg.graph.validate()
        base_n = len(tf.graph)
        # replicas minus per-worker collective tasks, plus ring legs
        n_coll = sum(1 for t in tf.graph.tasks()
                     if t.kind == TaskKind.COLLECTIVE)
        expected = 4 * (base_n - n_coll) + 4 * n_coll * 2 * 3
        assert len(cg.graph) == expected

    def test_single_worker_cluster_is_identity(self, step_graph):
        tf = whatif.what_if_distributed(step_graph, GRADS, num_workers=1)
        res = ClusterGraph.build(tf.graph, 1).simulate()
        assert res.makespan == pytest.approx(tf.simulate().makespan, rel=1e-12)

    def test_rejects_bad_inputs(self, step_graph):
        from repro.core import GraphError
        with pytest.raises(GraphError):
            ClusterGraph.build(step_graph, 0)
        with pytest.raises(GraphError):
            ClusterGraph.build(step_graph, 2, collective_mode="quantum")


def test_format_cluster_report():
    from repro.launch.perf_report import format_cluster_report
    g = training_step_graph()
    res = whatif.cluster_what_if_straggler(g, GRADS, 4, straggler=1,
                                           slowdown=2.0)
    out = format_cluster_report(res, title="test")
    assert "test: 4 workers" in out
    rows = [l for l in out.splitlines()
            if l.startswith("w") and not l.startswith("worker")]
    assert len(rows) == 4
    assert any("2.0" in r for r in rows)   # straggler's vs-best column
