"""Symmetry folding (repro.core.fold) + incremental cone re-simulation.

The exactness contract under test: a folded cluster graph — one
representative worker per equivalence class, collectives closed
algebraically over class sizes — produces the *same* timeline as the fully
materialized :class:`ClusterGraph` (makespan bit-exact, per-class results
equal to every member's per-worker rollup).  Folding must refuse (return
``None``) whenever the contract cannot hold, and
:func:`simulate_incremental` must reproduce a full replay exactly or bail
to ``None`` — never silently drift.  Randomized-seed deterministic tests
live here; hypothesis properties in ``test_fold_properties.py``.
"""

import random

import pytest

from repro.core import (ClusterGraph, GraphError, WorkerSpec, fold_cluster,
                        fold_plan, partition_workers, simulate,
                        simulate_incremental)
from repro.core.fold import FoldedClusterGraph, WorkerClass
from repro.core.optimize import Scenario, straggler_specs
from repro.parallel.plan import ParallelPlan, StageProfile
from synthgraphs import training_step_graph

LAYERS = 5
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}


@pytest.fixture()
def graph():
    return training_step_graph(layers=LAYERS)


def balanced_plan(S, M, dp, *, act=4e6, grad=8e6):
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=2e-3,
                               bwd_s=4e-3, update_s=1e-3, act_bytes=act,
                               grad_bytes=grad) for s in range(S))
    return ParallelPlan(profs, M, "gpipe", dp)


def assert_fold_equiv(fg, cg, *, tol=0.0):
    """Folded == materialized: makespan and every member's rollup."""
    rf, rm = fg.simulate(), cg.simulate()
    if tol:
        assert rf.makespan == pytest.approx(rm.makespan, abs=tol)
    else:
        assert rf.makespan == rm.makespan
    pw_f, pw_m = rf.per_worker, rm.per_worker
    assert set(pw_f) == set(pw_m)
    for w in pw_m:
        assert pw_f[w].makespan == pytest.approx(pw_m[w].makespan,
                                                 abs=1e-9)
        for k, v in pw_m[w].breakdown.items():
            assert pw_f[w].breakdown.get(k, 0.0) == pytest.approx(
                v, abs=1e-9)
    return rf, rm


class TestPartition:
    def test_ring_uniform_single_class(self):
        classes = partition_workers([WorkerSpec()] * 6, "ring")
        assert [c.members for c in classes] == [(0, 1, 2, 3, 4, 5)]
        assert classes[0].representative == 0 and classes[0].count == 6

    def test_ring_nonuniform_refuses(self):
        specs = [WorkerSpec()] * 5 + [WorkerSpec(compute_scale=2.0)]
        assert partition_workers(specs, "ring") is None

    def test_fused_groups_by_spec(self):
        specs = [WorkerSpec(), WorkerSpec(compute_scale=2.0),
                 WorkerSpec(), WorkerSpec(compute_scale=2.0)]
        classes = partition_workers(specs, "fused")
        assert sorted(c.members for c in classes) == [(0, 2), (1, 3)]

    def test_hierarchical_leader_and_members_per_pod(self):
        specs = [WorkerSpec(pod=i // 3) for i in range(6)]
        classes = partition_workers(specs, "hierarchical")
        got = sorted((c.role, c.members) for c in classes)
        assert got == [("leader", (0,)), ("leader", (3,)),
                       ("member", (1, 2)), ("member", (4, 5))]

    def test_hierarchical_mixed_pod_refuses(self):
        specs = [WorkerSpec(pod=0), WorkerSpec(pod=0,
                                               bandwidth_scale=0.5),
                 WorkerSpec(pod=1), WorkerSpec(pod=1)]
        assert partition_workers(specs, "hierarchical") is None

    def test_unknown_mode_raises(self):
        with pytest.raises(GraphError):
            partition_workers([WorkerSpec()], "warp")


class TestFoldCluster:
    @pytest.mark.parametrize("mode", ["ring", "fused", "hierarchical"])
    def test_uniform_bit_exact(self, graph, mode):
        specs = [WorkerSpec() for _ in range(8)]
        fg = fold_cluster(graph, specs, collective_mode=mode)
        assert isinstance(fg, FoldedClusterGraph)
        assert fg.num_classes < len(specs)
        cg = ClusterGraph.build(graph, specs, collective_mode=mode)
        assert_fold_equiv(fg, cg)

    def test_pod_uniform_hierarchical_bit_exact(self, graph):
        specs = [WorkerSpec(pod=i // 4,
                            bandwidth_scale=1.0 + 0.25 * (i // 4))
                 for i in range(12)]
        fg = fold_cluster(graph, specs, collective_mode="hierarchical")
        cg = ClusterGraph.build(graph, specs,
                                collective_mode="hierarchical")
        assert fg.num_classes == 6      # (leader, member) x 3 pods
        assert_fold_equiv(fg, cg)

    def test_straggler_folds_rest_into_one_class(self, graph):
        specs = straggler_specs(16, [2.5])[0]
        fg = fold_cluster(graph, specs, collective_mode="fused")
        cg = ClusterGraph.build(graph, specs, collective_mode="fused")
        assert fg.num_classes == 2
        assert_fold_equiv(fg, cg)

    def test_no_gain_returns_none(self, graph):
        """All-distinct specs: classes == workers, fold refuses."""
        specs = [WorkerSpec(compute_scale=1.0 + 0.1 * i) for i in range(4)]
        assert fold_cluster(graph, specs,
                            collective_mode="fused") is None
        assert fold_cluster(graph, specs, collective_mode="ring") is None

    def test_per_class_rollup(self, graph):
        specs = [WorkerSpec() for _ in range(6)]
        fg = fold_cluster(graph, specs, collective_mode="ring")
        res = fg.simulate()
        (cls,) = fg.classes
        (pc,) = res.per_class.values()
        for w in cls.members:
            assert res.per_worker[w].makespan == pc.makespan


class TestFoldPlan:
    def test_hybrid_pp_dp_bit_exact(self):
        p = balanced_plan(4, 8, dp=4)
        fg = p.fold_place()
        cg = p.place()
        assert fg is not None and fg.num_classes == 4
        assert_fold_equiv(fg, cg)

    def test_stage_heterogeneous_but_uniform_within(self):
        p = balanced_plan(3, 6, dp=8)
        specs = [WorkerSpec(compute_scale=1.0 + 0.1 * (w // 8))
                 for w in range(p.num_workers)]
        fg = p.fold_place(specs)
        cg = p.place(specs)
        assert fg is not None
        assert_fold_equiv(fg, cg)

    def test_refusals(self):
        # dp=1: no replica symmetry to fold
        assert balanced_plan(4, 8, dp=1).fold_place() is None
        # hierarchical stage rings are not foldable
        assert balanced_plan(2, 4, dp=4).fold_place(
            collective_mode="hierarchical") is None
        # a straggler inside one stage breaks within-stage uniformity
        p = balanced_plan(2, 4, dp=4)
        specs = [WorkerSpec() for _ in range(p.num_workers)]
        specs[1] = WorkerSpec(compute_scale=3.0)
        assert p.fold_place(specs) is None


class TestFoldRetune:
    def test_retune_matches_rebuild(self, graph):
        specs = [WorkerSpec() for _ in range(8)]
        fg = fold_cluster(graph, specs, collective_mode="ring")
        new = [WorkerSpec(bandwidth_scale=1.7)] * 8
        assert fg.can_retune(new)
        fg.retune(new)
        cg = ClusterGraph.build(graph, new, collective_mode="ring")
        assert_fold_equiv(fg, cg)

    def test_partition_change_rejected(self, graph):
        specs = [WorkerSpec() for _ in range(8)]
        fg = fold_cluster(graph, specs, collective_mode="ring")
        broken = [WorkerSpec()] * 7 + [WorkerSpec(compute_scale=2.0)]
        assert not fg.can_retune(broken)
        with pytest.raises(GraphError):
            fg.retune(broken)

    def test_fused_straggler_retunes_within_partition(self, graph):
        specs = [WorkerSpec()] * 7 + [WorkerSpec(compute_scale=2.0)]
        fg = fold_cluster(graph, specs, collective_mode="fused")
        new = [WorkerSpec()] * 7 + [WorkerSpec(compute_scale=3.5)]
        assert fg.can_retune(new)
        fg.retune(new)
        cg = ClusterGraph.build(graph, new, collective_mode="fused")
        assert_fold_equiv(fg, cg)


class TestIncremental:
    def _assert_same(self, inc, full):
        assert inc.makespan == full.makespan
        assert inc.start == full.start
        assert inc.finish == full.finish
        assert inc.thread_busy == full.thread_busy

    def test_empty_dirty_returns_prev(self, graph):
        prev = simulate(graph)
        assert simulate_incremental(graph, prev, set()) is prev

    def test_stale_prev_bails(self, graph):
        cg = ClusterGraph.build(graph, 4)
        prev = cg.simulate()
        cg2 = ClusterGraph.build(graph, 3)
        dirty = {next(iter(cg2.graph._tasks))}
        assert simulate_incremental(cg2.graph, prev.global_result,
                                    dirty) is None

    @pytest.mark.parametrize("mode", ["ring", "fused", "hierarchical"])
    def test_random_retunes_match_full(self, graph, mode):
        rng = random.Random(hash(mode) & 0xFFFF)
        cg = ClusterGraph.build(graph, [WorkerSpec() for _ in range(5)],
                                collective_mode=mode)
        prev = cg.simulate()
        hits = 0
        for trial in range(12):
            # bandwidth-only perturbations keep the dirty set to the
            # collective tasks (the realistic sweep axis); a rare
            # compute perturbation exercises the large-cone bail path
            specs = [WorkerSpec(bandwidth_scale=1.0 + rng.random(),
                                compute_scale=1.5 if trial == 5 else 1.0)
                     for _ in range(5)]
            if rng.random() < 0.4:      # uniform point: small dirty cone
                specs = [specs[0]] * 5
            cg.retune(specs)
            inc = cg.simulate_incremental(prev)
            full = cg.simulate()
            if inc is not None:
                hits += 1
                self._assert_same(inc.global_result, full.global_result)
            prev = full
        assert hits > 0     # the route must actually engage

    def test_folded_incremental(self, graph):
        fg = fold_cluster(graph, [WorkerSpec() for _ in range(64)],
                          collective_mode="ring")
        prev = fg.simulate()
        fg.retune([WorkerSpec(bandwidth_scale=1.3)] * 64)
        inc = fg.simulate_incremental(prev)
        full = fg.simulate()
        assert inc is not None
        self._assert_same(inc.global_result, full.global_result)
        assert set(inc.per_worker) == set(range(64))


class TestScenarioIntegration:
    def test_forced_fold_matches_materialized_predict(self, graph):
        spec_list = [WorkerSpec() for _ in range(8)]
        folded = Scenario(graph, layer_grad_bytes=GRADS,
                          workers=spec_list, fold=True).predict("ddp")
        mat = Scenario(graph, layer_grad_bytes=GRADS,
                       workers=spec_list, fold=False).predict("ddp")
        from repro.core.fold import FoldedClusterResult
        assert folded.predicted == mat.predicted
        assert isinstance(folded.cluster, FoldedClusterResult)
        assert not isinstance(mat.cluster, FoldedClusterResult)

    def test_sweep_incremental_matches_rebuilds(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(6)])
        grid = {"workers": [[WorkerSpec(bandwidth_scale=b)] * 6
                            for b in (1.0, 1.3, 0.8, 2.0)]}
        reused = s.sweep("ddp", grid, reuse=True)
        rebuilt = s.sweep("ddp", grid, reuse=False)
        for a, b in zip(reused, rebuilt):
            assert a.predicted == pytest.approx(b.predicted, rel=1e-12)

    def test_forced_fold_sweep_matches_materialized(self, graph):
        grid = {"workers": [[WorkerSpec(bandwidth_scale=b)] * 8
                            for b in (1.0, 1.5, 0.75)]}
        f = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(8)],
                     fold=True).sweep("ddp", grid)
        m = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(8)],
                     fold=False).sweep("ddp", grid)
        for a, b in zip(f, m):
            assert a.predicted == b.predicted

    def test_auto_threshold(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS, workers=8)
        assert not s._fold_enabled()            # < 64 workers: stay exact-simple
        assert s._fold_enabled(64)
        assert not Scenario(graph, layer_grad_bytes=GRADS, workers=8,
                            fold=False)._fold_enabled(4096)


class TestRebuildReason(object):
    def test_sweep_rebuild_reasons(self, graph, tmp_path):
        from repro.obs import spans as spans_mod
        path = str(tmp_path / "spans.jsonl")
        spans_mod.configure(path)
        try:
            s = Scenario(graph, layer_grad_bytes=GRADS,
                         workers=[WorkerSpec() for _ in range(4)])
            s.sweep("ddp", [{"workers": [WorkerSpec()] * 4},
                            {"workers": [WorkerSpec()] * 6},
                            {"workers": [WorkerSpec(
                                bandwidth_scale=1.4)] * 6}])
        finally:
            spans_mod.configure(None)
        import json
        recs = [json.loads(l) for l in open(path)]
        pts = [r["attrs"] for r in recs
               if r["name"] == "scenario.sweep_point"]
        assert pts[0]["route"] == "rebuild"
        assert pts[0]["reason"] == "first_point"
        assert pts[1]["route"] == "rebuild"
        assert pts[1]["reason"] == "worker_count_changed"
        assert pts[2]["route"] == "cluster_retune"
        assert pts[2]["sim"] in ("incremental", "full")
