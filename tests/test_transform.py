"""Graph-transformation primitives (paper §4.4)."""

import pytest

from repro.core import (DependencyGraph, GraphTransform, Task, TaskKind,
                        simulate, by_name, by_kind, by_layer, all_of,
                        on_device, predicted_speedup, DEVICE_STREAM,
                        HOST_THREAD)


def mk(name, thread=DEVICE_STREAM, dur=1.0, **kw):
    return Task(name=name, kind=kw.pop("kind", TaskKind.COMPUTE),
                thread=thread, duration=dur, **kw)


@pytest.fixture
def g():
    g = DependencyGraph()
    g.add_task(mk("dot.1", dur=3.0, layer="l0/attn"))
    g.add_task(mk("elementwise.1", dur=1.0, layer="l0/norm"))
    g.add_task(mk("dot.2", dur=3.0, layer="l1/attn"))
    g.add_task(mk("host", HOST_THREAD, dur=0.5))
    return g


def test_copy_semantics(g):
    tf = GraphTransform(g)
    tf.scale(by_name("dot"), 0.5)
    assert sum(t.duration for t in g.tasks()) == pytest.approx(7.5)
    assert sum(t.duration for t in tf.graph.tasks()) == pytest.approx(4.5)


def test_shrink_is_paper_semantics(g):
    tf = GraphTransform(g)
    n = tf.shrink(by_name("dot"), 3.0)            # "3x faster"
    assert n == 2
    assert all(t.duration == pytest.approx(1.0)
               for t in tf.select(by_name("dot")))


def test_select_by_layer(g):
    tf = GraphTransform(g)
    assert len(tf.select(by_layer(r"l0/"))) == 2
    assert len(tf.select(all_of(on_device, by_layer("attn")))) == 2


def test_insert_remove_keep_simulatable(g):
    tf = GraphTransform(g)
    anchor = tf.select(by_name("dot.1"))[0]
    tf.insert_after(anchor, mk("injected", dur=2.0))
    r1 = tf.simulate()
    tf.remove(by_name("injected"))
    r2 = tf.simulate()
    assert r1.makespan == pytest.approx(r2.makespan + 2.0)
    tf.graph.validate()


def test_insert_before_head(g):
    tf = GraphTransform(g)
    head = tf.graph.lane_tasks(DEVICE_STREAM)[0]
    tf.insert_before(head, mk("pre", dur=1.0))
    lane = tf.graph.lane_tasks(DEVICE_STREAM)
    assert lane[0].name == "pre"
    tf.graph.validate()


def test_predicted_speedup_direction(g):
    s = predicted_speedup(g, lambda tf: tf.shrink(by_name("dot"), 2.0))
    assert s > 1.0


def test_set_duration(g):
    tf = GraphTransform(g)
    tf.set_duration(by_name("host"), 0.0)
    assert tf.select(by_name("host"))[0].duration == 0.0
