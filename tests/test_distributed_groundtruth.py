"""Paper §6.5 methodology with *measured* multi-device ground truth.

Marked ``@pytest.mark.slow``: each test spawns a fresh-XLA_FLAGS subprocess
that compiles multi-device programs (minutes on a cold cache).  The default
tier-1 run deselects them (``addopts = -m "not slow"`` in pyproject.toml);
``pytest -m slow`` still exercises them.

The paper's flagship claim: distributed training runtime predicted from a
single-worker profile.  This container has one physical CPU but XLA can host
N virtual devices; a subprocess (fresh XLA_FLAGS) measures a real 8-way
data-parallel step, and Daydream predicts it from the 1-device trace using
the calibrated local collective bandwidth — predict → implement → measure,
like the paper's Fig. 8.

Also: elastic re-shard ground truth — a checkpoint written under a (4,)
mesh restores bit-exactly onto a (2,) mesh.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_DDP_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.core import trace_measured, whatif, measure_wallclock
    from repro.core.calibrate import measure_collective_bandwidth

    d, ff, layers = 256, 1024, 4
    per_dev_batch, sq = 4, 32
    W = {{f"l{{i}}": {{
        "w1": jax.random.normal(jax.random.PRNGKey(i), (d, ff)) * 0.05,
        "w2": jax.random.normal(jax.random.PRNGKey(100+i), (ff, d)) * 0.05,
    }} for i in range(layers)}}

    def loss(W, x):
        for i in range(layers):
            with jax.named_scope(f"l{{i}}"):
                x = x + jnp.tanh(x @ W[f"l{{i}}"]["w1"]) @ W[f"l{{i}}"]["w2"]
        return jnp.mean(x * x)

    def step(W, x):
        g = jax.grad(loss)(W, x)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, W, g)

    x1 = jax.random.normal(jax.random.PRNGKey(7), (per_dev_batch, sq, d))

    # --- single-device profile -> Daydream prediction for 8 workers
    bundle = trace_measured(step, W, x1, iters=20)
    base = bundle.simulate().makespan
    grad_bytes = {{f"l{{i}}": 2 * d * ff * 4.0 for i in range(layers)}}
    bw = measure_collective_bandwidth(8)
    pred = whatif.what_if_distributed(
        bundle.graph, grad_bytes, num_workers=8, bandwidth=bw,
        cost=bundle.cost).simulate().makespan
    pred_slowdown = pred / base

    # --- ground truth: real 8-way DP on host devices
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((8,), ("data",))
    xg = jnp.concatenate([x1] * 8, axis=0)
    xg = jax.device_put(xg, NamedSharding(mesh, P("data", None, None)))
    Wr = jax.device_put(W, NamedSharding(mesh, P()))
    t1 = measure_wallclock(step, W, x1, iters=20)
    with set_mesh(mesh):
        t8 = measure_wallclock(step, Wr, xg, iters=20)
    true_slowdown = t8 / t1

    print(json.dumps({{"pred": pred_slowdown, "true": true_slowdown,
                       "base_ms": base * 1e3, "t1_ms": t1 * 1e3,
                       "t8_ms": t8 * 1e3}}))
""")


@pytest.mark.slow
def test_ddp_prediction_vs_measured_8way():
    code = _DDP_SNIPPET.format(src=_SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    r = json.loads(proc.stdout.strip().splitlines()[-1])
    # Both should see a slowdown >= ~1 (comm added); agreement within a wide
    # band (virtual devices share one core: compute scales 8x worse than a
    # real fleet, so we compare the comm-overhead *direction and order*).
    assert r["pred"] >= 1.0
    assert r["true"] >= 0.9
    assert r["pred"] < 30 and r["true"] < 30, r


_ELASTIC_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.ckpt import save_checkpoint, restore_checkpoint

    tmp = {tmp!r}
    tree = {{"w": jnp.arange(64.0).reshape(8, 8),
             "b": jnp.ones((16,), jnp.bfloat16)}}

    from repro.compat import make_mesh
    mesh4 = make_mesh((4,), ("data",), devices=jax.devices()[:4])
    sharded = jax.device_put(tree, NamedSharding(mesh4, P("data")))
    save_checkpoint(tmp, 11, sharded)

    mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])
    sh2 = {{"w": NamedSharding(mesh2, P("data", None)),
            "b": NamedSharding(mesh2, P("data"))}}
    out, step = restore_checkpoint(tmp, tree, shardings=sh2)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding.num_devices == 2
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_reshard_across_mesh_sizes(tmp_path):
    code = _ELASTIC_SNIPPET.format(src=_SRC, tmp=str(tmp_path))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout
