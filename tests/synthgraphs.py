"""Deterministic synthetic dependency graphs shared by simulator tests.

Not a test module — imported by test_engine_equivalence / test_cluster /
test_golden_speedups so they all exercise the same fixed topologies without
tracing any jax program (fast, machine-independent durations).
"""

import random

from repro.core import (DependencyGraph, Task, TaskKind, DEVICE_STREAM,
                        HOST_THREAD)


def training_step_graph(layers=6, fwd=2e-3, bwd=4e-3, upd=1e-3,
                        dispatch=20e-6):
    """A canonical single-worker step: host dispatch -> fwd chain -> bwd
    chain -> per-layer update -> host sync, with layer/phase tags so the
    DDP/P3/ZeRO what-ifs can bucket gradients."""
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, dispatch))
    first = True
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, fwd,
                            layer=f"l{i}", phase="fwd", flops=2e9,
                            bytes_accessed=1e6))
        if first:
            g.add_edge(h, t)
            first = False
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, bwd,
                        layer=f"l{i}", phase="bwd", flops=4e9,
                        bytes_accessed=2e6))
    for i in range(layers):
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, upd,
                        layer=f"l{i}", phase="update", flops=1e8,
                        bytes_accessed=3e6))
    s = g.add_task(Task("host:sync", TaskKind.SYNC, HOST_THREAD, 1e-6))
    g.add_edge(g.lane_tasks(DEVICE_STREAM)[-1], s)
    return g


def random_dag(seed, n_tasks=40, threads=("device", "host", "ici:x", "ici:y"),
               edge_prob=0.08, lane_prob=0.8):
    """Seeded random DAG mixing lane-ordered and free-floating tasks."""
    rng = random.Random(seed)
    g = DependencyGraph()
    tasks = []
    for i in range(n_tasks):
        th = rng.choice(threads)
        t = Task(f"t{i}", TaskKind.COMPUTE, th,
                 duration=rng.uniform(0.01, 5.0), gap=rng.uniform(0.0, 1.0))
        t.attrs["priority"] = rng.randint(0, 9)
        g.add_task(t, link_lane=rng.random() < lane_prob)
        for p in tasks:
            if rng.random() < edge_prob:
                g.add_edge(p, t)
        tasks.append(t)
    return g
