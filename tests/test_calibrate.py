"""Auto-calibration subsystem (repro.analysis.calibrate) acceptance tests.

The ISSUE's golden criterion lives here: perturb a CostModel, synthesize a
capture from the *unperturbed* one, and assert the simulate → diff → refit
loop recovers the constants, drives per-kind WAPE under 5% (dPRO's
headline bound), and keeps the loss history monotonically non-increasing —
plus the real ``jax.profiler`` capture fixture the calibrate CLI must
digest.
"""

import dataclasses
import io
import math
import os
import sys

import pytest

from repro.core.costmodel import CollectiveModel, CostModel, FittableConstant
from repro.core.optimize import Scenario
from repro.traceio import load_trace_dir, write_synthetic_trace_dir

LAYERS = 4
N_WORKERS = 4


@pytest.fixture(scope="module")
def capture_dir(tmp_path_factory):
    """A synthetic 4-worker capture generated from the TRUE (default)
    CostModel — the ground truth calibration must recover."""
    d = tmp_path_factory.mktemp("capture")
    write_synthetic_trace_dir(str(d), N_WORKERS, layers=LAYERS,
                              cost=CostModel())
    return str(d)


def perturbed_cost() -> CostModel:
    """Compute durations 30% hot, ICI bandwidth modeled at half speed."""
    return CostModel(kind_scales={"compute": 1.3}, ici_factor=0.5)


# ====================================================== parameter introspection
class TestFittableConstants:
    def test_typed_list_with_bounds(self):
        consts = CostModel().fittable_constants()
        by_name = {c.name: c for c in consts}
        assert "kind_scale:compute" in by_name
        assert "ici_factor" in by_name and "dcn_factor" in by_name
        assert "hop_latency" in by_name
        for c in consts:
            assert isinstance(c, FittableConstant)
            assert c.lo < c.hi
            assert c.lo <= c.value <= c.hi
        assert by_name["kind_scale:compute"].kind == "compute"
        assert by_name["hop_latency"].value == CollectiveModel.HOP_LATENCY

    def test_with_constants_round_trips(self):
        cost = CostModel().with_constants(
            {"kind_scale:compute": 1.5, "ici_factor": 0.5,
             "hop_latency": 5e-6})
        assert cost.kind_scale("compute") == 1.5
        assert cost.kind_scale("host") == 1.0        # untouched default
        assert cost.ici_factor == 0.5
        assert cost.collectives.hop_latency == 5e-6
        with pytest.raises(ValueError, match="unknown fittable"):
            CostModel().with_constants({"warp_factor": 9.0})

    def test_factors_thread_into_link_bandwidth(self):
        base = CostModel()
        half = CostModel(ici_factor=0.5, dcn_factor=2.0)
        assert half.link_bandwidth("ici") == \
            pytest.approx(0.5 * base.link_bandwidth("ici"))
        assert half.link_bandwidth("dcn") == \
            pytest.approx(2.0 * base.link_bandwidth("dcn"))
        # analytical collective formulas read the same factored bandwidth
        t_base = base.collectives.axis_time("all-reduce", 1e8, 8)
        t_half = half.collectives.axis_time("all-reduce", 1e8, 8)
        assert t_half > t_base

    def test_defaults_change_nothing(self):
        """kind_scales/factors default to the identity: a default-cost
        trace scenario predicts exactly what it did before this PR."""
        base = CostModel()
        assert base.kind_scale("compute") == 1.0
        assert base.link_bandwidth("ici") == \
            base.hw.ici_bandwidth * base.hw.ici_links_per_axis
        assert base.link_bandwidth("dcn") == base.hw.dcn_bandwidth

    def test_kind_scales_reach_trace_route_durations(self, capture_dir):
        plain = Scenario(trace_dir=capture_dir)
        hot = Scenario(trace_dir=capture_dir,
                       cost=CostModel(kind_scales={"compute": 2.0}))
        d_plain = plain.diff_against(plain.traces)
        d_hot = hot.diff_against(hot.traces)
        assert d_plain.per_kind()["compute"].wape == pytest.approx(0.0)
        assert d_hot.per_kind()["compute"].wape == pytest.approx(1.0)


# ================================================================ golden loop
class TestGoldenCalibration:
    def test_recovers_constants_and_fidelity(self, capture_dir):
        scn = Scenario(trace_dir=capture_dir, cost=perturbed_cost())
        calibrated, rep = scn.calibrate()

        # loss must be monotonically non-increasing and actually improve
        assert all(b <= a + 1e-15 for a, b in
                   zip(rep.loss_history, rep.loss_history[1:]))
        assert rep.loss_after < rep.loss_before
        assert rep.loss_before > 0.2          # the perturbation was real

        # the perturbed compute scale is recovered exactly (closed-form
        # weighted-median update against the same capture)
        init, fitted = rep.fitted["kind_scale:compute"]
        assert init == 1.3
        assert fitted == pytest.approx(1.0, rel=1e-6)

        # per-kind WAPE under dPRO's 5% bound, all kinds
        for kind, st in rep.after.per_kind().items():
            assert st.wape < 0.05, (kind, st.wape)
        assert abs(rep.after.makespan_rel_error) < 0.05

        # the calibrated scenario reproduces the fit stand-alone
        d = calibrated.diff_against(calibrated.traces)
        for kind, st in d.per_kind().items():
            assert st.wape < 0.05, (kind, st.wape)
        # and the input scenario was not mutated
        assert scn.cost.kind_scale("compute") == 1.3

    def test_bounded_simulator_calls(self, capture_dir):
        scn = Scenario(trace_dir=capture_dir, cost=perturbed_cost())
        probes = 6
        _, rep = scn.calibrate(probes_per_constant=probes)
        budget = 1 + rep.rounds * len(rep.fitted) * probes
        assert rep.sim_calls <= budget

    def test_constant_subset_and_unknown_names(self, capture_dir):
        scn = Scenario(trace_dir=capture_dir, cost=perturbed_cost())
        _, rep = scn.calibrate(constants=["kind_scale:compute"])
        assert set(rep.fitted) == {"kind_scale:compute"}
        assert rep.fitted["kind_scale:compute"][1] == \
            pytest.approx(1.0, rel=1e-6)
        # ici stays perturbed -> collective error remains
        assert rep.after.per_kind()["collective"].wape > 0.05
        with pytest.raises(ValueError, match="unknown/unfittable"):
            scn.calibrate(constants=["kind_scale:bogus"])

    def test_faithful_model_converges_immediately(self, capture_dir):
        scn = Scenario(trace_dir=capture_dir)      # true constants already
        _, rep = scn.calibrate()
        assert rep.converged
        assert rep.sim_calls == 1                  # no probing a 0 loss
        assert rep.loss_before == pytest.approx(0.0, abs=1e-9)

    def test_report_format_renders_table(self, capture_dir):
        scn = Scenario(trace_dir=capture_dir, cost=perturbed_cost())
        _, rep = scn.calibrate()
        out = rep.format()
        assert "wape before" in out and "wape after" in out
        assert "kind_scale:compute" in out
        assert "makespan rel err" in out
        assert "inf" not in out

    def test_calibrate_needs_a_capture(self):
        from synthgraphs import training_step_graph
        scn = Scenario(training_step_graph(layers=2))
        with pytest.raises(ValueError, match="captured trace set"):
            scn.calibrate()

    def test_explicit_trace_dir_argument(self, capture_dir):
        """Calibrating an analytic scenario against an external capture
        takes the trace route internally and returns a calibrated copy."""
        scn = Scenario(trace_dir=capture_dir, cost=perturbed_cost())
        calibrated, rep = scn.calibrate(capture_dir)
        assert rep.loss_after < rep.loss_before
        assert calibrated.cost.kind_scale("compute") == \
            pytest.approx(1.0, rel=1e-6)


# ===================================================== real jax.profiler fixture
@pytest.fixture(scope="module")
def jax_profile_dir(tmp_path_factory):
    """A real ``jax.profiler`` capture of a few annotated steps of a jitted
    matmul — the CPU-backed XLA profile the calibrate CLI must digest."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    logdir = str(tmp_path_factory.mktemp("jaxprof"))
    f = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((256, 256))
    f(x).block_until_ready()                      # compile outside the trace
    jax.profiler.start_trace(logdir)
    for step in range(3):
        with jax.profiler.StepTraceAnnotation("train", step_num=step):
            f(x).block_until_ready()
    jax.profiler.stop_trace()
    from repro.traceio import find_xla_trace_files
    if not find_xla_trace_files(logdir):
        pytest.skip("jax.profiler produced no .trace.json.gz on this host")
    return logdir


class TestRealJaxCapture:
    def test_import_maps_onto_lane_model(self, jax_profile_dir):
        imp = load_trace_dir(jax_profile_dir)     # format auto-detected
        assert imp.num_workers >= 1
        events = imp.traces[0].events
        lanes = {e.thread for e in events}
        assert "device" in lanes                  # XLA runtime thread
        # step slicing kept one step: every HLO op of the jitted program
        # appears a bounded number of times, and lanes never overlap
        by_lane = {}
        for e in events:
            by_lane.setdefault(e.thread, []).append(e)
        for evs in by_lane.values():
            evs.sort(key=lambda e: e.ts)
            for a, b in zip(evs, evs[1:]):
                assert b.ts >= a.end - 1e-12
        assert all(e.dur >= 0 for e in events)

    def test_calibrate_cli_prints_fidelity_table(self, jax_profile_dir,
                                                 capsys, monkeypatch):
        from repro.launch.calibrate import main
        monkeypatch.setattr(sys, "argv",
                            ["calibrate", "--trace-dir", jax_profile_dir])
        main()
        out = capsys.readouterr().out
        assert "wape before" in out and "wape after" in out
        assert "makespan rel err" in out

    def test_scenario_calibrates_real_capture(self, jax_profile_dir):
        imp = load_trace_dir(jax_profile_dir)
        scn = Scenario(traces=imp,
                       cost=CostModel(kind_scales={"compute": 1.5}))
        calibrated, rep = scn.calibrate()
        # trace durations are ground truth here, so the injected 1.5x
        # compute perturbation must fit back out
        assert rep.fitted["kind_scale:compute"][1] == \
            pytest.approx(1.0, rel=1e-6)
        assert rep.after.per_kind()["compute"].wape < 0.05
