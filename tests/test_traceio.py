"""Trace I/O subsystem (repro.traceio) acceptance tests.

The ISSUE's acceptance criteria live here:

* **Round-trip invariant**: exporting a simulated uniform N-worker cluster
  to per-worker Chrome traces and re-importing via
  ``ClusterGraph.from_traces`` reproduces the predicted makespan within
  1e-6 relative error (a golden copy of the makespan is pinned under
  ``tests/golden/trace_roundtrip.json``).
* **Replicate equivalence**: a trace-imported cluster of N identical
  workers matches the replicate path (``ClusterGraph.build``) to float
  precision, for every collective mode.
* **Skew handling**: a synthetic trace set with per-worker clock offsets /
  drift and a straggler is aligned (dPRO-style least-squares offset+drift
  on collective-end anchors) and predicted correctly.
"""

import json
import math
import os

import pytest

from repro.core import (ClusterGraph, CostModel, GraphError, Task, TaskKind,
                        WorkerSpec, simulate, whatif, DEVICE_STREAM,
                        HOST_THREAD)
from repro.core.cluster import match_collective_groups
from repro import traceio
from repro.traceio import (TraceEvent, TraceImportError, WorkerTrace,
                           align_traces, apply_alignment, events_from_graph,
                           graph_from_events, load_trace_dir, read_jsonl,
                           synthetic_cluster_traces, write_jsonl,
                           write_synthetic_trace_dir)
from synthgraphs import training_step_graph

LAYERS = 6
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "trace_roundtrip.json")


@pytest.fixture()
def ddp_graph():
    g = training_step_graph(layers=LAYERS)
    return whatif.what_if_distributed(g, GRADS, num_workers=4).graph


def write_traces(tmp_path, traces):
    os.makedirs(str(tmp_path), exist_ok=True)
    for tr in traces:
        write_jsonl(tr.events, str(tmp_path / f"worker{tr.worker}.jsonl"))
    return str(tmp_path)


# ================================================================ round trip
class TestRoundTrip:
    def test_uniform_cluster_export_import_recovers_makespan(self, ddp_graph,
                                                             tmp_path):
        """THE acceptance invariant: simulate -> export -> import -> same
        makespan within 1e-6 relative."""
        cost = CostModel()
        cg = ClusterGraph.build(ddp_graph, 4, cost=cost)
        res = cg.simulate()
        traceio.export_cluster_traces(cg, res, str(tmp_path))
        res2 = ClusterGraph.from_traces(str(tmp_path), cost=cost).simulate()
        assert res2.makespan == pytest.approx(res.makespan, rel=1e-6)

    def test_roundtrip_matches_golden(self, ddp_graph, tmp_path):
        """The fixed synthetic cluster's makespan is pinned by a golden
        file: format/importer drift that changes predictions fails here."""
        with open(GOLDEN) as f:
            golden = json.load(f)
        cost = CostModel()
        cg = ClusterGraph.build(ddp_graph, golden["workers"], cost=cost)
        res = cg.simulate()
        assert res.makespan == pytest.approx(golden["makespan_s"], rel=1e-9)
        traceio.export_cluster_traces(cg, res, str(tmp_path))
        res2 = ClusterGraph.from_traces(str(tmp_path), cost=cost).simulate()
        assert res2.makespan == pytest.approx(golden["makespan_s"], rel=1e-6)

    def test_single_graph_chrome_roundtrip_exact(self, ddp_graph, tmp_path):
        """graph -> Chrome JSON -> graph reproduces the simulated makespan
        exactly (all edges/durations/gaps survive)."""
        res = simulate(ddp_graph)
        path = str(tmp_path / "step.trace.json")
        traceio.export_graph_trace(ddp_graph, res, path)
        tr = traceio.load_worker_trace(path)
        g2 = graph_from_events(tr)
        assert len(g2) == len(ddp_graph)
        assert simulate(g2).makespan == pytest.approx(res.makespan,
                                                      rel=1e-12)

    def test_export_tolerates_none_valued_attrs(self):
        """HLO-extracted graphs tag non-collective comm tasks with
        ``collective=None`` / ``group_size=None``; export must not choke."""
        from repro.core import DependencyGraph
        g = DependencyGraph()
        g.add_task(Task("permute", TaskKind.COLLECTIVE, "ici:x", 1e-3,
                        attrs={"collective": None, "group_size": None}))
        evs = events_from_graph(g)
        assert evs[0].group_size == 0 and evs[0].collective is None
        tr = read_jsonl(iter(write_jsonl(evs)))
        assert simulate(graph_from_events(tr)).makespan == \
            pytest.approx(1e-3)

    def test_jsonl_roundtrip_in_memory(self, ddp_graph):
        events = events_from_graph(ddp_graph)
        lines = write_jsonl(events)            # no path: in-memory
        tr = read_jsonl(iter(lines))
        g2 = graph_from_events(tr)
        assert simulate(g2).makespan == \
            pytest.approx(simulate(ddp_graph).makespan, rel=1e-12)

    def test_exported_cluster_trace_opens_as_chrome_json(self, ddp_graph,
                                                         tmp_path):
        cg = ClusterGraph.build(ddp_graph, 2)
        traceio.export_cluster_traces(cg, cg.simulate(), str(tmp_path))
        with open(tmp_path / "worker0.trace.json") as f:
            data = json.load(f)
        evs = data["traceEvents"]
        assert any(e.get("ph") == "X" for e in evs)
        assert any(e.get("ph") == "M" and e.get("name") == "thread_name"
                   for e in evs)
        # collective pieces collapsed back to one event per all-reduce
        names = [e["name"] for e in evs if e.get("ph") == "X"]
        assert not any(":leg" in n for n in names)
        assert any(e.get("args", {}).get("collective") == "all-reduce"
                   for e in evs if e.get("ph") == "X")


# ===================================================== replicate equivalence
class TestReplicateEquivalence:
    @pytest.mark.parametrize("n", [2, 4, 8])
    @pytest.mark.parametrize("mode", ["ring", "fused", "hierarchical"])
    def test_identical_workers_match_replicate_path(self, ddp_graph, n, mode,
                                                    tmp_path):
        """N identical imported traces == ClusterGraph.build to float
        precision, for every collective mode."""
        cost = CostModel()
        build = ClusterGraph.build(ddp_graph, n, cost=cost,
                                   collective_mode=mode).simulate()
        events = events_from_graph(ddp_graph)
        for w in range(n):
            write_jsonl(events, str(tmp_path / f"worker{w}.jsonl"))
        imported = ClusterGraph.from_traces(
            str(tmp_path), cost=cost, collective_mode=mode).simulate()
        assert imported.makespan == pytest.approx(build.makespan, rel=1e-12)
        assert imported.worker_makespans() == \
            pytest.approx(build.worker_makespans(), rel=1e-12)

    def test_from_worker_graphs_single_worker_identity(self, ddp_graph):
        res = ClusterGraph.from_worker_graphs([ddp_graph]).simulate()
        assert res.makespan == pytest.approx(simulate(ddp_graph).makespan,
                                             rel=1e-12)

    def test_worker_specs_layer_on_top_of_traces(self, ddp_graph):
        """Explicit WorkerSpecs scale the *traced* durations — the
        straggler what-if on imported traces."""
        uni = ClusterGraph.from_worker_graphs([ddp_graph] * 4).simulate()
        specs = [WorkerSpec(compute_scale=2.0 if i == 0 else 1.0)
                 for i in range(4)]
        slow = ClusterGraph.from_worker_graphs([ddp_graph] * 4,
                                               specs).simulate()
        assert slow.makespan > uni.makespan * 1.2
        assert slow.straggler() == 0


# ============================================================ clock alignment
class TestAlignment:
    OFFSETS = [0.0, 0.05, -0.03, 0.12]
    DRIFTS = [1.0, 1.0002, 0.9999, 1.0]

    def test_alignment_recovers_offset_and_drift(self):
        traces = synthetic_cluster_traces(
            4, clock_offsets=self.OFFSETS, clock_drifts=self.DRIFTS)
        aligns = align_traces(traces)
        for al, off, drift in zip(aligns, self.OFFSETS, self.DRIFTS):
            assert al.anchors == LAYERS
            # local = true*d + o  =>  true = (1/d)*local - o/d
            assert al.scale == pytest.approx(1.0 / drift, rel=1e-9)
            assert al.offset == pytest.approx(-off / drift, rel=1e-6,
                                              abs=1e-12)
            assert al.residual < 1e-9

    def test_skewed_clocks_do_not_change_prediction(self, tmp_path):
        """Prediction from offset/drifted traces == prediction from clean
        traces: alignment undoes the clocks."""
        cost = CostModel()
        clean = synthetic_cluster_traces(4)
        skewed = synthetic_cluster_traces(
            4, clock_offsets=self.OFFSETS, clock_drifts=self.DRIFTS)
        d1 = write_traces(tmp_path / "clean", clean)
        d2 = write_traces(tmp_path / "skewed", skewed)
        r1 = ClusterGraph.from_traces(d1, cost=cost).simulate()
        r2 = ClusterGraph.from_traces(d2, cost=cost).simulate()
        assert r2.makespan == pytest.approx(r1.makespan, rel=1e-6)

    def test_skewed_straggler_predicted_correctly(self, tmp_path):
        """Acceptance: clock-offset + straggler trace set is aligned and
        predicted correctly — the straggler's extra compute shifts the
        makespan by the analytical amount (everyone waits on the ring)."""
        cost = CostModel()
        slowdown = 2.0
        uni = synthetic_cluster_traces(4)
        strag = synthetic_cluster_traces(
            4, compute_scales=[slowdown, 1.0, 1.0, 1.0],
            clock_offsets=self.OFFSETS, clock_drifts=self.DRIFTS)
        d1 = write_traces(tmp_path / "uni", uni)
        d2 = write_traces(tmp_path / "strag", strag)
        r_uni = ClusterGraph.from_traces(d1, cost=cost).simulate()
        r = ClusterGraph.from_traces(d2, cost=cost).simulate()
        device_compute = sum(e.dur for e in uni[0].events
                             if e.thread == DEVICE_STREAM)
        expected = r_uni.makespan + (slowdown - 1.0) * device_compute
        assert r.makespan == pytest.approx(expected, rel=0.02)
        assert r.straggler() == 0

    def test_start_skew_gates_late_worker(self, tmp_path):
        """A worker whose (aligned) trace starts late stays late in the
        simulation — the start-skew gate tasks."""
        traces = synthetic_cluster_traces(2)
        late = 5e-3
        for ev in traces[1].events:
            ev.ts += late                     # true late start, not clock
        d = write_traces(tmp_path, traces)
        imp = load_trace_dir(d, align=False)
        assert imp.start_skews[1] == pytest.approx(late)
        res = ClusterGraph.from_traces(imp).simulate()
        base = ClusterGraph.from_traces(
            write_traces(tmp_path / "clean", synthetic_cluster_traces(2))
        ).simulate()
        assert res.makespan > base.makespan
        assert res.makespan == pytest.approx(base.makespan + late, rel=0.2)

    def test_single_worker_alignment_is_identity(self):
        traces = synthetic_cluster_traces(1)
        aligns = align_traces(traces)
        assert aligns[0].is_identity


# =============================================================== importing
class TestImport:
    def test_stream_order_and_deps_reconstructed(self):
        evs = [
            TraceEvent("a", "host", ts=0.0, dur=1e-3, eid=0),
            TraceEvent("b", "device", ts=2e-3, dur=1e-3, eid=1, deps=[0]),
            TraceEvent("c", "device", ts=4e-3, dur=1e-3, eid=2),
            TraceEvent("d", "ici:x", ts=5e-3, dur=1e-3, eid=3, deps=[2]),
        ]
        g = graph_from_events(WorkerTrace(0, evs))
        assert len(g) == 4
        by_name = {t.name: t for t in g.tasks()}
        # cross-thread dep a->b, lane edge b->c, cross-thread c->d
        assert by_name["b"] in g.children(by_name["a"])
        assert by_name["c"] in g.children(by_name["b"])
        assert by_name["d"] in g.children(by_name["c"])

    def test_host_gap_inference(self):
        evs = [
            TraceEvent("h1", "host", ts=0.0, dur=1e-3, eid=0),
            TraceEvent("h2", "host", ts=5e-3, dur=1e-3, eid=1),
            TraceEvent("k1", "device", ts=0.0, dur=1e-3, eid=2),
            TraceEvent("k2", "device", ts=5e-3, dur=1e-3, eid=3),
        ]
        g = graph_from_events(WorkerTrace(0, evs))
        by_name = {t.name: t for t in g.tasks()}
        assert by_name["h1"].gap == pytest.approx(4e-3)   # host: inferred
        assert by_name["k1"].gap == 0.0                   # device: not
        # explicit gap wins over inference
        evs[0].gap = 1e-3
        g2 = graph_from_events(WorkerTrace(0, evs))
        assert {t.name: t for t in g2.tasks()}["h1"].gap == 1e-3

    def test_kind_and_collective_inference(self):
        ev = TraceEvent("ncclAllReduce_f32", "comm", ts=0.0, dur=1e-3)
        t = ev.to_task()
        assert t.kind == TaskKind.COLLECTIVE
        assert t.attrs["collective"] == "all-reduce"
        assert traceio.infer_collective("fusion.123") is None
        assert traceio.classify("matmul", "device") == TaskKind.COMPUTE
        assert traceio.classify("enqueue", "host") == TaskKind.HOST

    def test_bad_dep_id_raises(self):
        evs = [TraceEvent("a", "device", ts=0.0, dur=1e-3, eid=0, deps=[7])]
        with pytest.raises(TraceImportError, match="unknown event id"):
            graph_from_events(WorkerTrace(0, evs))

    def test_cyclic_flow_raises(self):
        evs = [
            TraceEvent("a", "device", ts=0.0, dur=1e-3, eid=0, deps=[1]),
            TraceEvent("b", "ici:x", ts=0.5e-3, dur=1e-3, eid=1, deps=[0]),
        ]
        with pytest.raises(TraceImportError, match="DAG"):
            graph_from_events(WorkerTrace(0, evs))

    def test_missing_required_field_raises(self, tmp_path):
        p = tmp_path / "worker0.jsonl"
        p.write_text('{"name": "a", "thread": "device", "ts": 0.0}\n')
        with pytest.raises(TraceImportError, match="dur"):
            load_trace_dir(str(tmp_path))

    def test_empty_dir_raises(self, tmp_path):
        with pytest.raises(TraceImportError, match="no .*worker files"):
            load_trace_dir(str(tmp_path))
        with pytest.raises(TraceImportError, match="does not exist"):
            load_trace_dir(str(tmp_path / "nope"))

    def test_mismatched_collectives_raise(self, tmp_path):
        traces = synthetic_cluster_traces(2)
        # drop one collective from worker 1 -> matching must fail loudly
        drop = next(e for e in traces[1].events if e.name == "allreduce:l0")
        traces[1].events = [e for e in traces[1].events if e is not drop]
        for e in traces[1].events:
            e.deps = [dd for dd in e.deps if dd != drop.eid]
        d = write_traces(tmp_path, traces)
        with pytest.raises(GraphError, match="missing collective"):
            ClusterGraph.from_traces(d)

    def test_worker_file_ordering(self, tmp_path):
        for name, worker in [("worker10.jsonl", 10), ("worker2.jsonl", 2),
                             ("worker0.jsonl", 0)]:
            write_jsonl([TraceEvent("a", "device", ts=0.0, dur=1e-3,
                                    eid=0)], str(tmp_path / name))
        files = traceio.find_worker_files(str(tmp_path))
        assert [os.path.basename(f) for f in files] == \
            ["worker0.jsonl", "worker2.jsonl", "worker10.jsonl"]

    def test_chrome_flow_timestamp_binding(self, tmp_path):
        """Foreign Chrome traces (no args.bind extension) bind flows by
        timestamp: s -> enclosing slice, f -> next slice."""
        trace = {"traceEvents": [
            {"ph": "X", "name": "producer", "pid": 0, "tid": 1,
             "ts": 0.0, "dur": 100.0},
            {"ph": "X", "name": "consumer", "pid": 0, "tid": 2,
             "ts": 200.0, "dur": 50.0},
            {"ph": "s", "cat": "dep", "name": "dep", "id": 1, "pid": 0,
             "tid": 1, "ts": 50.0},
            {"ph": "f", "cat": "dep", "name": "dep", "id": 1, "pid": 0,
             "tid": 2, "ts": 200.0},
        ]}
        p = tmp_path / "worker0.json"
        p.write_text(json.dumps(trace))
        tr = traceio.read_chrome(str(p))
        consumer = next(e for e in tr.events if e.name == "consumer")
        producer = next(e for e in tr.events if e.name == "producer")
        assert consumer.deps == [producer.eid]

    def test_chrome_correlation_binding(self, tmp_path):
        trace = {"traceEvents": [
            {"ph": "X", "name": "launch", "pid": 0, "tid": 1, "ts": 0.0,
             "dur": 10.0, "args": {"correlation": 42}},
            {"ph": "X", "name": "kernel", "pid": 0, "tid": 2, "ts": 30.0,
             "dur": 99.0, "args": {"correlation": 42}},
        ]}
        p = tmp_path / "worker0.json"
        p.write_text(json.dumps(trace))
        tr = traceio.read_chrome(str(p))
        kernel = next(e for e in tr.events if e.name == "kernel")
        launch = next(e for e in tr.events if e.name == "launch")
        assert kernel.deps == [launch.eid]
        assert kernel.ts == pytest.approx(30e-6)   # us -> s


# ======================================================== scenario + sweeps
class TestTraceScenario:
    def test_scenario_trace_route_runs_registry_stack(self, tmp_path):
        """Acceptance: the PR-2 registry runs end-to-end on imported
        traces — amp|bandwidth composes and speeds up the cluster."""
        from repro.core import Scenario
        write_synthetic_trace_dir(str(tmp_path), 4)
        scn = Scenario(trace_dir=str(tmp_path))
        assert scn.is_cluster
        pred = scn.predict("amp,bandwidth:factor=2")
        assert pred.cluster is not None
        assert len(pred.cluster.per_worker) == 4
        assert pred.speedup > 1.5
        base = scn.predict("noop")
        assert base.predicted == pytest.approx(base.baseline, rel=1e-12)

    def test_scenario_sweep_reuses_trace_cluster(self, tmp_path):
        """Worker-spec sweeps on the trace route retune one imported
        build; predictions match per-point rebuilds exactly."""
        from repro.core import Scenario
        from repro.core.optimize import straggler_specs
        write_synthetic_trace_dir(str(tmp_path), 4)
        scn = Scenario(trace_dir=str(tmp_path))
        grid = {"workers": straggler_specs(4, [1.0, 1.5, 2.0])}
        reused = scn.sweep("noop", grid, reuse=True)
        rebuilt = scn.sweep("noop", grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]
        assert reused[0].predicted < reused[-1].predicted

    def test_scenario_worker_count_mismatch_raises(self, tmp_path):
        from repro.core import Scenario
        from repro.core.optimize import OptimizationError
        write_synthetic_trace_dir(str(tmp_path), 4)
        with pytest.raises(OptimizationError, match="4 trace worker"):
            Scenario(trace_dir=str(tmp_path), workers=8)
        with pytest.raises(OptimizationError, match="4 trace worker"):
            Scenario(trace_dir=str(tmp_path), workers=[WorkerSpec()] * 3)


# ========================================================== build invariants
class TestClusterBuildGuards:
    def test_hierarchical_rejects_unequal_pods(self, ddp_graph):
        """Satellite: unequal pod sizes would silently mis-group the
        cross-pod shard exchange; build must reject them loudly."""
        bad = [WorkerSpec(pod=0), WorkerSpec(pod=0), WorkerSpec(pod=0),
               WorkerSpec(pod=1)]
        with pytest.raises(GraphError, match="equal-size pods"):
            ClusterGraph.build(ddp_graph, bad,
                               collective_mode="hierarchical")
        with pytest.raises(GraphError, match="equal-size pods"):
            ClusterGraph.from_worker_graphs([ddp_graph] * 4, bad,
                                            collective_mode="hierarchical")
        # equal pods still fine (and ring mode never cares)
        ClusterGraph.build(ddp_graph, [WorkerSpec(pod=i // 2)
                                       for i in range(4)],
                           collective_mode="hierarchical")
        ClusterGraph.build(ddp_graph, bad, collective_mode="ring")

    def test_from_worker_graphs_spec_count_mismatch(self, ddp_graph):
        with pytest.raises(GraphError, match="pair up 1:1"):
            ClusterGraph.from_worker_graphs([ddp_graph] * 2,
                                            [WorkerSpec()] * 3)

    def test_match_collective_groups_on_identical_graphs(self, ddp_graph):
        groups = match_collective_groups([ddp_graph, ddp_graph])
        n_coll = sum(1 for t in ddp_graph.tasks()
                     if t.attrs.get("collective"))
        assert len(groups) == n_coll
        for op, members in groups:
            assert op == "all-reduce"
            assert members[0].name == members[1].name


def test_hop_latency_calibration_plumbing():
    """Satellite: measured hop latency flows CostModel -> CollectiveModel ->
    ring legs, the way compute calibration already flows into durations."""
    from repro.core.calibrate import (hop_latency_from_measurement,
                                      measure_collective_hop_latency)
    from repro.core.costmodel import CollectiveModel
    # formula: solve the ring model for hop
    n, bw, payload = 4, 8e9, 4096.0
    hop = 3e-6
    t = 2 * (n - 1) / n * payload / bw + 2 * (n - 1) * hop
    assert hop_latency_from_measurement(t, payload, n, bw) == \
        pytest.approx(hop, rel=1e-9)
    # degenerate inputs fall back to the analytical default
    assert hop_latency_from_measurement(t, payload, 1, bw) == \
        CollectiveModel.HOP_LATENCY
    assert measure_collective_hop_latency(1) == CollectiveModel.HOP_LATENCY
    # plumbing: CostModel(hop_latency=...) reaches ring legs
    cost = CostModel(hop_latency=hop)
    assert cost.collectives.hop_latency == hop
    base = CostModel()
    assert base.collectives.hop_latency == CollectiveModel.HOP_LATENCY
    g = training_step_graph(layers=2)
    tf = whatif.what_if_distributed(g, {"l0": 1e6, "l1": 1e6}, 4,
                                    cost=cost)
    cg = ClusterGraph.build(tf.graph, 4, cost=cost)
    legs = [t for t in cg.graph.tasks() if "ring_round" in t.attrs]
    assert legs
    hw = cost.hw
    # both layers land in one 2 MB bucket; leg = (payload/n)/link_bw + hop
    expected = (2e6 / 4) / (hw.ici_bandwidth * hw.ici_links_per_axis) + hop
    assert min(t.duration for t in legs) == pytest.approx(expected,
                                                          rel=1e-12)


# ===================================================== degenerate clock fits
class TestAlignmentGuards:
    """Satellite: _fit on noisy/degenerate anchors can produce a
    non-positive or wildly-off scale; apply_alignment would then negate
    every duration.  The fit must fall back to offset-only instead."""

    @staticmethod
    def _trace(worker, ends):
        evs = [TraceEvent(name, "ici:grad", ts=end - 1e-3, dur=1e-3,
                          eid=i, collective="all-reduce")
               for i, (name, end) in enumerate(ends)]
        return WorkerTrace(worker, evs)

    def test_negative_slope_anchors_fall_back_to_offset(self):
        # anchor pairs with anti-correlated times: least squares gives a
        # negative scale, which must be rejected
        t0 = self._trace(0, [("allreduce:a", 0.2), ("allreduce:b", 0.1)])
        t1 = self._trace(1, [("allreduce:a", 0.1), ("allreduce:b", 0.2)])
        aligns = align_traces([t0, t1])
        al = aligns[1]
        assert al.fallback
        assert al.scale == 1.0
        assert al.anchors == 2
        apply_alignment(t1, al)
        assert all(ev.dur > 0 for ev in t1.events)

    def test_wildly_off_scale_falls_back(self):
        # nearly-coincident local anchors against well-spread reference
        # ones: the regression slope explodes past any physical drift
        t0 = self._trace(0, [("allreduce:a", 0.1), ("allreduce:b", 0.9)])
        t1 = self._trace(1, [("allreduce:a", 0.5), ("allreduce:b", 0.502)])
        aligns = align_traces([t0, t1])
        assert aligns[1].fallback
        assert aligns[1].scale == 1.0
        # offset-only map still centers the anchors
        assert aligns[1].offset == pytest.approx(0.5 - 0.501, abs=1e-9)

    def test_physical_drift_is_not_rejected(self):
        traces = synthetic_cluster_traces(
            2, clock_offsets=[0.0, 0.1], clock_drifts=[1.0, 1.0005])
        aligns = align_traces(traces)
        assert not aligns[1].fallback
        assert aligns[1].scale == pytest.approx(1.0 / 1.0005, rel=1e-9)

    def test_degenerate_durations_never_go_negative(self, tmp_path):
        """End to end: an adversarial capture imports with positive
        durations everywhere (the graph would reject negatives)."""
        t0 = self._trace(0, [("allreduce:a", 0.2), ("allreduce:b", 0.1)])
        t1 = self._trace(1, [("allreduce:a", 0.1), ("allreduce:b", 0.2)])
        d = write_traces(tmp_path, [t0, t1])
        imp = load_trace_dir(d)
        for tr in imp.traces:
            assert all(ev.dur > 0 for ev in tr.events)


# ==================================================== unanchored multi-worker
class TestAlignmentQualityChecks:
    """Satellite: multi-worker captures whose traces share zero matched
    collectives must not silently proceed with identity alignment."""

    @staticmethod
    def _disjoint_dir(tmp_path):
        # two workers with no common collective names -> zero anchors
        t0 = WorkerTrace(0, [
            TraceEvent("allreduce:x", "ici:grad", ts=0.0, dur=1e-3, eid=0,
                       collective="all-reduce"),
            TraceEvent("k", "device", ts=0.0, dur=1e-3, eid=1)])
        t1 = WorkerTrace(1, [
            TraceEvent("allreduce:y", "ici:grad", ts=0.0, dur=1e-3, eid=0,
                       collective="all-reduce"),
            TraceEvent("k", "device", ts=0.0, dur=1e-3, eid=1)])
        return write_traces(tmp_path, [t0, t1])

    def test_zero_anchor_import_warns_by_default(self, tmp_path):
        d = self._disjoint_dir(tmp_path)
        with pytest.warns(UserWarning,
                          match="share no matched collectives"):
            imp = load_trace_dir(d)
        assert imp.num_workers == 2            # still usable, just flagged

    def test_strict_alignment_raises(self, tmp_path):
        d = self._disjoint_dir(tmp_path)
        with pytest.raises(TraceImportError, match="unreliable"):
            load_trace_dir(d, align="strict")

    def test_strict_rejects_fallback_fits(self, tmp_path):
        t0 = TestAlignmentGuards._trace(
            0, [("allreduce:a", 0.2), ("allreduce:b", 0.1)])
        t1 = TestAlignmentGuards._trace(
            1, [("allreduce:a", 0.1), ("allreduce:b", 0.2)])
        d = write_traces(tmp_path, [t0, t1])
        with pytest.raises(TraceImportError, match="degenerate drift"):
            load_trace_dir(d, align="strict")

    def test_align_false_stays_silent(self, tmp_path, recwarn):
        d = self._disjoint_dir(tmp_path)
        load_trace_dir(d, align=False)
        assert not [w for w in recwarn
                    if "collectives" in str(w.message)]

    def test_anchored_import_does_not_warn(self, tmp_path, recwarn):
        d = write_traces(tmp_path, synthetic_cluster_traces(2))
        load_trace_dir(d, align="strict")      # anchors exist: no raise
        assert not [w for w in recwarn
                    if "collectives" in str(w.message)]

    def test_bad_align_value_rejected(self, tmp_path):
        d = write_traces(tmp_path, synthetic_cluster_traces(2))
        with pytest.raises(ValueError, match="align must be"):
            load_trace_dir(d, align="loose")


# ============================================================ XLA profiler
class TestXlaImport:
    """jax.profiler / XLA capture reader (repro.traceio.xla) on
    handcrafted captures — the real-capture fixture lives in
    test_calibrate.py."""

    @staticmethod
    def _write_capture(path, events, gz=True):
        import gzip as _gzip
        doc = {"displayTimeUnit": "ns", "metadata": {},
               "traceEvents": events}
        if gz:
            with _gzip.open(path, "wt") as f:
                json.dump(doc, f)
        else:
            with open(path, "w") as f:
                json.dump(doc, f)

    @classmethod
    def _profile_dir(cls, tmp_path, events):
        run = tmp_path / "plugins" / "profile" / "2026_01_01_00_00_00"
        os.makedirs(str(run))
        cls._write_capture(str(run / "host.trace.json.gz"), events)
        return str(tmp_path)

    @staticmethod
    def _meta(pid, tid, pname, tname):
        return [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                 "args": {"name": pname}},
                {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                 "args": {"name": tname}}]

    def _step_capture(self):
        evs = self._meta(7, 1, "/host:CPU", "tf_XLATfrtCpuClient/1")
        evs += self._meta(7, 2, "/host:CPU", "python")[1:]
        for step, base in ((0, 1000.0), (1, 2000.0)):
            evs.append({"ph": "X", "name": "train", "pid": 7, "tid": 2,
                        "ts": base, "dur": 500.0,
                        "args": {"step_num": str(step)}})
            # nested python flame: outer frame contains two leaves
            evs.append({"ph": "X", "name": "$m outer", "pid": 7, "tid": 2,
                        "ts": base + 10, "dur": 100.0, "args": {}})
            evs.append({"ph": "X", "name": "$m leaf1", "pid": 7, "tid": 2,
                        "ts": base + 20, "dur": 30.0, "args": {}})
            evs.append({"ph": "X", "name": "$m leaf2", "pid": 7, "tid": 2,
                        "ts": base + 60, "dur": 40.0, "args": {}})
            evs.append({"ph": "X", "name": "dot.1", "pid": 7, "tid": 1,
                        "ts": base + 120, "dur": 200.0,
                        "args": {"hlo_op": "dot.1",
                                 "hlo_module": "jit_f"}})
            evs.append({"ph": "X", "name": "all-reduce.2", "pid": 7,
                        "tid": 1, "ts": base + 330, "dur": 50.0,
                        "args": {"hlo_op": "all-reduce.2",
                                 "hlo_module": "jit_f"}})
        return evs

    def test_step_slicing_keeps_last_step_only(self, tmp_path):
        d = self._profile_dir(tmp_path, self._step_capture())
        imp = traceio.load_xla_profile(d)          # step="last"
        names = [e.name for e in imp.traces[0].events]
        assert "dot.1" in names and "all-reduce.2" in names
        assert names.count("dot.1") == 1           # one step, not two
        assert "train" not in names                # marker itself excluded
        # leaf extraction: the container frame is gone, leaves survive
        assert "$m outer" not in names
        assert "$m leaf1" in names and "$m leaf2" in names

    def test_explicit_and_all_step_selection(self, tmp_path):
        d = self._profile_dir(tmp_path, self._step_capture())
        imp0 = traceio.load_xla_profile(d, step=0)
        assert [e.name for e in imp0.traces[0].events].count("dot.1") == 1
        imp_all = traceio.load_xla_profile(d, step=None)
        assert [e.name
                for e in imp_all.traces[0].events].count("dot.1") == 2
        with pytest.raises(TraceImportError, match="not in capture"):
            traceio.load_xla_profile(d, step=9)

    def test_lanes_kinds_and_units(self, tmp_path):
        d = self._profile_dir(tmp_path, self._step_capture())
        imp = traceio.load_xla_profile(d)
        by_name = {}
        for ev in imp.traces[0].events:
            by_name[ev.name] = ev
        assert by_name["dot.1"].thread == "device"
        assert by_name["$m leaf1"].thread == "host"
        assert by_name["dot.1"].dur == pytest.approx(200e-6)  # us -> s
        g = imp.graphs[0]
        kinds = {t.name: t.kind for t in g.tasks()}
        assert kinds["dot.1"] == TaskKind.COMPUTE
        assert kinds["all-reduce.2"] == TaskKind.COLLECTIVE
        assert kinds["$m leaf1"] == TaskKind.HOST

    def test_load_trace_dir_detects_xla_profiles(self, tmp_path):
        d = self._profile_dir(tmp_path, self._step_capture())
        imp = load_trace_dir(d)                    # auto-detected
        assert imp.num_workers == 1
        assert any(e.thread == "device" for e in imp.traces[0].events)

    def test_latest_run_wins_and_file_paths_accepted(self, tmp_path):
        d = self._profile_dir(tmp_path, self._step_capture())
        older = tmp_path / "plugins" / "profile" / "2020_01_01_00_00_00"
        os.makedirs(str(older))
        self._write_capture(str(older / "host.trace.json.gz"),
                            self._meta(1, 1, "/host:CPU", "python"))
        files = traceio.find_xla_trace_files(str(tmp_path))
        assert len(files) == 1 and "2026_01_01" in files[0]
        # a single trace file is also a valid entry point
        assert traceio.find_xla_trace_files(files[0]) == [files[0]]

    def test_native_chrome_exports_are_not_claimed(self, tmp_path):
        """Regression: a directory of native ``worker<N>.trace.json``
        exports must NOT be detected as an XLA capture — that would
        bypass the provenance-aware importer."""
        g = whatif.what_if_distributed(
            training_step_graph(layers=2),
            {f"l{i}": 1e6 for i in range(2)}, num_workers=2).graph
        cg = ClusterGraph.build(g, 2, cost=CostModel())
        res = cg.simulate()
        traceio.export_cluster_traces(cg, res, str(tmp_path))
        assert traceio.find_xla_trace_files(str(tmp_path)) == []
        imp = load_trace_dir(str(tmp_path))
        assert imp.num_workers == 2

    def test_capture_without_steps_keeps_everything(self, tmp_path):
        evs = self._meta(7, 1, "/host:CPU", "tf_XLATfrtCpuClient/1")
        evs.append({"ph": "X", "name": "dot.9", "pid": 7, "tid": 1,
                    "ts": 100.0, "dur": 10.0, "args": {"hlo_op": "dot.9"}})
        d = self._profile_dir(tmp_path, evs)
        imp = traceio.load_xla_profile(d)
        assert [e.name for e in imp.traces[0].events] == ["dot.9"]

    def test_empty_or_malformed_captures_raise(self, tmp_path):
        d = self._profile_dir(tmp_path, self._meta(1, 1, "/host:CPU",
                                                   "python"))
        with pytest.raises(TraceImportError, match="no complete"):
            traceio.load_xla_profile(d)
        with pytest.raises(TraceImportError, match="no XLA profile"):
            traceio.load_xla_profile(str(tmp_path / "nope"))
