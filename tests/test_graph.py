"""Dependency-graph construction invariants (paper §4.2).

Hypothesis-based property tests live in ``test_graph_properties.py`` so this
module collects and runs on machines without the optional ``hypothesis`` dev
dependency (declared in pyproject.toml ``[project.optional-dependencies]``).
"""

import pytest

from repro.core import (DependencyGraph, GraphError, Task, TaskKind,
                        DEVICE_STREAM, HOST_THREAD)


def mk(name="t", thread=DEVICE_STREAM, dur=1.0, **kw):
    return Task(name=name, kind=kw.pop("kind", TaskKind.COMPUTE),
                thread=thread, duration=dur, **kw)


def chain(g, n, thread=DEVICE_STREAM):
    return [g.add_task(mk(f"{thread}{i}", thread)) for i in range(n)]


class TestBasics:
    def test_lane_program_order(self):
        g = DependencyGraph()
        ts = chain(g, 4)
        for a, b in zip(ts, ts[1:]):
            assert b in g.children(a)
        g.validate()

    def test_insert_after_splices(self):
        g = DependencyGraph()
        a, b = chain(g, 2)
        c = g.add_task(mk("c"), after=a)
        assert c in g.children(a) and b in g.children(c)
        assert b not in g.children(a)
        g.validate()

    def test_remove_bridges(self):
        g = DependencyGraph()
        a, b, c = chain(g, 3)
        g.remove_task(b)
        assert c in g.children(a)
        g.validate()

    def test_remove_no_bridge(self):
        g = DependencyGraph()
        a, b, c = chain(g, 3)
        g.remove_task(b, bridge=False)
        assert c not in g.children(a)

    def test_cross_thread_edge_and_cycle_detection(self):
        g = DependencyGraph()
        h = g.add_task(mk("h", HOST_THREAD))
        d = g.add_task(mk("d"))
        g.add_edge(h, d)
        g.validate()
        g.add_edge(d, h)
        with pytest.raises(GraphError):
            g.validate()

    def test_copy_independent(self):
        g = DependencyGraph()
        chain(g, 3)
        g2 = g.copy()
        g2.remove_task(g2.tasks()[0])
        assert len(g) == 3 and len(g2) == 2

    def test_critical_path_includes_gap(self):
        g = DependencyGraph()
        a = g.add_task(mk("a", dur=1.0, gap=0.5))
        b = g.add_task(mk("b", dur=2.0))
        assert g.critical_path() == pytest.approx(3.5)

    def test_select(self):
        g = DependencyGraph()
        chain(g, 3)
        chain(g, 2, HOST_THREAD)
        assert len(g.select(lambda t: t.thread == HOST_THREAD)) == 2


