"""Property tests for the goodput simulator (ISSUE 10, satellite 3).

Three invariants of ``repro.faults.goodput.simulate_goodput`` that must hold
for *any* fault process, not just the hand-checked fixtures:

* **monotone in the failure set** — adding failure events can never
  increase useful work: for any timeline ``E`` and superset ``E' ⊇ E``,
  ``useful(E') <= useful(E)`` (rate-monotonicity follows, since a higher
  rate is distributionally a superset process);
* **bounded by fault-free throughput** — ``goodput_fraction <= 1.0`` and
  ``availability <= 1.0``: faults only remove capacity;
* **lost work bounded by the checkpoint interval** — a fail-stop rollback
  loses at most ``ckpt_interval_steps`` whole steps (the uncommitted block),
  so ``max_lost_steps_per_failure <= K``.

The cases are drawn from a seeded RNG so the suite is deterministic without
external dependencies; when ``hypothesis`` is installed an extra class
searches the same properties adversarially.
"""

import random

import pytest

from repro.faults import (FaultTimeline, RecoveryModel, exponential_failures,
                          preemption_windows, simulate_goodput,
                          transient_stragglers)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # the container image does not ship hypothesis
    HAVE_HYPOTHESIS = False

_REC = RecoveryModel(checkpoint_bytes=8e9)


def _sim(timeline, *, n, K, horizon_s, step_s=1.0, **kw):
    return simulate_goodput(n_workers=n, horizon_s=horizon_s,
                            timeline=timeline, recovery=_REC,
                            ckpt_interval_steps=K, step_s=step_s, **kw)


def _cases(n_cases=25, master_seed=20260809):
    rng = random.Random(master_seed)
    out = []
    for i in range(n_cases):
        out.append(dict(
            n=rng.randint(1, 32),
            mtbf_s=rng.uniform(0.5, 24.0) * 3600.0,
            K=rng.randint(1, 400),
            seed=rng.randint(0, 10_000),
            horizon_s=rng.uniform(2.0, 36.0) * 3600.0,
            step_s=rng.uniform(0.05, 5.0),
        ))
    return out


CASES = _cases()
_IDS = [f"case{i}" for i in range(len(CASES))]


def _mixed_timeline(c):
    """Failures + periodic preemptions + stragglers for case ``c``."""
    tl = exponential_failures(c["n"], c["mtbf_s"], c["horizon_s"], c["seed"])
    tl = tl | preemption_windows(7200.0, 300.0, c["horizon_s"],
                                 offset_s=1800.0)
    tl = tl | transient_stragglers(0.5, 2.0, 120.0, c["horizon_s"],
                                   seed=c["seed"])
    return tl


class TestSeededProperties:
    @pytest.mark.parametrize("c", CASES, ids=_IDS)
    def test_superset_of_failures_never_gains_useful_work(self, c):
        base = exponential_failures(c["n"], c["mtbf_s"], c["horizon_s"],
                                    c["seed"])
        extra = exponential_failures(c["n"], c["mtbf_s"], c["horizon_s"],
                                     c["seed"] + 1)
        more = base | extra
        assert set(base.events) <= set(more.events)
        kw = dict(n=c["n"], K=c["K"], horizon_s=c["horizon_s"],
                  step_s=c["step_s"])
        assert _sim(more, **kw).useful_steps <= _sim(base, **kw).useful_steps

    @pytest.mark.parametrize("c", CASES, ids=_IDS)
    def test_goodput_and_availability_at_most_one(self, c):
        rep = _sim(_mixed_timeline(c), n=c["n"], K=c["K"],
                   horizon_s=c["horizon_s"], step_s=c["step_s"])
        assert 0.0 <= rep.goodput_fraction <= 1.0 + 1e-9
        assert 0.0 <= rep.availability <= 1.0 + 1e-9

    @pytest.mark.parametrize("c", CASES, ids=_IDS)
    def test_lost_work_bounded_by_ckpt_interval(self, c):
        rep = _sim(_mixed_timeline(c), n=c["n"], K=c["K"],
                   horizon_s=c["horizon_s"], step_s=c["step_s"])
        assert rep.max_lost_steps_per_failure <= c["K"]
        if rep.failures:
            assert rep.lost_steps <= rep.failures * c["K"]

    @pytest.mark.parametrize("elastic", [False, True])
    def test_rate_monotone_goodput_curve(self, elastic):
        """Sweeping the per-worker MTBF down never raises goodput."""
        horizon, n, K = 24 * 3600.0, 8, 100
        prev = None
        for mtbf_h in (48.0, 12.0, 3.0, 0.75):
            tl = exponential_failures(n, mtbf_h * 3600.0, horizon, seed=7)
            rep = _sim(tl, n=n, K=K, horizon_s=horizon, elastic=elastic)
            if prev is not None:
                # distinct seeds per rate would only be distributionally
                # monotone; nested streams at the same seed give stronger
                # sample-path behaviour, but allow sampling slack anyway.
                assert rep.useful_steps <= prev * 1.02
            prev = rep.useful_steps


if HAVE_HYPOTHESIS:
    class TestHypothesisProperties:
        @settings(max_examples=50, deadline=None)
        @given(n=st.integers(1, 32),
               mtbf_h=st.floats(0.25, 48.0),
               K=st.integers(1, 500),
               seed=st.integers(0, 2**16),
               horizon_h=st.floats(1.0, 48.0),
               step_s=st.floats(0.01, 10.0))
        def test_bounds_and_lost_work(self, n, mtbf_h, K, seed, horizon_h,
                                      step_s):
            tl = exponential_failures(n, mtbf_h * 3600.0,
                                      horizon_h * 3600.0, seed)
            rep = _sim(tl, n=n, K=K, horizon_s=horizon_h * 3600.0,
                       step_s=step_s)
            assert rep.goodput_fraction <= 1.0 + 1e-9
            assert rep.availability <= 1.0 + 1e-9
            assert rep.max_lost_steps_per_failure <= K

        @settings(max_examples=25, deadline=None)
        @given(n=st.integers(1, 16),
               mtbf_h=st.floats(0.5, 24.0),
               K=st.integers(1, 200),
               seed=st.integers(0, 2**16))
        def test_superset_monotone(self, n, mtbf_h, K, seed):
            horizon = 12 * 3600.0
            base = exponential_failures(n, mtbf_h * 3600.0, horizon, seed)
            more = base | exponential_failures(n, mtbf_h * 3600.0, horizon,
                                               seed + 1)
            assert (_sim(more, n=n, K=K, horizon_s=horizon).useful_steps
                    <= _sim(base, n=n, K=K, horizon_s=horizon).useful_steps)
