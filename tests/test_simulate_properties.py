"""Hypothesis property tests for the simulator (paper Algorithm 1 bounds).

Skipped when the optional ``hypothesis`` dev dependency is absent so the
tier-1 suite collects on a clean machine.  Engine-vs-oracle equivalence tests
that need no optional dependency live in ``test_engine_equivalence.py``.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import DependencyGraph, Task, TaskKind, simulate
from repro.core.simulate import simulate_reference


def mk(name, thread, dur=1.0, gap=0.0):
    return Task(name=name, kind=TaskKind.COMPUTE, thread=thread,
                duration=dur, gap=gap)


@hypothesis.given(st.lists(st.tuples(st.sampled_from(["device", "host",
                                                      "ici:x"]),
                                     st.floats(0.01, 5.0),
                                     st.floats(0.0, 1.0)),
                           min_size=1, max_size=30))
@hypothesis.settings(max_examples=60, deadline=None)
def test_property_bounds(items):
    """critical path <= makespan <= total work, executed == all tasks."""
    g = DependencyGraph()
    prev = None
    for i, (th, dur, gap) in enumerate(items):
        t = g.add_task(mk(f"t{i}", th, dur=dur, gap=gap))
        if prev is not None and i % 3 == 0:
            g.add_edge(prev, t)
        prev = t
    r = simulate(g)
    assert len(r.start) == len(g)
    assert r.makespan >= g.critical_path() - 1e-6
    assert r.makespan <= g.total_work() + 1e-6


@hypothesis.given(st.lists(st.tuples(st.sampled_from(["device", "host",
                                                      "ici:x", "ici:y"]),
                                     st.floats(0.01, 5.0),
                                     st.floats(0.0, 1.0)),
                           min_size=1, max_size=40),
                  st.integers(2, 7))
@hypothesis.settings(max_examples=60, deadline=None)
def test_event_engine_matches_reference(items, stride):
    """The heap engine and the legacy loop agree on starts and makespan."""
    g = DependencyGraph()
    prev = None
    for i, (th, dur, gap) in enumerate(items):
        t = g.add_task(mk(f"t{i}", th, dur=dur, gap=gap))
        if prev is not None and i % stride == 0:
            g.add_edge(prev, t)
        prev = t
    fast = simulate(g)
    slow = simulate_reference(g)
    assert fast.makespan == pytest.approx(slow.makespan, abs=1e-9)
    for uid, s in slow.start.items():
        assert fast.start[uid] == pytest.approx(s, abs=1e-9)
