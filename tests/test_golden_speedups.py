"""Golden regression tests: frozen predicted speedups on a fixed graph.

The point is change detection, not truth: the values in
``tests/golden/speedups.json`` were produced by the analytical models on the
fixed synthetic step graph, and any engine/model/transform refactor that
moves a prediction by more than the stored ``rtol`` must either be a bug or
consciously re-freeze the numbers (regenerate via the commands in each
test's docstring — the computation is the test body itself).
"""

import json
import os

import pytest

from repro.core import whatif, simulate
from synthgraphs import training_step_graph

_GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "speedups.json")


@pytest.fixture(scope="module")
def golden():
    with open(_GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def setup(golden):
    layers = golden["graph"]["layers"]
    grads = {f"l{i}": golden["graph"]["grad_bytes_per_layer"]
             for i in range(layers)}
    return training_step_graph(layers=layers), grads


def _check(golden, key, value):
    want = golden[key]["value"]
    assert value == pytest.approx(want, rel=golden[key]["rtol"]), (
        f"{key}: got {value!r}, golden {want!r} — if the change is "
        f"intentional, re-freeze tests/golden/speedups.json")


def test_amp_golden(golden, setup):
    g, _ = setup
    base = simulate(g).makespan
    _check(golden, "amp_speedup",
           base / whatif.what_if_amp(g).simulate().makespan)


def test_p3_golden(golden, setup):
    g, grads = setup
    plain = whatif.what_if_p3(g, grads, 4, bandwidth=5e9, priority=False,
                              slice_bytes=float("inf")).simulate().makespan
    prio = whatif.what_if_p3(g, grads, 4, bandwidth=5e9,
                             priority=True).simulate().makespan
    _check(golden, "p3_priority_speedup_over_plain_ps", plain / prio)


def test_zero_golden(golden, setup):
    g, grads = setup
    ddp = whatif.cluster_what_if_distributed(g, grads, 8).makespan
    zero = whatif.cluster_what_if_zero(g, grads, 8).makespan
    _check(golden, "zero_speedup_over_ddp", ddp / zero)


def test_cluster_straggler_golden(golden, setup):
    g, grads = setup
    ddp = whatif.cluster_what_if_distributed(g, grads, 8).makespan
    strag = whatif.cluster_what_if_straggler(g, grads, 8, straggler=0,
                                             slowdown=2.0).makespan
    _check(golden, "cluster_straggler_2x_slowdown", ddp / strag)
