"""Serving-scenario subsystem acceptance tests (ISSUE 7).

Pins the subsystem's contracts:

* static-batch drain-time invariant: one full batch at t=0 simulates to
  ``sum(prefill_i) + budget * decode_step`` to float precision, and the
  exported serving trace self-diffs to ~zero error;
* seed determinism: same seed -> bit-identical ServingPrediction metrics;
* continuous batching beats static slots at saturating rate (>1x goodput),
  with the headroom bound covering the realized speedup — golden-frozen in
  ``tests/golden/serving.json``;
* stacks compose through the registry (``continuous_batching,
  chunked_prefill,tp:degree=2`` routes through the real cluster simulator
  with ring-wired per-step all-reduces) and ``critical_path`` diagnosis
  works unchanged on serving graphs.
"""

import json
import os

import pytest

from repro.analysis import diff_graph
from repro.analysis.opportunity import opportunity_bound
from repro.core import Stack, available, get_optimization, parse_stack
from repro.serving import (ContinuousBatching, ServingCostModel,
                           ServingPolicy, ServingPrediction, ServingScenario,
                           build_serving_graph, explicit_workload,
                           format_serving_table, poisson_workload,
                           scale_arrivals, slot_lane, trace_workload)

_GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "serving.json")

COST = ServingCostModel()


@pytest.fixture(scope="module")
def golden():
    with open(_GOLDEN) as f:
        return json.load(f)


def _check(golden, key, value):
    want = golden[key]["value"]
    assert value == pytest.approx(want, rel=golden[key]["rtol"]), (
        f"{key}: got {value!r}, golden {want!r} — if the change is "
        f"intentional, re-freeze tests/golden/serving.json")


def saturating_scenario(golden) -> ServingScenario:
    p = golden["saturating_workload"]
    wl = poisson_workload(p["rate"], p["duration"], seed=p["seed"],
                          prompt_mean=p["prompt_mean"],
                          prompt_sigma=p["prompt_sigma"],
                          output_mean=p["output_mean"],
                          output_sigma=p["output_sigma"])
    return ServingScenario(workload=wl,
                           policy=ServingPolicy(mode="static",
                                                slots=p["slots"]),
                           serving_cost=COST)


# ------------------------------------------------------------- invariants
class TestStaticDrainInvariant:
    def test_single_full_batch_drain_time(self):
        """Acceptance: simulated makespan of one full batch arriving at
        t=0 equals the analytic prefill + budget*decode_step drain time to
        float precision (see repro.serving.graphgen module docstring)."""
        slots, prompt, budget = 4, 100, 16
        wl = explicit_workload([(0.0, prompt, budget)] * slots)
        scn = ServingScenario(
            workload=wl, policy=ServingPolicy(mode="static", slots=slots),
            serving_cost=COST)
        kv = slots * (prompt + budget)
        analytic = slots * COST.prefill_time(prompt) \
            + budget * COST.decode_step_time(slots, kv)
        assert scn.baseline().makespan == pytest.approx(analytic, rel=1e-12)

    def test_uneven_budgets_drain_to_max(self):
        """Finished slots idle until the batch drains (seed semantics):
        the drain time is set by the max member budget."""
        wl = explicit_workload([(0.0, 50, 4), (0.0, 50, 12)])
        scn = ServingScenario(
            workload=wl, policy=ServingPolicy(mode="static", slots=2),
            serving_cost=COST)
        kv = 2 * 50 + 4 + 12
        analytic = 2 * COST.prefill_time(50) \
            + 12 * COST.decode_step_time(2, kv)
        assert scn.baseline().makespan == pytest.approx(analytic, rel=1e-12)

    def test_self_diff_is_zero(self, tmp_path):
        """Exporting the predicted serving timeline and diffing the graph
        against its own export round-trips with ~zero error."""
        from repro import traceio
        sg = build_serving_graph(
            poisson_workload(100, 0.2, seed=3, prompt_mean=32,
                             output_mean=8),
            COST, ServingPolicy(mode="continuous", slots=4))
        from repro.core import simulate
        res = simulate(sg.graph)
        path = str(tmp_path / "serving.trace.json")
        traceio.export_graph_trace(sg.graph, res, path)
        diff = diff_graph(sg.graph, res, path)
        assert not diff.unmatched_predicted and not diff.unmatched_captured
        assert diff.max_abs_error() <= 1e-9
        assert abs(diff.makespan_rel_error) <= 1e-9


class TestDeterminism:
    def test_same_seed_bit_identical_prediction(self, golden):
        a = saturating_scenario(golden).predict("continuous_batching")
        b = saturating_scenario(golden).predict("continuous_batching")
        assert a.predicted == b.predicted
        assert (a.ttft_p50, a.ttft_p99, a.tpot_p50, a.tpot_p99,
                a.latency_p50, a.latency_p99, a.goodput) == \
               (b.ttft_p50, b.ttft_p99, b.tpot_p50, b.tpot_p99,
                b.latency_p50, b.latency_p99, b.goodput)
        assert a.lane_util == b.lane_util

    def test_different_seed_differs(self):
        w1 = poisson_workload(100, 0.5, seed=0)
        w2 = poisson_workload(100, 0.5, seed=1)
        assert [r.arrival for r in w1.requests] != \
               [r.arrival for r in w2.requests]


# ------------------------------------------------------------ what-ifs
class TestWhatIfs:
    def test_continuous_beats_static_at_saturation(self, golden):
        """Acceptance: continuous batching >1x predicted goodput over
        static slots at saturating rate, bound >= realized."""
        scn = saturating_scenario(golden)
        noop = scn.predict("noop")
        cb = scn.predict("continuous_batching")
        assert isinstance(cb, ServingPrediction)
        assert cb.goodput > noop.goodput
        assert cb.speedup > 1.0
        bound = opportunity_bound(scn, ContinuousBatching())
        assert bound >= cb.speedup
        _check(golden, "cb_vs_static_goodput", cb.goodput / noop.goodput)
        _check(golden, "cb_speedup", cb.speedup)
        _check(golden, "cb_headroom_bound", bound)

    def test_chunked_prefill_ttft_win(self, golden):
        """Short interactive requests stuck behind huge prompts: chunking
        the prefill removes the stall and improves TTFT p50/p99."""
        specs, t = [], 0.0
        for i in range(60):
            t += 0.002
            specs.append((t, 4096, 8) if i % 15 == 7 else (t, 32, 16))
        wl = explicit_workload(specs, duration=t)
        scn = ServingScenario(
            workload=wl, policy=ServingPolicy(mode="continuous", slots=8),
            serving_cost=COST)
        plain = scn.predict("noop")
        chunked = scn.predict("chunked_prefill:chunk=256")
        assert chunked.ttft_p99 < plain.ttft_p99
        assert chunked.ttft_p50 < plain.ttft_p50
        _check(golden, "chunked_ttft_p99_win",
               plain.ttft_p99 / chunked.ttft_p99)
        _check(golden, "chunked_ttft_p50_win",
               plain.ttft_p50 / chunked.ttft_p50)

    def test_stack_with_tp_routes_through_cluster(self, golden):
        """continuous_batching,chunked_prefill,tp:degree=2 composes: TP
        shards the cost model, the graph routes through ClusterGraph with
        per-step all-reduce rings, and critical-path diagnosis works."""
        scn = saturating_scenario(golden)
        pred = scn.predict("continuous_batching,chunked_prefill:chunk=64,"
                           "tp:degree=2")
        assert pred.cluster is not None
        names = [t.name for t in pred.graph.tasks()]
        assert any("tp-ar" in n and ":leg" in n for n in names), \
            "per-step all-reduces should be ring-wired by the cluster"
        cp = pred.critical_path
        assert cp.makespan == pytest.approx(pred.predicted, rel=1e-9)

    def test_sweep_grid_returns_serving_predictions(self, golden):
        scn = saturating_scenario(golden)
        preds = scn.sweep("continuous_batching", {"slots": [4, 8, 16]})
        assert len(preds) == 3
        assert all(isinstance(p, ServingPrediction) for p in preds)
        assert all(p.tokens_generated ==
                   scn.workload.total_output_tokens for p in preds)

    def test_headroom_floor_is_last_arrival(self, golden):
        """Erasing all engine work leaves the open-loop arrival chain:
        the idealized makespan is exactly the last arrival."""
        scn = saturating_scenario(golden)
        from repro.analysis.opportunity import _Headroom
        pred = scn.predict(_Headroom(ContinuousBatching()))
        assert pred.predicted == pytest.approx(scn.workload.last_arrival,
                                               rel=1e-12)


# --------------------------------------------------------------- policy
class TestPolicy:
    def test_kv_capacity_caps_static_batch(self):
        """A tight KV budget admits fewer requests per batch than slots."""
        wl = explicit_workload([(0.0, 100, 10)] * 4)
        cap = 2 * 110 + 1          # fits two requests, not four
        tight = ServingScenario(
            workload=wl, serving_cost=COST,
            policy=ServingPolicy(mode="static", slots=4,
                                 kv_capacity_tokens=cap))
        assert tight._sgraph.num_batches == 2
        roomy = ServingScenario(
            workload=wl, serving_cost=COST,
            policy=ServingPolicy(mode="static", slots=4))
        assert roomy._sgraph.num_batches == 1

    def test_kv_offload_adds_dma_and_admits(self):
        wl = explicit_workload([(0.0, 100, 10)] * 4)
        cap = 2 * 110 + 1
        scn = ServingScenario(
            workload=wl, serving_cost=COST,
            policy=ServingPolicy(mode="static", slots=4,
                                 kv_capacity_tokens=cap))
        off = scn.predict("kv_offload")
        sg = scn.serving_graph("kv_offload")
        assert sg.num_batches == 1        # admits past the cap
        assert any(t.attrs.get("serving") == "dma"
                   for t in sg.graph.tasks())
        assert off.predicted > 0

    def test_token_conservation_all_modes(self):
        wl = poisson_workload(150, 0.3, seed=7, prompt_mean=32,
                              output_mean=8)
        for policy in (ServingPolicy(mode="static", slots=4),
                       ServingPolicy(mode="continuous", slots=4),
                       ServingPolicy(mode="continuous", slots=4,
                                     prefill_chunk=16)):
            sg = build_serving_graph(wl, COST, policy)
            assert sg.tokens_emitted == {
                r.rid: r.output_tokens for r in wl.requests}, policy.mode

    def test_slot_lanes_and_utilization(self, golden):
        scn = saturating_scenario(golden)
        pred = scn.predict("continuous_batching")
        assert any(th.startswith("slot:") for th in pred.lane_util)
        assert all(0.0 <= u <= 1.0 + 1e-9 for u in pred.lane_util.values())
        assert slot_lane(0) in pred.lane_util


# -------------------------------------------------------------- registry
class TestRegistry:
    def test_serving_opts_registered_and_roundtrip(self):
        for name in ("continuous_batching", "static_slots",
                     "chunked_prefill", "tp", "kv_offload"):
            assert name in available()
            cls = get_optimization(name)
            opt = cls()
            parsed, over = parse_stack(opt.spec())
            assert parsed == opt and over == {}

    def test_serving_opt_on_training_scenario_raises(self):
        from repro.core import Scenario, OptimizationError
        from synthgraphs import training_step_graph
        scn = Scenario(training_step_graph(layers=2))
        with pytest.raises(OptimizationError, match="ServingScenario"):
            scn.predict("continuous_batching")

    def test_stack_order_folds_policy(self, golden):
        scn = saturating_scenario(golden)
        a = scn.predict("continuous_batching:slots=4,static_slots")
        b = scn.predict("static_slots")
        # rightmost serving member wins the mode; slots=4 persists
        sg = scn.serving_graph("continuous_batching:slots=4,static_slots")
        assert sg.policy.mode == "static" and sg.policy.slots == 4
        assert a.predicted != b.predicted or True  # both simulate fine


# ------------------------------------------------------------- workloads
class TestWorkloads:
    def test_trace_roundtrip(self, tmp_path):
        wl = poisson_workload(50, 0.2, seed=5)
        path = tmp_path / "reqs.jsonl"
        with open(path, "w") as f:
            for r in wl.requests:
                f.write(json.dumps({"rid": r.rid, "arrival": r.arrival,
                                    "prompt_tokens": r.prompt_tokens,
                                    "output_tokens": r.output_tokens})
                        + "\n")
        back = trace_workload(str(path))
        assert back.requests == wl.requests

    def test_scale_arrivals_compresses_clock(self):
        wl = poisson_workload(50, 0.2, seed=5)
        fast = scale_arrivals(wl, 0.5)
        assert fast.offered_rate() == pytest.approx(2 * wl.offered_rate())
        assert [r.prompt_tokens for r in fast.requests] == \
               [r.prompt_tokens for r in wl.requests]

    def test_bad_inputs_raise(self):
        with pytest.raises(ValueError):
            poisson_workload(0, 1.0)
        with pytest.raises(ValueError):
            explicit_workload([(0.0, 0, 4)])
        with pytest.raises(ValueError):
            ServingPolicy(mode="banana")


# ------------------------------------------------------------------- CLI
class TestCLI:
    def test_serve_sim_table(self, capsys):
        from repro.launch import serve_sim
        rc = serve_sim.main(["--model", "tinyllama_1.1b", "--smoke",
                             "--rate", "20", "--duration", "0.5",
                             "--what-if", "continuous_batching"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "goodput" in out and "continuous_batching" in out

    def test_serve_sim_json(self, capsys):
        from repro.launch import serve_sim
        rc = serve_sim.main(["--model", "tinyllama-1.1b", "--smoke",
                             "--rate", "20", "--duration", "0.5",
                             "--json"])
        assert rc == 0
        data = json.loads(capsys.readouterr().out)
        assert data[0]["spec"].startswith("noop")
        assert data[0]["tokens_generated"] > 0

    def test_format_table(self, golden):
        scn = saturating_scenario(golden)
        table = format_serving_table([scn.predict("noop")])
        assert "ttft p50" in table and "noop" in table
