"""Unit tests for the seed runtime fault-tolerance layer (ISSUE 10, sat. 2).

``repro.runtime.fault`` shipped with the seed untested; these pin its
contracts so the goodput simulator's recovery assumptions (detection via
heartbeat staleness, bounded retry budget with exponential backoff,
rolling-median straggler flagging) match what the runtime actually does:

* ``RetryPolicy`` / ``FaultTolerantRunner`` — restart-from-checkpoint
  accounting: failures count against the budget, exceeding it re-raises,
  recovery resumes from the last committed step (or from scratch when no
  checkpoint exists), and backoff grows geometrically then resets after a
  clean step;
* ``StragglerMonitor`` — needs >= 5 samples before flagging, compares
  against the rolling-median window, and invokes the mitigation hook with
  (step, dt, median);
* ``Heartbeat`` — atomic JSON liveness file, staleness detection, and
  interval-based write suppression.
"""

import json
import os
import time

import pytest

from repro.runtime.fault import (FaultTolerantRunner, Heartbeat, RetryPolicy,
                                 StragglerMonitor)


class _Ckpt:
    """In-memory checkpoint store with save/restore hooks for the runner."""

    def __init__(self):
        self.saved = []          # (state, step) commits, in order
        self.restores = 0

    def save(self, state, step):
        self.saved.append((state, step))

    def restore(self):
        self.restores += 1
        return self.saved[-1] if self.saved else None


def _no_sleep(monkeypatch):
    naps = []
    monkeypatch.setattr(time, "sleep", lambda s: naps.append(s))
    return naps


class TestFaultTolerantRunner:
    def test_clean_run_saves_on_schedule_and_at_end(self):
        ck = _Ckpt()
        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                save_every=4)
        out = r.run(10)
        assert out == 10
        assert r.failures == 0 and r.restarts == 0
        # commits after steps 3, 7 and the final step 9
        assert [step for _, step in ck.saved] == [3, 7, 9]

    def test_failure_restores_last_commit_and_counts(self, monkeypatch):
        naps = _no_sleep(monkeypatch)
        ck = _Ckpt()
        fired = []

        def boom(i):
            if i == 6 and not fired:
                fired.append(i)
                raise RuntimeError("injected")

        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                save_every=4,
                                policy=RetryPolicy(max_failures=3,
                                                   backoff_s=0.5))
        out = r.run(10, inject_failure=boom)
        # steps 4,5 are replayed after restoring the step-3 commit: the
        # final state only reflects committed + replayed work.
        assert out == 10
        assert r.failures == 1 and r.restarts == 1
        assert naps == [0.5]

    def test_no_checkpoint_restarts_from_scratch(self, monkeypatch):
        _no_sleep(monkeypatch)
        ck = _Ckpt()
        fired = []

        def boom(i):
            if i == 2 and not fired:
                fired.append(i)
                raise RuntimeError("early crash")

        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                save_every=100)
        out = r.run(5, inject_failure=boom)
        assert out == 5
        assert r.restarts == 1
        # one probe before the loop, one after the failure
        assert ck.restores == 2

    def test_budget_exhaustion_reraises(self, monkeypatch):
        naps = _no_sleep(monkeypatch)
        ck = _Ckpt()

        def always(i):
            raise RuntimeError("persistent fault")

        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                policy=RetryPolicy(max_failures=2,
                                                   backoff_s=0.1,
                                                   backoff_mult=3.0))
        with pytest.raises(RuntimeError, match="persistent fault"):
            r.run(5, inject_failure=always)
        # budget of 2 absorbed, third failure re-raised without sleeping
        assert r.failures == 3
        assert naps == pytest.approx([0.1, 0.3])

    def test_backoff_resets_after_clean_step(self, monkeypatch):
        naps = _no_sleep(monkeypatch)
        ck = _Ckpt()
        fired = []

        def flaky(i):
            # two bursts separated by clean steps
            if i in (1, 3) and fired.count(i) < 1:
                fired.append(i)
                raise RuntimeError("transient")

        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                save_every=1,
                                policy=RetryPolicy(max_failures=5,
                                                   backoff_s=0.2,
                                                   backoff_mult=2.0))
        out = r.run(5, inject_failure=flaky)
        assert out == 5
        # each burst is a single failure after clean steps, so the backoff
        # restarts at backoff_s both times instead of compounding
        assert naps == pytest.approx([0.2, 0.2])

    def test_resume_from_existing_checkpoint(self):
        ck = _Ckpt()
        ck.saved.append((7, 6))   # state 7 committed at step 6
        r = FaultTolerantRunner(make_state=lambda: 0,
                                step_fn=lambda s, i: s + 1,
                                save=ck.save, restore=ck.restore,
                                save_every=100)
        out = r.run(10)
        # resumes at step 7, runs 7..9 on top of the restored state
        assert out == 7 + 3


class TestStragglerMonitor:
    def test_needs_five_samples_before_flagging(self):
        m = StragglerMonitor(threshold=2.0)
        for step in range(4):
            assert m.record(step, 100.0) is False   # warm-up, never flags
        assert m.flagged == []

    def test_flags_above_threshold_times_median_and_calls_hook(self):
        calls = []
        m = StragglerMonitor(threshold=2.0,
                             on_straggler=lambda s, dt, med:
                             calls.append((s, dt, med)))
        for step in range(5):
            m.record(step, 1.0)
        assert m.record(5, 1.9) is False            # below 2x median
        assert m.record(6, 2.5) is True
        assert m.flagged == [6]
        assert calls == [(6, 2.5, 1.0)]

    def test_rolling_window_adapts_median(self):
        m = StragglerMonitor(threshold=2.0, window=4)
        for step in range(8):
            m.record(step, 1.0)
        for step in range(8, 12):
            m.record(step, 10.0)    # regime shift fills the window
        # 10s is the new normal: median of the last 4 is 10, so 15 < 2x
        assert m.record(12, 15.0) is False
        assert m.median() == pytest.approx(1.0)     # all-time median lags

    def test_median_empty(self):
        assert StragglerMonitor().median() == 0.0


class TestHeartbeat:
    def test_beat_writes_atomic_json(self, tmp_path):
        path = str(tmp_path / "hb.json")
        hb = Heartbeat(path, interval_s=0.0)
        hb.beat(12, loss=0.5)
        with open(path) as f:
            beat = json.load(f)
        assert beat["step"] == 12 and beat["loss"] == 0.5
        assert not os.path.exists(path + ".tmp")
        assert Heartbeat.is_alive(path, timeout_s=60.0)

    def test_interval_suppresses_rewrites(self, tmp_path):
        path = str(tmp_path / "hb.json")
        hb = Heartbeat(path, interval_s=3600.0)
        hb.beat(1)
        hb.beat(2)      # within the interval: suppressed
        with open(path) as f:
            assert json.load(f)["step"] == 1

    def test_staleness_and_missing_file(self, tmp_path):
        path = str(tmp_path / "hb.json")
        assert Heartbeat.is_alive(path) is False            # missing
        with open(path, "w") as f:
            json.dump({"time": time.time() - 120.0, "step": 3}, f)
        assert Heartbeat.is_alive(path, timeout_s=60.0) is False    # stale
        assert Heartbeat.is_alive(path, timeout_s=300.0) is True

    def test_corrupt_file_is_dead(self, tmp_path):
        path = str(tmp_path / "hb.json")
        with open(path, "w") as f:
            f.write("{not json")
        assert Heartbeat.is_alive(path) is False
        with open(path, "w") as f:
            json.dump({"step": 3}, f)                       # no "time" key
        assert Heartbeat.is_alive(path) is False
