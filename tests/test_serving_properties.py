"""Hypothesis properties of the serving simulator (ISSUE 7 satellites).

* latency is monotone non-decreasing in arrival rate: compressing the
  arrival clock of the *same* request population (``scale_arrivals``) must
  not reduce aggregate latency;
* token conservation: every request's generated token count equals its
  requested budget once the workload drains, under every policy;
* low-utilization closed form: when requests are spaced far wider than
  their service time, there is no queueing and each request's TTFT is
  exactly ``prefill(prompt) + decode_step(1, kv)``.

Skipped wholesale when the optional ``hypothesis`` dev dependency is
absent, matching the other ``test_*_properties.py`` modules.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from hypothesis import given, settings

from repro.core import simulate
from repro.serving import (ServingCostModel, ServingPolicy, ServingScenario,
                           build_serving_graph, explicit_workload,
                           poisson_workload, scale_arrivals)

COST = ServingCostModel()

policies = st.sampled_from([
    ServingPolicy(mode="static", slots=4),
    ServingPolicy(mode="continuous", slots=4),
    ServingPolicy(mode="continuous", slots=4, prefill_chunk=16),
    ServingPolicy(mode="continuous", slots=2, kv_capacity_tokens=400.0,
                  kv_offload=True),
])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), policy=policies,
       factor=st.floats(0.2, 0.9))
def test_latency_monotone_in_rate(seed, policy, factor):
    """Compressing arrivals (higher rate, same requests) must not reduce
    the mean end-to-end latency.  Aggregate, not pointwise: admission
    reshuffling can help an individual request, never the population."""
    wl = poisson_workload(80, 0.25, seed=seed, prompt_mean=24,
                          output_mean=6, output_sigma=0.3)
    if not wl.requests:
        return
    faster = scale_arrivals(wl, factor)

    def mean_latency(w):
        scn = ServingScenario(workload=w, policy=policy, serving_cost=COST)
        sg = scn._sgraph
        res = scn.baseline()
        last = {}
        for t in sg.graph.tasks():
            if t.attrs.get("serving") == "decode":
                rid = t.attrs["rid"]
                f = res.finish[t.uid]
                if rid not in last or f > last[rid]:
                    last[rid] = f
        return sum(last[r.rid] - r.arrival for r in w.requests) / len(w)

    assert mean_latency(faster) >= mean_latency(wl) - 1e-9


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), policy=policies)
def test_token_conservation(seed, policy):
    """generated == requested at drain, for every request, every policy."""
    wl = poisson_workload(120, 0.25, seed=seed, prompt_mean=24,
                          output_mean=6)
    sg = build_serving_graph(wl, COST, policy)
    assert sg.tokens_emitted == {r.rid: r.output_tokens
                                 for r in wl.requests}
    # and the graph really contains exactly that many decode tasks
    n = sum(1 for t in sg.graph.tasks()
            if t.attrs.get("serving") == "decode")
    assert n == wl.total_output_tokens


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), prompt=st.integers(1, 64),
       out=st.integers(1, 8),
       mode=st.sampled_from(["static", "continuous"]))
def test_low_utilization_ttft_closed_form(n, prompt, out, mode):
    """No queueing: spacing >> service time means each request runs alone
    and TTFT is exactly prefill(prompt) + one single-slot decode step."""
    service = COST.prefill_time(prompt) \
        + out * COST.decode_step_time(1, prompt + out)
    gap = 10.0 * service + 1e-3
    wl = explicit_workload([(1e-3 + i * gap, prompt, out)
                            for i in range(n)])
    scn = ServingScenario(workload=wl, serving_cost=COST,
                          policy=ServingPolicy(mode=mode, slots=4))
    res = scn.baseline()
    first = {}
    for t in scn._sgraph.graph.tasks():
        if t.attrs.get("serving") == "decode" and t.attrs["tok"] == 0:
            first[t.attrs["rid"]] = res.finish[t.uid]
    # static decodes against the batch's full reserved footprint; the
    # continuous engine's first step reads only the resident prompt KV
    kv = prompt + out if mode == "static" else prompt
    expect = COST.prefill_time(prompt) + COST.decode_step_time(1, kv)
    for r in wl.requests:
        ttft = first[r.rid] - r.arrival
        assert ttft == pytest.approx(expect, rel=1e-9), r
