"""End-to-end behaviour tests: training converges, checkpoint resume works,
the serving engine generates, and the dry-run path lowers+compiles sharded
cells in a fresh multi-device subprocess."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import make_batch
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig


def _batches(cfg, seq, batch):
    step = 0
    while True:
        yield make_batch(cfg, seq_len=seq, batch=batch, step=step)
        step += 1


def test_training_reduces_loss():
    cfg = get_smoke_config("tinyllama-1.1b")
    tc = TrainerConfig(steps=40, log_every=0)
    tr = Trainer(cfg, tc, optimizer=AdamW(lr=3e-3))
    tr.fit(_batches(cfg, 64, 8))
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    assert all(np.isfinite(l) for l in losses)


def test_checkpoint_resume_continues(tmp_path):
    cfg = get_smoke_config("llama3.2-1b")
    tc = TrainerConfig(steps=6, log_every=0, ckpt_every=3,
                       ckpt_dir=str(tmp_path), ckpt_async=False)
    tr = Trainer(cfg, tc, optimizer=AdamW(lr=1e-3))
    tr.fit(_batches(cfg, 32, 4), steps=6)
    assert tr.ckpt.latest_step() is not None
    # a "restarted" trainer resumes from the checkpoint step
    tr2 = Trainer(cfg, tc, optimizer=AdamW(lr=1e-3))
    state = tr2.restore_or_init()
    assert int(jax.device_get(state["step"])) == 6


def test_serve_engine_generates():
    from repro.serve import ServeEngine, Request
    cfg = get_smoke_config("tinyllama-1.1b")
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=48)
    out = eng.generate([Request(prompt=[3, 5, 7], max_new_tokens=8),
                        Request(prompt=[11, 13], max_new_tokens=8)])
    assert len(out) == 2 and all(len(r.tokens) == 8 for r in out)


_DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys, json
    sys.path.insert(0, {src!r})
    import jax
    from repro.configs import get_smoke_config, registry
    from repro.launch.mesh import make_mesh
    from repro.launch.cell import build_cell
    from repro.core.hlo import parse_hlo_module, aggregate_costs

    results = {{}}
    for mesh_shape, axes in [((2, 2), ("data", "model")),
                             ((2, 2, 2), ("pod", "data", "model"))]:
        mesh = make_mesh(mesh_shape, axes)
        for arch, shape_name, seq, gb in {cells!r}:
            cfg = get_smoke_config(arch)
            kind = registry.SHAPES[shape_name].kind
            spec = registry.ShapeSpec(shape_name, seq, gb, kind)
            from repro import compat
            with compat.set_mesh(mesh):
                cell = build_cell(cfg, spec, mesh)
                compiled = cell.lower().compile()
                agg = aggregate_costs(parse_hlo_module(compiled.as_text()))
            results[f"{{arch}}:{{shape_name}}:{{len(mesh.devices.flatten())}}"] = agg["flops"]
    print(json.dumps(results))
""")


def test_dryrun_cells_lower_and_compile_sharded(tmp_path):
    """The dry-run path (sharded lower+compile, ShapeDtypeStruct inputs) on a
    16-host-device subprocess, covering every step kind and several families.
    """
    cells = [
        ("tinyllama-1.1b", "train_4k", 64, 8),
        ("moonshot-v1-16b-a3b", "train_4k", 64, 8),
        ("deepseek-v2-236b", "decode_32k", 64, 8),
        ("mamba2-2.7b", "long_500k", 128, 8),
        ("recurrentgemma-9b", "decode_32k", 64, 8),
        ("seamless-m4t-large-v2", "prefill_32k", 64, 8),
        ("internvl2-1b", "train_4k", 64, 8),
    ]
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _DRYRUN_SNIPPET.format(src=os.path.abspath(src), cells=cells)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    results = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(results) == 2 * len(cells)
    assert all(v > 0 for v in results.values())
