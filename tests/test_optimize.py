"""Unified optimization/scenario API (repro.core.optimize) acceptance tests.

Covers the composition semantics the ISSUE pins down:

* ``Stack`` associativity (flattening) and ``A | B`` == manual
  ``what_if_a`` -> ``what_if_b`` chaining to float precision;
* registry round-trip: every registered optimization is constructible from
  the CLI's ``name:param=value`` string form and survives spec() -> parse;
* sweep-reuse equivalence: swept points match independent per-point
  rebuilds (both cluster-route retunes and single-graph retunes);
* ``collective_mode`` threads through every cluster wrapper (the bug the
  old free functions had).
"""

import pytest

from repro.core import (Scenario, Stack, WorkerSpec, whatif,
                        available, get_optimization, parse_stack,
                        OptimizationError)
from repro.core.optimize import (DDP, AMP, Bandwidth, ZeRO, Straggler,
                                 default_candidates, greedy_search,
                                 straggler_specs, uniform_bandwidth_specs)
from synthgraphs import training_step_graph

LAYERS = 6
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}
ACTS = {f"l{i}": 4e6 for i in range(LAYERS)}

# constructor kwargs for registered optimizations with required params —
# the registry round-trip test fails if a new registered opt is missing
REQUIRED = {
    "p3": {"bandwidth": 5e9},
    "blueconnect": {"axes": (("data", 4), ("model", 4))},
    "remove_layer": {"layer_pattern": "l1"},
    "scale_layer": {"layer_pattern": "l1", "scale": 0.5},
    "offload": {"layer_pattern": "l"},
    "gist": {"layer_pattern": "l"},
}


@pytest.fixture()
def graph():
    return training_step_graph(layers=LAYERS)


@pytest.fixture()
def scenario(graph):
    return Scenario(graph, layer_grad_bytes=GRADS, activation_bytes=ACTS,
                    workers=8)


class TestRegistry:
    def test_every_registered_opt_constructible(self):
        for name in available():
            cls = get_optimization(name)
            opt = cls(**REQUIRED.get(name, {}))
            assert opt.name == name

    def test_roundtrip_spec_parse(self):
        """spec() -> parse_stack() reproduces every registered opt."""
        for name in available():
            cls = get_optimization(name)
            opt = cls(**REQUIRED.get(name, {}))
            parsed, overrides = parse_stack(opt.spec())
            assert parsed == opt, name
            assert overrides == {}

    def test_cli_stack_form(self):
        opt, over = parse_stack("amp,ddp:workers=16,zero")
        assert isinstance(opt, Stack)
        assert [o.name for o in opt.opts] == ["amp", "ddp", "zero"]
        assert over == {"workers": 16}

    def test_cli_param_typing(self):
        opt, _ = parse_stack("ddp:bucket_bytes=1e6")
        assert opt.bucket_bytes == pytest.approx(1e6)
        opt, _ = parse_stack("amp:matmul_speedup=2")
        assert isinstance(opt.matmul_speedup, float)

    def test_unknown_name_and_param_raise(self):
        with pytest.raises(OptimizationError):
            parse_stack("warp_drive")
        with pytest.raises(OptimizationError):
            parse_stack("amp:warp=9")

    def test_aliases_resolve(self):
        assert get_optimization("fusedadam") is get_optimization(
            "fused_optimizer")
        assert get_optimization("vdnn") is get_optimization("offload")
        assert get_optimization("distributed") is get_optimization("ddp")


class TestComposition:
    def test_stack_flattens_associatively(self):
        a, b, c = AMP(), Bandwidth(factor=2.0), ZeRO()
        assert ((a | b) | c) == (a | (b | c)) == Stack(a, b, c)

    def test_stacked_prediction_associative(self, scenario):
        a, b, c = AMP(), DDP(), ZeRO()
        left = scenario.predict((a | b) | c).predicted
        right = scenario.predict(a | (b | c)).predicted
        assert left == right

    def test_amp_ddp_stack_matches_manual_chain(self, graph):
        """`AMP | DDP` == what_if_amp -> what_if_distributed chaining."""
        s = Scenario(graph, layer_grad_bytes=GRADS, workers=16)
        pred = s.predict(AMP() | DDP())
        tf1 = whatif.what_if_amp(graph)
        tf2 = whatif.what_if_distributed(tf1.graph, GRADS, 16)
        manual = tf2.simulate().makespan
        assert pred.predicted == pytest.approx(manual, rel=1e-12)

    def test_wrapper_equals_registry_route(self, graph):
        via_wrapper = whatif.what_if_amp(graph).simulate().makespan
        via_registry = Scenario(graph).predict("amp").predicted
        assert via_wrapper == via_registry

    def test_prediction_fields(self, scenario):
        pred = scenario.predict("ddp")
        assert pred.baseline == scenario.baseline().makespan
        assert pred.speedup == pred.baseline / pred.predicted
        assert pred.cluster is None     # workers=8 int -> analytical route

    def test_cluster_route_by_worker_spec(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(4)])
        pred = s.predict("ddp")
        assert pred.cluster is not None
        assert len(pred.cluster.per_worker) == 4
        # uniform cluster == analytical single-graph prediction
        single = Scenario(graph, layer_grad_bytes=GRADS,
                          workers=4).predict("ddp")
        assert pred.predicted == pytest.approx(single.predicted, rel=1e-9)

    def test_missing_byte_maps_raise(self, graph):
        with pytest.raises(OptimizationError):
            Scenario(graph).predict("ddp")
        with pytest.raises(OptimizationError):
            Scenario(graph).predict("gist:layer_pattern=l")


class TestSweep:
    def test_cluster_bandwidth_sweep_matches_rebuilds(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(6)])
        grid = {"workers": uniform_bandwidth_specs(
            6, [0.25, 0.5, 1.0, 2.0, 4.0])}
        reused = s.sweep("ddp", grid, reuse=True)
        rebuilt = s.sweep("ddp", grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]
        # sanity: the retuned path matches the legacy wrapper too
        legacy = whatif.cluster_what_if_bandwidth(
            graph, GRADS, 6, scales=[0.5] * 6).makespan
        assert reused[1].predicted == pytest.approx(legacy, rel=1e-12)

    def test_cluster_straggler_sweep_matches_rebuilds(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS,
                     workers=[WorkerSpec() for _ in range(4)])
        grid = {"workers": straggler_specs(4, [1.0, 1.5, 2.0, 3.0])}
        reused = s.sweep("ddp", grid, reuse=True)
        rebuilt = s.sweep("ddp", grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]
        # slower straggler -> larger makespan
        ms = [p.predicted for p in reused]
        assert ms == sorted(ms)

    def test_single_graph_retune_sweep(self, graph):
        """Opts with a retune hook (bandwidth, straggler) rescale in place."""
        s = Scenario(whatif.what_if_distributed(graph, GRADS, 8).graph)
        for opt, grid in [
                (Bandwidth(factor=1.0),
                 {"factor": [0.25, 0.5, 1.0, 2.0, 4.0]}),
                (Straggler(), {"slowdown": [1.0, 1.5, 2.0]})]:
            reused = s.sweep(opt, grid, reuse=True)
            rebuilt = s.sweep(opt, grid, reuse=False)
            for a, b in zip(reused, rebuilt):
                assert a.predicted == pytest.approx(b.predicted, rel=1e-9)

    def test_opt_param_grid_rebuilds(self, graph):
        """Structural params (bucket_bytes) fall back to rebuild per point."""
        s = Scenario(graph, layer_grad_bytes=GRADS, workers=8)
        preds = s.sweep("ddp", {"bucket_bytes": [1e6, 30e6, 300e6]})
        assert len(preds) == 3
        assert all(p.point["bucket_bytes"] for p in preds)
        rebuilt = s.sweep("ddp", {"bucket_bytes": [1e6, 30e6, 300e6]},
                          reuse=False)
        assert [p.predicted for p in preds] == \
            [p.predicted for p in rebuilt]

    def test_worker_count_grid(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS)
        preds = s.sweep("ddp", {"workers": [2, 4, 8]})
        assert [p.point["workers"] for p in preds] == [2, 4, 8]
        for p, w in zip(preds, [2, 4, 8]):
            manual = whatif.what_if_distributed(
                graph, GRADS, w).simulate().makespan
            assert p.predicted == manual

    def test_explicit_point_list_and_bad_key(self, graph):
        s = Scenario(graph, layer_grad_bytes=GRADS, workers=4)
        preds = s.sweep("ddp", [{"bucket_bytes": 1e6}, {"workers": 8}])
        assert len(preds) == 2
        with pytest.raises(OptimizationError):
            s.sweep("ddp", {"warp": [1, 2]})


class TestCollectiveModeThreading:
    """Satellite fix: cluster_what_if_bandwidth / _p3 used to drop
    collective_mode on the floor."""

    def test_bandwidth_threads_mode(self, graph):
        ring = whatif.cluster_what_if_bandwidth(
            graph, GRADS, 4, scales=[1.0, 0.25, 1.0, 1.0])
        fused = whatif.cluster_what_if_bandwidth(
            graph, GRADS, 4, scales=[1.0, 0.25, 1.0, 1.0],
            collective_mode="fused")
        # ring: the slow link throttles legs crossing it; fused: only the
        # slow worker's own analytical collective stretches — different
        # numbers prove the kwarg reaches ClusterGraph.build
        assert ring.makespan != pytest.approx(fused.makespan, rel=1e-6)

    def test_p3_accepts_mode(self, graph):
        res = whatif.cluster_what_if_p3(graph, GRADS, 4, bandwidth=5e9,
                                        collective_mode="fused")
        assert res.makespan > 0

    def test_all_cluster_wrappers_accept_mode(self, graph):
        import inspect
        for fn in (whatif.cluster_what_if_distributed,
                   whatif.cluster_what_if_zero, whatif.cluster_what_if_p3,
                   whatif.cluster_what_if_straggler,
                   whatif.cluster_what_if_bandwidth):
            assert "collective_mode" in inspect.signature(fn).parameters, \
                fn.__name__


class TestRetune:
    def test_retune_matches_fresh_build_exactly(self, graph):
        from repro.core import ClusterGraph
        tf = whatif.what_if_distributed(graph, GRADS, 6)
        cg = ClusterGraph.build(tf.graph, 6)
        skew = [WorkerSpec(bandwidth_scale=0.5, compute_scale=1.5)
                if i == 2 else WorkerSpec() for i in range(6)]
        retuned = cg.retune(skew).simulate()
        fresh = ClusterGraph.build(tf.graph, skew).simulate()
        assert retuned.makespan == fresh.makespan
        assert retuned.worker_makespans() == fresh.worker_makespans()

    def test_retune_rejects_count_change_and_pod_change(self, graph):
        from repro.core import ClusterGraph, GraphError
        tf = whatif.what_if_distributed(graph, GRADS, 4)
        cg = ClusterGraph.build(tf.graph, 4)
        with pytest.raises(GraphError):
            cg.retune(8)
        assert not cg.can_retune(8)
        hier = ClusterGraph.build(tf.graph,
                                  [WorkerSpec(pod=i % 2) for i in range(4)],
                                  collective_mode="hierarchical")
        # stage durations are recomputable in place; only the pod *layout*
        # is structural
        assert hier.can_retune([WorkerSpec(pod=i % 2) for i in range(4)])
        assert not hier.can_retune([WorkerSpec(pod=i // 2) for i in range(4)])
        with pytest.raises(GraphError):
            hier.retune([WorkerSpec(pod=i // 2) for i in range(4)])

    def test_hierarchical_retune_matches_fresh_build(self, graph):
        """Satellite (PR 3): hierarchical stage durations retune in place —
        sweeps over bandwidth/compute scales reuse one build, bit-identically
        to rebuilding per point, as long as the pod layout is fixed."""
        from repro.core import ClusterGraph
        tf = whatif.what_if_distributed(graph, GRADS, 8)
        pods = [WorkerSpec(pod=i // 4) for i in range(8)]
        cg = ClusterGraph.build(tf.graph, pods,
                                collective_mode="hierarchical")
        skew = [WorkerSpec(pod=i // 4,
                           bandwidth_scale=0.5 if i == 2 else 1.0,
                           compute_scale=2.0 if i == 5 else 1.0)
                for i in range(8)]
        retuned = cg.retune(skew).simulate()
        fresh = ClusterGraph.build(tf.graph, skew,
                                   collective_mode="hierarchical").simulate()
        assert retuned.makespan == fresh.makespan
        assert retuned.worker_makespans() == fresh.worker_makespans()

    def test_sweep_reuses_hierarchical_build(self, graph):
        """The PR-2 sweep-reuse speedup now extends to hierarchical mode:
        same-pod-layout points retune one build with identical predictions."""
        scn = Scenario(graph, layer_grad_bytes=GRADS,
                       workers=[WorkerSpec(pod=i // 2) for i in range(4)],
                       collective_mode="hierarchical")
        grid = {"workers": [[WorkerSpec(pod=i // 2, bandwidth_scale=s)
                             for i in range(4)] for s in (1.0, 0.5, 0.25)]}
        reused = scn.sweep("ddp", grid, reuse=True)
        rebuilt = scn.sweep("ddp", grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]

    def test_stale_result_breakdown_survives_retune(self, graph):
        """A lazily-split ClusterResult must reflect the durations at its
        own simulate() time, not a later retune's."""
        from repro.core import ClusterGraph
        tf = whatif.what_if_distributed(graph, GRADS, 4)
        cg = ClusterGraph.build(tf.graph, 4)
        first = cg.simulate()
        eager = ClusterGraph.build(tf.graph, 4).simulate()
        _ = eager.per_worker        # split before any retune
        cg.retune([WorkerSpec(compute_scale=3.0)] + [WorkerSpec()] * 3)
        cg.simulate()
        for i in range(4):
            assert first.per_worker[i].thread_busy == \
                eager.per_worker[i].thread_busy


class TestGreedySearch:
    def test_search_improves_and_stacks(self, scenario):
        best, trail = greedy_search(scenario, max_depth=3)
        assert best is not None
        assert trail[-1].predicted < scenario.baseline().makespan
        # monotone improvement round over round
        ms = [p.predicted for p in trail]
        assert ms == sorted(ms, reverse=True)

    def test_candidates_skip_required_param_opts(self, scenario):
        names = {c.name for c in default_candidates(scenario)}
        assert "p3" not in names        # requires bandwidth
        assert "amp" in names
