"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,KH,S,D", [
    (1, 2, 1, 128, 64),
    (2, 4, 2, 256, 128),
    (1, 8, 2, 96, 80),        # non-multiple S and D (padding path)
    (1, 1, 1, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, KH, S, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KH, S, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("n", [100, 1024, 5000, 1 << 14])
def test_fused_adam_sweep(n):
    key = jax.random.PRNGKey(1)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (n,))) * 0.01
    kw = dict(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, c1=0.2, c2=0.1)
    po, mo, vo = ops.fused_adam(p, g, m, v, **kw)
    pr, mr, vr = ref.fused_adam_ref(p, g, m, v, **kw)
    np.testing.assert_allclose(po, pr, atol=1e-5)
    np.testing.assert_allclose(mo, mr, atol=1e-6)
    np.testing.assert_allclose(vo, vr, atol=1e-6)


@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 300), (16, 1024), (1, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],),
                          jnp.float32)
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("shape,ratio", [((100,), 0.1), ((123, 45), 0.01),
                                         ((4096,), 0.001)])
def test_dgc_threshold_matches_topk(shape, ratio):
    g = jax.random.normal(jax.random.PRNGKey(3), shape)
    want, k, thr = ref.dgc_topk_ref(g, ratio)
    got, cnt = ops.dgc_mask(g, thr)
    np.testing.assert_allclose(got, want, atol=0)
    assert int(cnt) >= k            # ties may keep extras


def test_fused_adam_multi_step_agrees_with_optimizer():
    """AdamW(fused=True) == AdamW(fused=False) over several steps."""
    from repro.optim import AdamW
    params = {"a": jnp.ones((130,)) * 0.3,
              "b": {"w": jnp.linspace(-1, 1, 77)}}
    grads = jax.tree.map(lambda p: p * 0.1 + 0.01, params)
    o1, o2 = AdamW(lr=1e-2), AdamW(lr=1e-2, fused=True)
    s1, s2 = o1.init(params), o2.init(params)
    p1 = p2 = params
    for _ in range(3):
        p1, s1 = o1.apply(grads, s1, p1)
        p2, s2 = o2.apply(grads, s2, p2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, atol=1e-5)
