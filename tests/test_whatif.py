"""Modeled optimizations (paper §5) — direction checks + measured ground truth.

The ground-truth tests mirror the paper's methodology (§6): predict the
speedup from the baseline trace, implement the optimization for real, measure
both, compare.  On this container the measurable substrate is the CPU
backend, so durations come from ``trace_measured`` (analytical relative
weights pinned to wall-clock) — the prediction-error targets follow the
paper's observed band (<=25% here vs their <=16% on GPU, CPU timers are
noisier).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModel, trace_compiled, trace_measured, simulate,
                        whatif, measure_wallclock, TaskKind)


@pytest.fixture(scope="module")
def lm_bundle():
    """A small named-scope LM-ish step traced from compiled HLO."""
    d, ff, v, bs, sq = 64, 256, 512, 4, 32
    key = jax.random.PRNGKey(0)
    W = {
        "emb": jax.random.normal(key, (v, d)) * 0.02,
        "w1": jax.random.normal(key, (d, ff)) * 0.05,
        "w2": jax.random.normal(key, (ff, d)) * 0.05,
    }

    def loss_fn(W, toks, labels):
        x = W["emb"][toks]
        for i in range(2):
            with jax.named_scope(f"blk{i}"):
                with jax.named_scope("mlp"):
                    h = jax.nn.gelu(x @ W["w1"])
                    x = x + h @ W["w2"]
        with jax.named_scope("loss"):
            logits = x @ W["emb"].T
            return -jnp.mean(jax.nn.log_softmax(logits)[
                jnp.arange(bs)[:, None], jnp.arange(sq)[None], labels])

    def step(W, toks, labels):
        with jax.named_scope("update"):
            g = jax.grad(loss_fn)(W, toks, labels)
            return jax.tree.map(lambda p, gg: p - 1e-3 * gg, W, g)

    toks = jnp.zeros((bs, sq), jnp.int32)
    labels = jnp.zeros((bs, sq), jnp.int32)
    return trace_compiled(step, W, toks, labels)


class TestDirections:
    def test_amp_speeds_up(self, lm_bundle):
        base = lm_bundle.simulate().makespan
        opt = whatif.what_if_amp(lm_bundle.graph).simulate().makespan
        assert opt < base

    def test_bandwidth_scaling_monotone(self, lm_bundle):
        g = whatif.what_if_distributed(
            lm_bundle.graph, {"blk0": 1e6, "blk1": 1e6}, num_workers=8).graph
        base = simulate(g).makespan
        faster = whatif.what_if_bandwidth(g, 4.0).simulate().makespan
        slower = whatif.what_if_bandwidth(g, 0.25).simulate().makespan
        assert faster <= base <= slower

    def test_dgc_reduces_comm(self, lm_bundle):
        g = whatif.what_if_distributed(
            lm_bundle.graph, {"blk0": 50e6, "blk1": 50e6},
            num_workers=32).graph
        base = simulate(g).makespan
        dgc = whatif.what_if_dgc(g, compression=0.01).simulate().makespan
        assert dgc < base

    def test_straggler_slows(self, lm_bundle):
        g = whatif.what_if_distributed(
            lm_bundle.graph, {"blk0": 1e6}, num_workers=8).graph
        base = simulate(g).makespan
        s = whatif.what_if_straggler(g, slowdown=2.0).simulate().makespan
        assert s > base

    def test_zero_replaces_allreduce(self, lm_bundle):
        g = whatif.what_if_distributed(
            lm_bundle.graph, {"blk0": 8e6, "blk1": 8e6}, num_workers=16).graph
        tf = whatif.what_if_zero(g, num_workers=16)
        colls = [t.attrs.get("collective") for t in tf.graph.tasks()
                 if t.kind == TaskKind.COLLECTIVE]
        assert "all-reduce" not in colls
        assert "reduce-scatter" in colls and "all-gather" in colls

    def test_blueconnect_decomposes(self, lm_bundle):
        g = whatif.what_if_distributed(
            lm_bundle.graph, {"blk0": 32e6}, num_workers=16).graph
        tf = whatif.what_if_blueconnect(g, [("data", 4), ("model", 4)])
        names = [t.name for t in tf.graph.tasks()]
        assert any("reduce-scatter" in n for n in names)
        assert any("all-gather" in n for n in names)
        tf.graph.validate()

    def test_p3_priority_helps_at_low_bandwidth(self, lm_bundle):
        grads = {"blk0": 20e6, "blk1": 20e6}
        bw = 1e9
        plain = whatif.what_if_p3(lm_bundle.graph, grads, 4, bandwidth=bw,
                                  priority=False).simulate().makespan
        prio = whatif.what_if_p3(lm_bundle.graph, grads, 4, bandwidth=bw,
                                 priority=True).simulate().makespan
        assert prio <= plain * 1.001

    def test_gist_and_offload_add_overhead(self, lm_bundle):
        base = lm_bundle.simulate().makespan
        act = {l: 4e6 for l in ("blk0", "blk1")}
        gist = whatif.what_if_gist(lm_bundle.graph, "blk",
                                   act).simulate().makespan
        off = whatif.what_if_offload(lm_bundle.graph, "blk",
                                     act).simulate().makespan
        assert gist >= base and off >= base

    def test_fused_norm_removes_tasks(self, lm_bundle):
        tf = whatif.what_if_fused_norm(lm_bundle.graph, norm_layer="mlp")
        assert len(tf.graph) <= len(lm_bundle.graph)


@pytest.mark.slow
class TestGroundTruth:
    """predict -> implement -> measure -> compare (paper §6 methodology).

    ``slow``-tier (run with ``pytest -m slow``): these compare predictions
    against *measured wall-clock ratios* of sub-10ms kernels, which are
    load-sensitive on a shared CPU no matter how wide the tolerance band
    (observed 1-in-3 in-module flake rate).  The fast tier keeps the
    deterministic prediction-side coverage: TestDirections above and the
    golden regressions in test_golden_speedups.py.
    """

    @staticmethod
    def _adam_chain(n: int, chunks: int, fused: bool):
        def unfused(p, g, m, v):
            # deliberately many small ops (the paper's 2633-kernel update)
            outs = []
            for chunk in range(chunks):
                sl = slice(chunk * n // chunks, (chunk + 1) * n // chunks)
                mm = 0.9 * m[sl] + 0.1 * g[sl]
                vv = 0.95 * v[sl] + 0.05 * g[sl] * g[sl]
                step = mm / (jnp.sqrt(vv) + 1e-8)
                outs.append(p[sl] - 1e-3 * step)
            return jnp.concatenate(outs)

        def fused_fn(p, g, m, v):
            mm = 0.9 * m + 0.1 * g
            vv = 0.95 * v + 0.05 * g * g
            return p - 1e-3 * (mm / (jnp.sqrt(vv) + 1e-8))

        return fused_fn if fused else unfused

    def test_fused_update_prediction_matches_measurement(self):
        """Paper §6.3 (FusedAdam), re-grounded for this substrate.

        The paper's 2633-small-kernel update cannot be reproduced here: XLA's
        CPU backend loop-fuses the whole chunked update into ONE kernel, so
        per-kernel dispatch overhead is already gone in the baseline.  The
        win that *is* measurable is the eliminated memory traffic: the
        chunked implementation materializes per-chunk outputs and re-reads
        them through ``concatenate`` (7n element moves: 4n reads + n chunk
        writes + n concat reads + n concat writes), while the flat fused
        kernel moves 5n (4n reads + n writes).  Predict by scaling the
        measured device task by the modeled traffic ratio, then measure
        ground truth for both variants.
        """
        n, chunks = 1 << 18, 64
        key = jax.random.PRNGKey(0)
        args = [jax.random.normal(jax.random.fold_in(key, i), (n,))
                for i in range(4)]
        unfused = self._adam_chain(n, chunks, False)
        fused = self._adam_chain(n, chunks, True)

        bundle = trace_measured(unfused, *args, iters=30)
        base_sim = bundle.simulate().makespan

        from repro.core.transform import GraphTransform, on_device
        tf = GraphTransform(bundle.graph)
        unfused_bytes = 7 * n * 4.0     # slices + chunk outs + concat r/w
        fused_bytes = 5 * n * 4.0       # read p,g,m,v + write out once
        tf.scale(on_device, fused_bytes / unfused_bytes)
        pred = tf.simulate().makespan
        pred_speedup = base_sim / pred

        # interleave the baseline measurement around the fused one so slow
        # machine-load drift cancels out of the ratio
        t_unfused_a = measure_wallclock(unfused, *args, iters=20)
        t_fused = measure_wallclock(fused, *args, iters=20)
        t_unfused_b = measure_wallclock(unfused, *args, iters=20)
        true_speedup = (t_unfused_a + t_unfused_b) / 2.0 / t_fused

        # directional + band agreement (CPU wall-clock is noisy; the fused
        # win here is ~1.1-1.7x and can dip under contention, so the
        # measured-direction bound is slack while the prediction stays strict)
        assert pred_speedup > 1.0
        assert true_speedup > 0.95
        rel_err = abs(pred_speedup - true_speedup) / true_speedup
        assert rel_err < 0.75, (pred_speedup, true_speedup)

    def test_amp_analogue_prediction(self):
        """Precision-halving analogue measurable on CPU: f64 -> f32.

        (bf16 is software-emulated on the CPU backend, so the GPU paper's
        fp32->fp16 pair maps to fp64->fp32 here: compute and memory both
        roughly halve, like AMP on tensor-core-less memory-bound kernels.)
        """
        import jax
        jax.config.update("jax_enable_x64", True)
        try:
            n = 384
            a64 = jnp.ones((n, n), jnp.float64)
            a32 = jnp.ones((n, n), jnp.float32)

            def chain(a):
                for _ in range(8):
                    a = jnp.tanh(a @ a * (1.0 / n))
                return a

            bundle = trace_measured(chain, a64, iters=10)
            base = bundle.simulate().makespan
            tf = whatif.what_if_amp(bundle.graph, matmul_speedup=2.0,
                                    memory_speedup=2.0)
            pred = base / tf.simulate().makespan
            t64 = measure_wallclock(chain, a64, iters=10)
            t32 = measure_wallclock(chain, a32, iters=10)
            true = t64 / t32
            assert pred > 1.0
            assert abs(pred - true) / true < 0.75, (pred, true)
        finally:
            jax.config.update("jax_enable_x64", False)
