"""Hypothesis properties for symmetry folding + incremental re-simulation.

Skipped when the optional ``hypothesis`` dev dependency is absent (same
policy as the other ``*_properties`` modules); the deterministic
seeded-random equivalents always run in ``test_fold.py``.

Properties pinned here:

* folded == materialized on randomized mixed clusters — uniform rings,
  pod-uniform hierarchical layouts, fused straggler mixes, and hybrid
  PP×DP plans — makespan to 1e-9 and per-class breakdowns equal to the
  per-worker rollups of the materialized build;
* incremental-vs-full re-simulation equivalence over random retune
  perturbations: whenever ``simulate_incremental`` engages, its timeline
  (start/finish/busy/makespan) is exactly the full replay's.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import ClusterGraph, WorkerSpec, fold_cluster
from repro.parallel.plan import ParallelPlan, StageProfile
from synthgraphs import training_step_graph

GRAPH = training_step_graph(layers=4)

scales = st.sampled_from([0.5, 0.75, 1.0, 1.5, 2.0])


def _assert_equiv(fg, cg):
    rf, rm = fg.simulate(), cg.simulate()
    assert rf.makespan == pytest.approx(rm.makespan, abs=1e-9)
    pw_f, pw_m = rf.per_worker, rm.per_worker
    assert set(pw_f) == set(pw_m)
    for w in pw_m:
        assert pw_f[w].makespan == pytest.approx(pw_m[w].makespan,
                                                 abs=1e-9)
        for k, v in pw_m[w].breakdown.items():
            assert pw_f[w].breakdown.get(k, 0.0) == pytest.approx(
                v, abs=1e-9)


@hypothesis.given(n=st.integers(2, 10), bw=scales,
                  mode=st.sampled_from(["ring", "fused", "hierarchical"]))
@hypothesis.settings(max_examples=40, deadline=None)
def test_uniform_cluster_folds_exactly(n, bw, mode):
    specs = [WorkerSpec(bandwidth_scale=bw) for _ in range(n)]
    fg = fold_cluster(GRAPH, specs, collective_mode=mode)
    assert fg is not None and fg.num_classes < n
    _assert_equiv(fg, ClusterGraph.build(GRAPH, specs,
                                         collective_mode=mode))


@hypothesis.given(pods=st.lists(st.tuples(st.integers(1, 4), scales),
                                min_size=1, max_size=3))
@hypothesis.settings(max_examples=30, deadline=None)
def test_pod_uniform_hierarchical_folds_exactly(pods):
    specs = [WorkerSpec(pod=p, bandwidth_scale=bw)
             for p, (k, bw) in enumerate(pods) for _ in range(k)]
    fg = fold_cluster(GRAPH, specs, collective_mode="hierarchical")
    cg = ClusterGraph.build(GRAPH, specs, collective_mode="hierarchical")
    if fg is None:      # no class smaller than its pod: nothing to fold
        assert all(k <= 2 for k, _ in pods)
        return
    _assert_equiv(fg, cg)


@hypothesis.given(n=st.integers(3, 8), slow=scales,
                  straggler=st.integers(0, 7))
@hypothesis.settings(max_examples=30, deadline=None)
def test_straggler_mix_folds_exactly(n, slow, straggler):
    specs = [WorkerSpec(compute_scale=slow if i == straggler % n else 1.0)
             for i in range(n)]
    fg = fold_cluster(GRAPH, specs, collective_mode="fused")
    cg = ClusterGraph.build(GRAPH, specs, collective_mode="fused")
    if fg is None:      # slow == 1.0 degenerates to uniform, still folds
        assert n <= 2
        return
    _assert_equiv(fg, cg)


@hypothesis.given(S=st.integers(2, 4), M=st.integers(2, 6),
                  dp=st.integers(2, 4), stage_scales=st.lists(scales,
                                                              min_size=4,
                                                              max_size=4))
@hypothesis.settings(max_examples=25, deadline=None)
def test_hybrid_pp_dp_folds_exactly(S, M, dp, stage_scales):
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=2e-3,
                               bwd_s=4e-3, update_s=1e-3, act_bytes=4e6,
                               grad_bytes=8e6) for s in range(S))
    plan = ParallelPlan(profs, M, "gpipe", dp)
    specs = [WorkerSpec(compute_scale=stage_scales[w // dp % 4])
             for w in range(plan.num_workers)]
    fg = plan.fold_place(specs)
    assert fg is not None and fg.num_classes == S
    _assert_equiv(fg, plan.place(specs))


@hypothesis.given(bws=st.lists(st.tuples(scales, scales, scales),
                               min_size=1, max_size=8))
@hypothesis.settings(max_examples=30, deadline=None)
def test_incremental_matches_full_over_retunes(bws):
    cg = ClusterGraph.build(GRAPH, [WorkerSpec() for _ in range(3)],
                            collective_mode="ring")
    prev = cg.simulate()
    for b0, b1, b2 in bws:
        cg.retune([WorkerSpec(bandwidth_scale=b) for b in (b0, b1, b2)])
        inc = cg.simulate_incremental(prev)
        full = cg.simulate()
        if inc is not None:
            gi, gf = inc.global_result, full.global_result
            assert gi.makespan == gf.makespan
            assert gi.start == gf.start
            assert gi.finish == gf.finish
            assert gi.thread_busy == gf.thread_busy
        prev = full
