"""Hypothesis property tests for the trace I/O subsystem.

Optional-dependency module (``pytest.importorskip``) like the other
``*_properties`` files: a clean machine still collects and runs the rest of
the suite.

Properties:

* **Replicate equivalence** (the ISSUE's property test): for any training
  step shape, worker count, and collective mode, importing N identical
  per-worker traces through the full JSONL round trip matches the
  replicate path (``ClusterGraph.build``) to float precision.
* **Import determinism**: export -> import -> export is a fixed point of
  the event stream (names/durations/deps stable).
* **Alignment exactness**: affine clock skew on any synthetic cluster is
  recovered to numerical precision from the collective-end anchors.
* **Alignment under noise**: with per-anchor jitter on the collective end
  times (real captures never observe a synchronous end at exactly the
  same instant), the least-squares fit still recovers the injected
  offset+drift within a tolerance proportional to the noise — the
  guarantee trace diffing (repro.analysis.diff) leans on.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.core import ClusterGraph, CostModel, Scenario, whatif, simulate
from repro.traceio import (align_traces, apply_alignment, events_from_graph,
                           graph_from_events, read_jsonl,
                           synthetic_cluster_traces, write_jsonl,
                           write_synthetic_trace_dir)
from repro.traceio.events import WorkerTrace
from synthgraphs import training_step_graph

durations = st.floats(min_value=1e-5, max_value=1e-2,
                      allow_nan=False, allow_infinity=False)


@settings(max_examples=25, deadline=None)
@given(layers=st.integers(1, 8), n=st.integers(2, 6),
       mode=st.sampled_from(["ring", "fused", "hierarchical"]),
       fwd=durations, bwd=durations, grad_mb=st.floats(0.5, 64.0))
def test_imported_identical_workers_match_replicate_path(layers, n, mode,
                                                         fwd, bwd, grad_mb):
    g = training_step_graph(layers=layers, fwd=fwd, bwd=bwd)
    grads = {f"l{i}": grad_mb * 1e6 for i in range(layers)}
    tf = whatif.what_if_distributed(g, grads, num_workers=n)
    cost = CostModel()
    build = ClusterGraph.build(tf.graph, n, cost=cost,
                               collective_mode=mode).simulate()
    lines = write_jsonl(events_from_graph(tf.graph))
    worker_graphs = [graph_from_events(read_jsonl(iter(lines), w))
                     for w in range(n)]
    imported = ClusterGraph.from_worker_graphs(
        worker_graphs, cost=cost, collective_mode=mode).simulate()
    assert imported.makespan == pytest.approx(build.makespan, rel=1e-12)
    assert imported.worker_makespans() == \
        pytest.approx(build.worker_makespans(), rel=1e-12)


@settings(max_examples=25, deadline=None)
@given(layers=st.integers(1, 10), fwd=durations, bwd=durations,
       upd=durations)
def test_export_import_is_fixed_point(layers, fwd, bwd, upd):
    g = training_step_graph(layers=layers, fwd=fwd, bwd=bwd, upd=upd)
    res = simulate(g)
    ev1 = events_from_graph(g, res)
    g2 = graph_from_events(WorkerTrace(0, ev1))
    res2 = simulate(g2)
    assert res2.makespan == pytest.approx(res.makespan, rel=1e-12)
    ev2 = events_from_graph(g2, res2)
    assert [(e.name, e.thread, e.dur, e.deps) for e in ev1] == \
        [(e.name, e.thread, e.dur, e.deps) for e in ev2]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 5), layers=st.integers(2, 8),
       offsets=st.lists(st.floats(-1.0, 1.0), min_size=5, max_size=5),
       drifts=st.lists(st.floats(0.95, 1.05), min_size=5, max_size=5))
def test_alignment_recovers_affine_clock_skew(n, layers, offsets, drifts):
    # worker 0 is the reference timeline: its clock stays clean so the
    # recovered maps are directly comparable to the injected skews
    off = [0.0] + offsets[1:n]
    dr = [1.0] + drifts[1:n]
    traces = synthetic_cluster_traces(
        n, layers=layers, clock_offsets=off, clock_drifts=dr)
    aligns = align_traces(traces)
    for al, off, drift in zip(aligns, off, dr):
        assert al.anchors == layers
        assert al.scale == pytest.approx(1.0 / drift, rel=1e-6)
        assert al.offset == pytest.approx(-off / drift, rel=1e-6,
                                          abs=1e-9)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 5), layers=st.integers(6, 12),
       offsets=st.lists(st.floats(-1.0, 1.0), min_size=5, max_size=5),
       drifts=st.lists(st.floats(0.98, 1.02), min_size=5, max_size=5),
       noise_us=st.floats(0.1, 20.0), seed=st.integers(0, 2**31))
def test_alignment_recovers_skew_under_anchor_noise(n, layers, offsets,
                                                    drifts, noise_us, seed):
    """Injected per-worker offset+drift is recovered within a tolerance
    proportional to the anchor jitter.  Each collective end observation is
    perturbed by bounded noise (scaled into the worker's local clock);
    the least-squares fit must land within a few noise-widths of the
    injected affine map — previously this was only exercised indirectly
    via exact round-trip tests.
    """
    import random
    rng = random.Random(seed)
    off = [0.0] + offsets[1:n]
    dr = [1.0] + drifts[1:n]
    traces = synthetic_cluster_traces(
        n, layers=layers, clock_offsets=off, clock_drifts=dr)
    noise = noise_us * 1e-6
    for w, tr in enumerate(traces):
        if w == 0:
            continue            # keep the reference timeline clean
        for ev in tr.events:
            if ev.resolved_collective():
                # jitter the observed *end* via the duration, in the
                # worker's local clock units (ts stamps already drifted)
                ev.dur += rng.uniform(-noise, noise) * dr[w]
    aligns = align_traces(traces)
    # a least-squares fit over k anchors with bounded noise b keeps the
    # offset within a few b; the drift error is b / anchor-time-spread
    for w, (al, o, d) in enumerate(zip(aligns, off, dr)):
        if w == 0:
            continue
        assert al.anchors == layers
        span = 4e-3 * layers      # bwd spacing lower-bounds anchor spread
        assert al.scale == pytest.approx(1.0 / d,
                                         abs=8 * noise / (d * span))
        recovered_offset_at_t0 = al.offset - (-o / d)
        assert abs(recovered_offset_at_t0) <= 8 * noise / d + \
            abs(al.scale - 1.0 / d) * 2.0  # offset trades off against drift
        assert al.residual <= 4 * noise


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 4), layers=st.integers(2, 6),
       offsets=st.lists(st.floats(-1000.0, 1000.0), min_size=3, max_size=3),
       drifts=st.lists(st.floats(1.05, 1.9), min_size=3, max_size=3))
def test_alignment_round_trips_negative_drift_and_large_offsets(
        n, layers, offsets, drifts):
    """Satellite property: aligning traces skewed by drift > 1 (recovered
    scale < 1) and offsets up to ±1000 s reproduces the clean reference
    timeline — and such physical skews must never trip the degenerate-fit
    fallback guard."""
    off = [0.0] + offsets[:n - 1]
    dr = [1.0] + drifts[:n - 1]
    clean = synthetic_cluster_traces(n, layers=layers)
    skewed = synthetic_cluster_traces(
        n, layers=layers, clock_offsets=off, clock_drifts=dr)
    aligns = align_traces(skewed)
    for w, al in enumerate(aligns):
        assert not al.fallback
        if w > 0:
            assert al.scale == pytest.approx(1.0 / dr[w], rel=1e-9)
            assert al.scale < 1.0          # drift > 1 compresses the map
        apply_alignment(skewed[w], al)
        for ev_clean, ev in zip(clean[w].events, skewed[w].events):
            assert ev.ts == pytest.approx(ev_clean.ts, abs=1e-6)
            assert ev.dur == pytest.approx(ev_clean.dur, abs=1e-6)
            assert ev.dur > 0


@pytest.fixture(scope="module")
def true_capture(tmp_path_factory):
    """A small 2-worker capture from the TRUE (default) CostModel, shared
    across calibration-recovery examples."""
    d = tmp_path_factory.mktemp("prop_capture")
    write_synthetic_trace_dir(str(d), 2, layers=3, cost=CostModel())
    return str(d)


@settings(max_examples=8, deadline=None)
@given(scale=st.one_of(st.floats(0.3, 0.8), st.floats(1.25, 3.0)))
def test_calibration_recovers_perturbed_compute_scale(true_capture, scale):
    """Satellite property: for any real compute-duration perturbation the
    simulate → diff → refit loop fits the scale back out — recovered
    kind_scale ≈ 1.0 against the true capture, loss non-increasing."""
    scn = Scenario(trace_dir=true_capture,
                   cost=CostModel(kind_scales={"compute": scale}))
    calibrated, rep = scn.calibrate(constants=["kind_scale:compute"])
    assert rep.fitted["kind_scale:compute"][1] == \
        pytest.approx(1.0, rel=1e-6)
    assert all(b <= a + 1e-15 for a, b in
               zip(rep.loss_history, rep.loss_history[1:]))
    assert rep.after.per_kind()["compute"].wape < 1e-6
