"""Hypothesis property tests for graph invariants (paper §4.2).

Skipped wholesale when the optional ``hypothesis`` dev dependency is absent
(``pytest.importorskip``) so a clean machine still collects and runs the rest
of the tier-1 suite end-to-end.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import (DependencyGraph, Task, TaskKind, DEVICE_STREAM,
                        HOST_THREAD)


def mk(name="t", thread=DEVICE_STREAM, dur=1.0, **kw):
    return Task(name=name, kind=kw.pop("kind", TaskKind.COMPUTE),
                thread=thread, duration=dur, **kw)


def chain(g, n, thread=DEVICE_STREAM):
    return [g.add_task(mk(f"{thread}{i}", thread)) for i in range(n)]


@st.composite
def random_graph(draw):
    g = DependencyGraph()
    n_dev = draw(st.integers(1, 12))
    n_host = draw(st.integers(0, 6))
    dev = chain(g, n_dev)
    host = chain(g, n_host, HOST_THREAD)
    # random forward (acyclic) cross-edges host -> device
    for h_i in range(n_host):
        for d_i in range(n_dev):
            if draw(st.booleans()):
                g.add_edge(host[h_i], dev[d_i])
    return g


class TestProperties:
    @hypothesis.given(random_graph())
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_random_graphs_valid(self, g):
        g.validate()
        assert g.critical_path() <= g.total_work() + 1e-9

    @hypothesis.given(random_graph(), st.integers(0, 5))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_remove_preserves_acyclicity(self, g, idx):
        ts = g.tasks()
        g.remove_task(ts[idx % len(ts)])
        g.validate()

    @hypothesis.given(random_graph())
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_copy_roundtrip_stats(self, g):
        s1, s2 = g.stats(), g.copy().stats()
        assert s1 == s2
