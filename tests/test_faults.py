"""Fault-injection subsystem acceptance tests (ISSUE 10).

Pins the subsystem's contracts:

* seeded fault timelines are reproducible (bit-identical reruns) and
  stable per worker stream (growing the cluster never reshuffles an
  existing worker's failure times);
* the renewal goodput engine is exact on hand-computable cases (quiet
  horizon, single mid-block failure) and deterministic end-to-end;
* the checkpoint-interval sweep's optimum agrees with the Young/Daly
  closed form on a golden case, and the golden goodput numbers for the
  seeded MTBF scenario are frozen in ``tests/golden/faults.json``;
* fault policies route through the registry/stack/sweep surfaces
  (``ddp,elastic,ckpt_interval:steps=K`` parses, sweeps, and answers
  ``straggler_mitigation`` pay/no-pay both ways);
* ``checkpoint_bytes`` matches the real on-disk payload of
  ``save_checkpoint`` and ``CheckpointManager.wait`` surfaces background
  save failures exactly once without wedging the manager.
"""

import dataclasses
import json
import math
import os

import pytest

from repro.core import available, parse_stack
from repro.core.optimize import OptimizationError, Scenario
from repro.faults import (CkptInterval, FaultEvent, FaultScenario,
                          FaultTimeline, GoodputPrediction, RecoveryModel,
                          demo_scenario, exponential_failures,
                          format_goodput_table, preemption_windows,
                          simulate_goodput, transient_stragglers,
                          young_daly_interval, young_daly_steps)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "faults.json")


def quiet_recovery(**kw):
    """A RecoveryModel with simple numbers for hand computation."""
    base = dict(detection_s=10.0, restart_s=5.0, remesh_s=2.0,
                repair_s=100.0, spare_activation_s=3.0,
                checkpoint_bytes=0.0, ckpt_bandwidth=1e9,
                ckpt_latency_s=1.0)
    base.update(kw)
    return RecoveryModel(**base)


# ================================================================ events
class TestEvents:
    def test_seeded_timelines_are_reproducible(self):
        a = exponential_failures(8, 3600.0, 86400.0, seed=7)
        b = exponential_failures(8, 3600.0, 86400.0, seed=7)
        assert a == b
        assert a.events == b.events
        c = exponential_failures(8, 3600.0, 86400.0, seed=8)
        assert a.events != c.events

    def test_per_worker_streams_stable_under_growth(self):
        small = exponential_failures(4, 3600.0, 86400.0, seed=1)
        big = exponential_failures(8, 3600.0, 86400.0, seed=1)
        for w in range(4):
            small_w = [e.time for e in small.events if e.worker == w]
            big_w = [e.time for e in big.events if e.worker == w]
            assert small_w == big_w

    def test_preemption_windows_deterministic(self):
        tl = preemption_windows(1000.0, 100.0, 3600.0, offset_s=500.0,
                                workers=2)
        assert [e.time for e in tl.events] == [500.0, 1500.0, 2500.0,
                                               3500.0]
        assert all(e.duration == 100.0 and e.count == 2
                   for e in tl.events)

    def test_merge_sorts_and_keeps_horizon(self):
        a = FaultTimeline((FaultEvent(5.0, "fail", worker=1),), 100.0)
        b = FaultTimeline((FaultEvent(2.0, "straggler", duration=3.0,
                                      slowdown=2.0),), 50.0)
        m = a | b
        assert [e.time for e in m.events] == [2.0, 5.0]
        assert m.horizon_s == 100.0

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultEvent(1.0, "explode")
        with pytest.raises(ValueError):
            FaultEvent(-1.0, "fail")
        with pytest.raises(ValueError):
            preemption_windows(10.0, 20.0, 100.0)


# ================================================================ engine
class TestGoodputEngine:
    def test_quiet_horizon_closed_form(self):
        # no faults: blocks of K steps + one ckpt write; exact count
        rec = quiet_recovery()            # ckpt write = 1.0s
        rep = simulate_goodput(
            n_workers=4, horizon_s=1000.0, timeline=FaultTimeline(),
            recovery=rec, ckpt_interval_steps=10, step_s=1.0)
        # block = 10*1 + 1 = 11s -> 90 blocks = 990s, then 10 more steps
        assert rep.useful_steps == 910
        assert rep.committed_steps == 900
        assert rep.failures == 0 and rep.lost_steps == 0
        assert rep.ckpt_s == pytest.approx(90.0)
        assert rep.useful_s == pytest.approx(910.0)

    def test_single_failure_rolls_back_to_last_commit(self):
        rec = quiet_recovery()            # downtime 10+100+1+5 = 116s
        tl = FaultTimeline((FaultEvent(25.0, "fail", worker=0),), 200.0)
        rep = simulate_goodput(
            n_workers=2, horizon_s=200.0, timeline=tl, recovery=rec,
            ckpt_interval_steps=10, step_s=1.0)
        # blocks (10 steps + 1s ckpt) commit at t=11 and t=22; at t=25 the
        # job is 3 steps into the third block.  Rollback loses those 3.
        assert rep.failures == 1
        assert rep.lost_steps == 3
        assert rep.lost_s == pytest.approx(3.0)
        # resumes at 25+116=141: 59s left -> 5 blocks (55s) + 4 steps
        assert rep.useful_steps == 20 + 54
        assert rep.committed_steps == 20 + 50
        assert rep.max_lost_steps_per_failure == 3

    def test_lost_work_bounded_by_interval(self):
        rec = quiet_recovery()
        tl = exponential_failures(8, 1800.0, 43200.0, seed=3)
        rep = simulate_goodput(
            n_workers=8, horizon_s=43200.0, timeline=tl, recovery=rec,
            ckpt_interval_steps=25, step_s=0.5)
        assert rep.failures > 10
        assert rep.max_lost_steps_per_failure <= 25
        assert rep.lost_steps <= rep.failures * 25

    def test_deterministic_bit_identical(self):
        rec = quiet_recovery()
        tl = exponential_failures(8, 3600.0, 86400.0, seed=11) | \
            transient_stragglers(2.0, 2.5, 300.0, 86400.0, seed=11)
        a = simulate_goodput(n_workers=8, horizon_s=86400.0, timeline=tl,
                             recovery=rec, ckpt_interval_steps=50,
                             step_s=0.25)
        b = simulate_goodput(n_workers=8, horizon_s=86400.0, timeline=tl,
                             recovery=rec, ckpt_interval_steps=50,
                             step_s=0.25)
        assert a == b

    def test_goodput_below_fault_free(self):
        rec = quiet_recovery()
        tl = exponential_failures(4, 7200.0, 86400.0, seed=5)
        rep = simulate_goodput(n_workers=4, horizon_s=86400.0, timeline=tl,
                               recovery=rec, ckpt_interval_steps=100,
                               step_s=1.0)
        assert 0.0 < rep.goodput_fraction <= 1.0

    def test_elastic_beats_halting_at_long_repair(self):
        rec = quiet_recovery(repair_s=1200.0)
        tl = exponential_failures(8, 7200.0, 43200.0, seed=2)
        halt = simulate_goodput(n_workers=8, horizon_s=43200.0, timeline=tl,
                                recovery=rec, ckpt_interval_steps=50,
                                step_s=lambda n: 8.0 / n)
        ela = simulate_goodput(n_workers=8, horizon_s=43200.0, timeline=tl,
                               recovery=rec, ckpt_interval_steps=50,
                               step_s=lambda n: 8.0 / n, elastic=True)
        assert ela.useful_steps > halt.useful_steps
        assert ela.availability > halt.availability

    def test_hot_spare_beats_cold_repair(self):
        rec = quiet_recovery(repair_s=1200.0)
        tl = exponential_failures(8, 7200.0, 43200.0, seed=2)
        cold = simulate_goodput(n_workers=8, horizon_s=43200.0, timeline=tl,
                                recovery=rec, ckpt_interval_steps=50,
                                step_s=1.0)
        spare = simulate_goodput(n_workers=8, horizon_s=43200.0,
                                 timeline=tl, recovery=rec,
                                 ckpt_interval_steps=50, step_s=1.0,
                                 hot_spares=2)
        assert spare.useful_steps > cold.useful_steps

    def test_preemption_graceful_no_lost_work(self):
        rec = quiet_recovery()
        tl = preemption_windows(600.0, 120.0, 3600.0, offset_s=300.0)
        rep = simulate_goodput(n_workers=4, horizon_s=3600.0, timeline=tl,
                               recovery=rec, ckpt_interval_steps=1000,
                               step_s=1.0)
        assert rep.preemptions == 6
        assert rep.lost_steps == 0 and rep.failures == 0
        assert rep.availability < 1.0

    def test_young_daly_crosscheck(self):
        # engine-level golden case: the simulated optimum agrees with the
        # closed form.  s=1.0s, c=10s, job MTBF 1h -> K* ~= 268 steps.
        rec = quiet_recovery(ckpt_latency_s=10.0, detection_s=30.0,
                             repair_s=60.0, restart_s=10.0)
        n, mtbf = 8, 8 * 3600.0            # job MTBF = 3600s
        horizon = 14 * 86400.0             # ~340 failures
        tl = exponential_failures(n, mtbf, horizon, seed=0)
        k_yd = young_daly_steps(rec.checkpoint_write_s, mtbf / n, 1.0)
        assert k_yd == pytest.approx(math.sqrt(2 * 10.0 * 3600.0), rel=0.01)
        best_k, best_useful, at_yd = None, -1, None
        for k in (34, 67, 134, 201, k_yd, 402, 536, 1072, 2144):
            rep = simulate_goodput(n_workers=n, horizon_s=horizon,
                                   timeline=tl, recovery=rec,
                                   ckpt_interval_steps=k, step_s=1.0)
            if rep.useful_steps > best_useful:
                best_k, best_useful = k, rep.useful_steps
            if k == k_yd:
                at_yd = rep.useful_steps
        # the sweep optimum lands within a factor 2 of Young/Daly and the
        # Young/Daly point is within 2% of the best swept goodput
        assert best_k is not None and k_yd / 2 <= best_k <= k_yd * 2
        assert at_yd >= 0.98 * best_useful

    def test_timeline_samples_consistent(self):
        rec = quiet_recovery()
        tl = exponential_failures(4, 3600.0, 14400.0, seed=9)
        rep = simulate_goodput(n_workers=4, horizon_s=14400.0, timeline=tl,
                               recovery=rec, ckpt_interval_steps=20,
                               step_s=1.0)
        # capacity starts at full N, dips to 0 during recovery
        assert rep.capacity_samples[0] == (0.0, 4)
        assert any(v == 0 for _, v in rep.capacity_samples)
        # progress is monotone non-decreasing
        vals = [v for _, v in rep.progress_samples]
        assert vals == sorted(vals)
        assert vals[-1] == rep.committed_steps

    def test_validation(self):
        rec = quiet_recovery()
        with pytest.raises(ValueError):
            simulate_goodput(n_workers=0, horizon_s=1.0,
                             timeline=FaultTimeline(), recovery=rec,
                             ckpt_interval_steps=1, step_s=1.0)
        with pytest.raises(ValueError):
            simulate_goodput(n_workers=1, horizon_s=1.0,
                             timeline=FaultTimeline(), recovery=rec,
                             ckpt_interval_steps=0, step_s=1.0)
        with pytest.raises(ValueError):
            simulate_goodput(n_workers=1, horizon_s=1.0,
                             timeline=FaultTimeline(), recovery=rec,
                             ckpt_interval_steps=1, step_s=-1.0)


# ============================================================== recovery
class TestRecoveryModel:
    def test_from_scenario_sizes_from_grad_bytes(self):
        scn = demo_scenario(workers=4, layers=8)
        rec = scn.recovery
        # 8 layers * 64 MB grads * 3x optimizer-state factor
        assert rec.checkpoint_bytes == pytest.approx(8 * 64e6 * 3.0)
        assert rec.ckpt_bandwidth == pytest.approx(scn.cost.hw.pcie_bandwidth)
        assert rec.restore_s > 0

    def test_from_scenario_params_tree(self):
        np = pytest.importorskip("numpy")
        scn = demo_scenario(workers=2)
        tree = {"w": np.zeros((1024, 1024), np.float32)}
        rec = RecoveryModel.from_scenario(scn, params_tree=tree)
        assert rec.checkpoint_bytes == 1024 * 1024 * 4

    def test_downtime_paths(self):
        rec = quiet_recovery()
        assert rec.downtime_s() == pytest.approx(10 + 100 + 1 + 5)
        assert rec.downtime_s(hot_spare=True) == pytest.approx(10 + 3 + 1 + 5)
        assert rec.downtime_s(elastic=True) == pytest.approx(10 + 1 + 5 + 2)


# ============================================================== scenario
class TestFaultScenario:
    def test_registry_round_trip(self):
        names = available()
        for n in ("ckpt_interval", "elastic", "hot_spare",
                  "straggler_mitigation"):
            assert n in names
        opt, overrides = parse_stack("ddp,elastic,ckpt_interval:steps=250")
        assert not overrides
        assert "ckpt_interval:steps=250" in opt.spec()

    def test_fault_opt_on_plain_scenario_raises(self):
        scn = demo_scenario(workers=4)
        plain = Scenario(graph=scn.graph, cost=scn.cost,
                         layer_grad_bytes=scn.layer_grad_bytes, workers=4)
        with pytest.raises(OptimizationError, match="FaultScenario"):
            plain.predict("ckpt_interval:steps=10")

    def test_predict_deterministic(self):
        scn = demo_scenario(workers=8, mtbf_s=4 * 3600.0,
                            horizon_s=43200.0, seed=5)
        a = scn.predict("ddp,ckpt_interval:steps=200")
        b = scn.predict("ddp,ckpt_interval:steps=200")
        assert a.report == b.report
        assert isinstance(a, GoodputPrediction)
        # fresh scenario, same seed: still identical
        scn2 = demo_scenario(workers=8, mtbf_s=4 * 3600.0,
                             horizon_s=43200.0, seed=5)
        c = scn2.predict("ddp,ckpt_interval:steps=200")
        assert c.report == a.report

    def test_goodput_fraction_below_one(self):
        scn = demo_scenario(workers=8, mtbf_s=4 * 3600.0,
                            horizon_s=43200.0, seed=5)
        p = scn.predict("ddp")
        assert 0.0 < p.goodput_fraction <= 1.0
        assert p.report.useful_steps > 0

    def test_elastic_and_spare_beat_baseline(self):
        scn = demo_scenario(workers=8, mtbf_s=3 * 3600.0,
                            horizon_s=43200.0, seed=1)
        base = scn.predict("ddp")
        ela = scn.predict("ddp,elastic")
        spare = scn.predict("ddp,hot_spare:count=2")
        assert ela.goodput > base.goodput
        assert spare.goodput > base.goodput

    def test_steady_cache_shared_across_policy_points(self):
        scn = demo_scenario(workers=8, mtbf_s=4 * 3600.0,
                            horizon_s=14400.0)
        scn.predict("ddp,ckpt_interval:steps=100")
        n_cached = len(scn._steady_cache)
        scn.predict("ddp,ckpt_interval:steps=400")
        scn.predict("ddp,hot_spare")
        assert len(scn._steady_cache) == n_cached  # no new steady builds

    def test_sweep_routes_stacked_params(self):
        scn = demo_scenario(workers=4, mtbf_s=4 * 3600.0,
                            horizon_s=14400.0)
        preds = scn.sweep("ddp,ckpt_interval", {"steps": [50, 200]})
        assert [p.point["steps"] for p in preds] == [50, 200]
        assert all(isinstance(p, GoodputPrediction) for p in preds)
        assert preds[0].policy.ckpt_interval_steps == 50

    def test_straggler_mitigation_pay_and_no_pay(self):
        heavy = demo_scenario(workers=8, mtbf_s=0.0, horizon_s=43200.0,
                              seed=3, straggler_rate_per_hour=6.0,
                              straggler_slowdown=3.0,
                              straggler_duration_s=600.0)
        assert heavy.predict("ddp,straggler_mitigation").goodput > \
            heavy.predict("ddp").goodput
        light = demo_scenario(workers=8, mtbf_s=0.0, horizon_s=43200.0,
                              seed=3, straggler_rate_per_hour=0.05,
                              straggler_slowdown=1.3,
                              straggler_duration_s=60.0)
        assert light.predict(
            "ddp,straggler_mitigation:overhead=0.05").goodput < \
            light.predict("ddp").goodput

    def test_optimal_interval_matches_young_daly(self):
        scn = demo_scenario(workers=16, mtbf_s=6 * 3600.0,
                            horizon_s=86400.0, seed=1)
        best, preds, k_yd = scn.optimal_ckpt_interval("ddp")
        best_k = best.policy.ckpt_interval_steps
        assert k_yd / 2 <= best_k <= k_yd * 2
        at_yd = next(p for p in preds
                     if p.policy.ckpt_interval_steps == k_yd)
        best_useful = max(p.report.useful_steps for p in preds)
        assert at_yd.report.useful_steps >= 0.98 * best_useful

    def test_surfaces_critical_path_and_timelines(self):
        scn = demo_scenario(workers=4, mtbf_s=6 * 3600.0,
                            horizon_s=14400.0)
        p = scn.predict("ddp")
        cp = p.critical_path
        assert cp.makespan == pytest.approx(p.steady_step_s)
        assert p.timelines is not None
        assert p.capacity_timeline.peak == 4
        # samples are sparse (event times + horizon); the final one at the
        # horizon carries the committed-step count.
        tl = p.progress_timeline
        assert tl.value_at(scn.horizon_s) == p.report.committed_steps
        assert tl.values == tuple(sorted(tl.values))  # monotone progress
        assert "steps/h" in format_goodput_table([p])

    def test_elastic_on_trace_route_raises(self, tmp_path):
        pytest.importorskip("jax")
        from repro.traceio import write_synthetic_trace_dir
        d = str(tmp_path / "traces")
        write_synthetic_trace_dir(d, 2)
        scn = FaultScenario(trace_dir=d, mtbf_s=3600.0, horizon_s=7200.0)
        scn.predict("noop")  # non-elastic works
        with pytest.raises(OptimizationError, match="trace route"):
            scn.predict("elastic")

    def test_young_daly_helpers(self):
        assert young_daly_interval(10.0, 3600.0) == \
            pytest.approx(math.sqrt(2 * 10 * 3600))
        assert math.isinf(young_daly_interval(0.0, 3600.0))
        assert young_daly_steps(10.0, 3600.0, 1.0) == \
            round(math.sqrt(72000))


# ================================================================ golden
class TestGolden:
    def scenario(self):
        return demo_scenario(workers=16, mtbf_s=6 * 3600.0,
                             horizon_s=86400.0, seed=1)

    def compute(self):
        scn = self.scenario()
        out = {}
        for spec in ("ddp,ckpt_interval:steps=200",
                     "ddp,elastic,ckpt_interval:steps=200",
                     "ddp,hot_spare:count=2,ckpt_interval:steps=200"):
            r = scn.predict(spec).report
            out[spec] = {"useful_steps": r.useful_steps,
                         "failures": r.failures,
                         "lost_steps": r.lost_steps,
                         "goodput_steps_per_hour": r.goodput_steps_per_hour,
                         "availability": r.availability}
        return out

    def test_golden_goodput(self):
        got = self.compute()
        if not os.path.exists(GOLDEN):   # pragma: no cover - regen path
            with open(GOLDEN, "w") as f:
                json.dump(got, f, indent=2, sort_keys=True)
            pytest.skip("golden file regenerated")
        with open(GOLDEN) as f:
            want = json.load(f)
        assert set(got) == set(want)
        for spec, vals in want.items():
            for k, v in vals.items():
                assert got[spec][k] == pytest.approx(v, rel=1e-12), \
                    (spec, k)


# ================================================================== ckpt
class TestCheckpointBytes:
    def test_matches_on_disk_payload(self, tmp_path):
        jax = pytest.importorskip("jax")
        import numpy as np

        from repro.ckpt import checkpoint_bytes, save_checkpoint
        tree = {"w": np.ones((64, 32), np.float32),
                "b": np.ones((32,), np.float16),
                "step": np.int64(3),
                "bf": jax.numpy.ones((16, 8), jax.numpy.bfloat16)}
        est = checkpoint_bytes(tree)
        path = save_checkpoint(str(tmp_path), 0, tree)
        on_disk = 0
        for name in os.listdir(path):
            if name.endswith(".npy"):
                arr = np.load(os.path.join(path, name))
                on_disk += arr.nbytes
        assert est == on_disk
        # bf16 rides a float32 carrier: 16*8*4 bytes, not *2
        assert est == 64 * 32 * 4 + 32 * 2 + 8 + 16 * 8 * 4

    def test_abstract_leaves_size_without_materializing(self):
        jax = pytest.importorskip("jax")
        from repro.ckpt import checkpoint_bytes
        tree = {"w": jax.ShapeDtypeStruct((128, 256), jax.numpy.float32)}
        assert checkpoint_bytes(tree) == 128 * 256 * 4

    def test_seeds_recovery_restore_cost(self):
        np = pytest.importorskip("numpy")
        scn = demo_scenario(workers=2)
        tree = {"w": np.zeros((1000,), np.float64)}
        rec = RecoveryModel.from_scenario(scn, params_tree=tree)
        assert rec.checkpoint_bytes == 8000
        assert rec.restore_s == pytest.approx(
            8000 / rec.ckpt_bandwidth + rec.ckpt_latency_s)


class TestCheckpointManagerWait:
    def test_async_error_surfaces_once_and_unwedges(self, tmp_path,
                                                    monkeypatch):
        pytest.importorskip("jax")
        import numpy as np

        import repro.ckpt.checkpoint as ckpt_mod
        mgr = ckpt_mod.CheckpointManager(str(tmp_path / "ck"))
        boom = RuntimeError("disk full")

        def failing_save(step, tree, **meta):
            raise boom

        monkeypatch.setattr(mgr, "save", failing_save)
        mgr.save_async(1, {"w": np.ones(4)})
        with pytest.raises(RuntimeError, match="disk full"):
            mgr.wait()
        # the error surfaced exactly once; the manager is not wedged
        mgr.wait()
        monkeypatch.undo()
        mgr.save_async(2, {"w": np.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 2
