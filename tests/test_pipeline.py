"""Pipeline parallelism: SPMD GPipe correctness + Daydream schedule model."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import simulate
from repro.parallel import pipeline_graph, gpipe_bubble_fraction


class TestDaydreamModel:
    def test_balanced_gpipe_matches_closed_form(self):
        """Simulator vs the classic (M + S - 1) * t makespan."""
        for S, M, t in [(4, 8, 1.0), (2, 16, 0.5), (8, 8, 2.0)]:
            g = pipeline_graph([t] * S, M)
            r = simulate(g)
            assert r.makespan == pytest.approx((M + S - 1) * t)

    def test_bubble_fraction(self):
        g = pipeline_graph([1.0] * 4, 12)
        r = simulate(g)
        ideal = 12 * 1.0
        bubble = 1 - ideal / r.makespan
        assert bubble == pytest.approx(gpipe_bubble_fraction([1.0] * 4, 12))

    def test_unbalanced_stage_dominates(self):
        """A slow stage serializes the pipe: makespan ~ M * t_max."""
        g = pipeline_graph([1.0, 3.0, 1.0], 10)
        r = simulate(g)
        assert r.makespan >= 10 * 3.0
        assert r.makespan <= 10 * 3.0 + 2 * (1.0 + 3.0)

    def test_hop_time_adds_latency(self):
        base = simulate(pipeline_graph([1.0] * 3, 4)).makespan
        hop = simulate(pipeline_graph([1.0] * 3, 4, hop_time_s=0.5)).makespan
        assert hop > base


_SPMD_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    import numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map, make_mesh
    from repro.parallel import gpipe_spmd

    S, M, mb, d = 4, 6, 2, 8
    mesh = make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, d, d)) * 0.3          # one weight per stage
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

    def stage_body(W, xm):                                 # W: (1, d, d) local
        return jnp.tanh(xm @ W[0])

    def spmd(W, xmb):
        return gpipe_spmd(partial(stage_body, W), xmb, n_microbatches=M)

    f = shard_map(spmd, mesh=mesh,
                  in_specs=(P("stage", None, None), P(None, None, None)),
                  out_specs=P(None, None, None))
    got = jax.jit(f)(Ws, x)

    want = x
    for s in range(S):
        want = jnp.tanh(want @ Ws[s])
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err
    print("OK", err)
""")


def test_spmd_gpipe_matches_sequential():
    """4-stage GPipe over shard_map == sequential stage application."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = _SPMD_SNIPPET.format(src=src)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2500:]
    assert proc.stdout.strip().startswith("OK")
