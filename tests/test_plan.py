"""Parallelism-plan subsystem (repro.parallel.plan + the pipeline route).

The ISSUE's acceptance criteria live here:

* simulated balanced-stage GPipe makespan matches the closed form
  ``(M + S - 1) * t_stage`` to float precision;
* ``pipeline:stages=S`` composes with ``amp`` / ``dgc`` / per-stage DP
  through the registry, and the placed plan's p2p legs retune in
  ``Scenario.sweep`` (bandwidth grids reuse one build; microbatch grids
  reuse the cached stage partition).
"""

import pytest

from repro.core import (ClusterGraph, CostModel, DependencyGraph, GraphError,
                        OptimizationError, Scenario, Task, TaskKind,
                        WorkerSpec, match_push_pull_groups, parse_stack,
                        simulate, whatif)
from repro.core.optimize import PipelineParallel, uniform_bandwidth_specs
from repro.parallel import (ParallelPlan, StageProfile, partition_stages,
                            pipeline_graph, schedule_order)
from synthgraphs import training_step_graph

LAYERS = 8
FWD, BWD, UPD = 2e-3, 4e-3, 1e-3
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}
ACTS = {f"l{i}": 4e6 for i in range(LAYERS)}


@pytest.fixture()
def step_graph():
    return training_step_graph(layers=LAYERS, fwd=FWD, bwd=BWD, upd=UPD)


@pytest.fixture()
def scenario(step_graph):
    return Scenario(step_graph, layer_grad_bytes=GRADS,
                    activation_bytes=ACTS)


def balanced_plan(S, M, *, schedule="gpipe", dp=1, act=0.0, grad=0.0,
                  upd=0.0):
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=FWD,
                               bwd_s=BWD, update_s=upd, act_bytes=act,
                               grad_bytes=grad) for s in range(S))
    return ParallelPlan(profs, M, schedule, dp)


class TestPartition:
    def test_contiguous_balanced_split(self, step_graph):
        profs = partition_stages(step_graph, 4, activation_bytes=ACTS,
                                 layer_grad_bytes=GRADS)
        assert [p.layers for p in profs] == \
            [("l0", "l1"), ("l2", "l3"), ("l4", "l5"), ("l6", "l7")]
        for p in profs:
            assert p.fwd_s == pytest.approx(2 * FWD)
            assert p.bwd_s == pytest.approx(2 * BWD)
            assert p.update_s == pytest.approx(2 * UPD)
            assert p.act_bytes == ACTS[p.layers[-1]]
            assert p.grad_bytes == pytest.approx(2 * 30e6)

    def test_unbalanced_layers_balance_by_time(self):
        g = DependencyGraph()
        # one heavy layer + three light ones: the heavy layer gets its own
        # stage
        for i, d in enumerate([9e-3, 1e-3, 1e-3, 1e-3]):
            g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, "device", d,
                            layer=f"l{i}", phase="fwd"))
        profs = partition_stages(g, 2)
        assert profs[0].layers == ("l0",)
        assert profs[1].layers == ("l1", "l2", "l3")

    def test_too_few_layers_raises(self, step_graph):
        with pytest.raises(GraphError):
            partition_stages(step_graph, LAYERS + 1)

    def test_unmapped_profile_raises(self):
        g = DependencyGraph()
        g.add_task(Task("t", TaskKind.COMPUTE, "device", 1e-3))
        with pytest.raises(GraphError):
            partition_stages(g, 2)


class TestSchedules:
    def test_gpipe_order(self):
        assert schedule_order(4, 1, 3, "gpipe") == \
            [("F", 0), ("F", 1), ("F", 2), ("B", 0), ("B", 1), ("B", 2)]

    def test_1f1b_warmup_and_drain(self):
        # last stage alternates from the start; first stage warms up S-1
        assert schedule_order(3, 2, 3, "1f1b") == \
            [("F", 0), ("B", 0), ("F", 1), ("B", 1), ("F", 2), ("B", 2)]
        assert schedule_order(3, 0, 3, "1f1b") == \
            [("F", 0), ("F", 1), ("F", 2), ("B", 0), ("B", 1), ("B", 2)]

    def test_every_microbatch_once(self):
        for sched in ("gpipe", "1f1b"):
            for s in range(5):
                order = schedule_order(5, s, 7, sched)
                assert sorted(m for op, m in order if op == "F") == list(range(7))
                assert sorted(m for op, m in order if op == "B") == list(range(7))

    def test_unknown_schedule_raises(self):
        with pytest.raises(GraphError):
            schedule_order(2, 0, 2, "interleaved")


class TestClosedForm:
    def test_balanced_gpipe_matches_closed_form(self):
        """Acceptance: (M + S - 1) * t_stage to float precision."""
        for S, M in [(4, 8), (2, 16), (8, 8), (4, 1), (1, 4)]:
            res = balanced_plan(S, M).place().simulate()
            t_mb = (FWD + BWD) / M
            assert res.makespan == pytest.approx((M + S - 1) * t_mb,
                                                 rel=1e-12)

    def test_update_tail_adds_once(self):
        res = balanced_plan(4, 8, upd=1e-3).place().simulate()
        t_mb = (FWD + BWD) / 8
        assert res.makespan == pytest.approx((8 + 4 - 1) * t_mb + 1e-3,
                                             rel=1e-12)

    def test_1f1b_equals_gpipe_on_balanced(self):
        for S, M in [(4, 8), (2, 16), (8, 4)]:
            g = balanced_plan(S, M, schedule="gpipe").place().simulate()
            f = balanced_plan(S, M, schedule="1f1b").place().simulate()
            assert f.makespan == pytest.approx(g.makespan, rel=1e-12)

    def test_partitioned_profile_matches_closed_form(self, scenario):
        """End-to-end through the registry: partition + place + simulate.

        Closed form incl. hops: the activation hop (fwd fill) and gradient
        hop (bwd drain) each cross S-1 stage boundaries on the critical
        path; steady-state hops overlap with compute (h << t_mb here).
        """
        S, M = 4, 16
        pred = scenario.predict(PipelineParallel(stages=S, microbatches=M))
        t_mb = (LAYERS / S) * (FWD + BWD) / M
        upd = (LAYERS / S) * UPD
        cost = CostModel()
        bw = cost.hw.ici_bandwidth * cost.hw.ici_links_per_axis
        hop = (4e6 / M) / bw + cost.collectives.hop_latency
        expected = (M + S - 1) * t_mb + 2 * (S - 1) * hop + upd
        assert pred.predicted == pytest.approx(expected, rel=1e-12)
        assert pred.cluster is not None
        assert len(pred.cluster.per_worker) == S


class TestHops:
    def test_act_payload_slows_pipe(self):
        base = balanced_plan(4, 8).place().simulate().makespan
        heavy = balanced_plan(4, 8, act=100e6).place().simulate().makespan
        assert heavy > base

    def test_cross_pod_stage_boundary_uses_dcn(self):
        plan = balanced_plan(4, 8, act=50e6)
        single = plan.place().simulate().makespan
        pods = [WorkerSpec(pod=s) for s in range(4)]   # every hop crosses
        multi = plan.place(pods).simulate().makespan
        assert multi > single

    def test_p2p_legs_retune_like_ring_legs(self):
        """Acceptance: retuned p2p legs == fresh build, bit-identical."""
        plan = balanced_plan(4, 8, act=50e6, grad=30e6, dp=2)
        cg = plan.place()
        skew = [WorkerSpec(bandwidth_scale=0.25 if i == 2 else 1.0,
                           compute_scale=1.5 if i == 5 else 1.0)
                for i in range(8)]
        retuned = cg.retune(skew).simulate()
        fresh = plan.place(skew).simulate()
        assert retuned.makespan == fresh.makespan
        assert retuned.worker_makespans() == fresh.worker_makespans()

    def test_hop_tasks_are_comm_kind(self):
        cg = balanced_plan(3, 4, act=8e6).place()
        hops = [t for t in cg.graph.tasks() if t.kind == TaskKind.COMM]
        assert len(hops) == 2 * 2 * 4      # (S-1) boundaries x 2 dirs x M
        assert all(t.comm_bytes == pytest.approx(8e6 / 4) for t in hops)


class TestHybrid:
    def test_per_stage_rings_exist_and_gate_update(self):
        plan = balanced_plan(2, 4, grad=60e6, dp=2)
        cg = plan.place()
        legs = [t for t in cg.graph.tasks()
                if t.attrs.get("collective") and "leg" in t.name]
        # 2 stages x 2 replicas x 2(dp-1) legs
        assert len(legs) == 2 * 2 * 2
        res = cg.simulate()
        no_dp = balanced_plan(2, 4, grad=60e6).place().simulate()
        assert res.makespan > no_dp.makespan

    def test_s1_plan_equals_replicate_path(self):
        """Acceptance satellite: plan build == replicate path when S=1."""
        plan = balanced_plan(1, 4, grad=120e6, dp=4)
        placed = plan.place().simulate()
        tmpl = plan.stage_templates(CostModel())[0]
        replicated = ClusterGraph.build(tmpl, 4).simulate()
        assert placed.makespan == pytest.approx(replicated.makespan,
                                                rel=1e-12)
        assert placed.worker_makespans() == \
            pytest.approx(replicated.worker_makespans(), rel=1e-12)

    def test_hybrid_on_heterogeneous_pods(self, scenario):
        """Stage replicas per pod: DP rings stay intra-pod (ICI), hops
        cross pods (DCN) — the BlueConnect-style layout for PP x DP."""
        opt = PipelineParallel(stages=2, microbatches=8, dp=2)
        pods = [WorkerSpec(pod=s) for s in (0, 0, 1, 1)]
        flat = [WorkerSpec() for _ in range(4)]
        import dataclasses as dc
        on_pods = dc.replace(scenario, workers=pods).predict(opt)
        on_flat = dc.replace(scenario, workers=flat).predict(opt)
        # only the act/grad hops cross the pod boundary; rings stay local
        assert on_pods.predicted > on_flat.predicted

    def test_worker_spec_count_must_match_plan(self, scenario):
        import dataclasses as dc
        s = dc.replace(scenario, workers=[WorkerSpec()] * 3)
        with pytest.raises(OptimizationError):
            s.predict(PipelineParallel(stages=2, microbatches=4, dp=2))
        s = dc.replace(scenario, workers=7)
        with pytest.raises(OptimizationError):
            s.predict(PipelineParallel(stages=2, microbatches=4))


class TestRegistryRoute:
    def test_cli_continuation_form(self):
        opt, over = parse_stack(
            "pipeline:stages=4,microbatches=16,schedule=1f1b")
        assert isinstance(opt, PipelineParallel)
        assert (opt.stages, opt.microbatches, opt.schedule) == (4, 16, "1f1b")
        assert over == {}
        # continuation + following optimization + scenario override
        opt, over = parse_stack(
            "pipeline:stages=2,microbatches=8,amp,workers=4")
        assert [o.name for o in opt.opts] == ["pipeline", "amp"]
        assert over == {"workers": 4}
        with pytest.raises(OptimizationError):
            parse_stack("stages=4,pipeline")

    def test_pipeline_composes_with_amp_and_dgc(self, scenario):
        plain = scenario.predict("pipeline:stages=4:microbatches=8")
        amped = scenario.predict("pipeline:stages=4:microbatches=8,amp")
        assert amped.predicted < plain.predicted
        hybrid = scenario.predict(
            "pipeline:stages=4:microbatches=8:dp=2")
        dgc = scenario.predict(
            "pipeline:stages=4:microbatches=8:dp=2,dgc:compression=0.01")
        assert dgc.predicted < hybrid.predicted

    def test_pre_stack_transforms_profile(self, scenario):
        """amp|pipeline: AMP reshapes the profile before partitioning."""
        pre = scenario.predict("amp,pipeline:stages=4:microbatches=8")
        plain = scenario.predict("pipeline:stages=4:microbatches=8")
        assert pre.predicted < plain.predicted

    def test_two_pipelines_raise(self, scenario):
        with pytest.raises(OptimizationError):
            scenario.predict("pipeline:stages=2,pipeline:stages=4")

    def test_comm_inserting_pre_stack_rejected(self, scenario):
        """ddp|pipeline must not silently predict a comm-free pipeline:
        the compute-only partition would drop the inserted all-reduces
        (use pipeline:dp=N instead)."""
        for spec in ("ddp,pipeline:stages=4:microbatches=8",
                     "p3:bandwidth=5e9,pipeline:stages=4:microbatches=8"):
            with pytest.raises(OptimizationError, match="drop"):
                scenario.predict(spec)
        # greedy_search probes such stacks; they must be skipped, not won
        from repro.core import greedy_search
        from repro.core.optimize import DDP, PipelineParallel
        best, _ = greedy_search(
            scenario, max_depth=2,
            candidates=[DDP(), PipelineParallel(stages=4, microbatches=8)])
        if best is not None:
            names = [o.name for o in getattr(best, "opts", [best])]
            assert names != ["ddp", "pipeline"]

    def test_profile_with_existing_collectives_still_places(self, scenario):
        """Pre-existing collectives in the *baseline* profile are dropped
        with documented compute-only semantics (no raise) — compiled
        profiles legitimately contain them."""
        tf = whatif.what_if_distributed(scenario.graph, GRADS, 8)
        s = Scenario(tf.graph, layer_grad_bytes=GRADS,
                     activation_bytes=ACTS)
        pred = s.predict("pipeline:stages=4:microbatches=8")
        assert pred.cluster is not None

    def test_trace_route_rejects_pipeline(self, tmp_path, step_graph):
        from repro import traceio
        res = simulate(step_graph)
        for i in range(2):
            traceio.export_graph_trace(step_graph, res,
                                       str(tmp_path / f"worker{i}.json"))
        s = Scenario(trace_dir=str(tmp_path))
        with pytest.raises(OptimizationError):
            s.predict("pipeline:stages=2:microbatches=4")

    def test_legacy_wrapper(self, step_graph):
        res = whatif.cluster_what_if_pipeline(
            step_graph, 4, 8, activation_bytes=ACTS,
            layer_grad_bytes=GRADS)
        assert len(res.per_worker) == 4
        direct = Scenario(step_graph, layer_grad_bytes=GRADS,
                          activation_bytes=ACTS).predict(
            PipelineParallel(stages=4, microbatches=8))
        assert res.makespan == pytest.approx(direct.predicted, rel=1e-12)


class TestPipelineSweeps:
    def test_microbatch_grid_reuses_partition(self, scenario):
        grid = {"microbatches": [2, 4, 8, 16], "stages": [4]}
        reused = scenario.sweep("pipeline", grid, reuse=True)
        rebuilt = scenario.sweep("pipeline", grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]
        # more microbatches -> smaller bubble -> faster
        ms = [p.predicted for p in reused]
        assert ms == sorted(ms, reverse=True)

    def test_bandwidth_grid_retunes_one_build(self, scenario):
        opt = PipelineParallel(stages=4, microbatches=8, dp=2)
        grid = {"workers": uniform_bandwidth_specs(8, [0.25, 0.5, 1.0, 2.0])}
        reused = scenario.sweep(opt, grid, reuse=True)
        rebuilt = scenario.sweep(opt, grid, reuse=False)
        assert [p.predicted for p in reused] == \
            [p.predicted for p in rebuilt]
        ms = [p.predicted for p in reused]
        assert ms == sorted(ms, reverse=True)

    def test_schedule_grid(self, scenario):
        preds = scenario.sweep("pipeline", {
            "stages": [4], "microbatches": [8],
            "schedule": ["gpipe", "1f1b"]})
        assert [p.point["schedule"] for p in preds] == ["gpipe", "1f1b"]
        # same work, same bubble on balanced stages; only the hop overlap
        # differs between the two orders
        assert preds[0].predicted == pytest.approx(preds[1].predicted,
                                                   rel=0.02)


class TestLegacyPipelineGraph:
    def test_hop_is_a_real_comm_task(self):
        """Satellite fix: the ppermute hop used to be a trailing gap on the
        producing task — invisible to bandwidth what-ifs."""
        g = pipeline_graph([1.0] * 3, 4, 0.5, hop_bytes=1e6)
        hops = [t for t in g.tasks() if t.kind == TaskKind.COMM]
        assert len(hops) == 2 * 4
        assert all(t.comm_bytes == 1e6 for t in hops)
        base = simulate(g).makespan
        faster = whatif.what_if_bandwidth(g, 4.0).simulate().makespan
        assert faster < base
        # and gaps carry nothing anymore
        assert all(t.gap == 0.0 for t in g.tasks())

    def test_fwd_bwd_closed_form(self):
        g = pipeline_graph([1.0] * 4, 8, bwd_stage_times_s=[2.0] * 4)
        assert simulate(g).makespan == pytest.approx((8 + 4 - 1) * 3.0)
        f = pipeline_graph([1.0] * 4, 8, bwd_stage_times_s=[2.0] * 4,
                           schedule="1f1b")
        assert simulate(f).makespan == pytest.approx((8 + 4 - 1) * 3.0)


class TestPushPullTracePath:
    """Satellite: P3 push/pull pairing on the asymmetric
    from_worker_graphs path (was replicate-build-only before PR 4)."""

    def test_from_worker_graphs_matches_build(self, step_graph):
        tf = whatif.what_if_p3(step_graph, GRADS, 4, bandwidth=5e9)
        built = ClusterGraph.build(tf.graph, 4,
                                   schedule=tf.schedule).simulate()
        asym = ClusterGraph.from_worker_graphs(
            [tf.graph] * 4, schedule=tf.schedule).simulate()
        assert asym.makespan == pytest.approx(built.makespan, rel=1e-12)

    def test_pairs_matched_by_layer_occurrence(self, step_graph):
        tf = whatif.what_if_p3(step_graph, GRADS, 2, bandwidth=5e9)
        groups = match_push_pull_groups([tf.graph, tf.graph])
        assert groups
        for group in groups:
            assert len(group) == 2
            (p0, pulls0), (p1, pulls1) = group
            assert p0.name == p1.name
            assert [v.name for v in pulls0] == [v.name for v in pulls1]

    def test_inconsistent_sets_raise(self, step_graph):
        tf = whatif.what_if_p3(step_graph, GRADS, 2, bandwidth=5e9)
        with pytest.raises(GraphError):
            ClusterGraph.from_worker_graphs([tf.graph, step_graph])

    def test_aggregation_semantics_on_asymmetric_path(self, step_graph):
        """A straggler's late pushes delay every worker's pulls through
        the aggregation barrier — now also on the imported-graph path."""
        tf = whatif.what_if_p3(step_graph, GRADS, 4, bandwidth=5e9)
        specs = [WorkerSpec(compute_scale=2.0 if i == 0 else 1.0)
                 for i in range(4)]
        uni = ClusterGraph.from_worker_graphs(
            [tf.graph] * 4, schedule=tf.schedule).simulate()
        strag = ClusterGraph.from_worker_graphs(
            [tf.graph] * 4, specs, schedule=tf.schedule).simulate()
        assert strag.per_worker[3].makespan > uni.per_worker[3].makespan
