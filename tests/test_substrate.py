"""Substrate layers: data, optimizer, checkpoint, fault tolerance, sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, host_shard, Prefetcher, make_batch
from repro.optim import AdamW, warmup_cosine, dgc_init, dgc_step, global_norm
from repro.ckpt import save_checkpoint, restore_checkpoint, latest_step, \
    CheckpointManager
from repro.runtime import FaultTolerantRunner, StragglerMonitor, RetryPolicy
from repro.sharding import ShardingRules, logical_spec


# ------------------------------------------------------------------- data
class TestData:
    def test_deterministic(self):
        a = SyntheticLM(100, 16, 4).batch_at(3)
        b = SyntheticLM(100, 16, 4).batch_at(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_next_tokens(self):
        b = SyntheticLM(100, 16, 4).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_shard_partitions(self):
        slices = [host_shard(10, i, 3) for i in range(3)]
        idx = []
        for s in slices:
            idx.extend(range(s.start, s.stop))
        assert sorted(idx) == list(range(10))

    def test_prefetcher_order_and_error(self):
        it = Prefetcher(iter([1, 2, 3]))
        assert list(it) == [1, 2, 3]

        def boom():
            yield 1
            raise ValueError("x")
        it = Prefetcher(boom())
        assert next(it) == 1
        with pytest.raises(ValueError):
            next(it)

    def test_structured_stream_learnable(self):
        b = SyntheticLM(97, 64, 8, noise=0.0).batch_at(0)
        # exact affine map when noise=0
        want = (5 * b["tokens"] + 131) % 97
        np.testing.assert_array_equal(want, b["labels"])


# ------------------------------------------------------------------ optim
class TestOptim:
    def test_adamw_decreases_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        p = {"x": jnp.asarray([5.0, -3.0])}
        s = opt.init(p)
        for _ in range(50):
            g = {"x": 2 * p["x"]}
            p, s = opt.apply(g, s, p)
        assert float(jnp.abs(p["x"]).max()) < 1.0

    def test_grad_clip_records_norm(self):
        opt = AdamW(lr=0.1, grad_clip=1.0)
        p = {"x": jnp.ones(4)}
        s = opt.init(p)
        g = {"x": jnp.full((4,), 100.0)}
        p, s = opt.apply(g, s, p)
        assert float(opt.last_grad_norm(s)) == pytest.approx(200.0)

    def test_warmup_cosine_shape(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, rel=0.2)
        assert float(f(jnp.asarray(100))) < 0.01

    def test_dgc_error_feedback_conserves(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
        st = dgc_init(g)
        sent, st = dgc_step(g, st, ratio=0.05)
        # sent + residual == original gradient (error feedback identity)
        total = sent["w"].astype(jnp.float32) + st.residual["w"]
        np.testing.assert_allclose(total, g["w"], atol=1e-6)
        nz = int(jnp.sum(sent["w"] != 0))
        assert 40 <= nz <= 80


# ------------------------------------------------------------------- ckpt
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
                "b": {"c": jnp.ones((4,), jnp.float32)}}
        save_checkpoint(str(tmp_path), 7, tree)
        out, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))

    def test_uncommitted_ignored(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        p = save_checkpoint(str(tmp_path), 1, tree)
        os.remove(os.path.join(p, "COMMIT"))
        assert latest_step(str(tmp_path)) is None

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, {"a": jnp.full((2,), s)})
        assert mgr.latest_step() == 4
        out, _ = mgr.restore_latest({"a": jnp.zeros(2)})
        np.testing.assert_array_equal(out["a"], [4, 4])
        steps = sorted(os.listdir(tmp_path))
        assert len([s for s in steps if s.startswith("step_")]) == 2

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(3, {"a": jnp.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 3

    def test_elastic_reshard(self, tmp_path):
        """Checkpoint restores onto a different mesh via NamedSharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        n = len(jax.devices())
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        save_checkpoint(str(tmp_path), 0, tree)
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------- runtime
class TestRuntime:
    def test_restart_from_checkpoint(self, tmp_path):
        saves = {}

        def make_state():
            return 0

        def step(s, i):
            return s + 1

        def save(s, i):
            saves["latest"] = (s, i)

        def restore():
            return saves.get("latest")

        crash_at = {5}

        def inject(i):
            if i in crash_at:
                crash_at.discard(i)
                raise RuntimeError("node failure")

        r = FaultTolerantRunner(make_state, step, save, restore,
                                save_every=2,
                                policy=RetryPolicy(max_failures=2,
                                                   backoff_s=0.0))
        final = r.run(10, inject_failure=inject)
        assert final == 10
        assert r.restarts == 1

    def test_failure_budget_exceeded(self):
        def step(s, i):
            raise RuntimeError("always")
        r = FaultTolerantRunner(lambda: 0, step, lambda s, i: None,
                                lambda: None,
                                policy=RetryPolicy(max_failures=2,
                                                   backoff_s=0.0))
        with pytest.raises(RuntimeError):
            r.run(3)

    def test_straggler_detection(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(8):
            mon.record(i, 1.0)
        assert mon.record(8, 5.0) is True
        assert mon.flagged == [8]


# --------------------------------------------------------------- sharding
class TestSharding:
    def test_no_mesh_resolves_replicated(self):
        spec = logical_spec("batch", None, "heads")
        assert all(s is None for s in spec)

    def test_rules_under_mesh(self):
        from repro.compat import make_mesh, set_mesh
        mesh = make_mesh((1,), ("model",))
        with set_mesh(mesh):
            rules = ShardingRules()
            spec = rules.spec("batch", "heads", dim_sizes=[4, 4])
            # model axis size 1 -> nothing shardable but no error
            assert len(spec) == 2

    def test_fsdp_toggle(self):
        r_on = ShardingRules(fsdp=True)
        r_off = ShardingRules(fsdp=False)
        assert r_off.physical("fsdp", dim_size=64) is None
        # without a mesh both degrade to None
        assert r_on.physical("fsdp", dim_size=64) is None
