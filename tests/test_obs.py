"""Counter-track telemetry (repro.obs) acceptance tests.

Covers the ISSUE's observability contracts:

* :class:`Timeline` math — delta construction, time-weighted rollups,
  Perfetto-shaped samples;
* the busy-interval helpers in ``core.simulate`` ARE ``obs.timeline``'s
  (single implementation, no drift);
* the acceptance golden: the live-memory timeline's peak equals the
  analytic sum over the live set at the peak instant to float precision
  on a DDP-transformed step graph;
* ``Prediction.timelines`` / ``ServingPrediction.timelines`` wiring
  (byte maps threaded, stale-retune guard raises instead of lying);
* counter round-trip: counter-carrying Chrome / XProf exports re-import
  byte-identically to counter-free ones;
* self-instrumentation spans: nested JSONL emission, error tagging,
  free disabled path, and the hot-path wiring (build/retune/sweep/import).
"""

import gzip
import json
import os

import pytest

from repro.core import (ClusterGraph, DependencyGraph, OptimizationError,
                        Scenario, Task, TaskKind, WorkerSpec, simulate,
                        whatif, DEVICE_STREAM)
# repro.core re-exports the simulate() function under the submodule's
# name, so fetch the module itself for the identity checks
import importlib
simulate_mod = importlib.import_module("repro.core.simulate")
from repro.obs import (Timeline, TimelineSet, compute_timelines,
                       check_result_fresh, format_timeline_report,
                       interval_overlap, interval_union, lane_utilization,
                       span)
from repro.obs import spans as spans_mod
from repro.obs import timeline as timeline_mod
from repro import traceio
from repro.traceio import (counter_track_events, export_cluster_traces,
                           export_graph_trace, read_chrome)
from repro.traceio.xla import read_xla_trace
from synthgraphs import training_step_graph

LAYERS = 6
GRADS = {f"l{i}": 30e6 for i in range(LAYERS)}
ACTS = {f"l{i}": 50e6 for i in range(LAYERS)}


# ============================================================ Timeline math
class TestTimeline:
    def test_from_deltas_merges_and_drops_zero_net(self):
        tl = Timeline.from_deltas(
            [(1.0, 2.0), (1.0, 3.0), (2.0, 1.0), (2.0, -1.0), (4.0, -5.0)],
            end=10.0)
        assert tl.times == (1.0, 4.0)          # t=2 net-zero point dropped
        assert tl.values == (5.0, 0.0)
        assert tl.end == 10.0

    def test_value_at_and_segments_cover_horizon(self):
        tl = Timeline.from_deltas([(1.0, 2.0), (3.0, -2.0)], end=5.0)
        assert tl.value_at(0.5) == 0.0          # before first change
        assert tl.value_at(1.0) == 2.0          # inclusive at change point
        assert tl.value_at(2.9) == 2.0
        assert tl.value_at(3.0) == 0.0
        segs = list(tl.segments())
        assert segs == [(0.0, 1.0, 0.0), (1.0, 3.0, 2.0), (3.0, 5.0, 0.0)]
        assert segs[0][0] == 0.0 and segs[-1][1] == tl.end   # gapless

    def test_peak_and_peak_time(self):
        tl = Timeline.from_deltas(
            [(1.0, 2.0), (2.0, 3.0), (3.0, -3.0), (4.0, -2.0)], end=6.0)
        assert tl.peak == 5.0
        assert tl.peak_time == 2.0
        # a series that starts below zero still reports peak >= 0 (the
        # implicit zero before the first change point counts)
        neg = Timeline.from_deltas([(1.0, -4.0), (2.0, 4.0)], end=3.0)
        assert neg.peak == 0.0

    def test_time_weighted_rollups(self):
        # 2.0 for 2s, 0 for the other 3s of a 5s horizon
        tl = Timeline.from_deltas([(1.0, 2.0), (3.0, -2.0)], end=5.0)
        assert tl.integral() == pytest.approx(4.0)
        assert tl.mean() == pytest.approx(0.8)
        # value <= 0 holds for 3/5 of the horizon -> p60 is 0, p61 is 2
        assert tl.percentile(0.60) == 0.0
        assert tl.percentile(0.61) == 2.0
        assert tl.percentile(1.0) == 2.0
        with pytest.raises(ValueError, match="percentile"):
            tl.percentile(1.5)

    def test_empty_timeline_rollups(self):
        tl = Timeline((), (), 4.0)
        assert tl.peak == 0.0 and tl.mean() == 0.0
        assert tl.value_at(2.0) == 0.0
        assert list(tl.segments()) == [(0.0, 4.0, 0.0)]
        assert tl.samples() == [(0.0, 0.0), (4.0, 0.0)]

    def test_samples_open_and_close_the_track(self):
        tl = Timeline.from_deltas([(1.0, 2.0), (3.0, -2.0)], end=5.0)
        s = tl.samples()
        assert s[0] == (0.0, 0.0)               # leading zero sample
        assert s[-1] == (5.0, 0.0)              # closing sample at end
        assert (1.0, 2.0) in s and (3.0, 0.0) in s


# ==================================================== single implementation
class TestHelperIdentity:
    def test_simulate_reexports_obs_helpers(self):
        """core.simulate's interval/utilization helpers must BE the obs
        ones — the dedup satellite, not a parallel re-implementation."""
        assert simulate_mod.lane_utilization is timeline_mod.lane_utilization
        assert simulate_mod._interval_union is timeline_mod.interval_union
        assert simulate_mod._overlap is timeline_mod.interval_overlap

    def test_interval_helpers(self):
        assert interval_union([(3, 4), (0, 1), (1, 2)]) == [(0, 2), (3, 4)]
        assert interval_overlap([(0, 2), (3, 4)], [(1, 5)]) == \
            pytest.approx(2.0)

    def test_lane_utilization_agrees_with_busy_timelines(self):
        g = training_step_graph()
        res = simulate(g)
        ts = compute_timelines(g, res)
        direct = lane_utilization(res)
        derived = ts.lane_utilization()
        assert set(direct) == set(derived)
        for th in direct:
            assert derived[th] == pytest.approx(direct[th], rel=1e-12)


# ========================================================= compute_timelines
class TestComputeTimelines:
    def test_utilization_bounded_and_scaled_by_lanes(self):
        g = training_step_graph()
        ts = compute_timelines(g, simulate(g))
        util = ts.utilization[0]
        assert all(0.0 <= v <= 1.0 + 1e-12 for v in util.values)
        assert ts.lanes_per_worker[0] >= 2      # device + host lanes

    def test_queue_depth_counts_ready_but_undispatched(self):
        # two free-floating unit tasks on ONE lane: both ready at t=0, the
        # second waits a full second for the lane -> depth 1 on [0, 1)
        g = DependencyGraph()
        g.add_task(Task("a", TaskKind.COMPUTE, DEVICE_STREAM, 1.0),
                   link_lane=False)
        g.add_task(Task("b", TaskKind.COMPUTE, DEVICE_STREAM, 1.0),
                   link_lane=False)
        ts = compute_timelines(g, simulate(g))
        q = ts.queue_depth[0]
        assert q.peak == 1.0
        assert q.value_at(0.5) == 1.0
        assert q.value_at(1.5) == 0.0
        assert q.integral() == pytest.approx(1.0)

    def test_zero_duration_barriers_never_queue(self):
        g = DependencyGraph()
        a = g.add_task(Task("a", TaskKind.COMPUTE, DEVICE_STREAM, 1.0))
        b = g.add_task(Task("barrier", TaskKind.SYNC, DEVICE_STREAM, 0.0))
        g.add_edge(a, b)
        ts = compute_timelines(g, simulate(g))
        assert 0 not in ts.queue_depth or ts.queue_depth[0].peak == 0.0

    def test_comm_bytes_in_flight(self):
        tf = whatif.what_if_distributed(training_step_graph(), GRADS,
                                        num_workers=4)
        ts = compute_timelines(tf.graph, tf.simulate())
        comm = ts.comm_bytes[0]
        assert comm.peak > 0.0
        assert comm.peak <= sum(GRADS.values()) + 1e-6

    def test_stale_result_raises(self):
        g = training_step_graph()
        res = simulate(g)
        next(iter(g.tasks())).duration *= 2.0   # retune after simulating
        with pytest.raises(ValueError, match="stale"):
            check_result_fresh(g, res)
        with pytest.raises(ValueError, match="stale"):
            compute_timelines(g, res)

    def test_report_renders(self):
        scn = Scenario(graph=training_step_graph(), layer_grad_bytes=GRADS,
                       activation_bytes=ACTS,
                       workers=[WorkerSpec()] * 4)
        text = format_timeline_report(scn.predict("ddp").timelines)
        assert "== timelines:" in text
        assert "w0" in text and "w3" in text
        assert "MiB" in text and "busiest lanes:" in text


# ==================================================== memory-timeline golden
def _brute_force_live_bytes(graph, res, t_star):
    """Analytic live bytes per worker at instant ``t_star``, straight from
    the documented alloc/free semantics — independent of the delta-merge
    path compute_timelines takes."""
    from repro.core.task import split_worker_thread
    comm_kinds = (TaskKind.COLLECTIVE, TaskKind.COMM)
    spans = {}          # (w, layer) -> [last_fwd, last_bwd, last_consumer]
    for t in graph.tasks():
        if not t.layer:
            continue
        w, _ = split_worker_thread(t.thread)
        w = 0 if w is None else w
        slot = spans.setdefault((w, t.layer), [None, None, None])
        fin = res.finish[t.uid]
        if t.phase == "fwd" and (slot[0] is None or fin > slot[0]):
            slot[0] = fin
        if t.phase == "bwd" and (slot[1] is None or fin > slot[1]):
            slot[1] = fin
        if (t.phase == "update" or t.kind in comm_kinds) \
                and (slot[2] is None or fin > slot[2]):
            slot[2] = fin
    live = {}
    for (w, layer), (fwd, bwd, consume) in spans.items():
        if fwd is not None:
            free = bwd if (bwd is not None and bwd > fwd) else res.makespan
            if fwd <= t_star < free:
                live[w] = live.get(w, 0.0) + ACTS[layer]
        if bwd is not None:
            free = consume if (consume is not None and consume > bwd) \
                else res.makespan
            if bwd <= t_star < free:
                live[w] = live.get(w, 0.0) + GRADS[layer]
    return live


class TestMemoryGolden:
    """Acceptance: the memory timeline's peak equals the analytic sum over
    the live set at the peak instant to float precision."""

    @pytest.fixture(scope="class")
    def ddp_cluster(self):
        tf = whatif.what_if_distributed(training_step_graph(), GRADS,
                                        num_workers=4)
        cg = ClusterGraph.build(tf.graph, 4)
        return cg, cg.simulate()

    def test_peak_equals_analytic_live_set(self, ddp_cluster):
        cg, cres = ddp_cluster
        ts = compute_timelines(cg.graph, cres, activation_bytes=ACTS,
                               layer_grad_bytes=GRADS)
        assert ts.workers == [0, 1, 2, 3]
        for w in ts.workers:
            mem = ts.memory[w]
            assert mem.peak > 0.0
            live = _brute_force_live_bytes(cg.graph, cres.global_result,
                                           mem.peak_time)
            assert mem.peak == pytest.approx(live[w], rel=1e-12)
        assert ts.peak_memory() == max(ts.memory[w].peak
                                       for w in ts.workers)

    def test_value_at_matches_analytic_everywhere(self, ddp_cluster):
        cg, cres = ddp_cluster
        ts = compute_timelines(cg.graph, cres, activation_bytes=ACTS,
                               layer_grad_bytes=GRADS)
        mem = ts.memory[0]
        probes = [0.5 * (t0 + t1) for t0, t1, _ in mem.segments()
                  if t1 > t0]
        for t_star in probes:
            live = _brute_force_live_bytes(cg.graph, cres.global_result,
                                           t_star)
            assert mem.value_at(t_star) == \
                pytest.approx(live.get(0, 0.0), rel=1e-12, abs=1e-6)

    def test_all_memory_eventually_freed(self, ddp_cluster):
        cg, cres = ddp_cluster
        ts = compute_timelines(cg.graph, cres, activation_bytes=ACTS,
                               layer_grad_bytes=GRADS)
        for w in ts.workers:
            assert ts.memory[w].value_at(ts.makespan) == pytest.approx(0.0)

    def test_no_byte_maps_no_memory_series(self, ddp_cluster):
        cg, cres = ddp_cluster
        ts = compute_timelines(cg.graph, cres)
        assert ts.memory == {}
        assert ts.peak_memory() == 0.0


# ===================================================== Prediction.timelines
class TestPredictionTimelines:
    def _scenario(self, workers):
        return Scenario(graph=training_step_graph(),
                        layer_grad_bytes=GRADS, activation_bytes=ACTS,
                        workers=workers)

    def test_cluster_route_carries_byte_maps(self):
        pred = self._scenario([WorkerSpec()] * 4).predict("ddp")
        ts = pred.timelines
        assert isinstance(ts, TimelineSet)
        assert ts.workers == [0, 1, 2, 3]
        assert ts.peak_memory(0) > 0.0
        assert pred.timelines is ts             # cached

    def test_single_route_carries_byte_maps(self):
        pred = self._scenario(4).predict("ddp")
        assert pred.timelines.peak_memory(0) > 0.0

    def test_sweep_reuse_stale_guard(self):
        """Spec-only sweep points retune one shared build in place; an
        earlier point's .timelines must raise, not describe the wrong
        point."""
        scn = self._scenario([WorkerSpec()] * 4)
        grid = {"workers": [[WorkerSpec()] * 4,
                            [WorkerSpec(compute_scale=2.0)]
                            + [WorkerSpec()] * 3]}
        preds = scn.sweep("ddp", grid, reuse=True)
        assert preds[1].predicted > preds[0].predicted   # retune took hold
        assert preds[-1].timelines.makespan > 0  # last point is fresh
        with pytest.raises(OptimizationError, match="stale"):
            preds[0].timelines

    def test_serving_prediction_timelines(self):
        from repro.serving import (ServingCostModel, ServingPolicy,
                                   ServingScenario, explicit_workload)
        scn = ServingScenario(
            workload=explicit_workload([(0.0, 64, 8)] * 4),
            policy=ServingPolicy(mode="static", slots=4),
            serving_cost=ServingCostModel())
        ts = scn.predict("noop").timelines
        assert ts.makespan > 0.0
        assert ts.utilization[0].mean() > 0.0


# ======================================================= counter round-trip
class TestCounterRoundTrip:
    def test_chrome_counter_events_shape(self):
        g = training_step_graph()
        res = simulate(g)
        ts = compute_timelines(g, res, activation_bytes=ACTS,
                               layer_grad_bytes=GRADS)
        cevs = counter_track_events(ts)
        names = {e["name"] for e in cevs}
        assert names == {"utilization", "memory_bytes", "ready_queue"}
        assert all(e["ph"] == "C" and "value" in e["args"] for e in cevs)

    def test_single_file_export_reimports_identically(self, tmp_path):
        g = training_step_graph()
        res = simulate(g)
        p_ctr = str(tmp_path / "with.trace.json")
        p_off = str(tmp_path / "without.trace.json")
        export_graph_trace(g, res, p_ctr, activation_bytes=ACTS,
                           layer_grad_bytes=GRADS)
        export_graph_trace(g, res, p_off, counters=False)
        with open(p_ctr) as f:
            assert any(e.get("ph") == "C"
                       for e in json.load(f)["traceEvents"])
        tr_ctr, tr_off = read_chrome(p_ctr), read_chrome(p_off)
        assert tr_ctr.events == tr_off.events   # reader skips counters

    def test_cluster_export_reimports_identically(self, tmp_path):
        tf = whatif.what_if_distributed(training_step_graph(), GRADS,
                                        num_workers=4)
        cg = ClusterGraph.build(tf.graph, 4)
        cres = cg.simulate()
        d_ctr, d_off = str(tmp_path / "ctr"), str(tmp_path / "off")
        paths = export_cluster_traces(cg, cres, d_ctr,
                                      activation_bytes=ACTS,
                                      layer_grad_bytes=GRADS)
        export_cluster_traces(cg, cres, d_off, counters=False)
        # every worker file carries C events, per-worker pid, plain names
        for i, p in enumerate(paths):
            with open(p) as f:
                cevs = [e for e in json.load(f)["traceEvents"]
                        if e.get("ph") == "C"]
            assert cevs and all(e["pid"] == i for e in cevs)
            assert {e["name"] for e in cevs} >= {"utilization",
                                                 "memory_bytes",
                                                 "ready_queue"}
        imp_ctr = traceio.load_trace_dir(d_ctr, align=False)
        imp_off = traceio.load_trace_dir(d_off, align=False)
        for a, b in zip(imp_ctr.traces, imp_off.traces):
            assert a.events == b.events
        re_ctr = ClusterGraph.from_worker_graphs(imp_ctr.graphs).simulate()
        assert re_ctr.makespan == pytest.approx(cres.makespan, rel=1e-9)

    def test_xla_reader_skips_counters(self, tmp_path):
        def meta(pid, tid, pname, tname):
            return [{"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "args": {"name": pname}},
                    {"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "args": {"name": tname}}]
        evs = meta(7, 1, "/host:CPU", "tf_XLATfrtCpuClient/1")
        evs.append({"ph": "X", "name": "dot.1", "pid": 7, "tid": 1,
                    "ts": 100.0, "dur": 200.0,
                    "args": {"hlo_op": "dot.1", "hlo_module": "jit_f"}})
        counters = [{"ph": "C", "name": "utilization", "pid": 7, "tid": 0,
                     "ts": float(t), "args": {"value": v}}
                    for t, v in ((0.0, 0.0), (100.0, 1.0), (300.0, 0.0))]
        p_off = str(tmp_path / "plain.trace.json.gz")
        p_ctr = str(tmp_path / "ctr.trace.json.gz")
        for path, events in ((p_off, evs), (p_ctr, evs + counters)):
            with gzip.open(path, "wt") as f:
                json.dump({"displayTimeUnit": "ns", "metadata": {},
                           "traceEvents": events}, f)
        tr_off = read_xla_trace(p_off, step=None)
        tr_ctr = read_xla_trace(p_ctr, step=None)
        assert len(tr_ctr) == len(tr_off) == 1
        assert tr_ctr[0].events == tr_off[0].events


# ================================================= self-instrumentation spans
class TestSpans:
    @pytest.fixture(autouse=True)
    def _clean(self):
        spans_mod.configure(None)
        yield
        spans_mod.configure(None)

    def _read(self, path):
        with open(path) as f:
            return [json.loads(line) for line in f]

    def test_disabled_is_shared_noop(self):
        assert not spans_mod.enabled()
        s = span("anything", x=1)
        assert s is span("other")               # the shared singleton
        with s as inner:
            inner.note(ignored=True)            # all no-ops

    def test_nested_emission_and_attrs(self, tmp_path):
        path = str(tmp_path / "spans.jsonl")
        spans_mod.configure(path)
        assert spans_mod.enabled()
        assert spans_mod.telemetry_path() == path
        with span("outer", a=1) as s:
            s.note(b=2)
            with span("inner"):
                pass
        spans_mod.configure(None)
        recs = self._read(path)
        assert [r["span"] for r in recs] == ["outer.inner", "outer"]
        assert recs[1]["attrs"] == {"a": 1, "b": 2}
        assert all(r["dur_s"] >= 0.0 for r in recs)
        assert "error" not in recs[0] and "error" not in recs[1]

    def test_error_tagged(self, tmp_path):
        path = str(tmp_path / "spans.jsonl")
        spans_mod.configure(path)
        with pytest.raises(RuntimeError):
            with span("boom"):
                raise RuntimeError("x")
        spans_mod.configure(None)
        (rec,) = self._read(path)
        assert rec["error"] == "RuntimeError"

    def test_hot_paths_are_wired(self, tmp_path):
        """build/retune/sweep/import all emit spans when enabled."""
        path = str(tmp_path / "spans.jsonl")
        d = str(tmp_path / "traces")
        traceio.write_synthetic_trace_dir(d, 2)
        spans_mod.configure(path)
        try:
            imp = traceio.load_trace_dir(d)
            cg = ClusterGraph.from_worker_graphs(imp.graphs)
            cg.retune([WorkerSpec(compute_scale=2.0), WorkerSpec()])
            scn = Scenario(graph=training_step_graph(),
                           layer_grad_bytes=GRADS,
                           workers=[WorkerSpec()] * 2)
            scn.sweep("ddp", {"bucket_bytes": [1e6, 120e6]})
        finally:
            spans_mod.configure(None)
        names = {r["name"] for r in self._read(path)}
        assert {"traceio.load_trace_dir", "cluster.from_worker_graphs",
                "cluster.build", "cluster.retune",
                "scenario.sweep_point"} <= names
