"""Per-architecture smoke tests (assignment requirement) + decode consistency.

Every assigned arch instantiates its REDUCED config and runs one forward +
train step on CPU, asserting output shapes and no NaNs.  Decode consistency
checks that prefill(S) + decode(S) token logits match a prefill over S+1
tokens (per family, with family-appropriate tolerances).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, get_smoke_config, get_config
from repro.data import make_batch
from repro.models import (build_model, make_train_step, make_serve_step,
                          make_prefill_step, count_params, active_params,
                          init_params)
from repro.optim import AdamW

ARCHS = list_archs()


def _batch(cfg, seq=32, batch=2, kind="train"):
    return {k: jnp.asarray(v) for k, v in
            make_batch(cfg, seq_len=seq, batch=batch, step=0,
                       kind=kind).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = AdamW(lr=1e-3)
    step = make_train_step(cfg, opt)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = _batch(cfg)
    state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state["step"]) == 1
    # params changed and stayed finite
    l0 = jax.tree.leaves(state["params"])[0]
    assert jnp.all(jnp.isfinite(l0.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_roundtrip(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S, B = 16, 2
    batch = _batch(cfg, seq=S, batch=B, kind="prefill")
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = jax.jit(model.decode)(
        params, cache, tok, jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v2-236b",
                                  "mamba2-2.7b", "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    """Greedy continuation equivalence: decode(S) logits ~= prefill(S+1)."""
    cfg = get_smoke_config(arch).with_(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S, B = 12, 2
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S + 1)), jnp.int32)

    full_logits, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    pre_logits, cache = jax.jit(model.prefill)(params,
                                               {"tokens": toks[:, :S]})
    # grow attention caches from S to S+1 where needed
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == S:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 1)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(grow, cache)
    dec_logits, _ = jax.jit(model.decode)(
        params, cache, toks[:, S:S + 1], jnp.asarray(S, jnp.int32))
    a = np.asarray(full_logits, np.float32)
    b = np.asarray(dec_logits, np.float32)
    # compare top-1 and normalized distance.  MoE archs are *expectedly*
    # looser: capacity allocation differs between a (S+1)-token prefill and
    # an incremental decode, so a few tokens route differently.
    cfg_full = get_smoke_config(arch)
    tol = 0.15 if cfg_full.n_experts else 0.05
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.5
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert rel < tol, rel


def test_param_counts_match_pool():
    """Full configs hit their advertised scale (sanity on exact numbers)."""
    expect = {
        "deepseek-v2-236b": (236e9, 0.05),
        "llama3-405b": (405e9, 0.02),
        "tinyllama-1.1b": (1.1e9, 0.05),
        "mamba2-2.7b": (2.7e9, 0.10),
        "llama3.2-1b": (1.24e9, 0.05),
    }
    for arch, (want, tol) in expect.items():
        n = count_params(get_config(arch))
        assert abs(n - want) / want < tol + 0.05, (arch, n)


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    assert active_params(cfg) < 0.15 * count_params(cfg)


def test_spec_mode_matches_real_init():
    cfg = get_smoke_config("tinyllama-1.1b")
    spec = init_params(cfg, None)
    real = init_params(cfg, jax.random.PRNGKey(0))
    spec_shapes = jax.tree.map(lambda l: tuple(l.shape), spec,
                               is_leaf=lambda x: hasattr(x, "logical"))
    real_shapes = jax.tree.map(lambda a: tuple(a.shape), real)
    assert spec_shapes == real_shapes


def test_grad_accum_equivalence():
    """grad_accum=2 must match accum=1 on the same global batch (linear loss
    in batch dim up to MoE noise; dense arch -> exact up to fp)."""
    cfg = get_smoke_config("tinyllama-1.1b").with_(dtype="float32")
    opt = AdamW(lr=0.0, weight_decay=0.0, grad_clip=0.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, seq=16, batch=4)
    s1 = {"params": params, "opt": opt.init(params),
          "step": jnp.zeros((), jnp.int32)}
    _, m1 = jax.jit(make_train_step(cfg, opt))(s1, batch)
    cfg2 = cfg.with_(grad_accum=2)
    s2 = {"params": params, "opt": opt.init(params),
          "step": jnp.zeros((), jnp.int32)}
    _, m2 = jax.jit(make_train_step(cfg2, opt))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
