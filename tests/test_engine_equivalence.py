"""Event-driven engine == legacy frontier-scan oracle (no optional deps).

These are the randomized property tests the ISSUE requires to run on a clean
machine: seeded ``random`` DAGs instead of hypothesis, asserting the engine
invariants documented in :mod:`repro.core.simulate`:

* identical makespans AND identical per-task start times vs the oracle,
  under both the default and a priority schedule;
* makespan >= critical-path lower bound (and <= total work upper bound);
* start order is topological on every simulated graph.
"""

import pytest

from repro.core import (DependencyGraph, Task, TaskKind, simulate,
                        simulate_reference, make_priority_schedule,
                        DEVICE_STREAM, HOST_THREAD)
from synthgraphs import random_dag, training_step_graph

SEEDS = list(range(25))


def _priority_schedule():
    return make_priority_schedule(lambda t: t.attrs.get("priority", -1))


@pytest.mark.parametrize("seed", SEEDS)
def test_engines_agree_default_schedule(seed):
    g = random_dag(seed)
    fast = simulate(g)
    slow = simulate_reference(g)
    assert fast.makespan == pytest.approx(slow.makespan, abs=1e-12)
    assert fast.start.keys() == slow.start.keys()
    for uid, s in slow.start.items():
        assert fast.start[uid] == pytest.approx(s, abs=1e-12)
    assert fast.thread_busy == pytest.approx(slow.thread_busy)


@pytest.mark.parametrize("seed", SEEDS)
def test_engines_agree_priority_schedule(seed):
    g = random_dag(seed, lane_prob=0.5)
    fast = simulate(g, _priority_schedule())
    slow = simulate_reference(g, _priority_schedule())
    assert fast.makespan == pytest.approx(slow.makespan, abs=1e-12)
    for uid, s in slow.start.items():
        assert fast.start[uid] == pytest.approx(s, abs=1e-12)


@pytest.mark.parametrize("seed", SEEDS)
def test_makespan_bounds(seed):
    g = random_dag(seed, n_tasks=60)
    r = simulate(g)
    assert len(r.start) == len(g)
    assert r.makespan >= g.critical_path() - 1e-9
    assert r.makespan <= g.total_work() + 1e-9


@pytest.mark.parametrize("seed", SEEDS[:10])
def test_start_order_topological(seed):
    """Every edge u->v implies start[v] >= finish[u] + u.gap."""
    g = random_dag(seed, n_tasks=50)
    r = simulate(g)
    for u in g.tasks():
        for v in g.children(u):
            assert r.start[v.uid] >= r.finish[u.uid] + u.gap - 1e-9


def test_engines_agree_on_training_step():
    g = training_step_graph()
    fast, slow = simulate(g), simulate_reference(g)
    assert fast.makespan == pytest.approx(slow.makespan, abs=1e-15)
    assert fast.breakdown == pytest.approx(slow.breakdown)


def test_zero_duration_and_gap_only_tasks():
    """Degenerate durations exercise the heap's tie handling."""
    g = DependencyGraph()
    a = g.add_task(Task("a", TaskKind.HOST, HOST_THREAD, 0.0, gap=1.0))
    b = g.add_task(Task("b", TaskKind.COMPUTE, DEVICE_STREAM, 0.0))
    c = g.add_task(Task("c", TaskKind.COMPUTE, DEVICE_STREAM, 2.0))
    g.add_edge(a, b)
    fast, slow = simulate(g), simulate_reference(g)
    assert fast.makespan == slow.makespan == pytest.approx(3.0)
    assert fast.start[b.uid] == pytest.approx(1.0)


def test_deadlock_detection_matches():
    g = DependencyGraph()
    a = g.add_task(Task("a", TaskKind.COMPUTE, DEVICE_STREAM, 1.0))
    b = g.add_task(Task("b", TaskKind.COMPUTE, DEVICE_STREAM, 1.0))
    g.add_edge(b, a)          # cycle through the lane edge a->b
    with pytest.raises(RuntimeError, match="deadlock"):
        simulate(g)
    with pytest.raises(RuntimeError, match="deadlock"):
        simulate_reference(g)
