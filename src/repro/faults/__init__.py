"""Goodput under failures: fault-injection simulation over the step engine.

Daydream-style what-ifs predict the *steady-state* step makespan.  Production
training jobs rarely run in steady state: workers fail at some MTBF and
restart from checkpoints, preemptible capacity comes and goes in windows, and
transient stragglers dilate whole step times.  This package answers the
question practitioners actually ask — "how many *useful* steps/hour do I get
at my MTBF, and does mitigation X pay?" — by simulation, before deployment.

Model (and its assumptions)
---------------------------

``events``    Seeded stochastic failure processes produce a reproducible
              :class:`FaultTimeline`: per-worker exponential MTBF failures,
              deterministic preemption windows, and transient straggler
              windows that dilate step time by a multiplicative factor.
              Everything is seeded per (seed, kind, worker) stream, so the
              timeline is bit-identical across reruns and stable when the
              worker count changes.

``recovery``  A typed :class:`RecoveryModel` costs each episode: detection
              (heartbeat timeout, from ``runtime.fault.Heartbeat`` defaults),
              checkpoint restore (bytes from ``ckpt.checkpoint_bytes`` or the
              Scenario gradient byte maps, bandwidth from the CostModel's
              host<->device DMA path), process restart, replacement
              acquisition (or hot-spare activation), and elastic re-meshing.

``goodput``   A renewal-style event simulator interleaves steady-state step
              makespans with fault/recovery episodes.  Between fault events
              progress advances in closed form over checkpoint blocks (K
              steps + one synchronous checkpoint write), so the cost is
              O(fault events), not O(steps).  A failure rolls the job back
              to the last *committed* step: work since the last finished
              checkpoint is lost, bounding lost work per failure by the
              checkpoint interval.

Assumptions, explicitly: failures are fail-stop and detected by heartbeat
timeout; checkpoint writes are synchronous on the step path (no async
overlap); rollback restores exactly the last committed step (no partial
credit); preemptions are *graceful* — a proactive checkpoint runs before
capacity disappears, so they cost availability but never lose work; elastic
re-meshing keeps the global batch size, so per-worker compute scales by
N/(N-k) while collectives re-close over the surviving group (via the same
fold/wire machinery as the steady-state cluster build); stragglers are
transient and job-wide (the dilated lane gates the synchronous step).

Surfaces
--------

:class:`FaultScenario` routes the registered what-ifs ``ckpt_interval``,
``elastic``, ``hot_spare`` and ``straggler_mitigation`` through the ordinary
registry / ``sweep`` / critical-path / timeline machinery and returns
:class:`GoodputPrediction` (useful steps/hour, availability, lost work,
checkpoint/recovery overheads, capacity + progress counter timelines).
``python -m repro.launch.goodput`` and ``perf_report --goodput`` are the CLI
entry points; ``young_daly_interval`` gives the closed-form optimum the
checkpoint-interval sweep is cross-checked against in tests.
"""

from repro.faults.events import (FaultEvent, FaultTimeline,
                                 exponential_failures, preemption_windows,
                                 transient_stragglers)
from repro.faults.goodput import (GoodputReport, simulate_goodput,
                                  young_daly_interval, young_daly_steps)
from repro.faults.recovery import RecoveryModel
from repro.faults.scenario import (CkptInterval, Elastic, FaultPolicy,
                                   FaultScenario, GoodputPrediction, HotSpare,
                                   StragglerMitigation, demo_scenario,
                                   format_goodput_table)

__all__ = [
    "FaultEvent",
    "FaultTimeline",
    "exponential_failures",
    "preemption_windows",
    "transient_stragglers",
    "GoodputReport",
    "simulate_goodput",
    "young_daly_interval",
    "young_daly_steps",
    "RecoveryModel",
    "FaultPolicy",
    "FaultScenario",
    "GoodputPrediction",
    "CkptInterval",
    "Elastic",
    "HotSpare",
    "StragglerMitigation",
    "demo_scenario",
    "format_goodput_table",
]
