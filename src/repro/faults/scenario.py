"""FaultScenario: goodput what-ifs through the optimization registry.

Mirrors the :class:`~repro.serving.scenario.ServingScenario` routing
pattern: ``ckpt_interval``, ``elastic``, ``hot_spare`` and
``straggler_mitigation`` are *registered optimizations* — they parse from
CLI stack specs, compose with ``|`` / :class:`Stack`, and sweep over grids
— but instead of rewriting the step graph they fold into a
:class:`FaultPolicy` and the scenario re-runs the goodput simulator under
that policy.  Every other stack member (``ddp``, ``amp``, ``bandwidth``,
...) applies as a normal graph what-if to produce the *steady-state* step
makespan the goodput simulation interleaves with fault episodes.

Steady-state reuse: evaluating one fault policy point needs the step
makespan at the full worker count (and, for elastic jobs, at each reduced
count the failure process actually visits).  Those cluster evaluations are
cached on the scenario keyed by ``(residual stack spec, worker count)``,
so a checkpoint-interval sweep — or any sweep that only moves fault-policy
parameters — re-runs only the O(fault events) renewal simulation per
point, never the cluster build.  ``bench_faults.py`` gates this at >= 3x
over rebuilding the steady state per point.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.core.cluster import ClusterGraph, WorkerSpec
from repro.core.graph import DependencyGraph
from repro.core.optimize import (Optimization, OptimizationError, Prediction,
                                 Scenario, Stack, _resolve, register)
from repro.core.task import DEVICE_STREAM, HOST_THREAD, Task, TaskKind
from repro.core.transform import GraphTransform
from repro.faults.events import (FaultTimeline, exponential_failures,
                                 preemption_windows, transient_stragglers)
from repro.faults.goodput import (GoodputReport, simulate_goodput,
                                  young_daly_steps)
from repro.faults.recovery import RecoveryModel

__all__ = [
    "FaultPolicy", "FaultOptimization", "CkptInterval", "Elastic",
    "HotSpare", "StragglerMitigation", "GoodputPrediction", "FaultScenario",
    "demo_scenario", "format_goodput_table",
]


# ================================================================= policy
@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """The resolved fault-handling configuration of one evaluation."""

    ckpt_interval_steps: int = 100
    elastic: bool = False
    min_workers: int = 1
    hot_spares: int = 0
    straggler_mitigation: bool = False
    mitigation_overhead: float = 0.02
    mitigation_cap: float = 1.2


# ===================================================== fault optimizations
class FaultOptimization(Optimization):
    """Base for registered optimizations that adjust the fault policy.

    A checkpoint interval is not a graph rewrite, so :meth:`build` raises
    (the :class:`~repro.serving.scenario.ServingOptimization` pattern) and
    :class:`FaultScenario` intercepts via :meth:`adjust` instead.
    """

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        raise OptimizationError(
            f"{self.name!r} is a fault-policy optimization; evaluate it "
            f"via a repro.faults.FaultScenario (it re-runs the goodput "
            f"simulation rather than rewriting the step graph)")

    def adjust(self, policy: FaultPolicy) -> FaultPolicy:
        raise NotImplementedError

    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        return None     # availability policies have no shrink-only bound


@register("ckpt_interval", "checkpoint_interval")
@dataclasses.dataclass(frozen=True)
class CkptInterval(FaultOptimization):
    """Checkpoint every ``steps`` steps: smaller intervals lose less work
    per failure but pay the synchronous write more often (Young/Daly)."""

    steps: int = 100

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise OptimizationError(
                f"ckpt_interval needs steps >= 1, got {self.steps}")

    def adjust(self, policy: FaultPolicy) -> FaultPolicy:
        return dataclasses.replace(policy, ckpt_interval_steps=self.steps)


@register("elastic")
@dataclasses.dataclass(frozen=True)
class Elastic(FaultOptimization):
    """Keep training on the surviving N-k workers instead of halting for a
    replacement: collectives re-close over the smaller group and per-worker
    compute scales by N/(N-k) (global batch preserved)."""

    min_workers: int = 1

    def adjust(self, policy: FaultPolicy) -> FaultPolicy:
        return dataclasses.replace(policy, elastic=True,
                                   min_workers=max(1, self.min_workers))


@register("hot_spare", "hot_spares")
@dataclasses.dataclass(frozen=True)
class HotSpare(FaultOptimization):
    """Provision ``count`` idle spares: replacement acquisition drops from
    the cold ``repair_s`` path to ``spare_activation_s``; a consumed spare
    restocks once the failed machine is repaired."""

    count: int = 1

    def __post_init__(self) -> None:
        if self.count < 1:
            raise OptimizationError(
                f"hot_spare needs count >= 1, got {self.count}")

    def adjust(self, policy: FaultPolicy) -> FaultPolicy:
        return dataclasses.replace(policy, hot_spares=self.count)


@register("straggler_mitigation")
@dataclasses.dataclass(frozen=True)
class StragglerMitigation(FaultOptimization):
    """Cap transient straggler dilation at ``cap`` (backup workers /
    work re-assignment) at the price of ``overhead`` on *every* step —
    whether it pays depends on the straggler process, which is exactly
    what the goodput simulation answers."""

    overhead: float = 0.02
    cap: float = 1.2

    def __post_init__(self) -> None:
        if self.overhead < 0:
            raise OptimizationError(
                f"straggler_mitigation overhead must be >= 0, "
                f"got {self.overhead}")
        if self.cap < 1.0:
            raise OptimizationError(
                f"straggler_mitigation cap must be >= 1.0, got {self.cap}")

    def adjust(self, policy: FaultPolicy) -> FaultPolicy:
        return dataclasses.replace(policy, straggler_mitigation=True,
                                   mitigation_overhead=self.overhead,
                                   mitigation_cap=self.cap)


def _split_fault(opt: Optimization
                 ) -> Tuple[List[FaultOptimization],
                            Optional[Optimization]]:
    """Partition a (possibly stacked) optimization into fault-policy
    members and the residual graph-transforming stack (``None`` if empty).
    """
    members = opt.opts if isinstance(opt, Stack) else (opt,)
    fault = [o for o in members if isinstance(o, FaultOptimization)]
    rest = [o for o in members if not isinstance(o, FaultOptimization)]
    if not fault:
        return [], opt
    if not rest:
        return fault, None
    return fault, (rest[0] if len(rest) == 1 else Stack(*rest))


# ============================================================== prediction
@dataclasses.dataclass
class GoodputPrediction(Prediction):
    """A :class:`Prediction` over *useful* throughput under failures.

    ``baseline`` is the scenario's fault-free baseline step makespan and
    ``predicted`` the *effective* seconds per useful step
    (``horizon / useful_steps``), so ``.speedup`` compares useful
    throughput against the fault-free baseline and composes across
    residual graph what-ifs.  The carried graph/result are the full-N
    steady-state step (critical path and counter timelines describe one
    steady step); the fault-horizon story lives in :attr:`report` and the
    :attr:`capacity_timeline` / :attr:`progress_timeline` counter series.
    """

    report: Optional[GoodputReport] = None
    policy: Optional[FaultPolicy] = None
    #: steady-state step makespan at full N under the residual stack
    steady_step_s: float = 0.0

    # ----------------------------------------------------- conveniences --
    @property
    def goodput(self) -> float:
        """Useful steps per hour."""
        return self.report.goodput_steps_per_hour

    @property
    def goodput_fraction(self) -> float:
        """Useful throughput over this policy's own fault-free rate."""
        return self.report.goodput_fraction

    @property
    def availability(self) -> float:
        return self.report.availability

    @property
    def capacity_timeline(self):
        """Piecewise-constant active-worker count over the horizon
        (:class:`repro.obs.Timeline`)."""
        return _samples_timeline(self.report.capacity_samples,
                                 self.report.horizon_s)

    @property
    def progress_timeline(self):
        """Committed (durable) steps over the horizon."""
        return _samples_timeline(self.report.progress_samples,
                                 self.report.horizon_s)

    @property
    def critical_path(self):
        """Critical path of the *steady-state step* this prediction
        interleaved with fault episodes (same checked extraction as the
        base class, against the steady step makespan)."""
        if self._cp is None:
            if self.graph is None:
                raise OptimizationError(
                    "this GoodputPrediction does not carry its steady-state "
                    "graph; re-evaluate via FaultScenario.predict")
            from repro.analysis import extract_critical_path
            cp = extract_critical_path(self.graph, schedule=self.schedule)
            if abs(cp.makespan - self.steady_step_s) > \
                    1e-9 * max(abs(self.steady_step_s), 1e-30):
                raise OptimizationError(
                    f"the steady-state graph no longer reproduces this "
                    f"prediction (makespan {cp.makespan} vs "
                    f"{self.steady_step_s}); re-evaluate this point via "
                    f"FaultScenario.predict")
            self._cp = cp
        return self._cp

    def __repr__(self) -> str:
        return (f"GoodputPrediction({self.optimization.spec()}: "
                f"{self.goodput:,.1f} useful steps/h "
                f"({self.goodput_fraction:.1%} of fault-free), "
                f"availability {self.availability:.1%})")


def _samples_timeline(samples, end: float):
    from repro.obs import Timeline
    deltas = []
    prev = 0.0
    for t, v in samples:
        if v != prev:
            deltas.append((t, v - prev))
            prev = v
    return Timeline.from_deltas(deltas, end)


# ================================================================ scenario
@dataclasses.dataclass
class FaultScenario(Scenario):
    """A :class:`Scenario` that predicts goodput under a fault process.

    The training side (graph, cost, byte maps, workers, traces) is a
    normal scenario; on top of it, ``mtbf_s``/``seed`` drive a per-worker
    exponential failure process, optional deterministic preemption windows
    and transient straggler windows complete the
    :class:`~repro.faults.events.FaultTimeline`, and ``recovery`` (derived
    from the scenario's byte maps + CostModel when not given) prices each
    episode.  ``evaluate``/``predict``/``sweep`` accept stacks mixing
    fault-policy members with ordinary graph what-ifs::

        scn.predict("ddp,elastic,ckpt_interval:steps=250")
    """

    mtbf_s: float = 0.0                 # per-worker MTBF; 0 = no failures
    horizon_s: float = 86400.0          # simulated wall-clock (24h)
    seed: int = 0
    ckpt_interval_steps: int = 100
    recovery: Optional[RecoveryModel] = None
    # deterministic preemption windows (period 0 = none)
    preempt_period_s: float = 0.0
    preempt_duration_s: float = 0.0
    preempt_offset_s: float = 0.0
    preempt_workers: int = 1
    # transient straggler windows (rate 0 = none)
    straggler_rate_per_hour: float = 0.0
    straggler_slowdown: float = 2.0
    straggler_duration_s: float = 120.0
    #: explicit event timeline overriding the generated processes
    timeline: Optional[FaultTimeline] = None

    _steady_cache: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _ftl: Optional[FaultTimeline] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.horizon_s <= 0:
            raise OptimizationError(
                f"FaultScenario horizon must be > 0, got {self.horizon_s}")
        if self.recovery is None:
            self.recovery = RecoveryModel.from_scenario(self)

    # ---------------------------------------------------------- timeline --
    def fault_timeline(self) -> FaultTimeline:
        """The (cached) reproducible event timeline for this scenario."""
        if self._ftl is None:
            if self.timeline is not None:
                self._ftl = self.timeline.until(self.horizon_s)
            else:
                tl = exponential_failures(self.num_workers, self.mtbf_s,
                                          self.horizon_s, self.seed)
                if self.preempt_period_s > 0 and self.preempt_duration_s > 0:
                    tl = tl | preemption_windows(
                        self.preempt_period_s, self.preempt_duration_s,
                        self.horizon_s, offset_s=self.preempt_offset_s,
                        workers=self.preempt_workers)
                if self.straggler_rate_per_hour > 0:
                    tl = tl | transient_stragglers(
                        self.straggler_rate_per_hour,
                        self.straggler_slowdown,
                        self.straggler_duration_s, self.horizon_s,
                        self.seed)
                self._ftl = tl
        return self._ftl

    @property
    def job_mtbf_s(self) -> float:
        """Job-level MTBF: any of the N workers failing ends the epoch."""
        if self.mtbf_s <= 0:
            return math.inf
        return self.mtbf_s / self.num_workers

    # ------------------------------------------------- steady-state cache --
    def _elastic_specs(self, n: int) -> List[WorkerSpec]:
        base = self.specs
        big_n = len(base)
        if n < 1 or n > big_n:
            raise OptimizationError(
                f"cannot evaluate steady state at {n} of {big_n} workers")
        scale = big_n / n
        # failed workers drop from the end of the spec list (approximation
        # for heterogeneous clusters); global batch is preserved, so the
        # survivors each compute scale-times more
        return [dataclasses.replace(w, compute_scale=w.compute_scale * scale)
                for w in base[:n]]

    def _steady(self, residual: Optional[Optimization], n: int, *,
                rescale: bool = False
                ) -> Tuple[Prediction, GraphTransform,
                           Optional[ClusterGraph]]:
        """Steady-state step evaluation at ``n`` workers, cached by
        (residual spec, n) so fault-policy sweeps never rebuild it."""
        key = (residual.spec() if residual is not None else "noop",
               n, bool(rescale))
        hit = self._steady_cache.get(key)
        if hit is not None:
            return hit
        if n == self.num_workers and not rescale:
            scn: Scenario = self
        else:
            if self.traces is not None:
                raise OptimizationError(
                    "elastic re-meshing is not supported on the trace "
                    "route: reduced-worker step times cannot be derived "
                    "from fixed per-worker traces")
            scn = dataclasses.replace(self, workers=self._elastic_specs(n))
        eval_opt = residual if residual is not None else _resolve("noop")
        out = Scenario._evaluate(scn, eval_opt)
        self._steady_cache[key] = out
        return out

    # ------------------------------------------------------------ routing --
    def _evaluate(self, opt: Optimization, *,
                  baseline: Optional[float] = None,
                  point: Optional[Dict[str, Any]] = None,
                  reuse: bool = True
                  ) -> Tuple[GoodputPrediction, GraphTransform,
                             Optional[ClusterGraph]]:
        base = self.baseline().makespan if baseline is None else baseline
        fault, residual = _split_fault(opt)
        policy = FaultPolicy(ckpt_interval_steps=self.ckpt_interval_steps)
        for fo in fault:
            policy = fo.adjust(policy)

        n = self.num_workers
        rescale = policy.elastic and n > 1
        steady_pred, tf, cg = self._steady(residual, n, rescale=rescale)
        step_full = steady_pred.predicted
        if rescale:
            def step_fn(active: int) -> float:
                if active >= n:
                    return step_full
                return self._steady(residual, active,
                                    rescale=True)[0].predicted
        else:
            step_fn = step_full

        report = simulate_goodput(
            n_workers=n, horizon_s=self.horizon_s,
            timeline=self.fault_timeline(), recovery=self.recovery,
            ckpt_interval_steps=policy.ckpt_interval_steps,
            step_s=step_fn, elastic=policy.elastic,
            hot_spares=policy.hot_spares,
            straggler_mitigation=policy.straggler_mitigation,
            mitigation_overhead=policy.mitigation_overhead,
            mitigation_cap=policy.mitigation_cap,
            min_workers=policy.min_workers)
        predicted = (self.horizon_s / report.useful_steps
                     if report.useful_steps else math.inf)
        pred = GoodputPrediction(
            opt, base, predicted, steady_pred.result, steady_pred.cluster,
            dict(point or {}), graph=steady_pred.graph,
            schedule=steady_pred.schedule, byte_maps=self._byte_maps(),
            report=report, policy=policy, steady_step_s=step_full)
        return pred, tf, cg

    def sweep(self, opt, grid, *, reuse: bool = True
              ) -> List[GoodputPrediction]:
        """Grid sweep; the base class's reuse fast paths construct plain
        :class:`Prediction`\\ s that would drop the goodput report, so
        ``reuse`` is forced off — the steady-state cache on this scenario
        is what makes fault-policy sweeps cheap instead."""
        return super().sweep(opt, grid, reuse=False)

    # ------------------------------------------------------------ helpers --
    def optimal_ckpt_interval(self, opt: Union[str, Optimization,
                                               None] = None,
                              intervals: Optional[List[int]] = None
                              ) -> Tuple[GoodputPrediction,
                                         List[GoodputPrediction], int]:
        """Sweep the checkpoint interval and return
        ``(best, all points, young_daly_steps)``.

        The default grid brackets the Young/Daly closed-form optimum
        geometrically; ``opt`` stacks extra members (fault policies or
        graph what-ifs) under every point.
        """
        fault, residual = _split_fault(_resolve(opt)) if opt is not None \
            else ([], None)
        rescale = any(isinstance(f, Elastic) for f in fault)
        step_full = self._steady(residual, self.num_workers,
                                 rescale=rescale)[0].predicted
        k_yd = young_daly_steps(self.recovery.checkpoint_write_s,
                                self.job_mtbf_s, step_full)
        if intervals is None:
            if math.isinf(self.job_mtbf_s):
                intervals = [self.ckpt_interval_steps]
            else:
                intervals = sorted({max(1, int(round(k_yd * f)))
                                    for f in (0.25, 0.5, 0.75, 1.0,
                                              1.5, 2.0, 4.0)})
        preds = []
        for k in intervals:
            members = [o for o in fault
                       if not isinstance(o, CkptInterval)]
            members.append(CkptInterval(steps=k))
            if residual is not None:
                members.insert(0, residual)
            o = members[0] if len(members) == 1 else Stack(*members)
            preds.append(self._evaluate(o, point={"steps": k})[0])
        best = max(preds, key=lambda p: (p.report.useful_steps,
                                         -p.policy.ckpt_interval_steps))
        return best, preds, k_yd


# ================================================================== demo
def demo_scenario(*, workers: int = 16, layers: int = 8,
                  mtbf_s: float = 6 * 3600.0, horizon_s: float = 86400.0,
                  seed: int = 0, **kw) -> FaultScenario:
    """A canonical synthetic data-parallel fault scenario (CLI/example/
    bench default): ``layers`` fwd/bwd/update layers, 64 MB gradients per
    layer, ``workers`` workers.  Evaluate stacks like
    ``"ddp,elastic,ckpt_interval:steps=250"`` against it."""
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM,
                            2e-3, layer=f"l{i}", phase="fwd"))
        if i == 0:
            g.add_edge(h, t)
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 4e-3,
                        layer=f"l{i}", phase="bwd"))
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 1e-3,
                        layer=f"l{i}", phase="update"))
    grads = {f"l{i}": 64e6 for i in range(layers)}
    acts = {f"l{i}": 32e6 for i in range(layers)}
    return FaultScenario(graph=g, layer_grad_bytes=grads,
                         activation_bytes=acts, workers=workers,
                         mtbf_s=mtbf_s, horizon_s=horizon_s, seed=seed,
                         **kw)


# ================================================================ report
def format_goodput_table(preds: List[GoodputPrediction]) -> str:
    """Fixed-width goodput table for the launch.goodput CLI."""
    hdr = (f"{'what-if':<44} {'steps/h':>10} {'of ideal':>9} "
           f"{'avail':>7} {'fails':>6} {'lost':>7} {'speedup':>8}")
    lines = [hdr, "-" * len(hdr)]
    for p in preds:
        spec = p.optimization.spec()
        if len(spec) > 43:
            spec = spec[:40] + "..."
        r = p.report
        lines.append(
            f"{spec:<44} {r.goodput_steps_per_hour:>10,.0f} "
            f"{r.goodput_fraction:>8.1%} {r.availability:>6.1%} "
            f"{r.failures:>6d} {r.lost_steps:>7d} {p.speedup:>7.2f}x")
    return "\n".join(lines)
