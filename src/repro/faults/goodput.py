"""Renewal-style goodput simulation: steady-state steps + fault episodes.

The simulator owns a tiny amount of state — current step/checkpoint phase,
steps since the last commit, the set of down workers — and advances it event
by event over a :class:`~repro.faults.events.FaultTimeline`.  Between fault
events progress is closed-form: a checkpoint block is ``K`` steps at ``s``
seconds plus one synchronous write of ``c`` seconds, so a quiet span of
``T`` seconds completes ``T // (K*s + c)`` whole blocks in O(1).  Total
cost is O(fault events), independent of the number of steps simulated —
simulating a week at a 2-second step costs the same as simulating an hour.

Semantics (see the package docstring for the full assumption list):

* A *failure* rolls back to the last committed step: everything since the
  last finished checkpoint (steps, partial step, partial checkpoint write)
  is lost, so lost work per failure is bounded by the checkpoint interval.
* A *preemption* is graceful: completed steps commit via a proactive
  checkpoint, the capacity disappears for the window, nothing is lost.
* A *straggler window* dilates the synchronous step by its slowdown factor;
  overlapping windows take the max.  ``straggler_mitigation`` caps the
  dilation at ``mitigation_cap`` but pays ``mitigation_overhead`` on every
  step — which is exactly why "does it pay?" needs simulating.
* An *elastic* job drops failed/preempted workers and keeps stepping at
  reduced capacity (per-step time from ``step_s(active)``); a non-elastic
  job halts until full capacity is restored.  ``hot_spares`` short-circuit
  replacement acquisition; a consumed spare is restocked once the failed
  machine is repaired.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, List, Optional, Tuple, Union

from repro.faults.events import FaultTimeline
from repro.faults.recovery import RecoveryModel

__all__ = ["GoodputReport", "simulate_goodput", "young_daly_interval",
           "young_daly_steps"]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class GoodputReport:
    """What a fault-injected run of ``horizon_s`` seconds produced."""

    n_workers: int
    horizon_s: float
    ckpt_interval_steps: int
    #: fault-free full-cluster step seconds (before dilation/overhead)
    step_s_full: float

    useful_steps: int           # surviving executed steps
    committed_steps: int        # steps durably committed by a checkpoint
    lost_steps: int             # steps rolled back by failures
    failures: int
    preemptions: int
    straggler_windows: int

    useful_s: float             # time spent on surviving steps
    ckpt_s: float               # time spent writing (surviving) checkpoints
    lost_s: float               # rolled-back step + partial-ckpt time
    stalled_s: float            # detection, repair, restore, remesh, idle

    max_lost_steps_per_failure: int
    #: (time, active running workers) — piecewise-constant capacity
    capacity_samples: Tuple[Tuple[float, int], ...]
    #: (time, committed steps) — durable-progress curve
    progress_samples: Tuple[Tuple[float, int], ...]

    @property
    def goodput_steps_per_hour(self) -> float:
        return self.useful_steps / self.horizon_s * 3600.0

    @property
    def fault_free_steps_per_hour(self) -> float:
        return 3600.0 / self.step_s_full

    @property
    def goodput_fraction(self) -> float:
        """Useful throughput as a fraction of fault-free throughput."""
        return self.goodput_steps_per_hour / self.fault_free_steps_per_hour

    @property
    def availability(self) -> float:
        """Fraction of the horizon spent making surviving progress."""
        return self.useful_s / self.horizon_s if self.horizon_s > 0 else 0.0

    @property
    def lost_work_per_failure_s(self) -> float:
        return self.lost_s / self.failures if self.failures else 0.0

    def describe(self) -> str:
        return (f"{self.goodput_steps_per_hour:,.1f} useful steps/h "
                f"({self.goodput_fraction:.1%} of fault-free), "
                f"availability {self.availability:.1%}, "
                f"{self.failures} failures, {self.lost_steps} steps lost")


def young_daly_interval(ckpt_write_s: float, job_mtbf_s: float) -> float:
    """Young/Daly first-order optimal checkpoint interval, in seconds.

    ``tau_opt = sqrt(2 * delta * M)`` with ``delta`` the checkpoint write
    cost and ``M`` the *job-level* MTBF (per-worker MTBF / N workers).
    """
    if ckpt_write_s <= 0 or job_mtbf_s <= 0 or math.isinf(job_mtbf_s):
        return math.inf
    return math.sqrt(2.0 * ckpt_write_s * job_mtbf_s)


def young_daly_steps(ckpt_write_s: float, job_mtbf_s: float,
                     step_s: float) -> int:
    """Young/Daly optimum expressed as a whole number of steps (>= 1)."""
    tau = young_daly_interval(ckpt_write_s, job_mtbf_s)
    if math.isinf(tau):
        return 1 << 30
    return max(1, int(round(tau / step_s)))


class _Engine:
    """Event-by-event goodput state machine (module-private)."""

    def __init__(self, *, n_workers, horizon_s, recovery, k,
                 step_fn, elastic, hot_spares, straggler_mitigation,
                 mitigation_overhead, mitigation_cap, min_workers):
        self.n = n_workers
        self.horizon = horizon_s
        self.rec = recovery
        self.K = k
        self.step_fn = step_fn
        self.elastic = elastic
        self.spares = hot_spares
        self.mitigate = straggler_mitigation
        self.mit_overhead = mitigation_overhead
        self.mit_cap = mitigation_cap
        self.min_workers = min_workers

        self.cw = recovery.checkpoint_write_s

        # progress state
        self.phase = "step"          # "step" | "ckpt"
        self.frac = 0.0              # work fraction of the current unit
        self.unit_spent = 0.0        # wall seconds invested in current unit
        self.executed = 0            # surviving steps (rolled back on fail)
        self.committed = 0
        self.since_ckpt = 0
        self.uncommitted_s = 0.0

        # availability state
        self.halted_until = 0.0
        self.down: set = set()       # failed workers awaiting replacement
        self.preempted = 0           # workers inside a preemption window
        self.dilations: List[float] = []

        # counters
        self.useful_s = 0.0
        self.ckpt_s = 0.0
        self.lost_s = 0.0
        self.lost_steps = 0
        self.failures = 0
        self.preemptions = 0
        self.straggler_windows = 0
        self.max_lost_one = 0

        self.cap_samples: List[Tuple[float, int]] = []
        self.prog_samples: List[Tuple[float, int]] = [(0.0, 0)]
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0

    # ---------------------------------------------------------- state --
    def active(self) -> int:
        if self.elastic:
            return self.n - len(self.down) - self.preempted
        return self.n

    def runnable(self, t: float) -> bool:
        if t + _EPS < self.halted_until:
            return False
        if self.elastic:
            return self.active() >= self.min_workers
        return not self.down and self.preempted == 0

    def step_seconds(self) -> float:
        dil = max(self.dilations) if self.dilations else 1.0
        if self.mitigate:
            dil = min(dil, self.mit_cap)
        s = self.step_fn(self.active()) * dil
        if self.mitigate:
            s *= 1.0 + self.mit_overhead
        return s

    def _push(self, t: float, kind: str, payload=None) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _sample_capacity(self, t: float) -> None:
        cap = self.active() if self.runnable(t + _EPS) else 0
        if not self.cap_samples or self.cap_samples[-1][1] != cap:
            self.cap_samples.append((t, cap))

    def _sample_progress(self, t: float) -> None:
        if self.prog_samples[-1][1] != self.committed:
            self.prog_samples.append((t, self.committed))

    # ------------------------------------------------------- progress --
    def _finish_step(self) -> None:
        self.executed += 1
        self.since_ckpt += 1
        self.uncommitted_s += self.unit_spent
        self.frac = 0.0
        self.unit_spent = 0.0
        if self.since_ckpt >= self.K:
            self.phase = "ckpt"

    def _commit(self) -> None:
        self.useful_s += self.uncommitted_s
        self.uncommitted_s = 0.0
        self.committed = self.executed
        self.since_ckpt = 0
        self.phase = "step"
        self.frac = 0.0
        self.unit_spent = 0.0

    def _finish_ckpt(self) -> None:
        self.ckpt_s += self.unit_spent
        self._commit()

    def _rollback(self) -> None:
        lost_now = self.since_ckpt
        self.lost_s += self.uncommitted_s + self.unit_spent
        self.lost_steps += lost_now
        self.max_lost_one = max(self.max_lost_one, lost_now)
        self.executed = self.committed
        self.since_ckpt = 0
        self.uncommitted_s = 0.0
        self.phase = "step"
        self.frac = 0.0
        self.unit_spent = 0.0

    def _commit_graceful(self) -> None:
        """Proactive checkpoint before a preemption window: whole steps
        commit, an in-flight step stays frozen and resumes afterwards."""
        if self.phase == "ckpt":
            self.ckpt_s += self.unit_spent
            self._commit()
        elif self.since_ckpt > 0:
            part_frac, part_spent = self.frac, self.unit_spent
            self._commit()
            self.frac, self.unit_spent = part_frac, part_spent

    def _advance(self, span: float, s: float) -> None:
        """Consume ``span`` running seconds at step cost ``s``."""
        c, k = self.cw, self.K
        rem = span
        while rem > _EPS:
            if self.phase == "ckpt":
                need = (1.0 - self.frac) * c
                if need > rem + _EPS:
                    self.frac += rem / c
                    self.unit_spent += rem
                    return
                rem -= need
                self.unit_spent += need
                self._finish_ckpt()
                continue
            if self.frac > 0.0:
                need = (1.0 - self.frac) * s
                if need > rem + _EPS:
                    self.frac += rem / s
                    self.unit_spent += rem
                    return
                rem -= need
                self.unit_spent += need
                self._finish_step()
                continue
            # clean step boundary: closed-form over whole blocks
            to_commit = k - self.since_ckpt
            t_block = to_commit * s + c
            if rem + _EPS >= t_block:
                self._bulk_steps(to_commit, s)
                self.unit_spent = c
                self.phase = "ckpt"
                self._finish_ckpt()
                rem -= t_block
                block = k * s + c
                nb = int((rem + _EPS) // block)
                if nb > 0:
                    self.executed += nb * k
                    self.useful_s += nb * k * s
                    self.ckpt_s += nb * c
                    self.committed = self.executed
                    rem -= nb * block
                continue
            m = min(to_commit, int((rem + _EPS) // s))
            if m > 0:
                self._bulk_steps(m, s)
                rem -= m * s
            if self.since_ckpt >= k:
                self.phase = "ckpt"
                continue
            if rem > _EPS:
                self.frac = rem / s
                self.unit_spent = rem
            return

    def _bulk_steps(self, m: int, s: float) -> None:
        self.executed += m
        self.since_ckpt += m
        self.uncommitted_s += m * s

    # --------------------------------------------------------- events --
    def _on_fail(self, t: float, worker: int) -> None:
        if worker in self.down:
            return  # already dead; its repair is in flight
        self.failures += 1
        self._rollback()
        rec = self.rec
        if self.elastic:
            self.down.add(worker)
            if self.spares > 0:
                self.spares -= 1
                back = t + rec.detection_s + rec.spare_activation_s
                self._push(t + rec.detection_s + rec.repair_s,
                           "spare_restock")
            else:
                back = t + rec.detection_s + rec.repair_s
            self._push(back, "rejoin", worker)
            self.halted_until = max(self.halted_until,
                                    t + rec.downtime_s(elastic=True))
        else:
            self.down.add(worker)
            if self.spares > 0:
                self.spares -= 1
                wait = rec.spare_activation_s
                self._push(t + rec.detection_s + rec.repair_s,
                           "spare_restock")
            else:
                wait = rec.repair_s
            resume = (t + rec.detection_s + wait + rec.restore_s
                      + rec.restart_s)
            self._push(resume, "resume", worker)
            self.halted_until = max(self.halted_until, resume)

    def _on_rejoin(self, t: float, worker: int) -> None:
        self.down.discard(worker)
        # scale-up re-mesh pauses the (running) job briefly
        self.halted_until = max(self.halted_until, t + self.rec.remesh_s)

    def _on_preempt_start(self, t: float, count: int) -> None:
        self.preemptions += 1
        self._commit_graceful()
        if self.elastic:
            self.preempted += count
            self.halted_until = max(self.halted_until,
                                    t + self.rec.remesh_s)
        else:
            self.preempted += count

    def _on_preempt_end(self, t: float, count: int) -> None:
        self.preempted = max(0, self.preempted - count)
        if self.elastic:
            self.halted_until = max(self.halted_until,
                                    t + self.rec.remesh_s)

    # ------------------------------------------------------------ run --
    def run(self, timeline: FaultTimeline) -> GoodputReport:
        for ev in timeline.until(self.horizon):
            if ev.kind == "fail":
                self._push(ev.time, "fail", ev.worker)
            elif ev.kind == "preempt":
                self._push(ev.time, "preempt_start", ev.count)
                self._push(ev.end, "preempt_end", ev.count)
            elif ev.kind == "straggler":
                self._push(ev.time, "strag_start", ev.slowdown)
                self._push(ev.end, "strag_end", ev.slowdown)
        self._sample_capacity(0.0)

        t = 0.0
        while True:
            te = self._heap[0][0] if self._heap else self.horizon
            te = min(te, self.horizon)
            # run (or idle through) the quiet segment [t, te)
            while te - t > _EPS:
                if t + _EPS < self.halted_until:
                    t = min(te, self.halted_until)
                    self._sample_capacity(t)
                    continue
                if not self.runnable(t):
                    t = te
                    break
                seg_end = te
                if self.halted_until > t:  # pragma: no cover - guard
                    seg_end = min(seg_end, self.halted_until)
                self._advance(seg_end - t, self.step_seconds())
                t = seg_end
            self._sample_progress(t)
            if not self._heap or self._heap[0][0] >= self.horizon - _EPS:
                break
            tev, _, kind, payload = heapq.heappop(self._heap)
            t = max(t, tev)
            if kind == "fail":
                self._on_fail(t, payload)
            elif kind == "rejoin":
                self._on_rejoin(t, payload)
            elif kind == "resume":
                self.down.discard(payload)
            elif kind == "spare_restock":
                self.spares += 1
            elif kind == "preempt_start":
                self._on_preempt_start(t, payload)
            elif kind == "preempt_end":
                self._on_preempt_end(t, payload)
            elif kind == "strag_start":
                self.straggler_windows += 1
                self.dilations.append(payload)
            elif kind == "strag_end":
                self.dilations.remove(payload)
            self._sample_capacity(t)

        return self._finalize()

    def _finalize(self) -> GoodputReport:
        # steps executed but not yet committed still count as useful: no
        # failure claimed them inside the horizon.
        useful_s = self.useful_s + self.uncommitted_s
        ckpt_s = self.ckpt_s
        if self.phase == "ckpt":
            ckpt_s += self.unit_spent
            inprog = 0.0
        else:
            inprog = self.unit_spent
        stalled = max(0.0, self.horizon - useful_s - ckpt_s - self.lost_s
                      - inprog)
        self._sample_progress(self.horizon)
        step_full = self.step_fn(self.n)
        return GoodputReport(
            n_workers=self.n,
            horizon_s=self.horizon,
            ckpt_interval_steps=self.K,
            step_s_full=step_full,
            useful_steps=self.executed,
            committed_steps=self.committed,
            lost_steps=self.lost_steps,
            failures=self.failures,
            preemptions=self.preemptions,
            straggler_windows=self.straggler_windows,
            useful_s=useful_s,
            ckpt_s=ckpt_s,
            lost_s=self.lost_s,
            stalled_s=stalled,
            max_lost_steps_per_failure=self.max_lost_one,
            capacity_samples=tuple(self.cap_samples),
            progress_samples=tuple(self.prog_samples),
        )


def simulate_goodput(*, n_workers: int, horizon_s: float,
                     timeline: FaultTimeline, recovery: RecoveryModel,
                     ckpt_interval_steps: int,
                     step_s: Union[float, Callable[[int], float]],
                     elastic: bool = False, hot_spares: int = 0,
                     straggler_mitigation: bool = False,
                     mitigation_overhead: float = 0.02,
                     mitigation_cap: float = 1.2,
                     min_workers: int = 1) -> GoodputReport:
    """Simulate ``horizon_s`` seconds of training under ``timeline``.

    ``step_s`` is either the constant steady-state step makespan or a
    callable ``active_workers -> seconds`` (elastic jobs query it at
    reduced worker counts).  Deterministic: the same inputs produce a
    bit-identical :class:`GoodputReport`.
    """
    if n_workers < 1:
        raise ValueError(f"need >= 1 worker, got {n_workers}")
    if horizon_s <= 0:
        raise ValueError(f"horizon must be > 0, got {horizon_s}")
    if ckpt_interval_steps < 1:
        raise ValueError(f"checkpoint interval must be >= 1 step, "
                         f"got {ckpt_interval_steps}")
    if callable(step_s):
        step_fn = step_s
    else:
        const = float(step_s)
        if const <= 0:
            raise ValueError(f"step_s must be > 0, got {const}")
        step_fn = lambda active: const  # noqa: E731
    eng = _Engine(n_workers=n_workers, horizon_s=horizon_s,
                  recovery=recovery, k=ckpt_interval_steps,
                  step_fn=step_fn, elastic=elastic, hot_spares=hot_spares,
                  straggler_mitigation=straggler_mitigation,
                  mitigation_overhead=mitigation_overhead,
                  mitigation_cap=mitigation_cap,
                  min_workers=max(1, min_workers))
    return eng.run(timeline)
