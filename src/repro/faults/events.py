"""Seeded fault processes producing a reproducible ``FaultTimeline``.

Three primitive event kinds cover the failure modes the goodput simulator
models:

``fail``       fail-stop worker failure (exponential inter-arrival at a
               per-worker MTBF).  The job rolls back to its last committed
               checkpoint and pays the recovery pipeline.
``preempt``    a capacity window: ``count`` workers disappear at ``time`` and
               return ``duration`` seconds later.  Preemptions are graceful
               (proactive checkpoint), so they cost availability, not work.
``straggler``  a transient slowdown window: the synchronous step dilates by
               ``slowdown`` for ``duration`` seconds.

Generators draw every stream from ``random.Random`` seeded with a
``"{seed}:{kind}:{worker}"`` string, which CPython hashes stably (sha512),
so timelines are bit-identical across processes and insensitive to
``PYTHONHASHSEED`` — and each worker's stream is independent of the total
worker count, so growing the cluster does not reshuffle existing streams.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Tuple

__all__ = [
    "FaultEvent",
    "FaultTimeline",
    "exponential_failures",
    "preemption_windows",
    "transient_stragglers",
]

_KINDS = ("fail", "preempt", "straggler")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One fault episode on the timeline (ordered by time)."""

    time: float
    kind: str = dataclasses.field(compare=False)
    worker: int = dataclasses.field(default=0, compare=False)
    #: window length for preempt/straggler episodes (0 for fail-stop)
    duration: float = dataclasses.field(default=0.0, compare=False)
    #: step-time dilation factor for straggler windows
    slowdown: float = dataclasses.field(default=1.0, compare=False)
    #: workers taken by a preemption window
    count: int = dataclasses.field(default=1, compare=False)

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_KINDS}")
        if self.time < 0:
            raise ValueError(f"fault event at negative time {self.time}")
        if self.duration < 0:
            raise ValueError(f"negative duration {self.duration}")

    @property
    def end(self) -> float:
        return self.time + self.duration


@dataclasses.dataclass(frozen=True)
class FaultTimeline:
    """An immutable, time-sorted sequence of fault events.

    Construct with any iterable of events (sorted on construction) and
    combine independent processes with ``|`` / :meth:`merge`.
    """

    events: Tuple[FaultEvent, ...] = ()
    horizon_s: float = 0.0

    def __post_init__(self) -> None:
        evs = tuple(sorted(self.events, key=lambda e: (e.time, e.kind,
                                                       e.worker)))
        object.__setattr__(self, "events", evs)
        horizon = self.horizon_s
        if evs and horizon <= 0:
            horizon = max(e.end for e in evs)
        object.__setattr__(self, "horizon_s", float(horizon))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __or__(self, other: "FaultTimeline") -> "FaultTimeline":
        return self.merge(other)

    def merge(self, *others: "FaultTimeline") -> "FaultTimeline":
        evs = list(self.events)
        horizon = self.horizon_s
        for tl in others:
            evs.extend(tl.events)
            horizon = max(horizon, tl.horizon_s)
        return FaultTimeline(tuple(evs), horizon)

    def of_kind(self, kind: str) -> Tuple[FaultEvent, ...]:
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        return tuple(e for e in self.events if e.kind == kind)

    def until(self, horizon_s: float) -> "FaultTimeline":
        """Clip to events starting before ``horizon_s``."""
        return FaultTimeline(tuple(e for e in self.events
                                   if e.time < horizon_s), horizon_s)


def _stream(seed: int, kind: str, worker: int) -> random.Random:
    return random.Random(f"{seed}:{kind}:{worker}")


def exponential_failures(n_workers: int, mtbf_s: float, horizon_s: float,
                         seed: int = 0) -> FaultTimeline:
    """Fail-stop failures: per-worker Poisson process at 1/``mtbf_s``.

    ``mtbf_s`` is the *per-worker* mean time between failures; the job-level
    MTBF is ``mtbf_s / n_workers``.  ``mtbf_s <= 0`` means no failures.
    """
    if n_workers < 1:
        raise ValueError(f"need >= 1 worker, got {n_workers}")
    events = []
    if mtbf_s > 0:
        rate = 1.0 / mtbf_s
        for w in range(n_workers):
            rng = _stream(seed, "fail", w)
            t = rng.expovariate(rate)
            while t < horizon_s:
                events.append(FaultEvent(time=t, kind="fail", worker=w))
                t += rng.expovariate(rate)
    return FaultTimeline(tuple(events), horizon_s)


def preemption_windows(period_s: float, duration_s: float, horizon_s: float,
                       offset_s: float = 0.0,
                       workers: int = 1) -> FaultTimeline:
    """Deterministic periodic preemption: ``workers`` vanish for
    ``duration_s`` every ``period_s`` seconds, first window at ``offset_s``.
    """
    events = []
    if period_s > 0 and duration_s > 0 and workers > 0:
        if duration_s >= period_s:
            raise ValueError("preemption duration must be < period")
        t = offset_s
        while t < horizon_s:
            events.append(FaultEvent(time=t, kind="preempt",
                                     duration=duration_s, count=workers))
            t += period_s
    return FaultTimeline(tuple(events), horizon_s)


def transient_stragglers(rate_per_hour: float, slowdown: float,
                         duration_s: float, horizon_s: float,
                         seed: int = 0) -> FaultTimeline:
    """Transient straggler windows arriving as a Poisson process.

    Each window dilates the synchronous step time by ``slowdown`` for
    ``duration_s`` seconds; overlapping windows take the max dilation, not
    the product (one slow lane gates the step, two slow lanes do not gate it
    twice).
    """
    events = []
    if rate_per_hour > 0 and slowdown > 1.0 and duration_s > 0:
        rate = rate_per_hour / 3600.0
        rng = _stream(seed, "straggler", 0)
        t = rng.expovariate(rate)
        while t < horizon_s:
            events.append(FaultEvent(time=t, kind="straggler",
                                     duration=duration_s, slowdown=slowdown))
            t += rng.expovariate(rate)
    return FaultTimeline(tuple(events), horizon_s)
