"""Typed recovery-cost model: what one fault episode costs, in seconds.

The recovery pipeline after a fail-stop failure is::

    detection -> (replacement | hot spare | elastic re-mesh) -> restore
              -> restart

``detection_s`` defaults to the ``runtime.fault.Heartbeat`` staleness
timeout (60s) — the simulator assumes failures are noticed when the
heartbeat goes stale, not instantly.  Checkpoint write/restore time is
``checkpoint_bytes`` over the host<->device DMA bandwidth from the
CostModel's :class:`HardwareSpec` (``pcie_bandwidth``), matching how
``repro/ckpt`` moves arrays through host memory to disk.  Replacement
acquisition (``repair_s``) models waiting for a fresh machine; a hot spare
short-circuits it to ``spare_activation_s``; an elastic job skips it
entirely and pays ``remesh_s`` to re-close collectives over N-k workers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["RecoveryModel"]

#: runtime.fault.Heartbeat.is_alive default staleness timeout
_HEARTBEAT_TIMEOUT_S = 60.0


@dataclasses.dataclass(frozen=True)
class RecoveryModel:
    """Per-episode recovery costs for the goodput simulator."""

    #: heartbeat-staleness detection latency after a fail-stop failure
    detection_s: float = _HEARTBEAT_TIMEOUT_S
    #: process restart / framework re-init after state is restored
    restart_s: float = 30.0
    #: re-closing collectives over the surviving group (elastic only)
    remesh_s: float = 15.0
    #: acquiring a replacement machine (cold path, no spare)
    repair_s: float = 600.0
    #: promoting a provisioned hot spare into the job
    spare_activation_s: float = 20.0
    #: checkpoint payload per worker, bytes (params + optimizer state)
    checkpoint_bytes: float = 0.0
    #: host<->device / host<->disk staging bandwidth for ckpt I/O
    ckpt_bandwidth: float = 32e9
    #: fixed per-checkpoint overhead (fsync, commit rename, barrier)
    ckpt_latency_s: float = 0.5

    def __post_init__(self) -> None:
        for name in ("detection_s", "restart_s", "remesh_s", "repair_s",
                     "spare_activation_s", "checkpoint_bytes",
                     "ckpt_latency_s"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if self.ckpt_bandwidth <= 0:
            raise ValueError("ckpt_bandwidth must be > 0")

    @property
    def checkpoint_write_s(self) -> float:
        """Synchronous checkpoint write cost on the step path."""
        return self.checkpoint_bytes / self.ckpt_bandwidth + \
            self.ckpt_latency_s

    @property
    def restore_s(self) -> float:
        """Reading the checkpoint back and placing it on device."""
        return self.checkpoint_bytes / self.ckpt_bandwidth + \
            self.ckpt_latency_s

    def downtime_s(self, *, elastic: bool = False,
                   hot_spare: bool = False) -> float:
        """Wall-clock pause after one failure, excluding lost work.

        Elastic jobs drop the failed worker and re-mesh; non-elastic jobs
        wait for a replacement (a hot spare if provisioned, else the cold
        ``repair_s`` acquisition path) before restoring.
        """
        t = self.detection_s + self.restore_s + self.restart_s
        if elastic:
            return t + self.remesh_s
        return t + (self.spare_activation_s if hot_spare else self.repair_s)

    @classmethod
    def from_scenario(cls, scenario, params_tree=None, *,
                      optimizer_state_factor: float = 3.0,
                      **overrides) -> "RecoveryModel":
        """Derive a model from a :class:`~repro.core.optimize.Scenario`.

        Checkpoint bytes come from, in order of preference: an explicit
        ``params_tree`` sized with :func:`repro.ckpt.checkpoint_bytes`, or
        the scenario's per-layer gradient byte map scaled by
        ``optimizer_state_factor`` (params + Adam moments ~= 3x the
        gradient payload, which is itself param-sized).  Bandwidth comes
        from the CostModel's host<->device DMA path.
        """
        byte_total = 0.0
        if params_tree is not None:
            from repro.ckpt import checkpoint_bytes
            byte_total = float(checkpoint_bytes(params_tree))
        elif getattr(scenario, "layer_grad_bytes", None):
            byte_total = (sum(scenario.layer_grad_bytes.values())
                          * optimizer_state_factor)
        kw = dict(checkpoint_bytes=byte_total)
        cost = getattr(scenario, "cost", None)
        hw = getattr(cost, "hw", None)
        if hw is not None and getattr(hw, "pcie_bandwidth", 0):
            kw["ckpt_bandwidth"] = float(hw.pcie_bandwidth)
        kw.update(overrides)
        return cls(**kw)

    def describe(self) -> str:
        return (f"detection {self.detection_s:.0f}s, restore "
                f"{self.restore_s:.1f}s ({self.checkpoint_bytes / 1e9:.2f} "
                f"GB @ {self.ckpt_bandwidth / 1e9:.0f} GB/s), restart "
                f"{self.restart_s:.0f}s, repair {self.repair_s:.0f}s, "
                f"remesh {self.remesh_s:.0f}s")
