"""Self-instrumentation spans: JSONL telemetry for the tool's own hot paths.

The simulator is itself a performance artifact — trace import, cluster
build/retune, sweep points, calibration rounds, and serving graphgen all
have bench-gated budgets, but regressions in the field are invisible
without timing in situ.  ``span()`` wraps those sections:

    from repro.obs import span
    with span("cluster.retune", records=len(prov)) as s:
        ...
        s.note(touched=n)

Emission is **off by default** and costs one module-global ``None`` check
(bench-gated <= 1.05x in ``benchmarks/bench_obs.py``).  Set
``REPRO_TELEMETRY=<path>`` in the environment (read once at import) or
call :func:`configure` (the ``--telemetry PATH`` CLI flag) to append one
JSON object per completed span::

    {"span": "scenario.sweep.scenario.sweep_point", "name": "...",
     "ts": <wall-clock start>, "dur_s": <perf_counter duration>,
     "attrs": {...}, "error": "ValueError"?}

``span`` is the dotted path of the contextvar-stacked enclosing spans, so
nested sections reconstruct a call tree without ids; ``contextvars`` keeps
the stack correct across threads and async tasks.  Stdlib-only: importable
from anywhere in the package without cycles.
"""

from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

__all__ = ["span", "configure", "enabled", "telemetry_path"]

_ENV = "REPRO_TELEMETRY"
_path: Optional[str] = os.environ.get(_ENV) or None
_file = None
_lock = threading.Lock()
_stack: "contextvars.ContextVar[Tuple[str, ...]]" = contextvars.ContextVar(
    "repro_obs_span_stack", default=())


def enabled() -> bool:
    """True when spans are being written somewhere."""
    return _path is not None


def telemetry_path() -> Optional[str]:
    """The active JSONL sink path, or None when disabled."""
    return _path


def configure(path: Optional[str]) -> None:
    """Point span emission at ``path`` (JSONL, appended); ``None``/empty
    disables.  Overrides ``REPRO_TELEMETRY``; safe to call repeatedly."""
    global _path, _file
    with _lock:
        if _file is not None:
            try:
                _file.close()
            finally:
                _file = None
        _path = path or None


def _emit(record: Dict[str, Any]) -> None:
    global _file
    line = json.dumps(record, default=str)
    with _lock:
        if _path is None:        # disabled between span start and end
            return
        if _file is None:
            _file = open(_path, "a", encoding="utf-8")
        _file.write(line + "\n")
        _file.flush()


class _NullSpan:
    """Shared no-op span: the entire disabled-path cost."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def note(self, **attrs: Any) -> None:
        pass


_NULL = _NullSpan()


class Span:
    """Context manager recording one timed section (see module doc)."""

    __slots__ = ("name", "attrs", "_t0", "_wall", "_token")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "Span":
        self._token = _stack.set(_stack.get() + (self.name,))
        self._wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def note(self, **attrs: Any) -> None:
        """Attach attributes discovered mid-section to the record."""
        self.attrs.update(attrs)

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        dur = time.perf_counter() - self._t0
        path = _stack.get()
        _stack.reset(self._token)
        rec: Dict[str, Any] = {"span": ".".join(path), "name": self.name,
                               "ts": self._wall, "dur_s": dur}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        if self.attrs:
            rec["attrs"] = self.attrs
        _emit(rec)
        return False


def span(name: str, **attrs: Any) -> Any:
    """A timed section named ``name``; no-op unless telemetry is enabled."""
    if _path is None:
        return _NULL
    return Span(name, attrs)
