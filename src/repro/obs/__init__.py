"""Observability: counter timelines and self-instrumentation spans.

Two independent layers share this package because both answer "what
happened *over time*?" rather than "what was the total?":

* :mod:`repro.obs.timeline` — piecewise-constant :class:`Timeline` counter
  series (per-lane busy/utilization, ready-queue depth, COMM bytes in
  flight, per-worker live memory) derived from any simulated result, plus
  the single busy-interval implementation ``core.simulate`` and serving
  route through.  Surfaced as ``Prediction.timelines`` and as Perfetto
  counter tracks in ``traceio.chrome`` exports.
* :mod:`repro.obs.spans` — JSONL span telemetry for the tool's own hot
  paths (``REPRO_TELEMETRY=<path>`` / ``--telemetry``), a no-op otherwise.

Neither submodule imports ``repro.*`` at module scope, so ``repro.obs``
is importable from anywhere in the package without cycles.
"""

from repro.obs.spans import configure, enabled, span, telemetry_path
from repro.obs.timeline import (Timeline, TimelineSet, check_result_fresh,
                                compute_timelines, format_timeline_report,
                                interval_overlap, interval_union,
                                lane_utilization)

__all__ = [
    "Timeline", "TimelineSet", "check_result_fresh", "compute_timelines",
    "format_timeline_report", "interval_overlap", "interval_union",
    "lane_utilization",
    "span", "configure", "enabled", "telemetry_path",
]
