"""Counter timelines: time-resolved telemetry derived from simulated results.

A :class:`SimResult` collapses a timeline to scalars (makespan, per-lane
busy seconds) plus per-task start/finish instants.  This module re-expands
those instants into piecewise-constant *counter* series — the view a
practitioner actually inspects when asking "why does this lane idle at
t=4ms?" or "when does activation memory peak?":

* per-lane **busy** (0/1) and per-worker **utilization** (busy-lane
  fraction, 0..1),
* per-worker **ready-queue depth** (tasks whose dependencies have resolved
  but whose lane has not dispatched them yet),
* per-worker **COMM bytes in flight** (outstanding COLLECTIVE/COMM payload),
* per-worker **live memory** (activations alloc'd at the last forward task
  of a layer and freed at its last backward consumer; gradients alloc'd at
  the last backward task and freed at the last collective/update consumer
  — sized from the Scenario byte maps).

The busy-interval helpers (:func:`interval_union`, :func:`interval_overlap`,
:func:`lane_utilization`) are THE single implementation; ``core/simulate``
imports them back so the engine's host/device breakdown and every serving
``lane_utilization`` consumer share one definition.

This module deliberately imports nothing from ``repro.*`` at module scope
(only inside functions) so ``repro.obs`` can be imported from anywhere in
the package — including ``core.simulate`` itself — without cycles.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Mapping,
                    Optional, Sequence, Tuple)

__all__ = [
    "Timeline", "TimelineSet", "interval_union", "interval_overlap",
    "lane_utilization", "check_result_fresh", "compute_timelines",
    "format_timeline_report",
]


# ------------------------------------------------------- interval helpers
def interval_union(intervals: List[Tuple[float, float]]
                   ) -> List[Tuple[float, float]]:
    """Merge overlapping/touching ``(start, end)`` intervals (sorted out)."""
    if not intervals:
        return []
    intervals = sorted(intervals)
    out = [intervals[0]]
    for s, e in intervals[1:]:
        ls, le = out[-1]
        if s <= le:
            out[-1] = (ls, max(le, e))
        else:
            out.append((s, e))
    return out


def interval_overlap(a: List[Tuple[float, float]],
                     b: List[Tuple[float, float]]) -> float:
    """Total overlap seconds between two *disjoint-sorted* interval lists."""
    i = j = 0
    tot = 0.0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if e > s:
            tot += e - s
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return tot


def lane_utilization(result: Any) -> Dict[str, float]:
    """Per-lane busy fraction of the makespan, from ``thread_busy``.

    A lane (simulator thread) at 1.0 worked the entire timeline; serving
    predictions report this per batch-slot lane to show how a policy keeps
    (or starves) its slots.  Zero-makespan results report 0.0 everywhere.
    """
    if result.makespan <= 0:
        return {th: 0.0 for th in result.thread_busy}
    return {th: busy / result.makespan
            for th, busy in result.thread_busy.items()}


# ---------------------------------------------------------------- Timeline
@dataclasses.dataclass(frozen=True)
class Timeline:
    """A piecewise-constant counter series on ``[0, end]``.

    ``values[i]`` holds on ``[times[i], times[i+1])`` (and ``values[-1]``
    to ``end``); the value before ``times[0]`` is 0.  Rollups are
    time-weighted over the full ``[0, end]`` horizon so an early spike and
    a long tail weigh what they actually cost in wall-clock.
    """

    times: Tuple[float, ...]
    values: Tuple[float, ...]
    end: float

    @staticmethod
    def from_deltas(deltas: Iterable[Tuple[float, float]],
                    end: float) -> "Timeline":
        """Build from ``(time, +/-delta)`` events (e.g. +1 at task start,
        -1 at finish).  Same-instant deltas merge, zero-net points drop."""
        acc: Dict[float, float] = {}
        for t, dv in deltas:
            if dv:
                acc[t] = acc.get(t, 0.0) + dv
        times: List[float] = []
        values: List[float] = []
        v = 0.0
        for t in sorted(acc):
            dv = acc[t]
            if dv == 0.0:
                continue
            v += dv
            times.append(t)
            values.append(v)
        hi = max(float(end), times[-1] if times else 0.0)
        return Timeline(tuple(times), tuple(values), hi)

    def __len__(self) -> int:
        return len(self.times)

    def value_at(self, t: float) -> float:
        """Series value at instant ``t`` (0 before the first change)."""
        i = bisect.bisect_right(self.times, t) - 1
        return self.values[i] if i >= 0 else 0.0

    def segments(self) -> Iterator[Tuple[float, float, float]]:
        """Yield ``(t0, t1, value)`` covering ``[0, end]`` gaplessly."""
        if not self.times:
            yield (0.0, self.end, 0.0)
            return
        if self.times[0] > 0.0:
            yield (0.0, self.times[0], 0.0)
        for i, t0 in enumerate(self.times):
            t1 = self.times[i + 1] if i + 1 < len(self.times) else self.end
            yield (t0, t1, self.values[i])

    @property
    def peak(self) -> float:
        hi = max(self.values, default=0.0)
        return max(hi, 0.0) if (not self.times or self.times[0] > 0.0) \
            else hi

    @property
    def peak_time(self) -> float:
        """First instant at which :attr:`peak` is attained."""
        peak = self.peak
        if not self.times or peak == 0.0 and self.times[0] > 0.0:
            return 0.0
        for t, v in zip(self.times, self.values):
            if v == peak:
                return t
        return 0.0

    def integral(self) -> float:
        """Time integral over ``[0, end]`` (e.g. byte-seconds)."""
        return sum((t1 - t0) * v for t0, t1, v in self.segments())

    def mean(self) -> float:
        """Time-weighted mean over ``[0, end]``."""
        return self.integral() / self.end if self.end > 0 else 0.0

    def percentile(self, q: float) -> float:
        """Time-weighted percentile: smallest value v such that the series
        is <= v for at least ``q`` of the horizon (``q`` in [0, 1])."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"percentile q must be in [0, 1], got {q}")
        if self.end <= 0:
            return 0.0
        segs = sorted(((v, t1 - t0) for t0, t1, v in self.segments()
                       if t1 > t0), key=lambda s: s[0])
        target = q * self.end
        acc = 0.0
        for v, w in segs:
            acc += w
            if acc >= target:
                return v
        return segs[-1][0] if segs else 0.0

    def samples(self) -> List[Tuple[float, float]]:
        """``(t, value)`` at each change point plus a closing sample at
        ``end`` — the exact payload of a Chrome/Perfetto counter track."""
        out = [(0.0, 0.0)] if (not self.times or self.times[0] > 0.0) \
            else []
        out.extend(zip(self.times, self.values))
        if not out or out[-1][0] < self.end:
            out.append((self.end, out[-1][1] if out else 0.0))
        return out


# ------------------------------------------------------------- TimelineSet
@dataclasses.dataclass
class TimelineSet:
    """All counter timelines derived from one simulated timeline.

    Lane keys are simulator thread names; worker keys are integer worker
    indices (``w3/device`` -> 3; un-namespaced single-graph lanes -> 0).
    ``memory`` is empty when the scenario carries no byte maps.
    """

    makespan: float
    lane_busy: Dict[str, "Timeline"]
    utilization: Dict[int, "Timeline"]
    queue_depth: Dict[int, "Timeline"]
    comm_bytes: Dict[int, "Timeline"]
    memory: Dict[int, "Timeline"]
    lanes_per_worker: Dict[int, int]

    @property
    def workers(self) -> List[int]:
        keys = (set(self.utilization) | set(self.queue_depth)
                | set(self.comm_bytes) | set(self.memory))
        return sorted(keys)

    def lane_utilization(self) -> Dict[str, float]:
        """Busy fraction per lane, from the busy timelines (agrees with
        :func:`lane_utilization` on the result up to float noise)."""
        if self.makespan <= 0:
            return {th: 0.0 for th in self.lane_busy}
        return {th: tl.integral() / self.makespan
                for th, tl in self.lane_busy.items()}

    def peak_memory(self, worker: Optional[int] = None) -> float:
        """Peak live bytes for one worker (or the max across workers)."""
        if worker is not None:
            tl = self.memory.get(worker)
            return tl.peak if tl is not None else 0.0
        return max((tl.peak for tl in self.memory.values()), default=0.0)


# ------------------------------------------------------------ construction
def check_result_fresh(graph: Any, result: Any) -> None:
    """Raise if ``result`` no longer describes ``graph``'s timeline.

    Sweeps retune one shared build in place between points; deriving
    timelines from a stale pairing would silently describe a *different
    point's* schedule.  Same discipline (and tolerance) as
    ``traceio.chrome.predicted_worker_events``.
    """
    res = getattr(result, "global_result", result)
    try:
        for t in graph.tasks():
            start, finish = res.start[t.uid], res.finish[t.uid]
            tol = 1e-12 * (abs(finish) + abs(t.duration)) + 1e-18
            if abs((finish - start) - t.duration) > tol:
                raise ValueError(
                    f"result is stale for task {t.name!r} (uid {t.uid}): "
                    f"simulated span {finish - start!r}s vs current "
                    f"duration {t.duration!r}s — the graph was retuned "
                    f"after this simulation; re-simulate before deriving "
                    f"timelines")
    except KeyError as e:
        raise ValueError(
            f"result is stale: task uid {e.args[0]} is not in the "
            f"simulated start/finish maps (graph changed structurally "
            f"after this simulation)") from e


def _worker_of(thread: str, split: Callable[[str], Tuple[Optional[int], str]]
               ) -> int:
    w, _ = split(thread)
    return 0 if w is None else w


def compute_timelines(graph: Any, result: Any, *,
                      activation_bytes: Optional[Mapping[str, float]] = None,
                      layer_grad_bytes: Optional[Mapping[str, float]] = None,
                      check_fresh: bool = True) -> TimelineSet:
    """Derive a :class:`TimelineSet` from a simulated graph.

    ``result`` is a ``SimResult`` or ``ClusterResult`` (its global result
    is used).  Byte maps are the Scenario's ``activation_bytes`` /
    ``layer_grad_bytes``; omit them and the memory timelines are empty.

    Live-memory semantics (per worker ``w``, layer ``L``):

    * **activation** (``activation_bytes[L]``): alloc at the finish of the
      last ``phase == "fwd"`` task of ``(w, L)``; freed at the finish of
      the last ``phase == "bwd"`` task of ``(w, L)`` (its final consumer),
      else held to the makespan.
    * **gradient** (``layer_grad_bytes[L]``): alloc at the finish of the
      last ``phase == "bwd"`` task of ``(w, L)``; freed at the latest
      finish among ``(w, L)`` COLLECTIVE/COMM or ``phase == "update"``
      tasks at-or-after the alloc (all-reduce legs and the optimizer step
      both read the gradient), else held to the makespan.

    O(V + E) over the graph; bench-gated in ``benchmarks/bench_obs.py``.
    """
    from repro.core.task import TaskKind, split_worker_thread
    res = getattr(result, "global_result", result)
    if check_fresh:
        check_result_fresh(graph, res)
    makespan = res.makespan
    comm_kinds = (TaskKind.COLLECTIVE, TaskKind.COMM)

    lane_deltas: Dict[str, List[Tuple[float, float]]] = {}
    util_deltas: Dict[int, List[Tuple[float, float]]] = {}
    queue_deltas: Dict[int, List[Tuple[float, float]]] = {}
    comm_deltas: Dict[int, List[Tuple[float, float]]] = {}
    worker_lanes: Dict[int, set] = {}
    # (worker, layer) -> [last fwd finish, last bwd finish, last consumer]
    produce: Dict[Tuple[int, str], List[Optional[float]]] = {}

    want_mem = bool(activation_bytes) or bool(layer_grad_bytes)
    for t in graph.tasks():
        if t.duration <= 0 and not (want_mem and t.layer):
            continue
        start, finish = res.start[t.uid], res.finish[t.uid]
        w = _worker_of(t.thread, split_worker_thread)
        if t.duration > 0:
            lane_deltas.setdefault(t.thread, []).extend(
                ((start, 1.0), (finish, -1.0)))
            util_deltas.setdefault(w, []).extend(
                ((start, 1.0), (finish, -1.0)))
            worker_lanes.setdefault(w, set()).add(t.thread)
            if t.kind in comm_kinds and t.comm_bytes > 0:
                comm_deltas.setdefault(w, []).extend(
                    ((start, t.comm_bytes), (finish, -t.comm_bytes)))
            # queued: all dependencies resolved but the lane has not
            # dispatched it yet (zero-duration barriers are structure,
            # not work — they never queue)
            ready = 0.0
            for p in graph.parents(t):
                r = res.finish[p.uid] + p.gap
                if r > ready:
                    ready = r
            if start > ready:
                queue_deltas.setdefault(w, []).extend(
                    ((ready, 1.0), (start, -1.0)))
        if want_mem and t.layer:
            slot = produce.setdefault((w, t.layer), [None, None, None])
            if t.phase == "fwd":
                if slot[0] is None or finish > slot[0]:
                    slot[0] = finish
            elif t.phase == "bwd":
                if slot[1] is None or finish > slot[1]:
                    slot[1] = finish
            if t.phase == "update" or t.kind in comm_kinds:
                if slot[2] is None or finish > slot[2]:
                    slot[2] = finish

    mem_deltas: Dict[int, List[Tuple[float, float]]] = {}
    for (w, layer), (fwd, bwd, consume) in produce.items():
        act = float((activation_bytes or {}).get(layer, 0.0) or 0.0)
        if act > 0.0 and fwd is not None:
            free = bwd if (bwd is not None and bwd > fwd) else makespan
            mem_deltas.setdefault(w, []).extend(((fwd, act), (free, -act)))
        grad = float((layer_grad_bytes or {}).get(layer, 0.0) or 0.0)
        if grad > 0.0 and bwd is not None:
            free = consume if (consume is not None and consume > bwd) \
                else makespan
            mem_deltas.setdefault(w, []).extend(((bwd, grad), (free, -grad)))

    def build(deltas: Dict[int, List[Tuple[float, float]]],
              scale: Optional[Dict[int, float]] = None
              ) -> Dict[int, Timeline]:
        out = {}
        for k in sorted(deltas):
            ds = deltas[k]
            if scale is not None:
                f = scale.get(k, 1.0)
                ds = [(t, dv / f) for t, dv in ds]
            out[k] = Timeline.from_deltas(ds, makespan)
        return out

    lanes_per_worker = {w: len(ls) for w, ls in worker_lanes.items()}
    return TimelineSet(
        makespan=makespan,
        lane_busy={th: Timeline.from_deltas(lane_deltas[th], makespan)
                   for th in sorted(lane_deltas)},
        utilization=build(util_deltas,
                          {w: float(max(n, 1))
                           for w, n in lanes_per_worker.items()}),
        queue_depth=build(queue_deltas),
        comm_bytes=build(comm_deltas),
        memory=build(mem_deltas),
        lanes_per_worker=lanes_per_worker,
    )


# ---------------------------------------------------------------- report
def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n:.0f} B"
        n /= 1024.0
    return f"{n:.1f} TiB"


def format_timeline_report(ts: TimelineSet, *, top_lanes: int = 8) -> str:
    """Human-readable per-worker rollup table (perf_report/diagnose
    ``--timeline``): utilization, peak live memory (+instant), ready-queue
    depth, and peak COMM bytes in flight."""
    ms = ts.makespan * 1e3
    lines = [f"== timelines: makespan {ms:.3f} ms, "
             f"{len(ts.workers)} worker(s) =="]
    hdr = (f"{'worker':<8} {'util-mean':>9} {'util-p95':>8} "
           f"{'peak-mem':>10} {'@ms':>9} {'queue-peak':>10} "
           f"{'queue-mean':>10} {'comm-peak':>10}")
    lines.append(hdr)
    empty = Timeline((), (), ts.makespan)
    for w in ts.workers:
        util = ts.utilization.get(w, empty)
        mem = ts.memory.get(w, empty)
        q = ts.queue_depth.get(w, empty)
        comm = ts.comm_bytes.get(w, empty)
        mem_s = _fmt_bytes(mem.peak) if len(mem) else "-"
        mem_at = f"{mem.peak_time * 1e3:.3f}" if len(mem) else "-"
        comm_s = _fmt_bytes(comm.peak) if len(comm) else "-"
        lines.append(
            f"{'w%d' % w:<8} {util.mean():>9.3f} "
            f"{util.percentile(0.95):>8.3f} {mem_s:>10} {mem_at:>9} "
            f"{q.peak:>10.0f} {q.mean():>10.2f} {comm_s:>10}")
    lane_util = sorted(ts.lane_utilization().items(),
                       key=lambda kv: -kv[1])
    if lane_util:
        shown = ", ".join(f"{th} {u:.2f}" for th, u in
                          lane_util[:top_lanes])
        extra = len(lane_util) - top_lanes
        tail = f" (+{extra} more)" if extra > 0 else ""
        lines.append(f"busiest lanes: {shown}{tail}")
    return "\n".join(lines)
