"""Unified optimization / scenario API: composable what-ifs over one registry.

Daydream's promise is that optimizations are *graph-transformation
primitives* practitioners can stack and compare (paper §4.4, §5).  This
module is the one entry point for that promise:

* :class:`Optimization` — a named, typed-parameter graph transformation
  (``apply(scenario) -> GraphTransform``).  Every modeled optimization is a
  frozen dataclass registered under a string name via :func:`register`, so
  CLIs and search drivers construct them from ``name:param=value`` specs
  (:func:`parse_stack`).
* :class:`Scenario` — the context an optimization is evaluated in: the
  baseline graph, :class:`~repro.core.costmodel.CostModel`, per-layer
  gradient/activation byte maps, and a worker spec.  Per-optimization
  kwargs (``layer_grad_bytes`` here, ``activation_bytes`` there,
  ``num_workers`` vs ``workers``) are no longer threaded by hand.
* :class:`Stack` / the ``|`` operator — composition with well-defined
  ordering: ``A | B`` applies A to the baseline, then B to A's output
  (left-to-right).  Stacks flatten, so composition is associative.
* :class:`Prediction` — the unified result: baseline/predicted makespan,
  ``speedup``, and (on the cluster route) the per-worker
  :class:`~repro.core.cluster.ClusterResult` breakdown.
* :meth:`Scenario.sweep` — parameter-grid evaluation (bandwidth scales,
  straggler slowdowns, bucket sizes, worker counts) that reuses one
  :class:`~repro.core.cluster.ClusterGraph` build and one base-graph copy
  across points (via :meth:`ClusterGraph.retune`) instead of rebuilding
  per point.

Cluster routing is decided by the scenario's worker spec, not by which
function you called: ``workers=N`` (an int) takes the paper's analytical
single-graph route (collective costs spliced into one timeline), while
``workers=[WorkerSpec(...), ...]`` routes through the dPRO-style global
:class:`ClusterGraph` and yields a per-worker breakdown.

Paper-algorithm -> registered-name map (Algorithms 3-12, §5 + Appendix A):

    ======  =======================  ===============================
    Alg  3  AMP                      ``amp``
    Alg  4  FusedAdam                ``fused_optimizer`` / ``fusedadam``
    Alg  5  Reconstructing BN        ``fused_norm``
    Alg  6  DDP insertion            ``ddp`` / ``distributed``
    Alg  7  P3                       ``p3``
    Alg  8  BlueConnect              ``blueconnect``
    Alg  9  MetaFlow                 ``remove_layer``, ``scale_layer``
    Alg 10  vDNN                     ``offload`` / ``vdnn``
    Alg 11  Gist                     ``gist``
    Alg 12  DGC                      ``dgc``
    beyond  ZeRO sharding            ``zero``
    beyond  async collectives        ``overlap`` / ``overlap_collectives``
    beyond  straggler                ``straggler``
    beyond  bandwidth scaling        ``bandwidth``
    beyond  gradient accumulation    ``grad_accum``
    beyond  pipeline / hybrid PPxDP  ``pipeline`` / ``pp``
    beyond  identity / baseline      ``noop``
    ======  =======================  ===============================

``pipeline`` is a *placement*, not a graph rewrite: the scenario's profile
is partitioned into stages (:mod:`repro.parallel.plan`) and placed onto
``stages * dp`` workers through the real cluster simulator.  In a stack,
optimizations *before* ``pipeline`` transform the single-worker profile
(so the partition sees their effect); optimizations *after* it transform
each stage's schedule template (so ``pipeline|amp|dgc`` speeds stage
compute, shrinks hop payloads, and compresses the per-stage gradient
rings) before the plan wires the global graph.  A pre-stack that *inserts*
communication (``ddp|pipeline``, ``zero|pipeline``) is rejected loudly —
the compute-only partition would silently drop it; use ``pipeline:dp=N``
for data parallelism.

Scenarios built from *real traces* (``Scenario(trace_dir=...)`` — see
:mod:`repro.traceio`) run every registered optimization on the imported
per-worker graphs: the stack transforms each worker's graph and the
prediction comes from the asymmetric global
:meth:`ClusterGraph.from_worker_graphs` build.

The legacy ``repro.core.whatif.what_if_*`` / ``cluster_what_if_*`` functions
are thin wrappers over these registered optimizations.
"""

from __future__ import annotations

import ast
import dataclasses
import itertools
import math
import typing
from typing import (Any, Callable, ClassVar, Dict, List, Optional, Sequence,
                    Tuple, Union)

from repro.obs.spans import span as _span

from .cluster import ClusterGraph, ClusterResult, WorkerSpec, _as_specs
from .costmodel import CollectiveModel, CostModel
from .graph import DependencyGraph
from .layermap import bucket_layers
from .simulate import SimResult, simulate
from .task import (Task, TaskKind, DEVICE_STREAM, DMA_CHANNEL, HOST_THREAD,
                   ici_channel)
from .transform import (GraphTransform, all_of, by_layer, by_name, by_phase,
                        on_device)

GRAD_CHANNEL = ici_channel("grad")

# Scenario fields a CLI stack spec / sweep grid may override per point.
_SCENARIO_OVERRIDES = ("workers", "collective_mode")

# "auto" symmetry folding kicks in at this cluster size: below it the
# materialized build is already interactive and stays byte-identical with
# historical behavior; above it O(classes) simulation is what keeps
# predict/sweep interactive (see repro.core.fold).
_FOLD_AUTO_MIN_WORKERS = 64


class OptimizationError(ValueError):
    """Bad optimization name, parameter, or scenario for the optimization."""


# ============================================================== registry
_REGISTRY: Dict[str, type] = {}


def register(name: str, *aliases: str, algorithm: str = ""
             ) -> Callable[[type], type]:
    """Class decorator: register an :class:`Optimization` under ``name``.

    ``algorithm`` records the paper-algorithm label for docs/reports.
    """
    def deco(cls: type) -> type:
        cls.name = name
        cls.algorithm = algorithm
        for n in (name,) + aliases:
            key = n.lower()
            if key in _REGISTRY:
                raise OptimizationError(f"duplicate optimization name {n!r}")
            _REGISTRY[key] = cls
        return cls
    return deco


def get_optimization(name: str) -> type:
    """Look up a registered :class:`Optimization` class by name or alias."""
    cls = _REGISTRY.get(name.lower())
    if cls is None:
        raise OptimizationError(
            f"unknown optimization {name!r}; available: "
            f"{', '.join(available())}")
    return cls


def available() -> List[str]:
    """Primary (non-alias) registered optimization names, sorted."""
    return sorted({cls.name for cls in _REGISTRY.values()})


# ============================================================== scenario
@dataclasses.dataclass
class Scenario:
    """Everything an optimization needs to be evaluated, in one object.

    ``workers`` decides the routing: an ``int`` keeps the paper's analytical
    single-graph route; a sequence of :class:`WorkerSpec` routes through the
    global :class:`ClusterGraph` (per-worker breakdown, heterogeneous
    clusters, ``collective_mode`` selectable).

    ``trace_dir`` (or a pre-loaded ``traces``
    :class:`repro.traceio.ImportedCluster`) takes the *trace route*: N
    per-worker profiler traces (Chrome trace-event JSON / native JSONL) are
    clock-aligned and imported as per-worker graphs, every optimization in
    the stack is applied to each worker's graph, and the prediction comes
    from the asymmetric global graph
    (:meth:`ClusterGraph.from_worker_graphs`).  ``workers`` then defaults to
    uniform specs matching the trace count — the traces already encode real
    per-worker speeds — and explicit specs layer what-if scaling on top.
    """

    graph: Optional[DependencyGraph] = None
    cost: Optional[CostModel] = None
    layer_grad_bytes: Optional[Dict[str, float]] = None
    activation_bytes: Optional[Dict[str, float]] = None
    workers: Union[int, Sequence[WorkerSpec]] = 1
    collective_mode: str = "ring"
    trace_dir: Optional[str] = None
    traces: Optional[Any] = None       # repro.traceio.ImportedCluster
    # symmetry folding (repro.core.fold): True forces it, False disables,
    # "auto" (default) folds clusters of >= _FOLD_AUTO_MIN_WORKERS workers.
    # Folding is exact (bit-identical results) and silently falls back to
    # full materialization when the worker mix cannot fold.
    fold: Any = "auto"

    _baseline: Optional[SimResult] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    # stage-partition cache for the pipeline route: (pre-stack spec, stages)
    # -> StageProfile tuple.  Partitioning scans the whole profile (O(V));
    # microbatch/schedule sweep points reuse it and rebuild only the
    # O(S*M) schedule graph.
    _plan_cache: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.cost is None:
            self.cost = CostModel()
        if self.trace_dir is not None and self.traces is None:
            from repro.traceio import load_trace_dir
            self.traces = load_trace_dir(self.trace_dir)
        if self.traces is not None:
            n = len(self.traces.graphs)
            if isinstance(self.workers, int):
                if self.workers not in (1, n):
                    raise OptimizationError(
                        f"scenario has {n} trace worker(s) but workers="
                        f"{self.workers}; leave workers unset or pass one "
                        f"WorkerSpec per trace")
                self.workers = [WorkerSpec() for _ in range(n)]
            elif len(list(self.workers)) != n:
                raise OptimizationError(
                    f"scenario has {n} trace worker(s) but "
                    f"{len(list(self.workers))} WorkerSpec(s)")
            if self.graph is None:
                self.graph = self.traces.graphs[0]
        if self.graph is None:
            raise OptimizationError(
                "Scenario needs a baseline graph or trace_dir/traces")

    # ------------------------------------------------------------ routing
    @property
    def is_cluster(self) -> bool:
        return self.traces is not None or not isinstance(self.workers, int)

    @property
    def specs(self) -> List[WorkerSpec]:
        return _as_specs(self.workers)

    @property
    def num_workers(self) -> int:
        return self.workers if isinstance(self.workers, int) \
            else len(list(self.workers))

    def _fold_enabled(self, n: Optional[int] = None) -> bool:
        """Whether to try symmetry folding for an ``n``-worker build."""
        if self.fold is True:
            return True
        if self.fold == "auto":
            return (self.num_workers if n is None else n) \
                >= _FOLD_AUTO_MIN_WORKERS
        return False

    # ----------------------------------------------------------- accessors
    @property
    def grads(self) -> Dict[str, float]:
        if self.layer_grad_bytes is None:
            raise OptimizationError(
                "this optimization needs Scenario.layer_grad_bytes "
                "(per-layer gradient payload bytes)")
        return self.layer_grad_bytes

    @property
    def acts(self) -> Dict[str, float]:
        if self.activation_bytes is None:
            raise OptimizationError(
                "this optimization needs Scenario.activation_bytes "
                "(per-layer activation bytes)")
        return self.activation_bytes

    def transform(self) -> GraphTransform:
        """A fresh mutable what-if session over a copy of the baseline."""
        return GraphTransform(self.graph)

    def baseline(self) -> SimResult:
        """Simulated baseline, cached.

        Single-graph and replicate-cluster routes simulate the one baseline
        graph; the trace route simulates the imported (untransformed)
        cluster — the traces *are* the distributed baseline.
        """
        if self._baseline is None:
            if self.traces is not None:
                self._baseline = self._trace_cluster(
                    self.traces.graphs).simulate().global_result
            else:
                self._baseline = simulate(self.graph)
        return self._baseline

    def _trace_cluster(self, graphs: Sequence[DependencyGraph],
                       schedule: Any = None) -> ClusterGraph:
        return ClusterGraph.from_worker_graphs(
            graphs, self.specs, cost=self.cost,
            collective_mode=self.collective_mode, schedule=schedule,
            start_skews=self.traces.start_skews)

    # ----------------------------------------------------------- evaluate
    def predict(self, opt: Union[str, "Optimization"],
                **params: Any) -> "Prediction":
        """Apply ``opt`` (instance, name, or ``name:param=value`` spec) and
        simulate; routing per the worker spec."""
        pred, _, _ = self._evaluate(_resolve(opt, params))
        return pred

    def evaluate(self, opt: Union[str, "Optimization"], **params: Any
                 ) -> Tuple["Prediction", GraphTransform,
                            Optional[ClusterGraph]]:
        """:meth:`predict` plus the applied transform and (cluster routes)
        the built :class:`ClusterGraph` — for exporters and drivers that
        need the predicted graph itself (e.g. ``perf_report
        --export-trace``)."""
        return self._evaluate(_resolve(opt, params))

    def diff_against(self, traces: Any,
                     opt: Union[str, "Optimization"] = "noop"):
        """Diff this scenario's predicted timeline against a captured
        per-worker trace set, task-by-task (paper §6's validation
        methodology as a reusable tool — see :mod:`repro.analysis.diff`).

        ``traces`` is a trace directory or a pre-loaded
        :class:`repro.traceio.ImportedCluster`; ``opt`` defaults to
        ``"noop"`` (how faithfully does the simulator reproduce the
        capture), and any registered stack answers "how far is reality
        from the predicted optimized timeline".  Returns a
        :class:`repro.analysis.TraceDiff`.
        """
        from repro.analysis import diff_prediction
        pred, tf, cg = self.evaluate(opt)
        return diff_prediction(pred, tf, cg, traces)

    def calibrate(self, traces: Any = None, **kwargs):
        """Fit this scenario's :class:`CostModel` constants against a
        captured trace set (default: the scenario's own capture) by
        iterating simulate → :meth:`diff_against` → refit through the real
        simulator — dPRO's trace-fitted-replayer loop (see
        :mod:`repro.analysis.calibrate`).

        Returns ``(calibrated_scenario, CalibrationReport)``; this
        scenario is not mutated, so before/after what-ifs can be compared
        side by side.  Keyword arguments (``constants``, ``max_rounds``,
        ``tol``, ``probes_per_constant``) pass through to
        :func:`repro.analysis.calibrate.calibrate_scenario`.
        """
        from repro.analysis.calibrate import calibrate_scenario
        return calibrate_scenario(self, traces, **kwargs)

    def _byte_maps(self) -> Tuple[Optional[Dict[str, float]],
                                  Optional[Dict[str, float]]]:
        """What every Prediction carries so ``.timelines`` can size its
        live-memory series without re-threading the scenario."""
        return (self.activation_bytes, self.layer_grad_bytes)

    def _evaluate(self, opt: "Optimization", *,
                  baseline: Optional[float] = None,
                  point: Optional[Dict[str, Any]] = None,
                  reuse: bool = True
                  ) -> Tuple["Prediction", GraphTransform,
                             Optional[ClusterGraph]]:
        base = self.baseline().makespan if baseline is None else baseline
        pre, pipe, post = _split_pipeline(opt)
        if pipe is not None:
            return self._evaluate_pipeline(opt, pre, pipe, post, base,
                                           point or {}, reuse)
        if self.traces is not None:
            # trace route: the optimization transforms *each* worker's own
            # graph (workers run the same program, so the same rewrite
            # applies per worker), then the asymmetric global graph is
            # rebuilt from the transformed per-worker graphs.
            tfs = []
            for wg in self.traces.graphs:
                tf = GraphTransform(wg)
                opt.build(self, tf)
                tfs.append(tf)
            cg = self._trace_cluster([tf.graph for tf in tfs],
                                     schedule=tfs[0].schedule)
            cres = cg.simulate()
            return (Prediction(opt, base, cres.makespan, cres.global_result,
                               cres, point or {}, graph=cg.graph,
                               schedule=cg.schedule,
                               byte_maps=self._byte_maps()), tfs[0], cg)
        tf = opt.apply(self)
        if self.is_cluster:
            cg = None
            if self._fold_enabled():
                from .fold import fold_cluster
                cg = fold_cluster(tf.graph, self.specs, cost=self.cost,
                                  collective_mode=self.collective_mode,
                                  schedule=tf.schedule)
            if cg is None:
                cg = ClusterGraph.build(tf.graph, self.specs,
                                        cost=self.cost,
                                        collective_mode=self.collective_mode,
                                        schedule=tf.schedule)
            cres = cg.simulate()
            return (Prediction(opt, base, cres.makespan, cres.global_result,
                               cres, point or {}, graph=cg.graph,
                               schedule=cg.schedule,
                               byte_maps=self._byte_maps()), tf, cg)
        res = tf.simulate()
        return Prediction(opt, base, res.makespan, res, None, point or {},
                          graph=tf.graph, schedule=tf.schedule,
                          byte_maps=self._byte_maps()), \
            tf, None

    # ------------------------------------------------------ pipeline route
    def _evaluate_pipeline(self, opt: "Optimization",
                           pre: Optional["Optimization"],
                           pipe: "PipelineParallel",
                           post: Optional["Optimization"], base: float,
                           point: Dict[str, Any], reuse: bool
                           ) -> Tuple["Prediction", GraphTransform,
                                      Optional[ClusterGraph]]:
        """Place a pipeline/hybrid plan and simulate it on the cluster path.

        Stack semantics: ``pre`` (everything left of ``pipeline``)
        transforms the single-worker profile before partitioning; ``post``
        (everything right of it) transforms each stage's schedule template
        before placement — so AMP shrinks hop payloads and DGC compresses
        the per-stage gradient rings.  The stage partition is cached per
        (pre-stack, stages) so microbatch/schedule sweep points skip the
        O(V) profile scan (``reuse=False`` bypasses the cache).
        """
        from repro.parallel.plan import ParallelPlan, partition_stages
        if self.traces is not None:
            raise OptimizationError(
                "pipeline placement re-partitions a single-worker profile; "
                "it is not supported on the trace route")
        key = (pre.spec() if pre is not None else "", pipe.stages)
        profiles = self._plan_cache.get(key) if reuse else None
        tf: Optional[GraphTransform] = None
        if profiles is None:
            tf = pre.apply(self) if pre is not None else self.transform()
            if pre is not None and \
                    _num_comm_tasks(tf.graph) > _num_comm_tasks(self.graph):
                # the partition places compute only; silently dropping
                # comm the pre-stack just inserted would make ddp|pipeline
                # a no-op that *looks* faster (greedy_search would pick it)
                raise OptimizationError(
                    f"optimization(s) before 'pipeline' insert "
                    f"communication tasks ({pre.spec()}) that the stage "
                    f"partition would drop; express data parallelism with "
                    f"pipeline:dp=N and stack communication what-ifs "
                    f"*after* the placement instead")
            profiles = tuple(partition_stages(
                tf.graph, pipe.stages,
                activation_bytes=self.activation_bytes,
                layer_grad_bytes=self.layer_grad_bytes))
            if reuse:
                self._plan_cache[key] = profiles
        plan = ParallelPlan(profiles, pipe.microbatches, pipe.schedule,
                            pipe.dp)
        templates = plan.stage_templates(self.cost)
        sched_fn = None
        if post is not None:
            stfs = [GraphTransform(tmpl, copy=False) for tmpl in templates]
            for stf in stfs:
                post.build(self, stf)
            sched_fn = next((stf.schedule for stf in stfs
                             if stf.schedule is not None), None)
        pspecs = self._pipeline_specs(plan)
        cg = None
        if self._fold_enabled(plan.num_workers):
            from .fold import fold_plan
            cg = fold_plan(plan, pspecs, cost=self.cost,
                           collective_mode=self.collective_mode,
                           sched_fn=sched_fn, templates=templates)
        if cg is None:
            cg = plan.place(pspecs, cost=self.cost,
                            collective_mode=self.collective_mode,
                            sched_fn=sched_fn, templates=templates)
        cres = cg.simulate()
        out_tf = tf if tf is not None \
            else GraphTransform(templates[0], copy=False)
        return (Prediction(opt, base, cres.makespan, cres.global_result,
                           cres, dict(point), graph=cg.graph,
                           schedule=cg.schedule,
                           byte_maps=self._byte_maps()), out_tf, cg)

    def _pipeline_specs(self, plan: Any) -> List[WorkerSpec]:
        """Worker specs for a plan: the scenario's list must pair 1:1 with
        the (stage, replica) slots; an int spec must be 1 (default) or the
        plan's worker count; otherwise uniform workers."""
        n = plan.num_workers
        if isinstance(self.workers, int):
            if self.workers not in (1, n):
                raise OptimizationError(
                    f"pipeline places {plan.num_stages} stage(s) x "
                    f"{plan.dp} replica(s) = {n} worker(s), but the "
                    f"scenario pins workers={self.workers}; leave workers "
                    f"unset or pass one WorkerSpec per slot")
            return [WorkerSpec() for _ in range(n)]
        specs = list(self.workers)
        if len(specs) != n:
            raise OptimizationError(
                f"pipeline places {n} worker(s) (stage-major: worker = "
                f"stage*dp + replica) but the scenario has "
                f"{len(specs)} WorkerSpec(s)")
        return specs

    # --------------------------------------------------------------- sweep
    def sweep(self, opt: Union[str, "Optimization"],
              grid: Union[Dict[str, Sequence[Any]],
                          Sequence[Dict[str, Any]]],
              *, reuse: bool = True) -> List["Prediction"]:
        """Evaluate ``opt`` across a parameter grid.

        ``grid`` maps names to value lists (evaluated as a cartesian
        product) or is an explicit sequence of point dicts.  Keys are either
        parameters of ``opt`` or the scenario fields ``workers`` /
        ``collective_mode``.

        With ``reuse=True`` (default) consecutive points share work instead
        of rebuilding from scratch: on the cluster route, points that only
        change worker specs (bandwidth scales, straggler slowdowns) retune
        one :class:`ClusterGraph` build in place
        (:meth:`ClusterGraph.retune` — exact, not approximate) and replay
        only the dirty downstream cone of the retuned tasks
        (:func:`simulate_incremental`, falling back to a full event replay
        when the cone grows too large); on the
        single-graph route, optimizations that support cheap
        re-parameterization (:meth:`Optimization.retune`) rescale the
        applied transform.  Structural changes (bucket sizes, worker
        counts) fall back to a full rebuild for that point.
        """
        base_opt = _resolve(opt)
        opt_names = set(base_opt.param_names())
        points = _expand_grid(grid)
        base = self.baseline().makespan
        preds: List[Prediction] = []
        cache: Dict[str, Any] = {"opt": None, "scn": None, "tf": None,
                                 "cg": None, "cres": None}
        for i, pt in enumerate(points):
            opt_params = {k: v for k, v in pt.items() if k in opt_names}
            over = {k: v for k, v in pt.items()
                    if k in _SCENARIO_OVERRIDES and k not in opt_names}
            unknown = set(pt) - set(opt_params) - set(over)
            if unknown:
                raise OptimizationError(
                    f"sweep grid key(s) {sorted(unknown)} are neither "
                    f"parameters of {base_opt.name!r} "
                    f"({sorted(opt_names)}) nor scenario fields "
                    f"{list(_SCENARIO_OVERRIDES)}")
            popt = base_opt.with_params(**opt_params)
            scn = dataclasses.replace(self, **over) if over else self
            with _span("scenario.sweep_point", opt=base_opt.name,
                       index=i, total=len(points)) as sp:
                pred = None
                if reuse and cache["cg"] is not None \
                        and self._cluster_reusable(popt, scn, cache):
                    cg = cache["cg"]
                    cg.retune(scn.specs)
                    cres = None
                    if cache["cres"] is not None:
                        cres = cg.simulate_incremental(cache["cres"])
                    if cres is not None:
                        sp.note(route="cluster_retune", sim="incremental",
                                dirty=len(cg.last_retune_dirty))
                    else:
                        cres = cg.simulate()
                        sp.note(route="cluster_retune", sim="full",
                                dirty=len(cg.last_retune_dirty))
                    pred = Prediction(popt, base, cres.makespan,
                                      cres.global_result, cres, dict(pt),
                                      graph=cg.graph,
                                      schedule=cg.schedule,
                                      byte_maps=scn._byte_maps())
                    cache["opt"], cache["scn"] = popt, scn
                    cache["cres"] = cres
                elif reuse and cache["tf"] is not None and not over \
                        and scn is self and not scn.is_cluster \
                        and type(popt) is type(cache["opt"]) \
                        and popt.retune(scn, cache["tf"], cache["opt"]):
                    sp.note(route="transform_retune")
                    res = simulate(cache["tf"].graph, cache["tf"].schedule)
                    pred = Prediction(popt, base, res.makespan, res, None,
                                      dict(pt), graph=cache["tf"].graph,
                                      schedule=cache["tf"].schedule,
                                      byte_maps=scn._byte_maps())
                    cache["opt"] = popt
                if pred is None:
                    sp.note(route="rebuild",
                            reason=self._rebuild_reason(popt, scn, cache,
                                                        over, reuse))
                    pred, tf, cg = scn._evaluate(popt, baseline=base,
                                                 point=dict(pt),
                                                 reuse=reuse)
                    if reuse:
                        cache.update(opt=popt, scn=scn, tf=tf, cg=cg,
                                     cres=pred.cluster)
            preds.append(pred)
        return preds

    def _cluster_reusable(self, popt: "Optimization", scn: "Scenario",
                          cache: Dict[str, Any]) -> bool:
        """Points differing only in same-length worker specs retune."""
        prev = cache["scn"]
        return (scn.is_cluster and prev is not None
                and popt == cache["opt"]
                and scn.graph is prev.graph
                and scn.traces is prev.traces
                and scn.cost is prev.cost
                and scn.layer_grad_bytes is prev.layer_grad_bytes
                and scn.activation_bytes is prev.activation_bytes
                and scn.collective_mode == prev.collective_mode
                and cache["cg"].can_retune(scn.specs))

    def _rebuild_reason(self, popt: "Optimization", scn: "Scenario",
                        cache: Dict[str, Any], over: Dict[str, Any],
                        reuse: bool) -> str:
        """Name why a sweep point fell back to a full rebuild.

        Mirrors the reuse predicates in :meth:`sweep` /
        :meth:`_cluster_reusable`, reporting the *first* failed condition
        so scale regressions show up in telemetry with a cause attached.
        """
        if not reuse:
            return "reuse_disabled"
        if cache["opt"] is None:
            return "first_point"
        prev = cache["scn"]
        if scn.is_cluster:
            if cache["cg"] is None:
                return "no_cached_cluster"
            if popt != cache["opt"]:
                return "opt_params_changed"
            if prev is None or scn.graph is not prev.graph \
                    or scn.traces is not prev.traces:
                return "graph_changed"
            if scn.cost is not prev.cost \
                    or scn.layer_grad_bytes is not prev.layer_grad_bytes \
                    or scn.activation_bytes is not prev.activation_bytes:
                return "cost_or_bytes_changed"
            if scn.collective_mode != prev.collective_mode:
                return "collective_mode_changed"
            if len(scn.specs) != len(getattr(prev, "specs", ())):
                return "worker_count_changed"
            return "retune_rejected"
        if over:
            return "scenario_override"
        if cache["tf"] is None:
            return "no_cached_transform"
        if type(popt) is not type(cache["opt"]):
            return "opt_type_changed"
        return "retune_unsupported"


# ============================================================== prediction
@dataclasses.dataclass
class Prediction:
    """Unified what-if outcome, identical across both routes.

    ``baseline``/``predicted`` are makespans in seconds; ``cluster`` is the
    per-worker :class:`ClusterResult` breakdown when the scenario routed
    through the global cluster graph, else ``None``.
    """

    optimization: "Optimization"
    baseline: float
    predicted: float
    result: SimResult
    cluster: Optional[ClusterResult] = None
    point: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # the evaluated graph (cluster global graph on cluster routes) and its
    # schedule override — what Prediction.critical_path walks
    graph: Optional[DependencyGraph] = dataclasses.field(
        default=None, repr=False, compare=False)
    schedule: Optional[Any] = dataclasses.field(
        default=None, repr=False, compare=False)
    # (activation_bytes, layer_grad_bytes) from the evaluating scenario —
    # what sizes Prediction.timelines' live-memory series
    byte_maps: Optional[Tuple[Optional[Dict[str, float]],
                              Optional[Dict[str, float]]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _cp: Optional[Any] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _timelines: Optional[Any] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def speedup(self) -> float:
        return (self.baseline / self.predicted if self.predicted > 0
                else float("inf"))

    @property
    def critical_path(self):
        """The predicted timeline's makespan-defining chain
        (:class:`repro.analysis.CriticalPath`), extracted lazily.

        Re-simulates the evaluated graph with binding recording (same
        engine, bit-identical timeline) on first access.  Sweeps share one
        build and retune it in place between points, which would silently
        yield a *different point's* path — so the extraction is checked
        against this prediction's makespan and raises (instead of lying)
        when the carried graph has moved on; re-evaluate the point via
        :meth:`Scenario.predict` to diagnose it.
        """
        if self._cp is None:
            if self.graph is None:
                raise OptimizationError(
                    "this Prediction does not carry its evaluated graph; "
                    "re-evaluate via Scenario.predict/evaluate")
            from repro.analysis import extract_critical_path
            cp = extract_critical_path(self.graph, schedule=self.schedule)
            if abs(cp.makespan - self.predicted) > \
                    1e-9 * max(abs(self.predicted), 1e-30):
                raise OptimizationError(
                    f"the evaluated graph no longer reproduces this "
                    f"prediction (makespan {cp.makespan} vs "
                    f"{self.predicted}): a later sweep point retuned the "
                    f"shared build in place — re-evaluate this point via "
                    f"Scenario.predict to get its critical path")
            self._cp = cp
        return self._cp

    @property
    def timelines(self):
        """Counter timelines of the predicted timeline
        (:class:`repro.obs.TimelineSet`): per-lane busy/utilization,
        ready-queue depth, COMM bytes in flight, and — when the scenario
        carries byte maps — per-worker live memory.  Derived lazily from
        the carried graph + result; like :attr:`critical_path`, raises
        instead of lying when a later sweep point retuned the shared
        build in place.
        """
        if self._timelines is None:
            if self.graph is None:
                raise OptimizationError(
                    "this Prediction does not carry its evaluated graph; "
                    "re-evaluate via Scenario.predict/evaluate")
            from repro.obs import compute_timelines
            acts, grads = self.byte_maps or (None, None)
            try:
                self._timelines = compute_timelines(
                    self.graph, self.cluster or self.result,
                    activation_bytes=acts, layer_grad_bytes=grads)
            except ValueError as e:
                raise OptimizationError(str(e)) from e
        return self._timelines

    def __repr__(self) -> str:
        tag = f" point={self.point}" if self.point else ""
        return (f"Prediction({self.optimization.spec()}: "
                f"{self.baseline*1e3:.3f}ms -> {self.predicted*1e3:.3f}ms, "
                f"{self.speedup:.2f}x{tag})")


# ============================================================ optimization
class Optimization:
    """A named graph transformation with typed parameters.

    Subclasses are frozen dataclasses (fields == parameters) registered via
    :func:`register`; they implement :meth:`build`, which mutates a
    :class:`GraphTransform` in place — that is what makes stacking
    composable (every optimization in a :class:`Stack` mutates the same
    transform, in order).
    """

    name: ClassVar[str] = "?"
    algorithm: ClassVar[str] = ""

    # ------------------------------------------------------------ protocol
    def build(self, s: Scenario, tf: GraphTransform) -> None:
        raise NotImplementedError

    def apply(self, scenario: Scenario,
              tf: Optional[GraphTransform] = None) -> GraphTransform:
        """Apply to (a copy of) the scenario's baseline graph."""
        if tf is None:
            tf = scenario.transform()
        self.build(scenario, tf)
        return tf

    def predict(self, scenario: Scenario) -> Prediction:
        return scenario.predict(self)

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        """Cheaply re-parameterize ``tf`` (already built with ``old``'s
        params) to this instance's params, in place.  Return ``False`` when
        the change is structural and needs a rebuild (the default)."""
        return False

    # ------------------------------------------------------------ headroom
    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        """Predicate over the tasks this optimization can *shrink*, or None.

        The contract backing :func:`repro.analysis.opportunity`'s Amdahl
        bounds: :meth:`build` must never make a targeted task slower, and
        everything else it does must be added work (the makespan is
        monotone in durations/payloads, so erasing the targets then upper-
        bounds any real parameterization).  Return a predicate selecting
        every task the model might speed up (``lambda t: False`` for
        optimizations that only add or redistribute work — their bound is
        exactly 1.0x); return ``None`` (the default) when the optimization
        restructures the graph and no shrink-bound exists (``pipeline``).
        """
        return None

    def headroom(self, s: Scenario, tf: GraphTransform) -> bool:
        """Mutate ``tf`` into this optimization's idealized best case.

        Default: erase the :meth:`headroom_targets` (duration *and*
        payload to zero — a collective with zero payload still wires, as
        hop-latency-only legs, so the bound flows through the real cluster
        simulator).  Returns False when no bound exists.  Override when
        the ideal case is not expressible as target-erasure (``overlap``
        removes its targets outright — fully hidden communication also
        frees the device lane's issue slots).
        """
        targets = self.headroom_targets(s)
        if targets is None:
            return False
        for t in tf.select(targets):
            t.duration = 0.0
            t.comm_bytes = 0.0
        return True

    # ---------------------------------------------------------- parameters
    def param_names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in dataclasses.fields(self))

    def with_params(self, **params: Any) -> "Optimization":
        if not params:
            return self
        bad = [k for k in params if k not in self.param_names()]
        if bad:
            raise OptimizationError(
                f"{self.name} has no parameter(s) {bad}; valid: "
                f"{list(self.param_names())}")
        return dataclasses.replace(self, **params)

    # -------------------------------------------------------- composition
    def __or__(self, other: "Optimization") -> "Stack":
        if not isinstance(other, Optimization):
            return NotImplemented
        return Stack(self, other)

    # --------------------------------------------------------------- spec
    def spec(self) -> str:
        """``name:param=value`` round-trip form (:func:`parse_stack`)."""
        parts = [self.name]
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            parts.append(f"{f.name}={v!r}")
        return ":".join(parts)


@dataclasses.dataclass(frozen=True, init=False)
class Stack(Optimization):
    """Ordered composition: ``Stack(A, B)`` applies A, then B to A's output.

    Nested stacks flatten on construction, so ``(A | B) | C == A | (B | C)``
    — composition is associative by construction.
    """

    opts: Tuple[Optimization, ...]

    name: ClassVar[str] = "stack"

    def __init__(self, *opts: Union[Optimization,
                                    Sequence[Optimization]]) -> None:
        flat: List[Optimization] = []
        for o in opts:
            if isinstance(o, Stack):
                flat.extend(o.opts)
            elif isinstance(o, Optimization):
                flat.append(o)
            else:
                for x in o:
                    flat.extend(x.opts if isinstance(x, Stack) else [x])
        object.__setattr__(self, "opts", tuple(flat))

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        for o in self.opts:
            o.build(s, tf)

    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        preds = [o.headroom_targets(s) for o in self.opts]
        if any(p is None for p in preds):
            return None
        return lambda t: any(p(t) for p in preds)

    def headroom(self, s: Scenario, tf: GraphTransform) -> bool:
        # every member must bound; erasure composes (idempotent), so the
        # union of the members' ideal cases is the stack's ideal case
        return all(o.headroom(s, tf) for o in self.opts)

    def _param_owners(self) -> Dict[str, List[int]]:
        owners: Dict[str, List[int]] = {}
        for i, o in enumerate(self.opts):
            for p in o.param_names():
                owners.setdefault(p, []).append(i)
        return owners

    def param_names(self) -> Tuple[str, ...]:
        """Member parameters owned by exactly one member — those route
        unambiguously through :meth:`with_params`, which is what lets
        ``sweep("ddp,ckpt_interval", {"steps": [...]})`` move a stacked
        member's knob.  Shared names are excluded (set them on the member
        directly)."""
        return tuple(p for p, idx in self._param_owners().items()
                     if len(idx) == 1)

    def with_params(self, **params: Any) -> "Optimization":
        if not params:
            return self
        owners = self._param_owners()
        out = list(self.opts)
        for k, v in params.items():
            idx = owners.get(k, [])
            if not idx:
                raise OptimizationError(
                    f"no member of stack {self.spec()!r} has parameter "
                    f"{k!r}")
            if len(idx) > 1:
                raise OptimizationError(
                    f"parameter {k!r} is ambiguous in stack "
                    f"{self.spec()!r} ({len(idx)} members define it); "
                    f"set it on the member directly")
            out[idx[0]] = out[idx[0]].with_params(**{k: v})
        return Stack(*out)

    def spec(self) -> str:
        return ",".join(o.spec() for o in self.opts)


# ================================================================ parsing
def _split_outside(s: str, sep: str) -> List[str]:
    """Split on ``sep`` outside brackets/quotes (so ``axes=[("d",4)]`` and
    stacked specs coexist)."""
    out, cur, depth, quote = [], [], 0, None
    for ch in s:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            cur.append(ch)
        elif ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            depth -= 1
            cur.append(ch)
        elif ch == sep and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return [p.strip() for p in out if p.strip()]


def _parse_value(v: str) -> Any:
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    try:
        return ast.literal_eval(v)
    except (ValueError, SyntaxError):
        return v


def _coerce(value: Any, hint: Any) -> Any:
    """Nudge CLI-parsed values toward the declared parameter type."""
    if hint is None:
        return value
    origin = typing.get_origin(hint)
    if origin is Union:
        args = [a for a in typing.get_args(hint) if a is not type(None)]
        hint = args[0] if len(args) == 1 else None
    if hint is float and isinstance(value, (int, bool)):
        return float(value)
    if hint is int and isinstance(value, float) and value.is_integer():
        return int(value)
    return value


def parse_stack(spec: str) -> Tuple[Optimization, Dict[str, Any]]:
    """Parse a CLI stack spec like ``"amp,ddp:workers=16,zero"``.

    Comma-separated optimizations, ``param=value`` pairs parsed against the
    registry (typed via each optimization's dataclass fields).  Parameters
    attach with colons (``ddp:bucket_bytes=1e6``) or as comma-separated
    continuations of the preceding optimization
    (``pipeline:stages=4,microbatches=16,schedule=1f1b`` — a comma part
    whose head is ``name=value`` extends the optimization to its left).
    Keys that are :class:`Scenario` fields (``workers``,
    ``collective_mode``) are collected into the returned override dict
    instead.  Returns ``(optimization_or_stack, scenario_overrides)``.
    """
    pending: List[Tuple[type, Dict[str, Any], str]] = []
    overrides: Dict[str, Any] = {}
    for part in _split_outside(spec, ","):
        fields = _split_outside(part, ":")
        if "=" in fields[0]:
            # continuation: the whole part parameterizes the previous opt
            if not pending:
                raise OptimizationError(
                    f"parameter {fields[0]!r} appears before any "
                    f"optimization name in {spec!r}")
            cls, params, _ = pending[-1]
            kvs = fields
        else:
            cls = get_optimization(fields[0])
            params = {}
            pending.append((cls, params, part))
            kvs = fields[1:]
        try:
            hints = typing.get_type_hints(cls)
        except Exception:
            hints = {}
        valid = {f.name for f in dataclasses.fields(cls)}
        for kv in kvs:
            if "=" not in kv:
                raise OptimizationError(
                    f"bad parameter {kv!r} in {part!r}; expected name=value")
            k, v = kv.split("=", 1)
            k, val = k.strip(), _parse_value(v.strip())
            if k in valid:
                params[k] = _coerce(val, hints.get(k))
            elif k in _SCENARIO_OVERRIDES:
                overrides[k] = val
            else:
                raise OptimizationError(
                    f"{cls.name} has no parameter {k!r}; valid: "
                    f"{sorted(valid)} (or scenario overrides "
                    f"{list(_SCENARIO_OVERRIDES)})")
    opts: List[Optimization] = []
    for cls, params, part in pending:
        try:
            opts.append(cls(**params))
        except TypeError as e:
            raise OptimizationError(
                f"cannot construct {cls.name!r} from {part!r}: {e}") from e
    if not opts:
        raise OptimizationError(f"empty stack spec {spec!r}")
    return (opts[0] if len(opts) == 1 else Stack(*opts)), overrides


def _resolve(opt: Union[str, Optimization],
             params: Optional[Dict[str, Any]] = None) -> Optimization:
    if isinstance(opt, str):
        if "," in opt or ":" in opt:
            stack, over = parse_stack(opt)
            if over:
                raise OptimizationError(
                    f"scenario overrides {sorted(over)} are not allowed in "
                    f"this context; set them on the Scenario")
            if params:
                raise OptimizationError(
                    "pass parameters either in the spec string or as "
                    "keyword arguments, not both")
            return stack
        cls = get_optimization(opt)
        try:
            return cls(**(params or {}))
        except TypeError as e:
            raise OptimizationError(
                f"cannot construct {cls.name!r}: {e}") from e
    if not isinstance(opt, Optimization):
        raise OptimizationError(
            f"expected an Optimization or registered name, got {opt!r}")
    return opt.with_params(**params) if params else opt


def _expand_grid(grid: Union[Dict[str, Sequence[Any]],
                             Sequence[Dict[str, Any]]]
                 ) -> List[Dict[str, Any]]:
    if isinstance(grid, dict):
        keys = list(grid)
        return [dict(zip(keys, combo))
                for combo in itertools.product(*(list(grid[k])
                                                 for k in keys))]
    return [dict(p) for p in grid]


# ====================================================== worker-spec grids
def uniform_bandwidth_specs(n: int, scales: Sequence[float]
                            ) -> List[List[WorkerSpec]]:
    """One sweep point per scale: all ``n`` workers' links throttled alike —
    the ``workers`` grid for a cluster bandwidth sweep."""
    return [[WorkerSpec(bandwidth_scale=s) for _ in range(n)]
            for s in scales]


def straggler_specs(n: int, slowdowns: Sequence[float], *, straggler: int = 0
                    ) -> List[List[WorkerSpec]]:
    """One sweep point per slowdown: worker ``straggler`` is that much
    slower — the ``workers`` grid for a straggler sweep."""
    return [[WorkerSpec(compute_scale=s if i == straggler else 1.0)
             for i in range(n)] for s in slowdowns]


# ================================================================= models
@register("noop", "baseline", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class Noop(Optimization):
    """Identity: predict the unmodified scenario.

    Useful to route a baseline through the same machinery as real
    optimizations — e.g. ``perf_report --trace-dir`` renders the imported
    cluster's per-worker breakdown via ``predict("noop")``, and stacks can
    be compared against ``noop`` point-for-point in sweeps.
    """

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        pass

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        return True

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # identity: bound is exactly 1.0x


@register("amp", algorithm="Alg 3")
@dataclasses.dataclass(frozen=True)
class AMP(Optimization):
    """Paper Algorithm 3 (AMP).

    GPU original: sgemm/scudnn kernels 3x (TensorCore), everything else 2x
    (halved bytes).  TPU analogue: MXU-bound ops (dot/convolution fusions
    whose roofline is compute) get ``matmul_speedup`` (bf16 -> int8/fp8 on
    the MXU); bandwidth-bound ops get ``memory_speedup`` (halved HBM
    traffic).
    """

    matmul_speedup: float = 3.0
    memory_speedup: float = 2.0

    @staticmethod
    def _targets(tf: GraphTransform) -> List[Task]:
        # device tasks plus point-to-point COMM legs anywhere (pipeline
        # activation/gradient hops: halved precision halves the payload)
        return tf.select(lambda t: on_device(t) or t.kind == TaskKind.COMM)

    def _rescale(self, tf: GraphTransform, matmul: float,
                 memory: float) -> None:
        """Divide durations by the per-class factors (build == factor,
        retune == new/old ratio; classification is duration-independent,
        so re-applying with a ratio is exact re-parameterization)."""
        for t in self._targets(tf):
            if t.is_comm():
                t.duration /= memory   # payload bits halve too
                t.comm_bytes /= memory
            elif t.attrs.get("opcode") in ("dot", "convolution") or (
                    t.kind == TaskKind.COMPUTE and t.flops > t.bytes_accessed):
                t.duration /= matmul
            else:
                t.duration /= memory

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        self._rescale(tf, self.matmul_speedup, self.memory_speedup)

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        if old.matmul_speedup == 0 or old.memory_speedup == 0:
            return False
        self._rescale(tf, self.matmul_speedup / old.matmul_speedup,
                      self.memory_speedup / old.memory_speedup)
        return True

    def headroom_targets(self, s: Scenario):
        # everything _rescale divides: device tasks and p2p hop payloads
        return lambda t: on_device(t) or t.kind == TaskKind.COMM


@register("fused_optimizer", "fusedadam", algorithm="Alg 4")
@dataclasses.dataclass(frozen=True)
class FusedOptimizer(Optimization):
    """Paper Algorithm 4 (FusedAdam).

    Remove every weight-update-phase device task, insert one fused task
    whose duration is the roofline of the *summed* FLOPs/bytes — on GPU the
    win is eliminated CUDA-launch overhead; on TPU it is the eliminated
    per-op issue overhead and re-fused memory traffic.
    """

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        wu = [t for t in tf.select(all_of(on_device, by_phase("update")))
              if t.kind != TaskKind.COLLECTIVE]
        if not wu:
            return
        total_flops = sum(t.flops for t in wu)
        # fused kernel reads params/grads/moments once: bytes = unique
        # traffic, approximated as the sum minus re-read intermediates
        # (2/3 of memory ops).
        total_bytes = sum(t.bytes_accessed for t in wu) / 3.0
        first, rest = wu[0], wu[1:]
        first.name = "fused_optimizer_kernel"
        first.flops = total_flops
        first.bytes_accessed = total_bytes
        first.duration = s.cost.compute_time(total_flops, total_bytes)
        for t in rest:
            tf.remove(t)

    def headroom_targets(self, s: Scenario):
        return lambda t: (on_device(t) and t.phase == "update"
                          and t.kind != TaskKind.COLLECTIVE)


@register("fused_norm", algorithm="Alg 5")
@dataclasses.dataclass(frozen=True)
class FusedNorm(Optimization):
    """Paper Algorithm 5 (Reconstructing Batchnorm), normalized for LMs.

    Split the normalization, fuse halves with neighbouring compute: remove
    the activation tasks (now fused into matmuls) and speed normalization
    tasks by 2x (halved input reads).
    """

    norm_layer: str = "norm"
    activation_pattern: str = r"max|tanh|gelu|silu|logistic"
    norm_speedup: float = 2.0

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        tf.remove(all_of(on_device, by_layer(self.norm_layer),
                         by_name(self.activation_pattern)))
        for t in tf.select(all_of(on_device, by_layer(self.norm_layer))):
            if t.kind != TaskKind.COLLECTIVE:
                t.duration /= self.norm_speedup

    def headroom_targets(self, s: Scenario):
        sel = all_of(on_device, by_layer(self.norm_layer))
        return lambda t: sel(t) and t.kind != TaskKind.COLLECTIVE


@register("ddp", "distributed", algorithm="Alg 6")
@dataclasses.dataclass(frozen=True)
class DDP(Optimization):
    """Paper Algorithm 6: predict DP training from a single-worker profile.

    Inserts one all-reduce per gradient bucket on a dedicated communication
    lane (NCCL-stream semantics: buckets serialize on the lane), with
    wait-free-backprop dependencies: last bwd task of the bucket's layers ->
    all-reduce -> first update task.  Worker count and gradient payloads
    come from the scenario.
    """

    bucket_bytes: float = 25 * 1024 * 1024
    bandwidth: Optional[float] = None
    crosses_pod: bool = False

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost = s.cost
        num_workers = s.num_workers
        layer_grad_bytes = s.grads
        coll = CollectiveModel(cost.hw, cost.topo)
        if self.bandwidth is not None:
            # override link bandwidth (the paper's 10/20/40 Gbps sweeps)
            coll = CollectiveModel(
                dataclasses.replace(cost.hw, ici_bandwidth=self.bandwidth,
                                    dcn_bandwidth=self.bandwidth), cost.topo)
        g = tf.graph

        # ready order: reverse forward order, approximated by
        # last-bwd-finish order
        bwd_last: Dict[str, Task] = {}
        for t in g.lane_tasks(DEVICE_STREAM):
            if t.phase == "bwd" and t.layer in layer_grad_bytes:
                bwd_last[t.layer] = t          # lane order => last wins
        order = [l for l in bwd_last] or list(reversed(list(layer_grad_bytes)))
        missing = [l for l in layer_grad_bytes if l not in order]
        order += missing
        buckets = bucket_layers(layer_grad_bytes, self.bucket_bytes,
                                reverse_order=order)

        lane = g.lane_tasks(DEVICE_STREAM)
        lane_pos = {t.uid: i for i, t in enumerate(lane)}
        update_tasks = [t for t in lane if t.phase == "update"]
        sync = [t for t in g.lane_tasks(HOST_THREAD)
                if t.kind == TaskKind.SYNC]
        tail = sync[-1] if sync else None

        for i, (layers, payload) in enumerate(buckets):
            dur = coll.group_time("all-reduce", payload, num_workers,
                                  self.crosses_pod)
            ar = Task(name=f"allreduce:bucket{i}", kind=TaskKind.COLLECTIVE,
                      thread=GRAD_CHANNEL, duration=dur, comm_bytes=payload,
                      phase="comm", attrs={"collective": "all-reduce",
                                           "group_size": num_workers,
                                           "bucket": i, "layers": layers})
            parents = [bwd_last[l] for l in layers if l in bwd_last]
            # paper: AllReduce -> WU.  XLA may interleave update ops with
            # bwd, so pick the earliest update task scheduled *after* every
            # parent to stay acyclic; fall back to the host-side completion
            # sync.
            after = max((lane_pos[p.uid] for p in parents), default=-1)
            barrier = next((t for t in update_tasks
                            if lane_pos[t.uid] > after), tail)
            children = [x for x in (barrier,) if x is not None]
            tf.append(ar, parents=parents, children=children)

    def headroom_targets(self, s: Scenario):
        # pure insertion: DP communication only ever adds to a
        # single-worker baseline, so the bound is exactly 1.0x
        return lambda t: False


def extend_next_forward(tf: GraphTransform) -> Dict[str, Task]:
    """Clone the forward-phase device tasks as a next-iteration prologue.

    Cross-iteration what-ifs (P3, parameter-server pulls) gate the *next*
    forward pass on communication; a single-iteration graph cannot express
    that, so we append a copy of the fwd segment after the current
    iteration's device lane (paper Algorithm 7 inserts push/pull "between
    the backward and the forward GPU tasks for each layer").  Returns
    {layer: first cloned fwd task}.
    """
    g = tf.graph
    fwd = [t for t in g.lane_tasks(DEVICE_STREAM) if t.phase == "fwd"]
    first_of_layer: Dict[str, Task] = {}
    sync = [t for t in g.lane_tasks(HOST_THREAD) if t.kind == TaskKind.SYNC]
    tail = sync[-1] if sync else None
    for t in fwd:
        c = t.clone()
        c.name = f"next:{t.name}"
        c.phase = "next_fwd"
        g.add_task(c)                      # appends to device lane => ordered
        if t.layer and t.layer not in first_of_layer:
            first_of_layer[t.layer] = c
        if tail is not None:
            g.add_edge(c, tail)
    return first_of_layer


@register("p3", algorithm="Alg 7")
@dataclasses.dataclass(frozen=True)
class P3(Optimization):
    """Paper Algorithm 7 (Priority-Based Parameter Propagation).

    Slice each layer's gradient, insert push/pull pairs on send/receive
    channels, prioritize slices of layers closer to the *input* (they are
    needed last in bwd but first in the *next* fwd), and override the
    scheduler with the priority policy.  The next-iteration forward segment
    is cloned so the pull->fwd dependency is expressible.

    ``priority=False, slice_bytes=inf`` gives the plain parameter-server
    baseline of paper Fig. 10.
    """

    bandwidth: float = 0.0
    slice_bytes: float = 4 * 1024 * 1024
    priority: bool = True

    def __post_init__(self) -> None:
        if self.bandwidth <= 0:
            raise OptimizationError(
                "p3 needs bandwidth=<bytes/s> (the per-link push/pull "
                "bandwidth)")

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        layer_grad_bytes = s.grads
        num_workers = s.num_workers
        g = tf.graph

        bwd_last: Dict[str, Task] = {}
        for t in g.lane_tasks(DEVICE_STREAM):
            if t.layer in layer_grad_bytes and t.phase == "bwd":
                bwd_last[t.layer] = t
        next_fwd = extend_next_forward(tf)
        sync = [t for t in g.lane_tasks(HOST_THREAD)
                if t.kind == TaskKind.SYNC]
        tail = sync[-1] if sync else None

        # priority: negative distance to output == earlier layers first
        # (paper line 9)
        layer_order = list(layer_grad_bytes)
        prio = {l: -(len(layer_order) - i)
                for i, l in enumerate(layer_order)}

        for layer, gbytes in layer_grad_bytes.items():
            nslices = max(1, math.ceil(gbytes / self.slice_bytes))
            per = gbytes / nslices
            t_push = per * (num_workers - 1) / max(num_workers, 1) \
                / self.bandwidth
            for sl in range(nslices):
                push = Task(name=f"push:{layer}:{sl}",
                            kind=TaskKind.COLLECTIVE,
                            thread=ici_channel("send"), duration=t_push,
                            comm_bytes=per, phase="comm",
                            attrs={"priority": prio[layer]})
                pull = Task(name=f"pull:{layer}:{sl}",
                            kind=TaskKind.COLLECTIVE,
                            thread=ici_channel("recv"), duration=t_push,
                            comm_bytes=per, phase="comm",
                            attrs={"priority": prio[layer]})
                parents = [bwd_last[layer]] if layer in bwd_last else []
                tf.append(push, parents=parents)
                children = [x for x in (next_fwd.get(layer, tail),)
                            if x is not None]
                tf.append(pull, parents=[push], children=children)

        if self.priority:
            tf.prioritize(lambda t: t.attrs.get("priority", -1e9))

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # insertion-only vs the baseline


@register("blueconnect", algorithm="Alg 8")
@dataclasses.dataclass(frozen=True)
class BlueConnect(Optimization):
    """Paper Algorithm 8: decompose each all-reduce into per-axis
    reduce-scatter chains + reversed all-gather chains on parallel channels.

    ``axes`` is ((axis_name, size), ...) — the factorization p1*p2*...*pk.
    """

    axes: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self) -> None:
        if not self.axes:
            raise OptimizationError(
                "blueconnect needs axes=[(axis_name, size), ...]")
        object.__setattr__(self, "axes",
                           tuple((str(a), int(n)) for a, n in self.axes))

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost = s.cost
        coll = CollectiveModel(cost.hw, cost.topo)
        targets = [t for t in tf.select(
            lambda t: t.kind == TaskKind.COLLECTIVE
            and t.attrs.get("collective") == "all-reduce")]
        for u in targets:
            parents = tf.graph.parents(u)
            children = tf.graph.children(u)
            payload = u.comm_bytes
            prev: List[Task] = list(parents)
            p = payload
            chain: List[Task] = []
            for ax, n in self.axes:
                kind = cost.topo.axis_kind.get(ax, "ici")
                rs = Task(name=f"reduce-scatter:{u.name}:{ax}",
                          kind=TaskKind.COLLECTIVE, thread=ici_channel(ax),
                          duration=coll.axis_time("reduce-scatter", p, n,
                                                  kind),
                          comm_bytes=p, phase="comm",
                          attrs={"collective": "reduce-scatter",
                                 "group_size": n})
                tf.append(rs, parents=prev)
                prev = [rs]
                chain.append(rs)
                p /= max(n, 1)
            for ax, n in reversed(list(self.axes)):
                kind = cost.topo.axis_kind.get(ax, "ici")
                p *= max(n, 1)
                ag = Task(name=f"all-gather:{u.name}:{ax}",
                          kind=TaskKind.COLLECTIVE, thread=ici_channel(ax),
                          duration=coll.axis_time("all-gather", p, n, kind),
                          comm_bytes=p, phase="comm",
                          attrs={"collective": "all-gather",
                                 "group_size": n})
                tf.append(ag, parents=prev)
                prev = [ag]
                chain.append(ag)
            for c in children:
                tf.graph.add_edge(prev[0], c)
            tf.remove(u)

    def headroom_targets(self, s: Scenario):
        return lambda t: (t.kind == TaskKind.COLLECTIVE and
                          t.attrs.get("collective") == "all-reduce")


@register("remove_layer", algorithm="Alg 9")
@dataclasses.dataclass(frozen=True)
class RemoveLayer(Optimization):
    """Paper Algorithm 9 Remove_layer (MetaFlow)."""

    layer_pattern: str

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        tf.remove(all_of(on_device, by_layer(self.layer_pattern)))

    def headroom_targets(self, s: Scenario):
        return all_of(on_device, by_layer(self.layer_pattern))


@register("scale_layer", algorithm="Alg 9")
@dataclasses.dataclass(frozen=True)
class ScaleLayer(Optimization):
    """Paper Algorithm 9 Scale_layer (MetaFlow)."""

    layer_pattern: str
    scale: float = 1.0

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        tf.scale(all_of(on_device, by_layer(self.layer_pattern)), self.scale)

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        if self.layer_pattern != old.layer_pattern or old.scale == 0:
            return False
        tf.scale(all_of(on_device, by_layer(self.layer_pattern)),
                 self.scale / old.scale)
        return True

    def headroom_targets(self, s: Scenario):
        # scale > 1 only slows the targets; erasure still upper-bounds it
        return all_of(on_device, by_layer(self.layer_pattern))


def _layer_anchors(graph: DependencyGraph, layer_pattern: str
                   ) -> Tuple[Dict[str, Task], Dict[str, Task]]:
    """Per matching layer: (last forward task, first backward task) on the
    device lane — the insertion anchors of the activation what-ifs."""
    import re
    rx = re.compile(layer_pattern)
    fwd_last: Dict[str, Task] = {}
    bwd_first: Dict[str, Task] = {}
    for t in graph.lane_tasks(DEVICE_STREAM):
        if t.layer and rx.search(t.layer):
            if t.phase == "fwd":
                fwd_last[t.layer] = t
            elif t.phase == "bwd" and t.layer not in bwd_first:
                bwd_first[t.layer] = t
    return fwd_last, bwd_first


@register("offload", "vdnn", algorithm="Alg 10")
@dataclasses.dataclass(frozen=True)
class Offload(Optimization):
    """Paper Algorithm 10 (vDNN), TPU form: activations of matching layers
    are offloaded HBM->host after their forward task and prefetched
    host->HBM before their backward task, on the DMA channel.
    ``prefetch_distance`` controls how many layers ahead the prefetch is
    hooked (the paper's custom Schedule override becomes an explicit
    dependency re-wiring here).  Activation bytes come from the scenario.
    """

    layer_pattern: str
    prefetch_distance: int = 1

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost, activation_bytes = s.cost, s.acts
        fwd_last, bwd_first = _layer_anchors(tf.graph, self.layer_pattern)
        bwd_order = [l for l in bwd_first]
        for i, layer in enumerate(bwd_order):
            nbytes = activation_bytes.get(layer, 0.0)
            if nbytes <= 0 or layer not in fwd_last:
                continue
            off = Task(name=f"offload:{layer}", kind=TaskKind.OFFLOAD,
                       thread=DMA_CHANNEL,
                       duration=cost.offload_time(nbytes),
                       bytes_accessed=nbytes, phase="fwd")
            tf.append(off, parents=[fwd_last[layer]])
            pre = Task(name=f"prefetch:{layer}", kind=TaskKind.OFFLOAD,
                       thread=DMA_CHANNEL,
                       duration=cost.offload_time(nbytes),
                       bytes_accessed=nbytes, phase="bwd")
            # prefetch is triggered `prefetch_distance` bwd layers early
            trigger_idx = max(0, i - self.prefetch_distance)
            trigger = bwd_first[bwd_order[trigger_idx]]
            parents = [off] + ([trigger] if trigger_idx != i else [])
            tf.append(pre, parents=parents, children=[bwd_first[layer]])

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # trades time for memory, never faster


@register("gist", algorithm="Alg 11")
@dataclasses.dataclass(frozen=True)
class Gist(Optimization):
    """Paper Algorithm 11 (Gist): insert encode after fwd / decode before
    bwd as device tasks costed like element-wise kernels over the
    activation (bytes from the scenario)."""

    layer_pattern: str
    codec_bytes_per_elem_ratio: float = 2.0

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost, activation_bytes = s.cost, s.acts
        fwd_last, bwd_first = _layer_anchors(tf.graph, self.layer_pattern)
        for layer, anchor in fwd_last.items():
            nbytes = activation_bytes.get(layer, 0.0)
            if nbytes <= 0:
                continue
            traffic = nbytes * self.codec_bytes_per_elem_ratio
            enc = Task(name=f"gist-encode:{layer}", kind=TaskKind.MEMORY,
                       thread=DEVICE_STREAM, bytes_accessed=traffic,
                       duration=cost.compute_time(nbytes, traffic),
                       phase="fwd")
            tf.insert_after(anchor, enc)
            if layer in bwd_first:
                dec = Task(name=f"gist-decode:{layer}",
                           kind=TaskKind.MEMORY, thread=DEVICE_STREAM,
                           bytes_accessed=traffic,
                           duration=cost.compute_time(nbytes, traffic),
                           phase="bwd")
                tf.insert_before(bwd_first[layer], dec, extra_parents=[enc])

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # codec insertion only adds device work


@register("dgc", algorithm="Alg 12")
@dataclasses.dataclass(frozen=True)
class DGC(Optimization):
    """Paper Algorithm 12 (Deep Gradient Compression): scale every gradient
    collective's payload by ``compression`` and insert compress/decompress
    device tasks around it.

    Re-parameterizable in place (:meth:`retune`): a ``Scenario.sweep`` grid
    over ``compression`` / ``codec_flops_per_byte`` rescales the applied
    transform instead of rebuilding per point.
    """

    compression: float = 0.01
    codec_flops_per_byte: float = 4.0

    _TARGET_OPS = ("all-reduce", "reduce-scatter")

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        if old.compression == 0:
            return False
        cost = s.cost
        colls = {t.name: t for t in tf.select(
            lambda t: t.kind == TaskKind.COLLECTIVE and
            t.attrs.get("collective") in self._TARGET_OPS)}
        base = {name: u.comm_bytes / old.compression
                for name, u in colls.items()}
        for t in tf.select(lambda t: t.name.startswith("dgc-")):
            role, _, cname = t.name.partition(":")
            payload = base.get(cname)
            if payload is None:
                return False          # structure drifted: rebuild the point
            t.flops = payload * self.codec_flops_per_byte
            out = 2 * payload if role == "dgc-compress" \
                else 2 * payload * self.compression
            t.bytes_accessed = out
            t.duration = cost.compute_time(t.flops, out)
        for name, u in colls.items():
            u.comm_bytes = base[name] * self.compression
            u.duration = u.duration / old.compression * self.compression
        return True

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost = s.cost
        targets = [t for t in tf.select(
            lambda t: t.kind == TaskKind.COLLECTIVE and
            t.attrs.get("collective") in ("all-reduce", "reduce-scatter"))]
        for u in targets:
            payload = u.comm_bytes
            u.comm_bytes = payload * self.compression
            u.duration = u.duration * self.compression
            f = payload * self.codec_flops_per_byte
            comp = Task(name=f"dgc-compress:{u.name}", kind=TaskKind.COMPUTE,
                        thread=DEVICE_STREAM, flops=f,
                        bytes_accessed=2 * payload,
                        duration=cost.compute_time(f, 2 * payload),
                        phase="comm")
            dec = Task(name=f"dgc-decompress:{u.name}",
                       kind=TaskKind.COMPUTE, thread=DEVICE_STREAM, flops=f,
                       bytes_accessed=2 * payload * self.compression,
                       duration=cost.compute_time(
                           f, 2 * payload * self.compression),
                       phase="comm")
            parents = list(tf.graph.parents(u))
            children = list(tf.graph.children(u))
            lane = tf.graph.lane_tasks(DEVICE_STREAM)
            lane_pos = {t.uid: i for i, t in enumerate(lane)}
            dev_parents = [p for p in parents if p.thread == DEVICE_STREAM]
            # compress right after its last device-lane producer (WFBP
            # overlap keeps)
            if dev_parents:
                anchor = max(dev_parents, key=lambda p: lane_pos[p.uid])
                tf.insert_after(anchor, comp, extra_children=[u])
            else:
                tf.append(comp, children=[u])
            for p in parents:
                tf.graph.remove_edge(p, u)
                if p.uid != comp.uid:
                    tf.graph.add_edge(p, comp)
            # decompress: must sit *after* compress in device program order
            # (XLA may schedule a bucket's consumer earlier in the lane than
            # a later bucket's last producer; splicing before such a
            # consumer would close a cycle through the lane edges).  Pick
            # the earliest device-lane consumer after comp; if none, run
            # decompress right after compress.
            lane = tf.graph.lane_tasks(DEVICE_STREAM)
            lane_pos = {t.uid: i for i, t in enumerate(lane)}
            dev_children = [c for c in children if c.thread == DEVICE_STREAM
                            and lane_pos[c.uid] > lane_pos[comp.uid]]
            if dev_children:
                anchor = min(dev_children, key=lambda c: lane_pos[c.uid])
                tf.insert_before(anchor, dec, extra_parents=[u])
            else:
                tf.insert_after(comp, dec, extra_parents=[u])
            lane_pos = {t.uid: i for i, t in
                        enumerate(tf.graph.lane_tasks(DEVICE_STREAM))}
            for c in children:
                tf.graph.remove_edge(u, c)
                if c.uid == dec.uid:
                    continue
                if (c.thread == DEVICE_STREAM
                        and lane_pos[c.uid] <= lane_pos[dec.uid]):
                    continue   # lane-earlier consumer: order kept by the lane
                tf.graph.add_edge(dec, c)

    def headroom_targets(self, s: Scenario):
        return lambda t: (t.kind == TaskKind.COLLECTIVE and
                          t.attrs.get("collective") in self._TARGET_OPS)


@register("zero", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class ZeRO(Optimization):
    """ZeRO-1/2 style: replace gradient all-reduce with reduce-scatter,
    shard the optimizer update by 1/N, all-gather updated params (N from
    the scenario's worker spec)."""

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        cost, num_workers = s.cost, s.num_workers
        coll = CollectiveModel(cost.hw, cost.topo)
        for u in tf.select(lambda t: t.kind == TaskKind.COLLECTIVE and
                           t.attrs.get("collective") == "all-reduce"):
            payload = u.comm_bytes
            u.name = f"reduce-scatter:{u.name}"
            u.attrs["collective"] = "reduce-scatter"
            u.duration = coll.group_time("reduce-scatter", payload,
                                         num_workers)
            ag = Task(name="all-gather:params", kind=TaskKind.COLLECTIVE,
                      thread=u.thread,
                      duration=coll.group_time("all-gather", payload,
                                               num_workers),
                      comm_bytes=payload, phase="comm",
                      attrs={"collective": "all-gather",
                             "group_size": num_workers})
            # forward only cross-thread consumers (the weight-update
            # barrier).  u's same-lane successor is the *next bucket's*
            # reduce-scatter; the channel lane already orders it, and an
            # explicit ag->successor edge would contradict ag's position at
            # the lane tail (a cycle)
            children = [c for c in tf.graph.children(u)
                        if c.thread != u.thread]
            tf.append(ag, parents=[u], children=children)
        tf.scale(all_of(on_device, by_phase("update")), 1.0 / num_workers)

    def headroom_targets(self, s: Scenario):
        # shrinks the sharded update and rewrites gradient all-reduces
        # (reduce-scatter + all-gather together never beat zero comm)
        return lambda t: ((t.kind == TaskKind.COLLECTIVE and
                           t.attrs.get("collective") == "all-reduce")
                          or (on_device(t) and t.phase == "update"))


@register("overlap", "overlap_collectives", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class OverlapCollectives(Optimization):
    """Move device-lane collectives onto ICI channel lanes (async
    collectives), keeping data dependencies — models compute/communication
    overlap."""

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        g = tf.graph
        for t in list(g.lane_tasks(DEVICE_STREAM)):
            if t.kind == TaskKind.COLLECTIVE:
                parents = g.parents(t)
                children = g.children(t)
                nt = t.clone()
                nt.thread = ici_channel("ici")
                g.remove_task(t, bridge=True)
                g.add_task(nt)
                for p in parents:
                    if nt.uid != p.uid and p in g:
                        g.add_edge(p, nt)
                for c in children:
                    if nt.uid != c.uid and c in g:
                        g.add_edge(nt, c)

    def headroom_targets(self, s: Scenario):
        return lambda t: (on_device(t) and t.kind == TaskKind.COLLECTIVE)

    def headroom(self, s: Scenario, tf: GraphTransform) -> bool:
        # fully hidden communication also frees the device lane's issue
        # slot, which erasure-in-place cannot express: the best case is the
        # collective gone from the lane entirely (bridged, like build does)
        for t in tf.select(self.headroom_targets(s)):
            tf.graph.remove_task(t, bridge=True)
        return True


@register("straggler", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class Straggler(Optimization):
    """One slow replica in a synchronous job: every collective waits for
    the straggler, so collective durations stretch by the straggler's extra
    compute time (symmetric-worker model, paper §4.2.1 'Duration').  For
    the structural per-worker model, use a cluster scenario with a slowed
    :class:`WorkerSpec` instead."""

    slowdown: float = 1.5
    affected_fraction: float = 1.0

    @staticmethod
    def _per_collective_extra(tf: GraphTransform, slowdown: float,
                              affected_fraction: float
                              ) -> Tuple[List[Task], float]:
        device_time = sum(t.duration for t in tf.select(on_device)
                          if t.kind != TaskKind.COLLECTIVE)
        extra = device_time * (slowdown - 1.0) * affected_fraction
        colls = tf.select(lambda t: t.kind == TaskKind.COLLECTIVE)
        return colls, (extra / len(colls) if colls else 0.0)

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        colls, per = self._per_collective_extra(tf, self.slowdown,
                                                self.affected_fraction)
        for t in colls:
            t.duration += per

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        # device durations are untouched by build, so the per-collective
        # extras of both parameterizations are recomputable from tf itself
        colls, per_old = self._per_collective_extra(
            tf, old.slowdown, old.affected_fraction)
        _, per_new = self._per_collective_extra(
            tf, self.slowdown, self.affected_fraction)
        for t in colls:
            t.duration += per_new - per_old
        return True

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # a straggler only ever slows the job


@register("bandwidth", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class Bandwidth(Optimization):
    """Paper Fig. 2 example: 'what if network bandwidth is N x'.

    Scales every communication task — group collectives *and* point-to-
    point COMM legs (pipeline activation/gradient hops), which the old
    trailing-gap hop model hid from this what-if.
    """

    factor: float = 1.0

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        tf.scale(lambda t: t.is_comm(), 1.0 / self.factor)

    def retune(self, s: Scenario, tf: GraphTransform,
               old: "Optimization") -> bool:
        if old.factor == 0:
            return False
        tf.scale(lambda t: t.is_comm(), old.factor / self.factor)
        return True

    def headroom_targets(self, s: Scenario):
        return lambda t: t.is_comm()    # infinite bandwidth == free comm


@register("grad_accum", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class GradAccum(Optimization):
    """Gradient accumulation: fwd+bwd repeat ``microbatches`` times per
    step, collectives and update run once (amortized)."""

    microbatches: int = 1

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        tf.scale(all_of(on_device, by_phase("fwd")),
                 float(self.microbatches))
        tf.scale(all_of(on_device, by_phase("bwd")),
                 float(self.microbatches))

    def headroom_targets(self, s: Scenario):
        return lambda t: False      # repeats fwd/bwd, never shrinks them


@register("pipeline", "pp", algorithm="beyond-paper")
@dataclasses.dataclass(frozen=True)
class PipelineParallel(Optimization):
    """Pipeline / hybrid parallelism as a *placement* through the real
    cluster simulator (GPipe / 1F1B; see :mod:`repro.parallel.plan`).

    The scenario's profile is partitioned by layer into ``stages`` balanced
    stage profiles, scheduled over ``microbatches``, replicated ``dp`` ways
    per stage (hybrid PP x DP: per-stage gradient rings over each stage's
    replicas), and placed onto ``stages * dp`` workers (stage-major; the
    scenario's WorkerSpec list — pods, stragglers, skewed links — maps
    1:1 onto the slots).  Cross-stage activation/gradient hops are
    point-to-point COMM legs whose duration follows the placed link (DCN
    across pods) and retunes in sweeps like ring legs.

    Unlike every other registered optimization this is not a graph rewrite
    — :meth:`Scenario.predict` evaluates it on the cluster route directly,
    splitting a stack at the pipeline element (see the module docstring
    for the pre/post composition semantics).
    """

    stages: int = 2
    microbatches: int = 8
    schedule: str = "gpipe"
    dp: int = 1

    def __post_init__(self) -> None:
        if self.stages < 1 or self.microbatches < 1 or self.dp < 1:
            raise OptimizationError(
                f"pipeline needs stages/microbatches/dp >= 1, got "
                f"{self.spec()}")
        from repro.parallel.plan import SCHEDULES
        if self.schedule not in SCHEDULES:
            raise OptimizationError(
                f"pipeline schedule must be one of {SCHEDULES}, got "
                f"{self.schedule!r}")

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        raise OptimizationError(
            "pipeline is a placement, not a graph transform; evaluate it "
            "via Scenario.predict/evaluate/sweep (not supported on the "
            "trace route)")


def _num_comm_tasks(graph: DependencyGraph) -> int:
    return sum(1 for t in graph.tasks()
               if t.kind in (TaskKind.COLLECTIVE, TaskKind.COMM))


def _split_pipeline(opt: Optimization
                    ) -> Tuple[Optional[Optimization],
                               Optional["PipelineParallel"],
                               Optional[Optimization]]:
    """Split a stack at its pipeline element: (pre, pipeline, post).

    ``(None, None, None)`` when the stack has no pipeline placement; raises
    when it has more than one (a graph can only be placed once).
    """
    if isinstance(opt, PipelineParallel):
        return None, opt, None
    if not isinstance(opt, Stack):
        return None, None, None
    idx = [i for i, o in enumerate(opt.opts)
           if isinstance(o, PipelineParallel)]
    if not idx:
        return None, None, None
    if len(idx) > 1:
        raise OptimizationError(
            "a stack can contain at most one pipeline placement")
    i = idx[0]
    pre = Stack(*opt.opts[:i]) if opt.opts[:i] else None
    post = Stack(*opt.opts[i + 1:]) if opt.opts[i + 1:] else None
    return pre, opt.opts[i], post


# ================================================================= search
def default_candidates(scenario: Scenario) -> List[Optimization]:
    """Default-constructible registered optimizations — the search space a
    driver explores when the user names none."""
    out: List[Optimization] = []
    for name in available():
        cls = get_optimization(name)
        try:
            out.append(cls())
        except (TypeError, OptimizationError):
            continue       # requires parameters the driver cannot default
    return out


def greedy_search(scenario: Scenario, *, max_depth: int = 3,
                  candidates: Optional[Sequence[Optimization]] = None,
                  round1: Optional[Dict[int, Prediction]] = None
                  ) -> Tuple[Optional[Optimization], List[Prediction]]:
    """Greedy hill-climb over the registry: repeatedly stack whichever
    candidate most reduces the predicted makespan, until no candidate
    improves or ``max_depth`` is reached.

    Candidates that do not apply to the scenario (missing byte maps, no
    collectives to transform, ...) are skipped, so the search runs on any
    scenario.  ``round1`` optionally seeds the first round with already-
    evaluated depth-1 predictions keyed by ``id(candidate)`` — the
    opportunity-ranking pass realizes every candidate anyway
    (:func:`repro.analysis.rank_opportunities`), and re-simulating them
    would double the most expensive stage.  Returns ``(best stack or
    None, per-round best predictions)``.
    """
    cands = list(candidates) if candidates is not None \
        else default_candidates(scenario)
    chosen: List[Optimization] = []
    best = scenario.baseline().makespan
    trail: List[Prediction] = []
    for _ in range(max_depth):
        round_best: Optional[Prediction] = None
        for cand in cands:
            if any(type(cand) is type(o) for o in chosen):
                continue
            try:
                if not chosen and round1 is not None \
                        and id(cand) in round1:
                    pred = round1[id(cand)]
                else:
                    pred = scenario.predict(Stack(*chosen, cand) if chosen
                                            else cand)
            except Exception:
                continue      # not applicable to this scenario
            if pred.predicted < (round_best.predicted if round_best
                                 else best):
                round_best = pred
        if round_best is None:
            break
        opt = round_best.optimization
        chosen = list(opt.opts) if isinstance(opt, Stack) else [opt]
        best = round_best.predicted
        trail.append(round_best)
    if not chosen:
        return None, trail
    return (chosen[0] if len(chosen) == 1 else Stack(*chosen)), trail
