"""CPU calibration for measured-mode validation (DESIGN.md §4).

The container has no TPU; validating Daydream's *methodology* (predict ->
implement -> compare, paper §6) therefore runs on the CPU backend.  This module
measures the local backend's effective matmul FLOP/s, element-wise memory
bandwidth, and (multi-host-device) collective bandwidth, producing a
:class:`repro.core.costmodel.CostModel` whose analytical durations are in local
wall-clock units.  The hardware constants for the TPU roofline path stay
untouched — calibration is only for ground-truth comparisons.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .costmodel import CollectiveModel, CostModel, MeshTopology
from .task import HardwareSpec


def _time(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


@functools.lru_cache(maxsize=4)
def measure_local_backend(size: int = 1024, dtype_str: str = "float32"
                          ) -> Dict[str, float]:
    """Measure matmul FLOP/s and elementwise bytes/s on the local backend."""
    dtype = jnp.dtype(dtype_str)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (size, size), dtype)
    b = jax.random.normal(key, (size, size), dtype)

    mm = jax.jit(lambda x, y: x @ y)
    t_mm = _time(mm, a, b)
    flops = 2.0 * size ** 3
    flops_per_s = flops / max(t_mm, 1e-9)

    big = jax.random.normal(key, (size * size * 8,), dtype)
    ew = jax.jit(lambda x: x * 1.0001 + 0.5)
    t_ew = _time(ew, big)
    traffic = 2.0 * big.size * dtype.itemsize
    bytes_per_s = traffic / max(t_ew, 1e-9)

    return {
        "matmul_flops_per_s": flops_per_s,
        "elementwise_bytes_per_s": bytes_per_s,
        "op_overhead_s": max(_time(jax.jit(lambda x: x + 1), jnp.ones(())), 1e-7),
    }


def measure_collective_bandwidth(num_devices: Optional[int] = None,
                                 payload_mb: int = 8) -> float:
    """All-reduce bus bandwidth across the local devices (bytes/s per device)."""
    devices = jax.devices()
    n = num_devices or len(devices)
    if n < 2:
        return 8e9
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro import compat
    mesh = compat.make_mesh((n,), ("d",))
    elems = payload_mb * 1024 * 1024 // 4
    x = jnp.ones((n, elems), jnp.float32)
    x = jax.device_put(x, NamedSharding(mesh, P("d", None)))
    f = jax.jit(lambda v: jnp.sum(v, axis=0),
                out_shardings=NamedSharding(mesh, P(None)))
    t = _time(f, x)
    payload = elems * 4
    # ring all-reduce equivalent: 2*(n-1)/n * payload / bw = t
    return 2 * (n - 1) / n * payload / max(t, 1e-9)


def hop_latency_from_measurement(t_small: float, payload_bytes: float,
                                 num_devices: int, bandwidth: float) -> float:
    """Per-ring-step latency implied by one tiny-payload all-reduce time.

    The ring model (``CollectiveModel.axis_time``) predicts
    ``t = 2*(n-1)/n * payload/bw + 2*(n-1)*hop``; a tiny payload makes the
    latency term dominant, so subtracting the measured-bandwidth transfer
    term and dividing by the hop count recovers ``hop`` — the collective
    analogue of deriving ``op_overhead`` from a measured no-op dispatch.
    Degenerate inputs (n < 2, negative residual from noise) fall back to the
    analytical default.
    """
    if num_devices < 2 or t_small <= 0:
        return CollectiveModel.HOP_LATENCY
    transfer = 2 * (num_devices - 1) / num_devices * payload_bytes \
        / max(bandwidth, 1e-9)
    hop = (t_small - transfer) / (2 * (num_devices - 1))
    return hop if hop > 0 else CollectiveModel.HOP_LATENCY


def measure_collective_hop_latency(num_devices: Optional[int] = None,
                                   payload_kb: int = 4,
                                   bandwidth: Optional[float] = None) -> float:
    """Measured per-ring-step latency of the local backend's collectives.

    Times a tiny (``payload_kb``) all-reduce — latency-dominated — and
    solves the ring formula for the per-hop term
    (:func:`hop_latency_from_measurement`).  This is the ROADMAP item:
    ring-leg ``HOP_LATENCY`` is calibrated against the measured local
    collective path exactly the way compute durations already are, so
    cluster ring legs land in local wall-clock units too.  Single-device
    backends return the analytical default.
    """
    devices = jax.devices()
    n = num_devices or len(devices)
    if n < 2:
        return CollectiveModel.HOP_LATENCY
    bw = bandwidth if bandwidth is not None \
        else measure_collective_bandwidth(n)
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro import compat
    mesh = compat.make_mesh((n,), ("d",))
    elems = max(payload_kb * 1024 // 4, 1)
    x = jnp.ones((n, elems), jnp.float32)
    x = jax.device_put(x, NamedSharding(mesh, P("d", None)))
    f = jax.jit(lambda v: jnp.sum(v, axis=0),
                out_shardings=NamedSharding(mesh, P(None)))
    t_small = _time(f, x)
    return hop_latency_from_measurement(t_small, elems * 4, n, bw)


def calibrated_cost_model(num_devices: int = 1) -> CostModel:
    """CostModel whose constants are the *local* backend's measured rates."""
    m = measure_local_backend()
    if num_devices > 1:
        coll_bw = measure_collective_bandwidth(num_devices)
        hop = measure_collective_hop_latency(num_devices, bandwidth=coll_bw)
    else:
        coll_bw, hop = 8e9, CollectiveModel.HOP_LATENCY
    hw = HardwareSpec(
        name="local-cpu",
        peak_flops=m["matmul_flops_per_s"],
        hbm_bandwidth=m["elementwise_bytes_per_s"],
        ici_bandwidth=coll_bw,
        dcn_bandwidth=8e9,
        op_overhead=m["op_overhead_s"] * 0.25,
        host_dispatch=m["op_overhead_s"],
    )
    topo = MeshTopology({"data": num_devices}, {"data": "ici"})
    return CostModel(hw=hw, topo=topo, hop_latency=hop)
