"""Modeled optimizations (paper §5 + Appendix A) as graph-transformation recipes.

Each ``what_if_*`` function takes a baseline graph (plus optimization-specific
knowledge, e.g. per-layer gradient bytes) and returns a transformed
:class:`GraphTransform` ready to simulate.  The implementations intentionally
track the paper's pseudo code (Algorithms 3–12) line-for-line where it exists,
re-grounded for TPU semantics per DESIGN.md §2.

Paper table-1 coverage implemented here:
  AMP, FusedAdam, Reconstructing-Norm, DDP insertion, P3,          (evaluated, §5.1)
  BlueConnect, MetaFlow, vDNN, Gist, DGC                            (modeled,   §5.2)
Beyond-paper what-ifs:
  ZeRO optimizer sharding, collective overlap, straggler, bandwidth
  scaling, gradient accumulation.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import ClusterGraph, ClusterResult, WorkerSpec, _as_specs
from .costmodel import CollectiveModel, CostModel
from .graph import DependencyGraph
from .layermap import bucket_layers
from .simulate import make_priority_schedule
from .task import (Task, TaskKind, DEVICE_STREAM, DMA_CHANNEL, HOST_THREAD,
                   ici_channel)
from .transform import (GraphTransform, all_of, by_kind, by_layer, by_name,
                        by_phase, on_device)

GRAD_CHANNEL = ici_channel("grad")


# --------------------------------------------------------------------- AMP
def what_if_amp(graph: DependencyGraph, *, matmul_speedup: float = 3.0,
                memory_speedup: float = 2.0) -> GraphTransform:
    """Paper Algorithm 3 (AMP).

    GPU original: sgemm/scudnn kernels 3x (TensorCore), everything else 2x
    (halved bytes).  TPU analogue: MXU-bound ops (dot/convolution fusions whose
    roofline is compute) get ``matmul_speedup`` (bf16 -> int8/fp8 on the MXU);
    bandwidth-bound ops get ``memory_speedup`` (halved HBM traffic).
    """
    tf = GraphTransform(graph)
    for t in tf.select(on_device):
        if t.kind == TaskKind.COLLECTIVE:
            t.duration /= memory_speedup          # payload bits halve too
            t.comm_bytes /= memory_speedup
        elif t.attrs.get("opcode") in ("dot", "convolution") or (
                t.kind == TaskKind.COMPUTE and t.flops > t.bytes_accessed):
            t.duration /= matmul_speedup
        else:
            t.duration /= memory_speedup
    return tf


# -------------------------------------------------------------- FusedAdam
def what_if_fused_optimizer(graph: DependencyGraph,
                            cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 4 (FusedAdam).

    Remove every weight-update-phase device task, insert one fused task whose
    duration is the roofline of the *summed* FLOPs/bytes — on GPU the win is
    eliminated CUDA-launch overhead; on TPU it is the eliminated per-op issue
    overhead and re-fused memory traffic.
    """
    cost = cost or CostModel()
    tf = GraphTransform(graph)
    wu = [t for t in tf.select(all_of(on_device, by_phase("update")))
          if t.kind != TaskKind.COLLECTIVE]
    if not wu:
        return tf
    total_flops = sum(t.flops for t in wu)
    # fused kernel reads params/grads/moments once: bytes = unique traffic,
    # approximated as the sum minus re-read intermediates (2/3 of memory ops).
    total_bytes = sum(t.bytes_accessed for t in wu) / 3.0
    first, rest = wu[0], wu[1:]
    first.name = "fused_optimizer_kernel"
    first.flops = total_flops
    first.bytes_accessed = total_bytes
    first.duration = cost.compute_time(total_flops, total_bytes)
    for t in rest:
        tf.remove(t)
    return tf


# ------------------------------------------------- Reconstructing BatchNorm
def what_if_fused_norm(graph: DependencyGraph, *, norm_layer: str = "norm",
                       activation_pattern: str = r"max|tanh|gelu|silu|logistic",
                       norm_speedup: float = 2.0) -> GraphTransform:
    """Paper Algorithm 5 (Reconstructing Batchnorm), normalized for LMs.

    Split the normalization, fuse halves with neighbouring compute: remove the
    activation tasks (now fused into matmuls) and speed normalization tasks by
    2x (halved input reads).
    """
    tf = GraphTransform(graph)
    tf.remove(all_of(on_device, by_layer(norm_layer), by_name(activation_pattern)))
    for t in tf.select(all_of(on_device, by_layer(norm_layer))):
        if t.kind != TaskKind.COLLECTIVE:
            t.duration /= norm_speedup
    return tf


# ------------------------------------------------------ Distributed (DDP)
def what_if_distributed(graph: DependencyGraph,
                        layer_grad_bytes: Dict[str, float],
                        num_workers: int,
                        *, bandwidth: Optional[float] = None,
                        bucket_bytes: float = 25 * 1024 * 1024,
                        cost: Optional[CostModel] = None,
                        crosses_pod: bool = False) -> GraphTransform:
    """Paper Algorithm 6: predict DP training from a single-worker profile.

    Inserts one all-reduce per gradient bucket on a dedicated communication
    lane (NCCL-stream semantics: buckets serialize on the lane), with
    wait-free-backprop dependencies: last bwd task of the bucket's layers ->
    all-reduce -> first update task.
    """
    cost = cost or CostModel()
    coll = CollectiveModel(cost.hw, cost.topo)
    if bandwidth is not None:
        # override link bandwidth (the paper's 10/20/40 Gbps sweeps)
        import dataclasses as _dc
        coll = CollectiveModel(_dc.replace(cost.hw, ici_bandwidth=bandwidth,
                                           dcn_bandwidth=bandwidth), cost.topo)
    tf = GraphTransform(graph)
    g = tf.graph

    # ready order: reverse forward order, approximated by last-bwd-finish order
    bwd_last: Dict[str, Task] = {}
    for t in g.lane_tasks(DEVICE_STREAM):
        if t.phase == "bwd" and t.layer in layer_grad_bytes:
            bwd_last[t.layer] = t          # lane order => last wins
    order = [l for l in bwd_last] or list(reversed(list(layer_grad_bytes)))
    missing = [l for l in layer_grad_bytes if l not in order]
    order += missing
    buckets = bucket_layers(layer_grad_bytes, bucket_bytes, reverse_order=order)

    lane = g.lane_tasks(DEVICE_STREAM)
    lane_pos = {t.uid: i for i, t in enumerate(lane)}
    update_tasks = [t for t in lane if t.phase == "update"]
    sync = [t for t in g.lane_tasks(HOST_THREAD) if t.kind == TaskKind.SYNC]
    tail = sync[-1] if sync else None

    for i, (layers, payload) in enumerate(buckets):
        dur = coll.group_time("all-reduce", payload, num_workers, crosses_pod)
        ar = Task(name=f"allreduce:bucket{i}", kind=TaskKind.COLLECTIVE,
                  thread=GRAD_CHANNEL, duration=dur, comm_bytes=payload,
                  phase="comm", attrs={"collective": "all-reduce",
                                       "group_size": num_workers,
                                       "bucket": i, "layers": layers})
        parents = [bwd_last[l] for l in layers if l in bwd_last]
        # paper: AllReduce -> WU.  XLA may interleave update ops with bwd, so
        # pick the earliest update task scheduled *after* every parent to stay
        # acyclic; fall back to the host-side completion sync.
        after = max((lane_pos[p.uid] for p in parents), default=-1)
        barrier = next((t for t in update_tasks if lane_pos[t.uid] > after), tail)
        children = [x for x in (barrier,) if x is not None]
        tf.append(ar, parents=parents, children=children)
    return tf


def extend_next_forward(tf: GraphTransform) -> Dict[str, Task]:
    """Clone the forward-phase device tasks as a next-iteration prologue.

    Cross-iteration what-ifs (P3, parameter-server pulls) gate the *next*
    forward pass on communication; a single-iteration graph cannot express
    that, so we append a copy of the fwd segment after the current iteration's
    device lane (paper Algorithm 7 inserts push/pull "between the backward and
    the forward GPU tasks for each layer").  Returns {layer: first cloned fwd
    task}.
    """
    g = tf.graph
    fwd = [t for t in g.lane_tasks(DEVICE_STREAM) if t.phase == "fwd"]
    first_of_layer: Dict[str, Task] = {}
    sync = [t for t in g.lane_tasks(HOST_THREAD) if t.kind == TaskKind.SYNC]
    tail = sync[-1] if sync else None
    for t in fwd:
        c = t.clone()
        c.name = f"next:{t.name}"
        c.phase = "next_fwd"
        g.add_task(c)                      # appends to device lane => ordered
        if t.layer and t.layer not in first_of_layer:
            first_of_layer[t.layer] = c
        if tail is not None:
            g.add_edge(c, tail)
    return first_of_layer


# ------------------------------------------------------------------- P3
def what_if_p3(graph: DependencyGraph, layer_grad_bytes: Dict[str, float],
               num_workers: int, *, bandwidth: float,
               slice_bytes: float = 4 * 1024 * 1024,
               priority: bool = True,
               cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 7 (Priority-Based Parameter Propagation).

    Slice each layer's gradient, insert push/pull pairs on send/receive
    channels, prioritize slices of layers closer to the *input* (they are
    needed last in bwd but first in the *next* fwd), and override the
    scheduler with the priority policy.  The next-iteration forward segment is
    cloned so the pull->fwd dependency is expressible (paper inserts push/pull
    "between the backward and the forward GPU tasks for each layer").

    ``priority=False, slice_bytes=inf`` gives the plain parameter-server
    baseline of paper Fig. 10.
    """
    cost = cost or CostModel()
    tf = GraphTransform(graph)
    g = tf.graph

    bwd_last: Dict[str, Task] = {}
    for t in g.lane_tasks(DEVICE_STREAM):
        if t.layer in layer_grad_bytes and t.phase == "bwd":
            bwd_last[t.layer] = t
    next_fwd = extend_next_forward(tf)
    sync = [t for t in g.lane_tasks(HOST_THREAD) if t.kind == TaskKind.SYNC]
    tail = sync[-1] if sync else None

    # priority: negative distance to output == earlier layers first (paper line 9)
    layer_order = list(layer_grad_bytes)
    prio = {l: -(len(layer_order) - i) for i, l in enumerate(layer_order)}

    for layer, gbytes in layer_grad_bytes.items():
        nslices = max(1, math.ceil(gbytes / slice_bytes))
        per = gbytes / nslices
        t_push = per * (num_workers - 1) / max(num_workers, 1) / bandwidth
        for s in range(nslices):
            push = Task(name=f"push:{layer}:{s}", kind=TaskKind.COLLECTIVE,
                        thread=ici_channel("send"), duration=t_push,
                        comm_bytes=per, phase="comm",
                        attrs={"priority": prio[layer]})
            pull = Task(name=f"pull:{layer}:{s}", kind=TaskKind.COLLECTIVE,
                        thread=ici_channel("recv"), duration=t_push,
                        comm_bytes=per, phase="comm",
                        attrs={"priority": prio[layer]})
            parents = [bwd_last[layer]] if layer in bwd_last else []
            tf.append(push, parents=parents)
            children = [x for x in (next_fwd.get(layer, tail),) if x is not None]
            tf.append(pull, parents=[push], children=children)

    if priority:
        tf.prioritize(lambda t: t.attrs.get("priority", -1e9))
    return tf


# ------------------------------------------------------------ BlueConnect
def what_if_blueconnect(graph: DependencyGraph, axes: Sequence[Tuple[str, int]],
                        cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 8: decompose each all-reduce into per-axis
    reduce-scatter chains + reversed all-gather chains on parallel channels.

    ``axes`` is [(axis_name, size), ...] — the factorization p1*p2*...*pk.
    """
    cost = cost or CostModel()
    coll = CollectiveModel(cost.hw, cost.topo)
    tf = GraphTransform(graph)
    targets = [t for t in tf.select(lambda t: t.kind == TaskKind.COLLECTIVE
                                    and t.attrs.get("collective") == "all-reduce")]
    for u in targets:
        parents = tf.graph.parents(u)
        children = tf.graph.children(u)
        payload = u.comm_bytes
        prev: List[Task] = list(parents)
        p = payload
        chain: List[Task] = []
        for ax, n in axes:
            kind = cost.topo.axis_kind.get(ax, "ici")
            rs = Task(name=f"reduce-scatter:{u.name}:{ax}",
                      kind=TaskKind.COLLECTIVE, thread=ici_channel(ax),
                      duration=coll.axis_time("reduce-scatter", p, n, kind),
                      comm_bytes=p, phase="comm",
                      attrs={"collective": "reduce-scatter", "group_size": n})
            tf.append(rs, parents=prev)
            prev = [rs]
            chain.append(rs)
            p /= max(n, 1)
        for ax, n in reversed(list(axes)):
            kind = cost.topo.axis_kind.get(ax, "ici")
            p *= max(n, 1)
            ag = Task(name=f"all-gather:{u.name}:{ax}",
                      kind=TaskKind.COLLECTIVE, thread=ici_channel(ax),
                      duration=coll.axis_time("all-gather", p, n, kind),
                      comm_bytes=p, phase="comm",
                      attrs={"collective": "all-gather", "group_size": n})
            tf.append(ag, parents=prev)
            prev = [ag]
            chain.append(ag)
        for c in children:
            tf.graph.add_edge(prev[0], c)
        tf.remove(u)
    return tf


# --------------------------------------------------------------- MetaFlow
def what_if_remove_layer(graph: DependencyGraph, layer_pattern: str
                         ) -> GraphTransform:
    """Paper Algorithm 9 Remove_layer."""
    tf = GraphTransform(graph)
    tf.remove(all_of(on_device, by_layer(layer_pattern)))
    return tf


def what_if_scale_layer(graph: DependencyGraph, layer_pattern: str,
                        scale: float) -> GraphTransform:
    """Paper Algorithm 9 Scale_layer."""
    tf = GraphTransform(graph)
    tf.scale(all_of(on_device, by_layer(layer_pattern)), scale)
    return tf


# ------------------------------------------------------------------ vDNN
def what_if_offload(graph: DependencyGraph, layer_pattern: str,
                    activation_bytes: Dict[str, float],
                    cost: Optional[CostModel] = None,
                    prefetch_distance: int = 1) -> GraphTransform:
    """Paper Algorithm 10 (vDNN), TPU form: activations of matching layers are
    offloaded HBM->host after their forward task and prefetched host->HBM
    before their backward task, on the DMA channel.  ``prefetch_distance``
    controls how many layers ahead the prefetch is hooked (the paper's custom
    Schedule override becomes an explicit dependency re-wiring here)."""
    cost = cost or CostModel()
    tf = GraphTransform(graph)
    g = tf.graph
    import re
    rx = re.compile(layer_pattern)
    fwd_last: Dict[str, Task] = {}
    bwd_first: Dict[str, Task] = {}
    for t in g.lane_tasks(DEVICE_STREAM):
        if t.layer and rx.search(t.layer):
            if t.phase == "fwd":
                fwd_last[t.layer] = t
            elif t.phase == "bwd" and t.layer not in bwd_first:
                bwd_first[t.layer] = t
    bwd_order = [l for l in bwd_first]
    for i, layer in enumerate(bwd_order):
        nbytes = activation_bytes.get(layer, 0.0)
        if nbytes <= 0 or layer not in fwd_last:
            continue
        off = Task(name=f"offload:{layer}", kind=TaskKind.OFFLOAD,
                   thread=DMA_CHANNEL, duration=cost.offload_time(nbytes),
                   bytes_accessed=nbytes, phase="fwd")
        tf.append(off, parents=[fwd_last[layer]])
        pre = Task(name=f"prefetch:{layer}", kind=TaskKind.OFFLOAD,
                   thread=DMA_CHANNEL, duration=cost.offload_time(nbytes),
                   bytes_accessed=nbytes, phase="bwd")
        # prefetch is triggered `prefetch_distance` bwd layers early
        trigger_idx = max(0, i - prefetch_distance)
        trigger = bwd_first[bwd_order[trigger_idx]]
        parents = [off] + ([trigger] if trigger_idx != i else [])
        tf.append(pre, parents=parents, children=[bwd_first[layer]])
    return tf


# ------------------------------------------------------------------ Gist
def what_if_gist(graph: DependencyGraph, layer_pattern: str,
                 activation_bytes: Dict[str, float],
                 cost: Optional[CostModel] = None,
                 codec_bytes_per_elem_ratio: float = 2.0) -> GraphTransform:
    """Paper Algorithm 11 (Gist): insert encode after fwd / decode before bwd
    as device tasks costed like element-wise kernels over the activation."""
    cost = cost or CostModel()
    tf = GraphTransform(graph)
    g = tf.graph
    import re
    rx = re.compile(layer_pattern)
    fwd_last: Dict[str, Task] = {}
    bwd_first: Dict[str, Task] = {}
    for t in g.lane_tasks(DEVICE_STREAM):
        if t.layer and rx.search(t.layer):
            if t.phase == "fwd":
                fwd_last[t.layer] = t
            elif t.phase == "bwd" and t.layer not in bwd_first:
                bwd_first[t.layer] = t
    for layer, anchor in fwd_last.items():
        nbytes = activation_bytes.get(layer, 0.0)
        if nbytes <= 0:
            continue
        traffic = nbytes * codec_bytes_per_elem_ratio
        enc = Task(name=f"gist-encode:{layer}", kind=TaskKind.MEMORY,
                   thread=DEVICE_STREAM, bytes_accessed=traffic,
                   duration=cost.compute_time(nbytes, traffic), phase="fwd")
        tf.insert_after(anchor, enc)
        if layer in bwd_first:
            dec = Task(name=f"gist-decode:{layer}", kind=TaskKind.MEMORY,
                       thread=DEVICE_STREAM, bytes_accessed=traffic,
                       duration=cost.compute_time(nbytes, traffic), phase="bwd")
            tf.insert_before(bwd_first[layer], dec, extra_parents=[enc])
    return tf


# ------------------------------------------------------------------- DGC
def what_if_dgc(graph: DependencyGraph, *, compression: float = 0.01,
                codec_flops_per_byte: float = 4.0,
                cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 12 (Deep Gradient Compression): scale every gradient
    collective's payload by ``compression`` and insert compress/decompress
    device tasks around it."""
    cost = cost or CostModel()
    tf = GraphTransform(graph)
    targets = [t for t in tf.select(lambda t: t.kind == TaskKind.COLLECTIVE and
                                    t.attrs.get("collective") in
                                    ("all-reduce", "reduce-scatter"))]
    for u in targets:
        payload = u.comm_bytes
        u.comm_bytes = payload * compression
        u.duration = u.duration * compression
        f = payload * codec_flops_per_byte
        comp = Task(name=f"dgc-compress:{u.name}", kind=TaskKind.COMPUTE,
                    thread=DEVICE_STREAM, flops=f, bytes_accessed=2 * payload,
                    duration=cost.compute_time(f, 2 * payload), phase="comm")
        dec = Task(name=f"dgc-decompress:{u.name}", kind=TaskKind.COMPUTE,
                   thread=DEVICE_STREAM, flops=f,
                   bytes_accessed=2 * payload * compression,
                   duration=cost.compute_time(f, 2 * payload * compression),
                   phase="comm")
        parents = list(tf.graph.parents(u))
        children = list(tf.graph.children(u))
        lane = tf.graph.lane_tasks(DEVICE_STREAM)
        lane_pos = {t.uid: i for i, t in enumerate(lane)}
        dev_parents = [p for p in parents if p.thread == DEVICE_STREAM]
        # compress right after its last device-lane producer (WFBP overlap keeps)
        if dev_parents:
            anchor = max(dev_parents, key=lambda p: lane_pos[p.uid])
            tf.insert_after(anchor, comp, extra_children=[u])
        else:
            tf.append(comp, children=[u])
        for p in parents:
            tf.graph.remove_edge(p, u)
            if p.uid != comp.uid:
                tf.graph.add_edge(p, comp)
        # decompress: must sit *after* compress in device program order (XLA
        # may schedule a bucket's consumer earlier in the lane than a later
        # bucket's last producer; splicing before such a consumer would close
        # a cycle through the lane edges).  Pick the earliest device-lane
        # consumer after comp; if none, run decompress right after compress.
        lane = tf.graph.lane_tasks(DEVICE_STREAM)
        lane_pos = {t.uid: i for i, t in enumerate(lane)}
        dev_children = [c for c in children if c.thread == DEVICE_STREAM
                        and lane_pos[c.uid] > lane_pos[comp.uid]]
        if dev_children:
            anchor = min(dev_children, key=lambda c: lane_pos[c.uid])
            tf.insert_before(anchor, dec, extra_parents=[u])
        else:
            tf.insert_after(comp, dec, extra_parents=[u])
        lane_pos = {t.uid: i
                    for i, t in enumerate(tf.graph.lane_tasks(DEVICE_STREAM))}
        for c in children:
            tf.graph.remove_edge(u, c)
            if c.uid == dec.uid:
                continue
            if (c.thread == DEVICE_STREAM
                    and lane_pos[c.uid] <= lane_pos[dec.uid]):
                continue      # lane-earlier consumer: order kept by the lane
            tf.graph.add_edge(dec, c)
    return tf


# ------------------------------------------------------- beyond the paper
def what_if_zero(graph: DependencyGraph, num_workers: int,
                 cost: Optional[CostModel] = None) -> GraphTransform:
    """ZeRO-1/2 style: replace gradient all-reduce with reduce-scatter, shard
    the optimizer update by 1/N, all-gather updated params."""
    cost = cost or CostModel()
    coll = CollectiveModel(cost.hw, cost.topo)
    tf = GraphTransform(graph)
    for u in tf.select(lambda t: t.kind == TaskKind.COLLECTIVE and
                       t.attrs.get("collective") == "all-reduce"):
        payload = u.comm_bytes
        u.name = f"reduce-scatter:{u.name}"
        u.attrs["collective"] = "reduce-scatter"
        u.duration = coll.group_time("reduce-scatter", payload, num_workers)
        ag = Task(name=f"all-gather:params", kind=TaskKind.COLLECTIVE,
                  thread=u.thread,
                  duration=coll.group_time("all-gather", payload, num_workers),
                  comm_bytes=payload, phase="comm",
                  attrs={"collective": "all-gather", "group_size": num_workers})
        # forward only cross-thread consumers (the weight-update barrier).
        # u's same-lane successor is the *next bucket's* reduce-scatter; the
        # channel lane already orders it, and an explicit ag->successor edge
        # would contradict ag's position at the lane tail (a cycle)
        children = [c for c in tf.graph.children(u) if c.thread != u.thread]
        tf.append(ag, parents=[u], children=children)
    n = tf.scale(all_of(on_device, by_phase("update")), 1.0 / num_workers)
    return tf


def what_if_overlap_collectives(graph: DependencyGraph) -> GraphTransform:
    """Move device-lane collectives onto ICI channel lanes (async collectives),
    keeping data dependencies — models compute/communication overlap."""
    tf = GraphTransform(graph)
    g = tf.graph
    for t in list(g.lane_tasks(DEVICE_STREAM)):
        if t.kind == TaskKind.COLLECTIVE:
            parents = g.parents(t)
            children = g.children(t)
            nt = t.clone()
            nt.thread = ici_channel("ici")
            g.remove_task(t, bridge=True)
            g.add_task(nt)
            for p in parents:
                if nt.uid != p.uid and p in g:
                    g.add_edge(p, nt)
            for c in children:
                if nt.uid != c.uid and c in g:
                    g.add_edge(nt, c)
    return tf


def what_if_straggler(graph: DependencyGraph, *, slowdown: float = 1.5,
                      affected_fraction: float = 1.0) -> GraphTransform:
    """One slow replica in a synchronous job: every collective waits for the
    straggler, so collective durations stretch by the straggler's extra
    compute time (symmetric-worker model, paper §4.2.1 'Duration')."""
    tf = GraphTransform(graph)
    device_time = sum(t.duration for t in tf.select(on_device)
                      if t.kind != TaskKind.COLLECTIVE)
    extra = device_time * (slowdown - 1.0) * affected_fraction
    colls = tf.select(lambda t: t.kind == TaskKind.COLLECTIVE)
    if colls:
        per = extra / len(colls)
        for t in colls:
            t.duration += per
    return tf


def what_if_bandwidth(graph: DependencyGraph, factor: float) -> GraphTransform:
    """Paper Fig. 2 example: 'what if network bandwidth is N x'."""
    tf = GraphTransform(graph)
    tf.scale(lambda t: t.kind == TaskKind.COLLECTIVE, 1.0 / factor)
    return tf


def what_if_grad_accum(graph: DependencyGraph, microbatches: int
                       ) -> GraphTransform:
    """Gradient accumulation: fwd+bwd repeat ``microbatches`` times per step,
    collectives and update run once (amortized)."""
    tf = GraphTransform(graph)
    tf.scale(all_of(on_device, by_phase("fwd")), float(microbatches))
    tf.scale(all_of(on_device, by_phase("bwd")), float(microbatches))
    return tf


# --------------------------------------------------- cluster-routed what-ifs
# The ``num_workers`` what-ifs above splice *analytical* collective costs into
# one worker's graph — every worker collapses onto one timeline.  The
# ``cluster_*`` functions below route the same transformations through
# :class:`repro.core.cluster.ClusterGraph`: the transformed single-worker
# graph is replicated across N (possibly heterogeneous) workers, collectives
# become cross-worker ring/hierarchical structures, and one global simulation
# yields a per-worker :class:`SimResult` breakdown — answering questions the
# single-graph path cannot (stragglers, skewed links, mixed generations).

_worker_specs = _as_specs       # int N or explicit WorkerSpec list, validated


def cluster_what_if_distributed(graph: DependencyGraph,
                                layer_grad_bytes: Dict[str, float],
                                workers, *,
                                bucket_bytes: float = 25 * 1024 * 1024,
                                cost: Optional[CostModel] = None,
                                collective_mode: str = "ring"
                                ) -> ClusterResult:
    """DDP what-if on the global cluster graph (paper Alg. 6 x dPRO).

    With uniform ``workers`` this matches :func:`what_if_distributed`'s
    single-graph prediction (the ring legs telescope to the same analytical
    collective time); heterogeneous specs answer the questions the
    single-graph path cannot.
    """
    specs = _worker_specs(workers)
    cost = cost or CostModel()
    tf = what_if_distributed(graph, layer_grad_bytes, num_workers=len(specs),
                             bucket_bytes=bucket_bytes, cost=cost)
    cg = ClusterGraph.build(tf.graph, specs, cost=cost,
                            collective_mode=collective_mode)
    return cg.simulate()


def cluster_what_if_zero(graph: DependencyGraph,
                         layer_grad_bytes: Dict[str, float],
                         workers, *, cost: Optional[CostModel] = None,
                         collective_mode: str = "ring") -> ClusterResult:
    """ZeRO sharding simulated on the global graph: the reduce-scatter and
    param all-gather each become cross-worker ring legs."""
    specs = _worker_specs(workers)
    cost = cost or CostModel()
    tf = what_if_distributed(graph, layer_grad_bytes, num_workers=len(specs),
                             cost=cost)
    tf2 = what_if_zero(tf.graph, num_workers=len(specs), cost=cost)
    cg = ClusterGraph.build(tf2.graph, specs, cost=cost,
                            collective_mode=collective_mode)
    return cg.simulate()


def cluster_what_if_p3(graph: DependencyGraph,
                       layer_grad_bytes: Dict[str, float],
                       workers, *, bandwidth: float,
                       slice_bytes: float = 4 * 1024 * 1024,
                       priority: bool = True,
                       cost: Optional[CostModel] = None) -> ClusterResult:
    """P3 on the global graph: pushes stay worker-local (preserving the
    overlap with late backprop); pulls gate on every worker's push via the
    parameter-server aggregation barrier.  The priority schedule carries
    over to the global simulation unchanged."""
    specs = _worker_specs(workers)
    cost = cost or CostModel()
    tf = what_if_p3(graph, layer_grad_bytes, len(specs), bandwidth=bandwidth,
                    slice_bytes=slice_bytes, priority=priority, cost=cost)
    cg = ClusterGraph.build(tf.graph, specs, cost=cost,
                            schedule=tf.schedule)
    return cg.simulate()


def cluster_what_if_straggler(graph: DependencyGraph,
                              layer_grad_bytes: Dict[str, float],
                              num_workers: int, *,
                              straggler: int = 0, slowdown: float = 1.5,
                              cost: Optional[CostModel] = None,
                              collective_mode: str = "ring") -> ClusterResult:
    """One slow worker, modeled structurally: unlike :func:`what_if_straggler`
    (which amortizes the delay into every collective's duration), the
    straggler's late gradients stall the ring legs and the delay propagates
    to the other workers through the dependency edges."""
    specs = [WorkerSpec(compute_scale=slowdown if i == straggler else 1.0)
             for i in range(num_workers)]
    return cluster_what_if_distributed(graph, layer_grad_bytes, specs,
                                       cost=cost,
                                       collective_mode=collective_mode)


def cluster_what_if_bandwidth(graph: DependencyGraph,
                              layer_grad_bytes: Dict[str, float],
                              num_workers: int, *,
                              scales: Sequence[float],
                              cost: Optional[CostModel] = None
                              ) -> ClusterResult:
    """Skewed per-worker link bandwidth (paper Fig. 2's sweep, made
    per-link): ``scales[i]`` throttles the ring links adjacent to worker i,
    so one congested NIC slows only the legs that traverse it."""
    if len(scales) != num_workers:
        raise ValueError("need one bandwidth scale per worker")
    specs = [WorkerSpec(bandwidth_scale=s) for s in scales]
    return cluster_what_if_distributed(graph, layer_grad_bytes, specs,
                                       cost=cost)
