"""Modeled optimizations (paper §5 + Appendix A) — legacy function surface.

The implementations live in :mod:`repro.core.optimize` as registered
:class:`~repro.core.optimize.Optimization` dataclasses (one per paper
algorithm; see the table in that module's docstring).  Every function here
is a thin wrapper that builds the matching optimization and a
:class:`~repro.core.optimize.Scenario`, kept so existing call sites and
notebooks keep working:

* ``what_if_*``          -> analytical single-graph route, returns the
  applied :class:`GraphTransform`.
* ``cluster_what_if_*``  -> global-cluster route (worker specs -> dPRO-style
  :class:`ClusterGraph`), returns the per-worker :class:`ClusterResult`.
  ``collective_mode`` threads through every cluster wrapper uniformly.

Paper table-1 coverage (all composable via ``optimize.Stack`` / ``|``):
  AMP, FusedAdam, Reconstructing-Norm, DDP insertion, P3,          (evaluated, §5.1)
  BlueConnect, MetaFlow, vDNN, Gist, DGC                            (modeled,   §5.2)
Beyond-paper what-ifs:
  ZeRO optimizer sharding, collective overlap, straggler, bandwidth
  scaling, gradient accumulation.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

from .cluster import ClusterResult, WorkerSpec, _as_specs
from .costmodel import CostModel
from .graph import DependencyGraph
from .optimize import (AMP, DDP, DGC, P3, Bandwidth, BlueConnect,
                       FusedNorm, FusedOptimizer, Gist, GradAccum,
                       GRAD_CHANNEL, Offload, OverlapCollectives,
                       PipelineParallel, RemoveLayer, ScaleLayer, Scenario,
                       Stack, Straggler, ZeRO, extend_next_forward)
from .transform import GraphTransform

_worker_specs = _as_specs       # int N or explicit WorkerSpec list, validated

__all__ = [
    "GRAD_CHANNEL", "extend_next_forward",
    "what_if_amp", "what_if_fused_optimizer", "what_if_fused_norm",
    "what_if_distributed", "what_if_p3", "what_if_blueconnect",
    "what_if_remove_layer", "what_if_scale_layer", "what_if_offload",
    "what_if_gist", "what_if_dgc", "what_if_zero",
    "what_if_overlap_collectives", "what_if_straggler", "what_if_bandwidth",
    "what_if_grad_accum",
    "cluster_what_if_distributed", "cluster_what_if_zero",
    "cluster_what_if_p3", "cluster_what_if_straggler",
    "cluster_what_if_bandwidth", "cluster_what_if_pipeline",
]


# --------------------------------------------------------------------- AMP
def what_if_amp(graph: DependencyGraph, *, matmul_speedup: float = 3.0,
                memory_speedup: float = 2.0) -> GraphTransform:
    """Paper Algorithm 3 (AMP) — see :class:`repro.core.optimize.AMP`."""
    return AMP(matmul_speedup=matmul_speedup,
               memory_speedup=memory_speedup).apply(Scenario(graph))


# -------------------------------------------------------------- FusedAdam
def what_if_fused_optimizer(graph: DependencyGraph,
                            cost: Optional[CostModel] = None
                            ) -> GraphTransform:
    """Paper Algorithm 4 (FusedAdam) — see
    :class:`repro.core.optimize.FusedOptimizer`."""
    return FusedOptimizer().apply(Scenario(graph, cost=cost))


# ------------------------------------------------- Reconstructing BatchNorm
def what_if_fused_norm(graph: DependencyGraph, *, norm_layer: str = "norm",
                       activation_pattern: str = r"max|tanh|gelu|silu|logistic",
                       norm_speedup: float = 2.0) -> GraphTransform:
    """Paper Algorithm 5 (Reconstructing Batchnorm) — see
    :class:`repro.core.optimize.FusedNorm`."""
    return FusedNorm(norm_layer=norm_layer,
                     activation_pattern=activation_pattern,
                     norm_speedup=norm_speedup).apply(Scenario(graph))


# ------------------------------------------------------ Distributed (DDP)
def what_if_distributed(graph: DependencyGraph,
                        layer_grad_bytes: Dict[str, float],
                        num_workers: int,
                        *, bandwidth: Optional[float] = None,
                        bucket_bytes: float = 25 * 1024 * 1024,
                        cost: Optional[CostModel] = None,
                        crosses_pod: bool = False) -> GraphTransform:
    """Paper Algorithm 6 (DDP) — see :class:`repro.core.optimize.DDP`."""
    return DDP(bucket_bytes=bucket_bytes, bandwidth=bandwidth,
               crosses_pod=crosses_pod).apply(
        Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 workers=num_workers))


# ------------------------------------------------------------------- P3
def what_if_p3(graph: DependencyGraph, layer_grad_bytes: Dict[str, float],
               num_workers: int, *, bandwidth: float,
               slice_bytes: float = 4 * 1024 * 1024,
               priority: bool = True,
               cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 7 (P3) — see :class:`repro.core.optimize.P3`."""
    return P3(bandwidth=bandwidth, slice_bytes=slice_bytes,
              priority=priority).apply(
        Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 workers=num_workers))


# ------------------------------------------------------------ BlueConnect
def what_if_blueconnect(graph: DependencyGraph, axes: Sequence[Tuple[str, int]],
                        cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 8 (BlueConnect) — see
    :class:`repro.core.optimize.BlueConnect`."""
    return BlueConnect(axes=tuple(axes)).apply(Scenario(graph, cost=cost))


# --------------------------------------------------------------- MetaFlow
def what_if_remove_layer(graph: DependencyGraph, layer_pattern: str
                         ) -> GraphTransform:
    """Paper Algorithm 9 Remove_layer."""
    return RemoveLayer(layer_pattern=layer_pattern).apply(Scenario(graph))


def what_if_scale_layer(graph: DependencyGraph, layer_pattern: str,
                        scale: float) -> GraphTransform:
    """Paper Algorithm 9 Scale_layer."""
    return ScaleLayer(layer_pattern=layer_pattern,
                      scale=scale).apply(Scenario(graph))


# ------------------------------------------------------------------ vDNN
def what_if_offload(graph: DependencyGraph, layer_pattern: str,
                    activation_bytes: Dict[str, float],
                    cost: Optional[CostModel] = None,
                    prefetch_distance: int = 1) -> GraphTransform:
    """Paper Algorithm 10 (vDNN) — see
    :class:`repro.core.optimize.Offload`."""
    return Offload(layer_pattern=layer_pattern,
                   prefetch_distance=prefetch_distance).apply(
        Scenario(graph, cost=cost, activation_bytes=activation_bytes))


# ------------------------------------------------------------------ Gist
def what_if_gist(graph: DependencyGraph, layer_pattern: str,
                 activation_bytes: Dict[str, float],
                 cost: Optional[CostModel] = None,
                 codec_bytes_per_elem_ratio: float = 2.0) -> GraphTransform:
    """Paper Algorithm 11 (Gist) — see :class:`repro.core.optimize.Gist`."""
    return Gist(layer_pattern=layer_pattern,
                codec_bytes_per_elem_ratio=codec_bytes_per_elem_ratio).apply(
        Scenario(graph, cost=cost, activation_bytes=activation_bytes))


# ------------------------------------------------------------------- DGC
def what_if_dgc(graph: DependencyGraph, *, compression: float = 0.01,
                codec_flops_per_byte: float = 4.0,
                cost: Optional[CostModel] = None) -> GraphTransform:
    """Paper Algorithm 12 (DGC) — see :class:`repro.core.optimize.DGC`."""
    return DGC(compression=compression,
               codec_flops_per_byte=codec_flops_per_byte).apply(
        Scenario(graph, cost=cost))


# ------------------------------------------------------- beyond the paper
def what_if_zero(graph: DependencyGraph, num_workers: int,
                 cost: Optional[CostModel] = None) -> GraphTransform:
    """ZeRO-1/2 style sharding — see :class:`repro.core.optimize.ZeRO`."""
    return ZeRO().apply(Scenario(graph, cost=cost, workers=num_workers))


def what_if_overlap_collectives(graph: DependencyGraph) -> GraphTransform:
    """Async collectives — see
    :class:`repro.core.optimize.OverlapCollectives`."""
    return OverlapCollectives().apply(Scenario(graph))


def what_if_straggler(graph: DependencyGraph, *, slowdown: float = 1.5,
                      affected_fraction: float = 1.0) -> GraphTransform:
    """Amortized straggler model — see
    :class:`repro.core.optimize.Straggler`."""
    return Straggler(slowdown=slowdown,
                     affected_fraction=affected_fraction).apply(
        Scenario(graph))


def what_if_bandwidth(graph: DependencyGraph, factor: float
                      ) -> GraphTransform:
    """Paper Fig. 2 example — see :class:`repro.core.optimize.Bandwidth`."""
    return Bandwidth(factor=factor).apply(Scenario(graph))


def what_if_grad_accum(graph: DependencyGraph, microbatches: int
                       ) -> GraphTransform:
    """Gradient accumulation — see
    :class:`repro.core.optimize.GradAccum`."""
    return GradAccum(microbatches=microbatches).apply(Scenario(graph))


# --------------------------------------------------- cluster-routed what-ifs
# The ``num_workers`` what-ifs above splice *analytical* collective costs
# into one worker's graph — every worker collapses onto one timeline.  The
# ``cluster_*`` wrappers below set a :class:`WorkerSpec` list on the
# Scenario, which routes the same registered optimizations through
# :class:`repro.core.cluster.ClusterGraph`: one global simulation with a
# per-worker :class:`SimResult` breakdown — answering questions the
# single-graph path cannot (stragglers, skewed links, mixed generations).

def cluster_what_if_distributed(graph: DependencyGraph,
                                layer_grad_bytes: Dict[str, float],
                                workers, *,
                                bucket_bytes: float = 25 * 1024 * 1024,
                                cost: Optional[CostModel] = None,
                                collective_mode: str = "ring"
                                ) -> ClusterResult:
    """DDP what-if on the global cluster graph (paper Alg. 6 x dPRO).

    With uniform ``workers`` this matches :func:`what_if_distributed`'s
    single-graph prediction (the ring legs telescope to the same analytical
    collective time); heterogeneous specs answer the questions the
    single-graph path cannot.
    """
    s = Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 workers=_worker_specs(workers),
                 collective_mode=collective_mode)
    return s.predict(DDP(bucket_bytes=bucket_bytes)).cluster


def cluster_what_if_zero(graph: DependencyGraph,
                         layer_grad_bytes: Dict[str, float],
                         workers, *, cost: Optional[CostModel] = None,
                         collective_mode: str = "ring") -> ClusterResult:
    """ZeRO sharding simulated on the global graph: the reduce-scatter and
    param all-gather each become cross-worker ring legs."""
    s = Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 workers=_worker_specs(workers),
                 collective_mode=collective_mode)
    return s.predict(DDP() | ZeRO()).cluster


def cluster_what_if_p3(graph: DependencyGraph,
                       layer_grad_bytes: Dict[str, float],
                       workers, *, bandwidth: float,
                       slice_bytes: float = 4 * 1024 * 1024,
                       priority: bool = True,
                       cost: Optional[CostModel] = None,
                       collective_mode: str = "ring") -> ClusterResult:
    """P3 on the global graph: pushes stay worker-local (preserving the
    overlap with late backprop); pulls gate on every worker's push via the
    parameter-server aggregation barrier.  The priority schedule carries
    over to the global simulation unchanged."""
    s = Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 workers=_worker_specs(workers),
                 collective_mode=collective_mode)
    return s.predict(P3(bandwidth=bandwidth, slice_bytes=slice_bytes,
                        priority=priority)).cluster


def cluster_what_if_straggler(graph: DependencyGraph,
                              layer_grad_bytes: Dict[str, float],
                              num_workers: int, *,
                              straggler: int = 0, slowdown: float = 1.5,
                              cost: Optional[CostModel] = None,
                              collective_mode: str = "ring") -> ClusterResult:
    """One slow worker, modeled structurally: unlike :func:`what_if_straggler`
    (which amortizes the delay into every collective's duration), the
    straggler's late gradients stall the ring legs and the delay propagates
    to the other workers through the dependency edges."""
    specs = [WorkerSpec(compute_scale=slowdown if i == straggler else 1.0)
             for i in range(num_workers)]
    return cluster_what_if_distributed(graph, layer_grad_bytes, specs,
                                       cost=cost,
                                       collective_mode=collective_mode)


def cluster_what_if_pipeline(graph: DependencyGraph,
                             stages: int, microbatches: int, *,
                             schedule: str = "gpipe", dp: int = 1,
                             workers=None,
                             activation_bytes: Optional[Dict[str, float]]
                             = None,
                             layer_grad_bytes: Optional[Dict[str, float]]
                             = None,
                             cost: Optional[CostModel] = None,
                             collective_mode: str = "ring") -> ClusterResult:
    """Pipeline / hybrid PP x DP placement simulated on the global graph.

    Partitions ``graph`` by layer into ``stages`` balanced stages, runs the
    GPipe or 1F1B microbatch schedule on ``stages * dp`` workers with
    point-to-point activation/gradient hops and per-stage gradient rings —
    see :class:`repro.core.optimize.PipelineParallel` and
    :mod:`repro.parallel.plan`.  ``workers`` (optional WorkerSpec list,
    stage-major) places stages on heterogeneous pods/stragglers.
    """
    s = Scenario(graph, cost=cost, layer_grad_bytes=layer_grad_bytes,
                 activation_bytes=activation_bytes,
                 workers=workers if workers is not None else 1,
                 collective_mode=collective_mode)
    return s.predict(PipelineParallel(stages=stages,
                                      microbatches=microbatches,
                                      schedule=schedule, dp=dp)).cluster


def cluster_what_if_bandwidth(graph: DependencyGraph,
                              layer_grad_bytes: Dict[str, float],
                              num_workers: int, *,
                              scales: Sequence[float],
                              cost: Optional[CostModel] = None,
                              collective_mode: str = "ring"
                              ) -> ClusterResult:
    """Skewed per-worker link bandwidth (paper Fig. 2's sweep, made
    per-link): ``scales[i]`` throttles the ring links adjacent to worker i,
    so one congested NIC slows only the legs that traverse it."""
    if len(scales) != num_workers:
        raise ValueError("need one bandwidth scale per worker")
    specs = [WorkerSpec(bandwidth_scale=s) for s in scales]
    return cluster_what_if_distributed(graph, layer_grad_bytes, specs,
                                       cost=cost,
                                       collective_mode=collective_mode)
