"""Cluster simulation: a global dependency graph spanning N workers.

Daydream (the paper) predicts distributed training by splicing analytical
collective-cost tasks into *one* worker's graph (``what_if_distributed``).
That collapses every worker onto one timeline, so per-worker questions —
"what if worker 3 is 2x slower?", "what if half the ring crosses a pod
boundary?", "what does a mixed v5e/v4 fleet look like?" — are unanswerable.
dPRO (arXiv:2205.02473) showed the fix: build a *global* graph whose nodes
are every worker's tasks and whose cross-worker edges encode collective
synchronization, then simulate it once.

:class:`ClusterGraph` does exactly that, from either of two sources:

* :meth:`ClusterGraph.build` replicates a profiled single-worker
  :class:`~repro.core.graph.DependencyGraph` across N (possibly
  heterogeneous) :class:`WorkerSpec` replicas.  Replica ``i``'s resources are
  namespaced ``w<i>/<thread>`` (:func:`~repro.core.task.worker_thread`);
  non-collective durations and gaps scale by ``compute_scale`` (stragglers,
  mixed device generations).

* :meth:`ClusterGraph.from_worker_graphs` builds the same global graph from
  N *different* per-worker graphs — the asymmetric general case the
  replicate path is a special case of.  Collectives are matched across
  workers by (name, occurrence) — :func:`match_collective_groups` — and each
  matched group is wired with the same mode-selected cross-worker structure.
  :meth:`ClusterGraph.from_traces` feeds it from real per-worker profiler
  traces via :mod:`repro.traceio` (Chrome trace-event JSON / native JSONL,
  dPRO-style clock alignment).

* Collectives become cross-worker structures, mode-selectable:

  - ``"ring"`` (default): each all-reduce is 2(n-1) per-worker *leg* tasks
    (reduce-scatter legs then all-gather legs); leg k of worker i depends on
    leg k-1 of ring predecessor i-1, which is what makes a straggler's delay
    propagate around the ring exactly as the analytical model predicts.  Leg
    time is (payload/n)/link_bw + hop latency; a link crossing pods uses DCN
    bandwidth, and a slow worker's ``bandwidth_scale`` throttles its links.
    With uniform workers, per-worker leg sums telescope to exactly
    ``CollectiveModel.group_time`` — the single-graph DDP prediction.

  - ``"hierarchical"`` (BlueConnect-style): intra-pod reduce-scatter, a
    cross-pod all-reduce among pod leaders over DCN, intra-pod all-gather —
    the decomposition of ``CollectiveModel.hierarchical_all_reduce``.  The
    cross-pod stage exchanges one equal shard per pod, so the pod layout
    must have equal-size pods; :meth:`build` rejects inconsistent layouts
    instead of producing a silently mis-grouped graph.

  - ``"fused"``: one synchronized task per worker keeping the analytical
    (or traced) duration (a zero-cost barrier provides the "wait for all"
    semantics).

  Point-to-point push/pull pairs (P3, parameter server) are synchronized at
  the aggregation boundary: every worker's push feeds a barrier that gates
  every worker's pull.  Pairing works on both build paths: the replicate
  path reads the shared base structure, the asymmetric trace path matches
  unnamed push/pull pairs across worker graphs by (layer, occurrence)
  (:func:`match_push_pull_groups`).

* The comm-primitive layer is *scoped*: :meth:`ClusterGraph.wire_collective_group`
  wires a matched collective over any subset of workers (``worker_ids``) —
  how hybrid pipeline x data parallelism gets its per-stage DDP rings — and
  :meth:`ClusterGraph.wire_p2p` wires a provenance-carrying point-to-point
  leg (:class:`~repro.core.task.TaskKind` ``COMM``) between tasks on two
  workers, its duration derived from the same link-bandwidth model as ring
  legs (pods -> DCN, ``bandwidth_scale`` throttling) and retunable like
  them.  :mod:`repro.parallel.plan` places pipeline stages with exactly
  these two primitives.

* :meth:`ClusterGraph.simulate` runs the event-driven engine
  (:func:`repro.core.simulate.simulate` — the O(E log V) heap engine makes
  these N-times-larger graphs tractable) and splits the result into a
  :class:`ClusterResult` with a per-worker :class:`SimResult` breakdown.

**Symmetry folding — the equivalence-class contract.**  Replicating every
worker is O(workers); :mod:`repro.core.fold` instead partitions workers
into *equivalence classes* and materializes one representative subgraph
per class, closing the collective structures algebraically over class
sizes (O(classes) tasks).  Folding is **exact** — bit-identical makespans
and per-worker timelines — precisely when every worker in a class is
guaranteed the same timeline as its representative:

* ``"ring"`` collectives fold only for a *fully uniform* group (identical
  :class:`WorkerSpec` including ``pod``): uniform legs make the
  cross-worker ring edges tie with each member's own channel
  serialization, so one representative leg chain reproduces every
  member's timeline.  A heterogeneous or multi-pod ring has
  position-dependent leg times (a DCN boundary link is slower), member
  timelines diverge, and the group *cannot* fold.
* ``"hierarchical"`` collectives fold per (pod, leader/member role) for
  any layout whose pods are internally spec-uniform — the pod-uniform
  case: stage durations depend only on pod membership, and the barrier
  structure takes maxima that are invariant under collapsing identical
  members.
* ``"fused"`` collectives and push/pull pairs fold for any per-spec
  partition (the barrier max over identical members is the max over
  representatives) — this is what makes straggler what-ifs cheap: N-1
  identical workers fold into one class, the straggler is its own class.

Anything that breaks per-class timeline identity — non-uniform specs
inside a would-be class, multi-pod rings, per-worker traces
(:meth:`ClusterGraph.from_worker_graphs` never folds), custom wiring the
fold layer does not recognize — makes :func:`repro.core.fold.fold_cluster`
return ``None`` and the caller falls back to full materialization, so
folding is a pure optimization, never a semantics change.  Retunes that
preserve the partition (same members per class) stay folded; ones that
split a class (e.g. perturbing one member of a uniform ring) are rejected
by ``FoldedClusterGraph.can_retune`` and trigger a rebuild.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from repro.obs.spans import span as _obs_span

from .costmodel import CollectiveModel, CostModel
from .graph import DependencyGraph, GraphError
from .simulate import (ScheduleFn, SimResult, _host_device_breakdown,
                       simulate, simulate_incremental)
from .task import (Task, TaskKind, HOST_THREAD, p2p_channel,
                   split_worker_thread, worker_thread)

# Ring-decomposable collectives -> number of leg rounds as a multiple of (n-1).
_RING_ROUNDS = {"all-reduce": 2, "reduce-scatter": 1, "all-gather": 1}

_SYNC_THREAD = "cluster/sync"

# Worker-local thread carrying the trace-import start skew (a zero-duration
# task whose gap models the worker joining the step late).
_SKEW_THREAD = "trace/skew"


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One worker (chip/replica) in the cluster.

    ``compute_scale`` multiplies every non-collective duration and gap of the
    replica (2.0 == a 2x-slower straggler or an older device generation).
    ``bandwidth_scale`` scales the bandwidth of links adjacent to this worker
    (0.5 == a worker behind a congested/slow NIC).  ``pod`` groups workers
    into pods: ring links between different pods travel over DCN instead of
    ICI, and the hierarchical mode builds its two-level decomposition from it.
    """

    compute_scale: float = 1.0
    bandwidth_scale: float = 1.0
    pod: int = 0


def _as_specs(workers: Union[int, Sequence[WorkerSpec]]) -> List[WorkerSpec]:
    if isinstance(workers, int):
        if workers < 1:
            raise GraphError(f"cluster needs >= 1 worker, got {workers}")
        return [WorkerSpec() for _ in range(workers)]
    specs = list(workers)
    if not specs:
        raise GraphError("cluster needs >= 1 worker")
    return specs


def _validate_hierarchical_pods(specs: Sequence[WorkerSpec]) -> None:
    """Reject pod layouts the hierarchical decomposition cannot express.

    The cross-pod stage all-reduces one equal shard per pod (each pod's
    reduce-scatter leaves ``payload / pod_size`` on its leader), so pods of
    different sizes would exchange mismatched shards — a silently
    mis-grouped graph.  Fail loudly instead.
    """
    sizes: Dict[int, int] = collections.Counter(s.pod for s in specs)
    if len(set(sizes.values())) > 1:
        raise GraphError(
            "hierarchical collective mode needs equal-size pods (the "
            "cross-pod all-reduce exchanges one equal shard per pod); got "
            f"pod sizes {dict(sorted(sizes.items()))} — fix the WorkerSpec "
            "pod layout or use collective_mode='ring'")


def match_collective_groups(graphs: Sequence[DependencyGraph]
                            ) -> List[Tuple[str, List[Task]]]:
    """Match named collectives across per-worker graphs.

    Workers of a data-parallel job run the same program, so the k-th
    occurrence of collective name X on each worker is the same logical
    collective (dPRO matches traced collectives the same way).  Tasks count
    as collectives when ``kind == COLLECTIVE`` and ``attrs["collective"]``
    names the op.  Scans lanes in sorted-thread order so the occurrence
    index is deterministic for any graph construction order.

    Returns ``[(op, [worker0_task, worker1_task, ...]), ...]`` in worker-0
    scan order.  Raises :class:`~repro.core.graph.GraphError` when any
    worker is missing a collective the others have (or has extras) — a
    mismatched trace set cannot be synchronized.
    """
    per_worker: List[Dict[Tuple[str, int], Task]] = []
    orders: List[List[Tuple[str, int]]] = []
    for wg in graphs:
        seen: Dict[str, int] = collections.defaultdict(int)
        keyed: Dict[Tuple[str, int], Task] = {}
        order: List[Tuple[str, int]] = []
        for thread in sorted(wg.lanes):
            for uid in wg.lanes[thread]:
                t = wg.get(uid)
                if t.kind == TaskKind.COLLECTIVE \
                        and t.attrs.get("collective") \
                        and t.attrs.get("coll_gid") is None:
                    # gid-carrying collectives (our own exports) belong to
                    # match_collective_gid_groups — they may legitimately
                    # exist on a worker *subset* (per-stage rings), which
                    # the every-worker consistency check below would
                    # misread as a corrupt trace set
                    key = (t.name, seen[t.name])
                    seen[t.name] += 1
                    keyed[key] = t
                    order.append(key)
        per_worker.append(keyed)
        orders.append(order)
    union = set().union(*(set(k) for k in per_worker)) if per_worker else set()
    for i, keyed in enumerate(per_worker):
        missing = union - set(keyed)
        if missing:
            names = sorted(f"{n}#{k}" for n, k in missing)[:5]
            raise GraphError(
                f"worker {i} trace is missing collective(s) present on "
                f"other workers: {', '.join(names)}"
                f"{' ...' if len(missing) > 5 else ''} — cannot match "
                f"collectives across an inconsistent trace set")
    groups: List[Tuple[str, List[Task]]] = []
    for key in orders[0]:
        members = [keyed[key] for keyed in per_worker]
        ops = {m.attrs["collective"] for m in members}
        if len(ops) > 1:
            raise GraphError(
                f"collective {key[0]!r}#{key[1]} has conflicting ops across "
                f"workers: {sorted(ops)}")
        groups.append((ops.pop(), members))
    return groups


def match_collective_gid_groups(graphs: Sequence[DependencyGraph]
                                ) -> List[Tuple[str, Tuple[int, ...],
                                                List[Task]]]:
    """Match exported collectives across per-worker graphs by ``coll_gid``.

    Traces this repo exports stamp every collapsed collective with the
    graph-unique gid of the structure it came from, which identifies the
    logical collective *exactly* — including collectives that exist only
    on a worker subset (hybrid PP x DP per-stage gradient rings), which
    (name, occurrence) matching cannot express because it requires every
    worker to carry every key.  Returns ``(op, worker_ids, members)`` per
    gid shared by >= 2 workers, ordered by gid (the original build's
    wiring order); single-worker gids stay local (a truncated set degrades
    instead of crashing).  Foreign captures carry no gids and fall through
    to :func:`match_collective_groups` untouched.
    """
    by_gid: Dict[int, List[Tuple[int, Task]]] = {}
    for w, wg in enumerate(graphs):
        for thread in sorted(wg.lanes):
            for uid in wg.lanes[thread]:
                t = wg.get(uid)
                if t.kind == TaskKind.COLLECTIVE \
                        and t.attrs.get("collective") \
                        and t.attrs.get("coll_gid") is not None:
                    by_gid.setdefault(int(t.attrs["coll_gid"]),
                                      []).append((w, t))
    groups: List[Tuple[str, Tuple[int, ...], List[Task]]] = []
    for gid in sorted(by_gid):
        group = by_gid[gid]
        if len(group) < 2:
            continue
        ids = tuple(w for w, _ in group)
        if len(set(ids)) != len(ids):
            raise GraphError(
                f"collective gid {gid} appears more than once in one "
                f"worker's trace — corrupt or re-stamped trace set")
        ops = {t.attrs["collective"] for _, t in group}
        if len(ops) > 1:
            raise GraphError(
                f"collective gid {gid} has conflicting ops across "
                f"workers: {sorted(ops)}")
        groups.append((ops.pop(), ids, [t for _, t in group]))
    return groups


def _is_unnamed_collective(t: Task) -> bool:
    return t.kind == TaskKind.COLLECTIVE and not t.attrs.get("collective")


def match_push_pull_groups(graphs: Sequence[DependencyGraph]
                           ) -> List[List[Tuple[Task, List[Task]]]]:
    """Match P3/parameter-server push->pull pairs across per-worker graphs.

    A *push* is an unnamed point-to-point collective (``kind == COLLECTIVE``
    with no ``attrs["collective"]`` group op) that has at least one
    unnamed-collective child — its *pulls*.  Workers of a data-parallel job
    run the same program, so the k-th push of a layer on each worker is the
    same logical slice transfer: pushes are keyed by (layer, occurrence) in
    sorted-lane scan order, the same discipline
    :func:`match_collective_groups` uses for named collectives.  This is
    what extends parameter-server synchronization to the asymmetric
    trace-import path (:meth:`ClusterGraph.from_worker_graphs`), which used
    to leave imported push/pull pairs unsynchronized.

    Returns one group per matched key, in worker-0 scan order:
    ``groups[k][w] == (push, pulls)`` for worker w.  Raises
    :class:`~repro.core.graph.GraphError` when any worker is missing a pair
    the others have — an inconsistent trace set cannot be synchronized.
    """
    per_worker: List[Dict[Tuple[Optional[str], int],
                          Tuple[Task, List[Task]]]] = []
    orders: List[List[Tuple[Optional[str], int]]] = []
    for wg in graphs:
        seen: Dict[Optional[str], int] = collections.defaultdict(int)
        keyed: Dict[Tuple[Optional[str], int], Tuple[Task, List[Task]]] = {}
        order: List[Tuple[Optional[str], int]] = []
        for thread in sorted(wg.lanes):
            for uid in wg.lanes[thread]:
                t = wg.get(uid)
                if not _is_unnamed_collective(t):
                    continue
                pulls = [v for v in wg.children(t)
                         if _is_unnamed_collective(v)]
                if not pulls:
                    continue
                key = (t.layer, seen[t.layer])
                seen[t.layer] += 1
                keyed[key] = (t, pulls)
                order.append(key)
        per_worker.append(keyed)
        orders.append(order)
    union = set().union(*(set(k) for k in per_worker)) if per_worker else set()
    for i, keyed in enumerate(per_worker):
        missing = union - set(keyed)
        if missing:
            names = sorted(f"{l or '?'}#{k}" for l, k in missing)[:5]
            raise GraphError(
                f"worker {i} is missing push/pull pair(s) present on other "
                f"workers: {', '.join(names)}"
                f"{' ...' if len(missing) > 5 else ''} — cannot pair "
                f"parameter-server transfers across an inconsistent set")
    return [[keyed[key] for keyed in per_worker] for key in orders[0]]


def max_imported_gid(graphs: Sequence[DependencyGraph]) -> int:
    """Largest collective/p2p gid any imported task still carries.

    Re-imported tasks keep exported ``coll_gid`` / ``p2p_gid`` /
    ``p2p_in`` attrs (fused-mode members and unmatched hop legs keep them
    verbatim through wiring), while a fresh :class:`ClusterGraph` hands
    out gids from 1 — so a rebuild over imported graphs must seed its
    counter above this value or a fresh gid can collide with a stale one
    and the next export cycle collapses/wires the wrong tasks together.
    """
    m = 0
    for wg in graphs:
        for t in wg.tasks():
            for g in (t.attrs.get("coll_gid"), t.attrs.get("p2p_gid")):
                if isinstance(g, (int, float)):
                    m = max(m, int(g))
            for g in t.attrs.get("p2p_in", ()):
                m = max(m, int(g))
    return m


def match_wired_p2p(graphs: Sequence[DependencyGraph]
                    ) -> List[Tuple[int, int, Task, int, Task]]:
    """Match exported point-to-point hops across per-worker graphs.

    A hop wired by :meth:`ClusterGraph.wire_p2p` exports with
    ``attrs["p2p_gid"]`` on the sender-side leg and the same gid in the
    receiver task's ``attrs["p2p_in"]`` — provenance that survives the
    per-worker Chrome/JSONL round trip even though the cross-worker edge
    itself is dropped at export.  Returns ``(gid, src_worker, leg_task,
    dst_worker, recv_task)`` per matched hop, ordered by gid (the wiring
    order of the original build, so re-wiring is deterministic).  Hops
    whose other side is absent (foreign or truncated traces) are skipped —
    they stay plain worker-local timeline events, the pre-provenance
    behavior.
    """
    legs: Dict[int, Tuple[int, Task]] = {}
    recvs: Dict[int, Tuple[int, Task]] = {}
    for w, wg in enumerate(graphs):
        for thread in sorted(wg.lanes):
            for uid in wg.lanes[thread]:
                t = wg.get(uid)
                gid = t.attrs.get("p2p_gid")
                if gid is not None and t.kind == TaskKind.COMM:
                    if int(gid) in legs:
                        raise GraphError(
                            f"p2p gid {gid} appears on more than one hop "
                            f"leg across the trace set — corrupt or "
                            f"re-stamped traces cannot be re-wired")
                    legs[int(gid)] = (w, t)
                for g in t.attrs.get("p2p_in", ()):
                    if int(g) in recvs:
                        raise GraphError(
                            f"p2p gid {g} is claimed by more than one "
                            f"receiver across the trace set — corrupt or "
                            f"re-stamped traces cannot be re-wired")
                    recvs[int(g)] = (w, t)
    out: List[Tuple[int, int, Task, int, Task]] = []
    for gid in sorted(set(legs) & set(recvs)):
        (sw, leg), (dw, recv) = legs[gid], recvs[gid]
        if sw != dw:
            out.append((gid, sw, leg, dw, recv))
    return out


@dataclasses.dataclass
class ClusterResult:
    """Global simulation outcome plus the per-worker breakdown.

    ``per_worker`` is computed lazily on first access: a sweep that only
    reads global makespans (``Scenario.sweep`` points) never pays for
    projecting the global result onto every worker's local resources.
    """

    makespan: float
    global_result: SimResult
    workers: List[WorkerSpec]
    _per_worker: Optional[Dict[int, SimResult]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _split_fn: Optional[Callable[[], Dict[int, SimResult]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    # uid -> (duration, gap) as of this result — lets a chained
    # simulate_incremental() refresh its own snapshot with just the dirty
    # deltas instead of an O(V) pass over the graph's tasks
    _snap: Optional[Dict[int, Tuple[float, float]]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def per_worker(self) -> Dict[int, SimResult]:
        if self._per_worker is None:
            self._per_worker = self._split_fn() if self._split_fn else {}
        return self._per_worker

    def speedup_over(self, other: "ClusterResult") -> float:
        return (other.makespan / self.makespan
                if self.makespan > 0 else float("inf"))

    def straggler(self) -> int:
        """Worker index with the largest local makespan."""
        return max(self.per_worker, key=lambda i: self.per_worker[i].makespan)

    def worker_makespans(self) -> List[float]:
        return [self.per_worker[i].makespan for i in sorted(self.per_worker)]


class ClusterGraph:
    """A global N-worker dependency graph built from per-worker profiles."""

    def __init__(self, graph: DependencyGraph, workers: List[WorkerSpec],
                 cost: CostModel, schedule: Optional[ScheduleFn] = None,
                 collective_mode: str = "ring") -> None:
        self.graph = graph
        self.workers = workers
        self.cost = cost
        self.schedule = schedule
        self.collective_mode = collective_mode
        # provenance records for :meth:`retune` — (kind, task, *base values);
        # tasks later detached from the graph are skipped.
        self._prov: List[Tuple] = []
        self._tasks_by_worker: Optional[Dict[int, List[Task]]] = None
        # monotone id shared by all pieces (legs/stages) of one wired
        # collective (attrs["coll_gid"]) — the trace exporter collapses
        # pieces back into one per-worker collective event by this id.
        self._gid = 0
        # uids whose duration/gap the most recent retune() actually changed
        # — the dirty set simulate_incremental() replays.
        self.last_retune_dirty: set = set()

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, base: DependencyGraph,
              workers: Union[int, Sequence[WorkerSpec]],
              *, cost: Optional[CostModel] = None,
              collective_mode: str = "ring",
              schedule: Optional[ScheduleFn] = None) -> "ClusterGraph":
        """Replicate ``base`` across ``workers`` and link the collectives.

        ``base`` is a single-worker graph whose collective tasks (typically
        inserted by :func:`repro.core.whatif.what_if_distributed` /
        ``what_if_zero``) carry ``attrs["collective"]``; each such task is
        replaced, per replica, by the cross-worker structure selected by
        ``collective_mode`` ("ring" | "hierarchical" | "fused").  This is
        the symmetric special case of :meth:`from_worker_graphs` — every
        worker runs the same profile.
        """
        specs = _as_specs(workers)
        cls._check_mode(collective_mode, specs)
        cost = cost or CostModel()
        n = len(specs)
        with _obs_span("cluster.build", workers=n, base_tasks=len(base),
                       mode=collective_mode):
            g = DependencyGraph()
            cg = cls(g, specs, cost, schedule, collective_mode)

            # 1. replicate: clone every task per worker, scale compute
            #    durations.
            replicas = [cg._clone_worker(i, spec, base)
                        for i, spec in enumerate(specs)]
            if n > 1:
                # 2. wire each base collective's replica group cross-worker.
                for c in base.tasks():
                    if c.kind == TaskKind.COLLECTIVE \
                            and c.attrs.get("collective"):
                        members = [remap[c.uid] for remap in replicas]
                        cg._wire_group(c.attrs["collective"], members,
                                       collective_mode)
                cg._sync_push_pull(
                    [[(remap[push.uid], [remap[v.uid] for v in pulls])
                      for remap in replicas]
                     for ((push, pulls),) in match_push_pull_groups([base])])
            return cg._finish()

    @classmethod
    def from_worker_graphs(cls, graphs: Sequence[DependencyGraph],
                           workers: Optional[Union[int, Sequence[WorkerSpec]]]
                           = None,
                           *, cost: Optional[CostModel] = None,
                           collective_mode: str = "ring",
                           schedule: Optional[ScheduleFn] = None,
                           start_skews: Optional[Sequence[float]] = None
                           ) -> "ClusterGraph":
        """Build an asymmetric global graph from N *different* worker graphs.

        This is the trace-import path (dPRO §4, Daydream §4.1 applied per
        worker): each graph comes from one worker's own profile, so
        durations, gaps, and even task sets may differ.  Collectives are
        matched across workers by (name, occurrence)
        (:func:`match_collective_groups`) and wired with the mode-selected
        cross-worker structure; P3-style unnamed push/pull pairs are
        matched by (layer, occurrence) (:func:`match_push_pull_groups`) and
        synchronized at the aggregation barrier; everything else stays
        worker-local.

        ``workers`` defaults to uniform specs (the traces already encode
        each worker's real speed); pass explicit :class:`WorkerSpec` lists
        to layer what-if scaling *on top of* the traced durations.
        ``start_skews`` (seconds per worker, from clock alignment) models
        workers that started the step late: a zero-duration task with that
        gap gates each worker's roots.

        With N references to one identical graph this reduces to
        :meth:`build` (minus push/pull pairing) — the property tests hold
        the two paths equal to float precision.
        """
        graphs = list(graphs)
        if not graphs:
            raise GraphError("from_worker_graphs needs >= 1 worker graph")
        specs = [WorkerSpec() for _ in graphs] if workers is None \
            else _as_specs(workers)
        if len(specs) != len(graphs):
            raise GraphError(
                f"{len(graphs)} worker graph(s) but {len(specs)} worker "
                f"spec(s); they must pair up 1:1")
        cls._check_mode(collective_mode, specs)
        cost = cost or CostModel()
        with _obs_span("cluster.from_worker_graphs", workers=len(graphs),
                       tasks=sum(len(wg) for wg in graphs),
                       mode=collective_mode):
            return cls._from_worker_graphs(graphs, specs, cost,
                                           collective_mode, schedule,
                                           start_skews)

    @classmethod
    def _from_worker_graphs(cls, graphs: List[DependencyGraph],
                            specs: List[WorkerSpec], cost: CostModel,
                            collective_mode: str,
                            schedule: Optional[ScheduleFn],
                            start_skews: Optional[Sequence[float]]
                            ) -> "ClusterGraph":
        g = DependencyGraph()
        cg = cls(g, specs, cost, schedule, collective_mode)
        # fresh gids must not collide with gids the traces carried in
        cg._gid = max_imported_gid(graphs)
        remaps = [cg._clone_worker(i, spec, wg)
                  for i, (wg, spec) in enumerate(zip(graphs, specs))]
        if start_skews:
            for i, skew in enumerate(start_skews):
                if skew > 0:
                    cg._add_start_skew(i, skew, remaps[i], graphs[i])
        if len(graphs) > 1:
            # exported collectives match exactly by gid (subset-scoped:
            # hybrid PP x DP per-stage rings re-wire over just their
            # stage's workers); gid-less ones by (name, occurrence)
            for op, ids, members in match_collective_gid_groups(graphs):
                cg.wire_collective_group(
                    op, [remaps[w][m.uid] for w, m in zip(ids, members)],
                    worker_ids=ids)
            for op, members in match_collective_groups(graphs):
                cg._wire_group(op, [remaps[i][m.uid]
                                    for i, m in enumerate(members)],
                               collective_mode)
            cg._sync_push_pull(
                [[(remaps[w][push.uid], [remaps[w][v.uid] for v in pulls])
                  for w, (push, pulls) in enumerate(group)]
                 for group in match_push_pull_groups(graphs)])
            # point-to-point hops (pipeline stage boundaries) re-wire from
            # their exported provenance: the leg keeps its traced lane and
            # regains both its cross-worker edge and its link-derived
            # duration/retune record
            for _, sw, leg, dw, recv in match_wired_p2p(graphs):
                cg.wire_p2p(None, remaps[dw][recv.uid], sw, dw,
                            leg=remaps[sw][leg.uid])
        return cg._finish()

    @classmethod
    def from_traces(cls, traces: Any,
                    workers: Optional[Union[int, Sequence[WorkerSpec]]] = None,
                    *, cost: Optional[CostModel] = None,
                    collective_mode: str = "ring",
                    schedule: Optional[ScheduleFn] = None,
                    align: bool = True) -> "ClusterGraph":
        """Import per-worker profiler traces into one global cluster graph.

        ``traces`` is a trace directory (one Chrome trace-event JSON or
        native JSONL file per worker — see :mod:`repro.traceio` for the
        format contract) or an already-loaded
        :class:`repro.traceio.ImportedCluster`.  Traces are clock-aligned
        (dPRO-style: least-squares offset+drift per worker anchored on
        matched collective ends) unless ``align=False``, then routed through
        :meth:`from_worker_graphs`.
        """
        from repro.traceio import ImportedCluster, load_trace_dir
        imp = traces if isinstance(traces, ImportedCluster) \
            else load_trace_dir(str(traces), align=align)
        return cls.from_worker_graphs(
            imp.graphs, workers, cost=cost, collective_mode=collective_mode,
            schedule=schedule, start_skews=imp.start_skews)

    # ----------------------------------------------------------- build pieces
    @staticmethod
    def _check_mode(mode: str, specs: Sequence[WorkerSpec]) -> None:
        if mode not in ("ring", "hierarchical", "fused"):
            raise GraphError(f"unknown collective_mode {mode!r}")
        if mode == "hierarchical":
            _validate_hierarchical_pods(specs)

    def _clone_worker(self, i: int, spec: WorkerSpec,
                      src: DependencyGraph, *,
                      comm_prov: bool = True) -> Dict[int, Task]:
        """Clone ``src`` into the global graph as worker ``i``'s subgraph.

        ``comm_prov=False`` leaves :data:`TaskKind.COMM` tasks without a
        provenance record (and unscaled): the caller is about to wire them
        as point-to-point legs (:meth:`wire_p2p`), which derives their
        duration from the actual placed link and records p2p provenance
        itself.  The default treats a traced COMM task like a traced
        collective — its duration throttles with the worker's
        ``bandwidth_scale``.
        """
        g = self.graph
        remap: Dict[int, Task] = {}
        for thread, lane in src.lanes.items():
            for uid in lane:
                t = src.get(uid)
                nt = t.clone()
                nt.thread = worker_thread(i, t.thread)
                if t.kind == TaskKind.COLLECTIVE or (
                        t.kind == TaskKind.COMM and comm_prov):
                    nt.duration = t.duration / max(spec.bandwidth_scale,
                                                   1e-12)
                    self._prov.append(("coll", nt, i, t.duration))
                elif t.kind != TaskKind.COMM:
                    # per-kind calibration scale on the duration only: gaps
                    # are untraced host time, not modeled task cost
                    nt.duration = t.duration * spec.compute_scale \
                        * self.cost.kind_scale(t.kind)
                    nt.gap = t.gap * spec.compute_scale
                    self._prov.append(("compute", nt, i, t.duration, t.gap))
                g.add_task(nt, link_lane=False)
                remap[uid] = nt
        for t in src.tasks():
            for c in src.children(t):
                g.add_edge(remap[t.uid], remap[c.uid])
        return remap

    def _add_start_skew(self, i: int, skew: float, remap: Dict[int, Task],
                        src: DependencyGraph) -> None:
        """Gate worker ``i``'s roots behind its trace-aligned start skew."""
        sk = self.graph.add_task(
            Task(name=f"w{i}:start-skew", kind=TaskKind.SYNC,
                 thread=worker_thread(i, _SKEW_THREAD), duration=0.0,
                 gap=skew, phase="comm"), link_lane=False)
        for t in src.tasks():
            if not src.parents(t):
                self.graph.add_edge(sk, remap[t.uid])

    def _finish(self) -> "ClusterGraph":
        self.graph.validate()
        # collective wiring detached some replica tasks: prune their records
        # once so retune() does no per-call membership checks
        self._prov = [r for r in self._prov if r[1] in self.graph]
        return self

    # ------------------------------------------------------- collective wiring
    def _link_bandwidth(self, i: int, j: int) -> float:
        """Bandwidth of the ring link worker i -> worker j."""
        wi, wj = self.workers[i], self.workers[j]
        bw = self.cost.link_bandwidth(
            "dcn" if wi.pod != wj.pod else "ici")
        # floor like every other scale use: a 0.0 scale (dead NIC) models as
        # an astronomically slow link rather than a ZeroDivisionError
        return bw * max(min(wi.bandwidth_scale, wj.bandwidth_scale), 1e-12)

    def _leg_duration(self, ids: Tuple[int, ...], pos: int,
                      payload: float) -> float:
        """One ring-leg's time for the member at ``pos`` of the ring over
        workers ``ids`` — shared by build and retune so a retuned sweep
        point is bit-identical to a fresh build.  ``ids`` is the full
        worker list for a global collective, or a subset (e.g. one pipeline
        stage's data-parallel replicas)."""
        k = len(ids)
        return ((payload / k)
                / self._link_bandwidth(ids[pos], ids[(pos + 1) % k])
                + self.cost.collectives.hop_latency)

    def _p2p_duration(self, i: int, j: int, payload: float) -> float:
        """One point-to-point hop worker i -> worker j (build == retune)."""
        return self.cost.collectives.p2p_time(payload,
                                              self._link_bandwidth(i, j))

    def _detach(self, task: Task) -> Tuple[List[Task], List[Task]]:
        """Remove ``task`` keeping (parents, children) for re-wiring."""
        parents = self.graph.parents(task)
        children = self.graph.children(task)
        self.graph.remove_task(task, bridge=False)
        return parents, children

    def _barrier(self, name: str) -> Task:
        return self.graph.add_task(
            Task(name=name, kind=TaskKind.SYNC, thread=_SYNC_THREAD,
                 duration=0.0, phase="comm"), link_lane=False)

    @staticmethod
    def _group_payload(members: Sequence[Task]) -> float:
        return max(max(m.comm_bytes for m in members), 0.0)

    def wire_collective_group(self, op: str, members: List[Task],
                              worker_ids: Optional[Sequence[int]] = None,
                              mode: Optional[str] = None) -> None:
        """Wire one matched collective over a (sub)group of workers.

        ``members[k]`` is the collective task of worker ``worker_ids[k]``
        (default: the full worker list in order — the classic data-parallel
        group).  Scoped groups are what hybrid parallelism is made of: a
        pipeline stage's DDP ring is a collective over just that stage's
        replicas, wired with exactly the same mode-selected structure as a
        global all-reduce.
        """
        ids = tuple(worker_ids) if worker_ids is not None \
            else tuple(range(len(self.workers)))
        if len(ids) != len(members):
            raise GraphError(
                f"collective group has {len(members)} member task(s) but "
                f"{len(ids)} worker id(s)")
        mode = mode or self.collective_mode
        self._gid += 1
        if mode == "hierarchical" and op == "all-reduce":
            # BlueConnect decomposition is an all-reduce rewrite; a bare
            # reduce-scatter / all-gather is already single-stage and
            # keeps its ring legs
            self._hierarchical_decompose(members, ids)
        elif mode in ("ring", "hierarchical") and op in _RING_ROUNDS:
            self._ring_decompose(op, members, ids)
        else:
            self._fused_sync(members)

    def _wire_group(self, op: str, members: List[Task], mode: str) -> None:
        """Wire one matched full-group collective (``members[i]`` = worker
        i's task) — the unscoped form used by the build paths."""
        self.wire_collective_group(op, members, mode=mode)

    def wire_p2p(self, src: Task, dst: Task, src_worker: int,
                 dst_worker: int, *, payload: Optional[float] = None,
                 leg: Optional[Task] = None, name: str = "p2p") -> Task:
        """Wire a point-to-point leg: ``src`` (on ``src_worker``) sends
        ``payload`` bytes to ``dst`` (on ``dst_worker``).

        The leg is a :data:`TaskKind.COMM` task on the sender's per-link
        channel (:func:`~repro.core.task.p2p_channel` — consecutive sends
        over one link serialize, exactly like ring legs on an ICI link);
        its duration comes from :meth:`_link_bandwidth` (pods -> DCN,
        ``bandwidth_scale`` throttling) plus the per-hop latency, and is
        recorded in provenance so :meth:`retune` recomputes it like a ring
        leg.  Pass ``leg`` to adopt an existing COMM task (e.g. a pipeline
        stage template's hop, cloned by :meth:`_clone_worker` with
        ``comm_prov=False``) instead of creating one; ``payload`` defaults
        to the adopted leg's ``comm_bytes``.

        Every wired hop gets round-trippable provenance: ``attrs["p2p"]``
        (src/dst worker) plus a graph-unique ``attrs["p2p_gid"]`` on the
        leg, mirrored in the receiver's ``attrs["p2p_in"]`` list.  Both
        sides survive the per-worker trace export, which is what lets
        :meth:`from_worker_graphs` re-wire imported hops
        (:func:`match_wired_p2p`) and :mod:`repro.analysis.diff` match them
        task-by-task — previously hops exported as plain timeline events
        and cross-stage coupling was lost on re-import.
        """
        i, j = src_worker, dst_worker
        if payload is None:
            payload = leg.comm_bytes if leg is not None else 0.0
        if leg is None:
            if src is None:
                raise GraphError(
                    "wire_p2p needs a src task (to create a leg) or an "
                    "existing leg task to adopt")
            leg = self.graph.add_task(
                Task(name=f"{name}:w{i}>w{j}", kind=TaskKind.COMM,
                     thread=worker_thread(i, p2p_channel(j)), duration=0.0,
                     comm_bytes=payload, phase="comm"), link_lane=False)
            self.graph.add_edge(src, leg)
        self._gid += 1
        # rebind (never mutate) the receiver's gid list: clone() copies
        # attrs dicts shallowly, so in-place list edits would leak into the
        # source graph a trace scenario re-evaluates from.  Re-wiring an
        # imported hop retires the stale imported gid, so repeated
        # export -> import cycles do not grow the list.
        ins = [g for g in dst.attrs.get("p2p_in", ())
               if g != leg.attrs.get("p2p_gid")]
        leg.attrs["p2p"] = (i, j)
        leg.attrs["p2p_gid"] = self._gid
        dst.attrs["p2p_in"] = ins + [self._gid]
        leg.duration = self._p2p_duration(i, j, payload)
        self._prov.append(("p2p", leg, i, j, payload))
        self.graph.add_edge(leg, dst)
        return leg

    def _ring_decompose(self, op: str, members: List[Task],
                        ids: Tuple[int, ...]) -> None:
        """Per-member ring legs with cross-worker pipeline edges.

        Leg round k of the member at position p waits on round k-1 of ring
        predecessor p-1 (the chunk it is about to forward) and on its own
        round k-1 (channel serialization).  Per-worker totals telescope to
        ``group_time`` for uniform workers.  ``ids[p]`` is the global
        worker index of member p — the ring spans exactly those workers.
        """
        n = len(members)
        rounds = _RING_ROUNDS[op] * (n - 1)
        payload = self._group_payload(members)
        legs: List[List[Task]] = []
        for pos, rc in enumerate(members):
            parents, children = self._detach(rc)
            leg_dur = self._leg_duration(ids, pos, payload)
            worker_legs: List[Task] = []
            prev: Optional[Task] = None
            for k in range(rounds):
                leg = rc.clone()
                leg.name = f"{rc.name}:leg{k}"
                leg.duration = leg_dur
                leg.comm_bytes = payload / n
                leg.attrs = dict(rc.attrs, ring_round=k, coll_gid=self._gid)
                self._prov.append(("ring", leg, ids, pos, payload))
                self.graph.add_task(leg, link_lane=False)
                for p in (parents if prev is None else [prev]):
                    self.graph.add_edge(p, leg)
                prev = leg
                worker_legs.append(leg)
            for ch in children:
                self.graph.add_edge(prev, ch)
            legs.append(worker_legs)
        for i in range(n):
            for k in range(1, rounds):
                self.graph.add_edge(legs[(i - 1) % n][k - 1], legs[i][k])

    def _hierarchical_decompose(self, members: List[Task],
                                ids: Tuple[int, ...]) -> None:
        """BlueConnect-style: pod-local reduce-scatter, cross-pod all-reduce
        among pod leaders over DCN, pod-local all-gather.

        The cross-pod stage is itself a collective among leaders, so it is
        gated on *every* pod's reduce-scatter finishing; the all-gather stage
        is gated on every leader's cross-pod leg.  Total per-worker time for
        uniform pods equals ``CollectiveModel.hierarchical_all_reduce``.
        Scoped groups (``ids`` a subset) build the pod structure from the
        group's workers only.
        """
        coll = self.cost.collectives
        payload = self._group_payload(members)
        cname = members[0].name
        pods: Dict[int, List[int]] = collections.defaultdict(list)
        member_pos = {w: pos for pos, w in enumerate(ids)}
        for w in ids:
            pods[self.workers[w].pod].append(w)
        _validate_hierarchical_pods([self.workers[w] for w in ids])
        pod_ids = sorted(pods)
        num_pods = len(pod_ids)

        bounds = {w: self._detach(members[member_pos[w]]) for w in ids}

        proto = {w: members[member_pos[w]] for w in ids}
        leaders_bar = self._barrier(f"{cname}:leaders-barrier")
        for p in pod_ids:
            pod_members = tuple(pods[p])
            m = len(pod_members)
            scale = min(self.workers[i].bandwidth_scale for i in pod_members)
            rs_dur = coll.axis_time("reduce-scatter", payload, m, "ici")
            rs_dur /= max(scale, 1e-12)
            bar = self._barrier(f"{cname}:pod{p}:rs-barrier")
            rs_tasks = []
            for i in pod_members:
                parents, _ = bounds[i]
                for par in parents:
                    self.graph.add_edge(par, bar)
                rs = self._add_comm(i, proto[i], f"pod{p}:reduce-scatter",
                                    rs_dur, payload)
                self._prov.append(("hrs", rs, pod_members, payload))
                self.graph.add_edge(bar, rs)
                rs_tasks.append(rs)
            for rs in rs_tasks:
                self.graph.add_edge(rs, leaders_bar)

        if num_pods > 1:
            gather_bar = self._barrier(f"{cname}:gather-barrier")
            for p in pod_ids:
                pod_members = pods[p]
                leader = pod_members[0]
                shard = payload / max(len(pod_members), 1)
                cross_dur = coll.axis_time("all-reduce", shard, num_pods,
                                           "dcn")
                cross_dur /= max(self.workers[leader].bandwidth_scale, 1e-12)
                cross = self._add_comm(leader, proto[leader],
                                       f"pod{p}:cross-all-reduce",
                                       cross_dur, shard)
                self._prov.append(("hcross", cross, leader, shard, num_pods))
                self.graph.add_edge(leaders_bar, cross)
                self.graph.add_edge(cross, gather_bar)
            gate = gather_bar
        else:
            gate = leaders_bar
        for p in pod_ids:
            self._pod_all_gather(proto, coll, payload, p, pods[p], gate,
                                 bounds)

    def _pod_all_gather(self, proto: Dict[int, Task], coll: CollectiveModel,
                        payload: float, p: int, pod_members: List[int],
                        gate: Task, bounds) -> None:
        m = len(pod_members)
        scale = min(self.workers[i].bandwidth_scale for i in pod_members)
        ag_dur = coll.axis_time("all-gather", payload, m, "ici")
        ag_dur /= max(scale, 1e-12)
        for i in pod_members:
            ag = self._add_comm(i, proto[i], f"pod{p}:all-gather", ag_dur,
                                payload)
            self._prov.append(("hag", ag, tuple(pod_members), payload))
            self.graph.add_edge(gate, ag)
            _, children = bounds[i]
            for ch in children:
                self.graph.add_edge(ag, ch)

    def _add_comm(self, i: int, proto: Task, label: str, dur: float,
                  nbytes: float) -> Task:
        t = Task(name=f"{proto.name}:{label}", kind=TaskKind.COLLECTIVE,
                 thread=worker_thread(i, split_worker_thread(proto.thread)[1]),
                 duration=dur, comm_bytes=nbytes, phase="comm",
                 attrs=dict(proto.attrs, stage=label, coll_gid=self._gid))
        return self.graph.add_task(t, link_lane=False)

    def _fused_sync(self, members: List[Task]) -> None:
        """Keep one analytical/traced-duration task per worker, gated by a
        barrier so no worker's collective starts before every worker is
        ready.  Members are stamped with the group's ``coll_gid`` so the
        exporter/importer identify the group exactly, like ring legs and
        hierarchical stages."""
        bar = self._barrier(f"{members[0].name}:barrier")
        for rc in members:
            rc.attrs["coll_gid"] = self._gid
            for p in self.graph.parents(rc):
                self.graph.add_edge(p, bar)
            self.graph.add_edge(bar, rc)

    def _sync_push_pull(self, groups: List[List[Tuple[Task, List[Task]]]]
                        ) -> None:
        """Parameter-server semantics for P3-style push/pull pairs.

        ``groups[k][w]`` is worker w's ``(push, pulls)`` for the k-th
        matched pair, already remapped into the global graph.  A pull
        returns the *aggregated* value, so every worker's pull of a slice
        waits (via one barrier per matched push) for every worker's push of
        that slice.  Pushes themselves stay local — that preserves P3's
        overlap of early pushes with the tail of backprop.
        """
        for group in groups:
            bar = self._barrier(f"{group[0][0].name}:aggregate")
            for push, pulls in group:
                self.graph.add_edge(push, bar)
                for v in pulls:
                    self.graph.add_edge(bar, v)

    # --------------------------------------------------------------- retune
    @property
    def retunable(self) -> bool:
        """Whether :meth:`retune` can re-parameterize this build in place.

        Every collective mode records enough provenance for a duration-only
        retune (ring legs and fused durations always; hierarchical stage
        durations are recomputable from the recorded pod membership).  A
        *pod-layout* change is still structural for hierarchical graphs —
        use :meth:`can_retune` to check a concrete target spec.
        """
        return True

    def can_retune(self, workers: Union[int, Sequence[WorkerSpec]]) -> bool:
        """True when :meth:`retune` accepts ``workers`` for this build:
        same worker count, and (hierarchical mode) the same pod layout."""
        try:
            specs = _as_specs(workers)
        except GraphError:
            return False
        if len(specs) != len(self.workers):
            return False
        if self.collective_mode == "hierarchical":
            return [s.pod for s in specs] == [w.pod for w in self.workers]
        return True

    def retune(self, workers: Union[int, Sequence[WorkerSpec]]
               ) -> "ClusterGraph":
        """Re-parameterize this build for new same-length worker specs.

        Recomputes every scaled duration (compute/gap by ``compute_scale``,
        replica collectives by ``bandwidth_scale``, ring legs from the link
        bandwidths, hierarchical stage durations from the recorded pod
        membership) from the recorded base values — the same expressions
        :meth:`build` used, so the result is bit-identical to a fresh build
        with ``workers``.  This is what lets :meth:`Scenario.sweep
        <repro.core.optimize.Scenario.sweep>` evaluate bandwidth/straggler
        grids without re-replicating and re-wiring the global graph per
        point.  Hierarchical graphs additionally require the pod layout to
        stay fixed (stage *structure* depends on it); changing pods raises.
        """
        specs = _as_specs(workers)
        if len(specs) != len(self.workers):
            raise GraphError(
                f"retune needs the same worker count (have "
                f"{len(self.workers)}, got {len(specs)}); rebuild instead")
        if self.collective_mode == "hierarchical" and \
                [s.pod for s in specs] != [w.pod for w in self.workers]:
            raise GraphError(
                "changing the pod layout is structural for hierarchical "
                "cluster graphs (stage membership depends on it); rebuild "
                "instead")
        self.workers = specs
        coll = self.cost.collectives
        with _obs_span("cluster.retune", workers=len(specs),
                       records=len(self._prov)) as sp:
            self.last_retune_dirty = self._retune_records(specs, coll)
            sp.note(dirty=len(self.last_retune_dirty))
        return self

    def _retune_records(self, specs: Sequence[WorkerSpec],
                        coll: CollectiveModel) -> set:
        """Recompute every provenance-recorded duration/gap for ``specs``.

        Returns the set of task uids whose duration or gap actually
        changed — the dirty set :meth:`simulate_incremental` replays.  The
        CostModel accessors behind the expressions are pure functions of
        their keys, so each distinct lookup is resolved once per retune
        (per kind, per (i, j) link pair, per (ids, pos, payload) leg, per
        pod) instead of once per task — same float expressions as
        :meth:`build`, just memoized.
        """
        kscale: Dict[Any, float] = {}         # TaskKind -> kind_scale
        link_bw: Dict[Tuple[int, int], float] = {}   # (i, j) -> bandwidth
        leg_dur: Dict[Tuple, float] = {}      # (ids, pos, payload)
        pod_scale: Dict[Tuple[int, ...], float] = {}  # pod members -> min bw
        hop = coll.hop_latency
        dirty: set = set()

        def bw(i: int, j: int) -> float:
            b = link_bw.get((i, j))
            if b is None:
                b = link_bw[(i, j)] = self._link_bandwidth(i, j)
            return b

        for rec in self._prov:
            kind, t = rec[0], rec[1]
            gap = t.gap
            if kind == "compute":
                _, _, i, dur, g0 = rec
                ks = kscale.get(t.kind)
                if ks is None:
                    ks = kscale[t.kind] = self.cost.kind_scale(t.kind)
                d = dur * specs[i].compute_scale * ks
                gap = g0 * specs[i].compute_scale
            elif kind == "coll":
                _, _, i, dur = rec
                d = dur / max(specs[i].bandwidth_scale, 1e-12)
            elif kind == "ring":
                _, _, ids, pos, payload = rec
                key = (ids, pos, payload)
                d = leg_dur.get(key)
                if d is None:
                    k = len(ids)
                    d = leg_dur[key] = \
                        (payload / k) / bw(ids[pos], ids[(pos + 1) % k]) + hop
            elif kind == "p2p":
                _, _, i, j, payload = rec
                d = coll.p2p_time(payload, bw(i, j))
            elif kind in ("hrs", "hag"):
                _, _, pod_members, payload = rec
                op = "reduce-scatter" if kind == "hrs" else "all-gather"
                scale = pod_scale.get(pod_members)
                if scale is None:
                    scale = pod_scale[pod_members] = \
                        min(specs[i].bandwidth_scale for i in pod_members)
                d = coll.axis_time(op, payload, len(pod_members),
                                   "ici") / max(scale, 1e-12)
            else:                   # hcross
                _, _, leader, shard, num_pods = rec
                d = coll.axis_time("all-reduce", shard, num_pods,
                                   "dcn") \
                    / max(specs[leader].bandwidth_scale, 1e-12)
            if d != t.duration or gap != t.gap:
                t.duration = d
                t.gap = gap
                dirty.add(t.uid)
        return dirty

    # -------------------------------------------------------------- simulate
    def simulate(self, schedule: Optional[ScheduleFn] = None, *,
                 record_binding: bool = False) -> ClusterResult:
        res = simulate(self.graph, schedule or self.schedule,
                       record_binding=record_binding)
        # snapshot durations/gaps: a later retune() (sweeps) must not bleed
        # into this result's lazily-computed per-worker breakdown
        snap = {t.uid: (t.duration, t.gap) for t in self.graph.tasks()}
        return ClusterResult(makespan=res.makespan, global_result=res,
                             workers=list(self.workers),
                             _split_fn=lambda: self._split_result(res, snap),
                             _snap=snap)

    def simulate_incremental(self, prev: ClusterResult,
                             dirty: Optional[set] = None,
                             schedule: Optional[ScheduleFn] = None
                             ) -> Optional[ClusterResult]:
        """Replay only the downstream cone of the tasks a retune changed.

        ``prev`` is this graph's :class:`ClusterResult` from *before* the
        retune; ``dirty`` defaults to :attr:`last_retune_dirty` (the uids
        whose duration/gap the most recent :meth:`retune` actually
        changed).  Returns a result bit-identical to :meth:`simulate`, or
        ``None`` when the cone replay cannot guarantee that (custom
        schedule, oversized cone, or a boundary reorder hazard — see
        :func:`repro.core.simulate.simulate_incremental`) and the caller
        should fall back to a full :meth:`simulate`.
        """
        if dirty is None:
            dirty = self.last_retune_dirty
        res = simulate_incremental(self.graph, prev.global_result, dirty,
                                   schedule or self.schedule)
        if res is None:
            return None
        if prev._snap is not None:
            # the incremental contract says only ``dirty`` changed since
            # ``prev`` — refresh just those entries
            snap = dict(prev._snap)
            by_uid = self.graph._tasks
            for uid in dirty:
                t = by_uid.get(uid)
                if t is not None:     # provenance of detached tasks
                    snap[uid] = (t.duration, t.gap)
        else:
            snap = {t.uid: (t.duration, t.gap)
                    for t in self.graph.tasks()}
        return ClusterResult(makespan=res.makespan, global_result=res,
                             workers=list(self.workers),
                             _split_fn=lambda: self._split_result(res, snap),
                             _snap=snap)

    def _worker_partition(self) -> Dict[int, List[Task]]:
        """Tasks grouped by worker, cached — the grouping only depends on
        the graph's structure, which retune keeps fixed across sweeps."""
        if self._tasks_by_worker is None:
            by_worker: Dict[int, List[Task]] = collections.defaultdict(list)
            for t in self.graph.tasks():
                w, _ = split_worker_thread(t.thread)
                if w is not None:
                    by_worker[w].append(t)
            self._tasks_by_worker = dict(by_worker)
        return self._tasks_by_worker

    def _split_result(self, res: SimResult,
                      snap: Dict[int, Tuple[float, float]]
                      ) -> Dict[int, SimResult]:
        """Project the global result onto each worker's local resources."""
        tasks_by_worker = self._worker_partition()
        out: Dict[int, SimResult] = {}
        for i in range(len(self.workers)):
            ts = tasks_by_worker.get(i, [])
            start = {t.uid: res.start[t.uid] for t in ts}
            finish = {t.uid: res.finish[t.uid] for t in ts}
            busy: Dict[str, float] = collections.defaultdict(float)
            intervals: Dict[str, List[Tuple[float, float]]] = \
                collections.defaultdict(list)
            makespan = 0.0
            for t in ts:
                duration, gap = snap[t.uid]
                local = split_worker_thread(t.thread)[1]
                busy[local] += duration
                if duration > 0:
                    intervals[local].append((start[t.uid], finish[t.uid]))
                makespan = max(makespan, finish[t.uid] + gap)
            breakdown = _host_device_breakdown(
                intervals, makespan, lambda th: th == HOST_THREAD)
            out[i] = SimResult(makespan=makespan, start=start, finish=finish,
                               thread_busy=dict(busy), _breakdown=breakdown)
        return out
