"""Cluster simulation: a global dependency graph spanning N workers.

Daydream (the paper) predicts distributed training by splicing analytical
collective-cost tasks into *one* worker's graph (``what_if_distributed``).
That collapses every worker onto one timeline, so per-worker questions —
"what if worker 3 is 2x slower?", "what if half the ring crosses a pod
boundary?", "what does a mixed v5e/v4 fleet look like?" — are unanswerable.
dPRO (arXiv:2205.02473) showed the fix: build a *global* graph whose nodes
are every worker's tasks and whose cross-worker edges encode collective
synchronization, then simulate it once.

:class:`ClusterGraph` does exactly that:

* :meth:`ClusterGraph.build` replicates a profiled single-worker
  :class:`~repro.core.graph.DependencyGraph` across N (possibly
  heterogeneous) :class:`WorkerSpec` replicas.  Replica ``i``'s resources are
  namespaced ``w<i>/<thread>`` (:func:`~repro.core.task.worker_thread`);
  non-collective durations and gaps scale by ``compute_scale`` (stragglers,
  mixed device generations).

* Collectives become cross-worker structures, mode-selectable:

  - ``"ring"`` (default): each all-reduce is 2(n-1) per-worker *leg* tasks
    (reduce-scatter legs then all-gather legs); leg k of worker i depends on
    leg k-1 of ring predecessor i-1, which is what makes a straggler's delay
    propagate around the ring exactly as the analytical model predicts.  Leg
    time is (payload/n)/link_bw + hop latency; a link crossing pods uses DCN
    bandwidth, and a slow worker's ``bandwidth_scale`` throttles its links.
    With uniform workers, per-worker leg sums telescope to exactly
    ``CollectiveModel.group_time`` — the single-graph DDP prediction.

  - ``"hierarchical"`` (BlueConnect-style): intra-pod reduce-scatter, a
    cross-pod all-reduce among pod leaders over DCN, intra-pod all-gather —
    the decomposition of ``CollectiveModel.hierarchical_all_reduce``.

  - ``"fused"``: one synchronized task per worker keeping the analytical
    duration (a zero-cost barrier provides the "wait for all" semantics).

  Point-to-point push/pull pairs (P3, parameter server) are synchronized at
  the aggregation boundary: every worker's push feeds a barrier that gates
  every worker's pull.

* :meth:`ClusterGraph.simulate` runs the event-driven engine
  (:func:`repro.core.simulate.simulate` — the O(E log V) heap engine makes
  these N-times-larger graphs tractable) and splits the result into a
  :class:`ClusterResult` with a per-worker :class:`SimResult` breakdown.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from .costmodel import CollectiveModel, CostModel
from .graph import DependencyGraph, GraphError
from .simulate import (ScheduleFn, SimResult, _host_device_breakdown,
                       simulate)
from .task import (Task, TaskKind, HOST_THREAD, split_worker_thread,
                   worker_thread)

# Ring-decomposable collectives -> number of leg rounds as a multiple of (n-1).
_RING_ROUNDS = {"all-reduce": 2, "reduce-scatter": 1, "all-gather": 1}

_SYNC_THREAD = "cluster/sync"


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One worker (chip/replica) in the cluster.

    ``compute_scale`` multiplies every non-collective duration and gap of the
    replica (2.0 == a 2x-slower straggler or an older device generation).
    ``bandwidth_scale`` scales the bandwidth of links adjacent to this worker
    (0.5 == a worker behind a congested/slow NIC).  ``pod`` groups workers
    into pods: ring links between different pods travel over DCN instead of
    ICI, and the hierarchical mode builds its two-level decomposition from it.
    """

    compute_scale: float = 1.0
    bandwidth_scale: float = 1.0
    pod: int = 0


def _as_specs(workers: Union[int, Sequence[WorkerSpec]]) -> List[WorkerSpec]:
    if isinstance(workers, int):
        if workers < 1:
            raise GraphError(f"cluster needs >= 1 worker, got {workers}")
        return [WorkerSpec() for _ in range(workers)]
    specs = list(workers)
    if not specs:
        raise GraphError("cluster needs >= 1 worker")
    return specs


@dataclasses.dataclass
class ClusterResult:
    """Global simulation outcome plus the per-worker breakdown.

    ``per_worker`` is computed lazily on first access: a sweep that only
    reads global makespans (``Scenario.sweep`` points) never pays for
    projecting the global result onto every worker's local resources.
    """

    makespan: float
    global_result: SimResult
    workers: List[WorkerSpec]
    _per_worker: Optional[Dict[int, SimResult]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _split_fn: Optional[Callable[[], Dict[int, SimResult]]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def per_worker(self) -> Dict[int, SimResult]:
        if self._per_worker is None:
            self._per_worker = self._split_fn() if self._split_fn else {}
        return self._per_worker

    def speedup_over(self, other: "ClusterResult") -> float:
        return (other.makespan / self.makespan
                if self.makespan > 0 else float("inf"))

    def straggler(self) -> int:
        """Worker index with the largest local makespan."""
        return max(self.per_worker, key=lambda i: self.per_worker[i].makespan)

    def worker_makespans(self) -> List[float]:
        return [self.per_worker[i].makespan for i in sorted(self.per_worker)]


class ClusterGraph:
    """A global N-worker dependency graph built from a single-worker profile."""

    def __init__(self, graph: DependencyGraph, workers: List[WorkerSpec],
                 cost: CostModel, schedule: Optional[ScheduleFn] = None,
                 collective_mode: str = "ring") -> None:
        self.graph = graph
        self.workers = workers
        self.cost = cost
        self.schedule = schedule
        self.collective_mode = collective_mode
        # provenance records for :meth:`retune` — (kind, task, worker,
        # *base values); tasks later detached from the graph are skipped.
        self._prov: List[Tuple] = []
        self._tasks_by_worker: Optional[Dict[int, List[Task]]] = None

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, base: DependencyGraph,
              workers: Union[int, Sequence[WorkerSpec]],
              *, cost: Optional[CostModel] = None,
              collective_mode: str = "ring",
              schedule: Optional[ScheduleFn] = None) -> "ClusterGraph":
        """Replicate ``base`` across ``workers`` and link the collectives.

        ``base`` is a single-worker graph whose collective tasks (typically
        inserted by :func:`repro.core.whatif.what_if_distributed` /
        ``what_if_zero``) carry ``attrs["collective"]``; each such task is
        replaced, per replica, by the cross-worker structure selected by
        ``collective_mode`` ("ring" | "hierarchical" | "fused").
        """
        if collective_mode not in ("ring", "hierarchical", "fused"):
            raise GraphError(f"unknown collective_mode {collective_mode!r}")
        specs = _as_specs(workers)
        cost = cost or CostModel()
        n = len(specs)
        g = DependencyGraph()
        base_tasks = base.tasks()

        # 1. replicate: clone every task per worker, scale compute durations.
        cg = cls(g, specs, cost, schedule, collective_mode)
        replicas: List[Dict[int, Task]] = []
        for i, spec in enumerate(specs):
            remap: Dict[int, Task] = {}
            for thread, lane in base.lanes.items():
                for uid in lane:
                    t = base.get(uid)
                    nt = t.clone()
                    nt.thread = worker_thread(i, t.thread)
                    if t.kind == TaskKind.COLLECTIVE:
                        nt.duration = t.duration / max(spec.bandwidth_scale,
                                                       1e-12)
                        cg._prov.append(("coll", nt, i, t.duration))
                    else:
                        nt.duration = t.duration * spec.compute_scale
                        nt.gap = t.gap * spec.compute_scale
                        cg._prov.append(("compute", nt, i, t.duration, t.gap))
                    g.add_task(nt, link_lane=False)
                    remap[uid] = nt
            for t in base_tasks:
                for c in base.children(t):
                    g.add_edge(remap[t.uid], remap[c.uid])
            replicas.append(remap)
        if n > 1:
            cg._link_collectives(base, replicas, collective_mode)
            cg._link_push_pull(base, replicas)
        g.validate()
        # collective wiring detached some replica tasks: prune their records
        # once so retune() does no per-call membership checks
        cg._prov = [r for r in cg._prov if r[1] in g]
        return cg

    # ------------------------------------------------------- collective wiring
    def _link_bandwidth(self, i: int, j: int) -> float:
        """Bandwidth of the ring link worker i -> worker j."""
        wi, wj = self.workers[i], self.workers[j]
        hw = self.cost.hw
        if wi.pod != wj.pod:
            bw = hw.dcn_bandwidth
        else:
            bw = hw.ici_bandwidth * hw.ici_links_per_axis
        # floor like every other scale use: a 0.0 scale (dead NIC) models as
        # an astronomically slow link rather than a ZeroDivisionError
        return bw * max(min(wi.bandwidth_scale, wj.bandwidth_scale), 1e-12)

    def _leg_duration(self, i: int, payload: float) -> float:
        """One ring-leg's time for worker i — shared by build and retune so
        a retuned sweep point is bit-identical to a fresh build."""
        n = len(self.workers)
        return ((payload / n) / self._link_bandwidth(i, (i + 1) % n)
                + CollectiveModel.HOP_LATENCY)

    def _detach(self, task: Task) -> Tuple[List[Task], List[Task]]:
        """Remove ``task`` keeping (parents, children) for re-wiring."""
        parents = self.graph.parents(task)
        children = self.graph.children(task)
        self.graph.remove_task(task, bridge=False)
        return parents, children

    def _barrier(self, name: str) -> Task:
        return self.graph.add_task(
            Task(name=name, kind=TaskKind.SYNC, thread=_SYNC_THREAD,
                 duration=0.0, phase="comm"), link_lane=False)

    def _link_collectives(self, base: DependencyGraph,
                          replicas: List[Dict[int, Task]], mode: str) -> None:
        linkable = [t for t in base.tasks()
                    if t.kind == TaskKind.COLLECTIVE
                    and t.attrs.get("collective")]
        for c in linkable:
            op = c.attrs.get("collective")
            if mode == "hierarchical" and op == "all-reduce":
                # BlueConnect decomposition is an all-reduce rewrite; a bare
                # reduce-scatter / all-gather is already single-stage and
                # keeps its ring legs
                self._hierarchical_decompose(c, replicas)
            elif mode in ("ring", "hierarchical") and op in _RING_ROUNDS:
                self._ring_decompose(c, replicas)
            else:
                self._fused_sync(c, replicas)

    def _ring_decompose(self, c: Task, replicas: List[Dict[int, Task]]) -> None:
        """Per-worker ring legs with cross-worker pipeline edges.

        Leg round k of worker i waits on round k-1 of worker i-1 (the chunk it
        is about to forward) and on its own round k-1 (channel serialization).
        Per-worker totals telescope to ``group_time`` for uniform workers.
        """
        n = len(replicas)
        rounds = _RING_ROUNDS[c.attrs["collective"]] * (n - 1)
        payload = max(c.comm_bytes, 0.0)
        legs: List[List[Task]] = []
        for i, remap in enumerate(replicas):
            rc = remap[c.uid]
            parents, children = self._detach(rc)
            leg_dur = self._leg_duration(i, payload)
            worker_legs: List[Task] = []
            prev: Optional[Task] = None
            for k in range(rounds):
                leg = rc.clone()
                leg.name = f"{c.name}:leg{k}"
                leg.duration = leg_dur
                leg.comm_bytes = payload / n
                leg.attrs = dict(c.attrs, ring_round=k)
                self._prov.append(("ring", leg, i, payload))
                self.graph.add_task(leg, link_lane=False)
                for p in (parents if prev is None else [prev]):
                    self.graph.add_edge(p, leg)
                prev = leg
                worker_legs.append(leg)
            for ch in children:
                self.graph.add_edge(prev, ch)
            legs.append(worker_legs)
        for i in range(n):
            for k in range(1, rounds):
                self.graph.add_edge(legs[(i - 1) % n][k - 1], legs[i][k])

    def _hierarchical_decompose(self, c: Task,
                                replicas: List[Dict[int, Task]]) -> None:
        """BlueConnect-style: pod-local reduce-scatter, cross-pod all-reduce
        among pod leaders over DCN, pod-local all-gather.

        The cross-pod stage is itself a collective among leaders, so it is
        gated on *every* pod's reduce-scatter finishing; the all-gather stage
        is gated on every leader's cross-pod leg.  Total per-worker time for
        uniform pods equals ``CollectiveModel.hierarchical_all_reduce``.
        """
        coll = CollectiveModel(self.cost.hw, self.cost.topo)
        payload = max(c.comm_bytes, 0.0)
        pods: Dict[int, List[int]] = collections.defaultdict(list)
        for i, w in enumerate(self.workers):
            pods[w.pod].append(i)
        pod_ids = sorted(pods)
        num_pods = len(pod_ids)

        bounds = [self._detach(remap[c.uid]) for remap in replicas]

        leaders_bar = self._barrier(f"{c.name}:leaders-barrier")
        rs_of_pod: Dict[int, List[Task]] = {}
        for p in pod_ids:
            members = pods[p]
            m = len(members)
            scale = min(self.workers[i].bandwidth_scale for i in members)
            rs_dur = coll.axis_time("reduce-scatter", payload, m, "ici")
            rs_dur /= max(scale, 1e-12)
            bar = self._barrier(f"{c.name}:pod{p}:rs-barrier")
            rs_tasks = []
            for i in members:
                parents, _ = bounds[i]
                for par in parents:
                    self.graph.add_edge(par, bar)
                rs = self._add_comm(i, c, f"pod{p}:reduce-scatter", rs_dur,
                                    payload)
                self.graph.add_edge(bar, rs)
                rs_tasks.append(rs)
            rs_of_pod[p] = rs_tasks
            for rs in rs_tasks:
                self.graph.add_edge(rs, leaders_bar)

        if num_pods > 1:
            gather_bar = self._barrier(f"{c.name}:gather-barrier")
            for p in pod_ids:
                members = pods[p]
                leader = members[0]
                shard = payload / max(len(members), 1)
                cross_dur = coll.axis_time("all-reduce", shard, num_pods,
                                           "dcn")
                cross_dur /= max(self.workers[leader].bandwidth_scale, 1e-12)
                cross = self._add_comm(leader, c, f"pod{p}:cross-all-reduce",
                                       cross_dur, shard)
                self.graph.add_edge(leaders_bar, cross)
                self.graph.add_edge(cross, gather_bar)
            gate = gather_bar
        else:
            gate = leaders_bar
        for p in pod_ids:
            self._pod_all_gather(c, coll, payload, p, pods[p], gate, bounds)

    def _pod_all_gather(self, c: Task, coll: CollectiveModel, payload: float,
                        p: int, members: List[int], gate: Task,
                        bounds) -> None:
        m = len(members)
        scale = min(self.workers[i].bandwidth_scale for i in members)
        ag_dur = coll.axis_time("all-gather", payload, m, "ici")
        ag_dur /= max(scale, 1e-12)
        for i in members:
            ag = self._add_comm(i, c, f"pod{p}:all-gather", ag_dur, payload)
            self.graph.add_edge(gate, ag)
            _, children = bounds[i]
            for ch in children:
                self.graph.add_edge(ag, ch)

    def _add_comm(self, i: int, c: Task, label: str, dur: float,
                  nbytes: float) -> Task:
        t = Task(name=f"{c.name}:{label}", kind=TaskKind.COLLECTIVE,
                 thread=worker_thread(i, split_worker_thread(c.thread)[1]),
                 duration=dur, comm_bytes=nbytes, phase="comm",
                 attrs=dict(c.attrs, stage=label))
        return self.graph.add_task(t, link_lane=False)

    def _fused_sync(self, c: Task, replicas: List[Dict[int, Task]]) -> None:
        """Keep one analytical-duration task per worker, gated by a barrier so
        no worker's collective starts before every worker is ready."""
        bar = self._barrier(f"{c.name}:barrier")
        for remap in replicas:
            rc = remap[c.uid]
            for p in self.graph.parents(rc):
                self.graph.add_edge(p, bar)
            self.graph.add_edge(bar, rc)

    def _link_push_pull(self, base: DependencyGraph,
                        replicas: List[Dict[int, Task]]) -> None:
        """Parameter-server semantics for P3-style push/pull pairs.

        A pull returns the *aggregated* value, so every worker's pull of a
        slice waits (via one barrier per push task) for every worker's push of
        that slice.  Pushes themselves stay local — that preserves P3's
        overlap of early pushes with the tail of backprop.
        """
        for u in base.tasks():
            if u.kind != TaskKind.COLLECTIVE or u.attrs.get("collective"):
                continue
            pulls = [v for v in base.children(u)
                     if v.kind == TaskKind.COLLECTIVE
                     and not v.attrs.get("collective")]
            if not pulls:
                continue
            bar = self._barrier(f"{u.name}:aggregate")
            for remap in replicas:
                self.graph.add_edge(remap[u.uid], bar)
                for v in pulls:
                    self.graph.add_edge(bar, remap[v.uid])

    # --------------------------------------------------------------- retune
    @property
    def retunable(self) -> bool:
        """Whether :meth:`retune` can re-parameterize this build in place.

        Ring and fused collective wiring is duration-only under a worker
        spec change; the hierarchical (BlueConnect) decomposition's stage
        *structure* depends on the pod layout, so it needs a rebuild.
        """
        return self.collective_mode != "hierarchical"

    def retune(self, workers: Union[int, Sequence[WorkerSpec]]
               ) -> "ClusterGraph":
        """Re-parameterize this build for new same-length worker specs.

        Recomputes every scaled duration (compute/gap by ``compute_scale``,
        replica collectives by ``bandwidth_scale``, ring legs from the link
        bandwidths) from the recorded base values — the same expressions
        :meth:`build` used, so the result is bit-identical to a fresh build
        with ``workers``.  This is what lets :meth:`Scenario.sweep
        <repro.core.optimize.Scenario.sweep>` evaluate bandwidth/straggler
        grids without re-replicating and re-wiring the global graph per
        point.
        """
        specs = _as_specs(workers)
        if len(specs) != len(self.workers):
            raise GraphError(
                f"retune needs the same worker count (have "
                f"{len(self.workers)}, got {len(specs)}); rebuild instead")
        if not self.retunable:
            raise GraphError(
                "hierarchical cluster graphs cannot be retuned (stage "
                "structure depends on the pod layout); rebuild instead")
        self.workers = specs
        leg_dur: Dict[Tuple[int, float], float] = {}   # (worker, payload)
        for rec in self._prov:
            kind, t = rec[0], rec[1]
            if kind == "compute":
                _, _, i, dur, gap = rec
                t.duration = dur * specs[i].compute_scale
                t.gap = gap * specs[i].compute_scale
            elif kind == "coll":
                _, _, i, dur = rec
                t.duration = dur / max(specs[i].bandwidth_scale, 1e-12)
            else:                   # ring leg
                _, _, i, payload = rec
                key = (i, payload)
                d = leg_dur.get(key)
                if d is None:
                    d = leg_dur[key] = self._leg_duration(i, payload)
                t.duration = d
        return self

    # -------------------------------------------------------------- simulate
    def simulate(self, schedule: Optional[ScheduleFn] = None) -> ClusterResult:
        res = simulate(self.graph, schedule or self.schedule)
        # snapshot durations/gaps: a later retune() (sweeps) must not bleed
        # into this result's lazily-computed per-worker breakdown
        snap = {t.uid: (t.duration, t.gap) for t in self.graph.tasks()}
        return ClusterResult(makespan=res.makespan, global_result=res,
                             workers=list(self.workers),
                             _split_fn=lambda: self._split_result(res, snap))

    def _worker_partition(self) -> Dict[int, List[Task]]:
        """Tasks grouped by worker, cached — the grouping only depends on
        the graph's structure, which retune keeps fixed across sweeps."""
        if self._tasks_by_worker is None:
            by_worker: Dict[int, List[Task]] = collections.defaultdict(list)
            for t in self.graph.tasks():
                w, _ = split_worker_thread(t.thread)
                if w is not None:
                    by_worker[w].append(t)
            self._tasks_by_worker = dict(by_worker)
        return self._tasks_by_worker

    def _split_result(self, res: SimResult,
                      snap: Dict[int, Tuple[float, float]]
                      ) -> Dict[int, SimResult]:
        """Project the global result onto each worker's local resources."""
        tasks_by_worker = self._worker_partition()
        out: Dict[int, SimResult] = {}
        for i in range(len(self.workers)):
            ts = tasks_by_worker.get(i, [])
            start = {t.uid: res.start[t.uid] for t in ts}
            finish = {t.uid: res.finish[t.uid] for t in ts}
            busy: Dict[str, float] = collections.defaultdict(float)
            intervals: Dict[str, List[Tuple[float, float]]] = \
                collections.defaultdict(list)
            makespan = 0.0
            for t in ts:
                duration, gap = snap[t.uid]
                local = split_worker_thread(t.thread)[1]
                busy[local] += duration
                if duration > 0:
                    intervals[local].append((start[t.uid], finish[t.uid]))
                makespan = max(makespan, finish[t.uid] + gap)
            breakdown = _host_device_breakdown(
                intervals, makespan, lambda th: th == HOST_THREAD)
            out[i] = SimResult(makespan=makespan, start=start, finish=finish,
                               thread_busy=dict(busy), breakdown=breakdown)
        return out
