"""Symmetry folding: O(classes) cluster simulation instead of O(workers).

A 4k-worker data-parallel job replicates the *same* per-worker subgraph
4k times and wires 4k-member collectives — yet with uniform workers every
replica has a provably identical timeline, so simulating all of them is
pure redundancy.  This module partitions workers into **equivalence
classes** (:func:`partition_workers`), materializes one representative
subgraph per class, and closes the collective structures *algebraically*
over the class sizes: a uniform ring keeps one representative leg chain
whose 2(n-1) legs carry the full-group leg duration, hierarchical
(BlueConnect) stages keep one representative per (pod, leader/member)
role, fused collectives and push/pull pairs keep one representative per
spec class.  The folded graph simulates bit-identically to the fully
materialized one (the property tests in ``tests/test_fold.py`` hold the
two equal) at a cost proportional to classes, not workers — this is what
makes predict/sweep/hillclimb interactive at 10k-worker scale (dPRO-style
replica-level simulation; see the equivalence-class contract in
:mod:`repro.core.cluster`'s module docstring).

Foldability is checked, never assumed: :func:`fold_cluster` /
:func:`fold_plan` return ``None`` whenever per-class timeline identity
cannot be guaranteed (heterogeneous ring groups, multi-pod rings,
non-uniform pipeline stages...), and the caller falls back to full
materialization.  A straggler what-if *does* fold: the N-1 identical
workers form one class and the straggler its own, exact under ``"fused"``
collectives and under hierarchical pod-uniform layouts.

Retunes that keep the partition (same members per class) stay folded and
feed :meth:`FoldedClusterGraph.simulate_incremental` — cone replay over
the already-folded graph, the two optimizations compose.
"""

from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, List, Optional, Sequence, Tuple, Union)

from repro.obs.spans import span as _obs_span

from .cluster import (ClusterGraph, ClusterResult, WorkerSpec, _RING_ROUNDS,
                      _as_specs, match_push_pull_groups)
from .costmodel import CostModel
from .graph import DependencyGraph, GraphError
from .simulate import (ScheduleFn, SimResult, simulate, simulate_incremental)
from .task import Task, TaskKind


@dataclasses.dataclass(frozen=True)
class WorkerClass:
    """One equivalence class of workers: identical spec, identical wiring
    role, provably identical timeline.  ``members`` are original worker
    indices (ascending); ``members[0]`` is the materialized
    representative."""

    members: Tuple[int, ...]
    spec: WorkerSpec
    role: str = "worker"        # "worker" | "leader" | "member" | "stage"

    @property
    def representative(self) -> int:
        return self.members[0]

    @property
    def count(self) -> int:
        return len(self.members)


def partition_workers(specs: Sequence[WorkerSpec], mode: str
                      ) -> Optional[List[WorkerClass]]:
    """Partition ``specs`` into fold classes valid for ``mode``.

    Returns ``None`` when no exact fold exists for the mode (see the
    contract in :mod:`repro.core.cluster`):

    * ``"ring"``: one class iff every spec (including pod) is identical —
      heterogeneous or multi-pod rings have position-dependent legs.
    * ``"hierarchical"``: per-(pod, leader/member) classes iff each pod is
      internally uniform (the pod-uniform case; pods may differ).
    * ``"fused"``: one class per distinct spec, always foldable.
    """
    specs = list(specs)
    n = len(specs)
    if mode == "ring":
        first = specs[0]
        if any(s != first for s in specs[1:]):
            return None
        return [WorkerClass(members=tuple(range(n)), spec=first)]
    if mode == "fused":
        groups: Dict[WorkerSpec, List[int]] = {}
        for i, s in enumerate(specs):
            groups.setdefault(s, []).append(i)
        return [WorkerClass(members=tuple(ms), spec=specs[ms[0]])
                for ms in sorted(groups.values())]
    if mode == "hierarchical":
        pods: Dict[int, List[int]] = {}
        for i, s in enumerate(specs):
            pods.setdefault(s.pod, []).append(i)
        classes: List[WorkerClass] = []
        for p in sorted(pods):
            ms = pods[p]
            first = specs[ms[0]]
            if any(specs[i] != first for i in ms[1:]):
                return None     # pod not internally uniform
            classes.append(WorkerClass(members=(ms[0],), spec=first,
                                       role="leader"))
            if len(ms) > 1:
                classes.append(WorkerClass(members=tuple(ms[1:]), spec=first,
                                           role="member"))
        return classes
    raise GraphError(f"unknown collective_mode {mode!r}")


@dataclasses.dataclass
class FoldedClusterResult(ClusterResult):
    """A :class:`~repro.core.cluster.ClusterResult` whose per-worker view
    expands lazily from the per-class one: class members share (by
    reference) their representative's :class:`SimResult`, so reading
    ``per_worker`` on a 4k-worker fold costs O(classes) simulation work
    plus an O(workers) dict, not O(workers) timeline projections."""

    classes: List[WorkerClass] = dataclasses.field(default_factory=list)
    _class_fn: Optional[Callable[[], Dict[int, SimResult]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _per_class: Optional[Dict[int, SimResult]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def per_class(self) -> Dict[int, SimResult]:
        """class index -> the representative's local :class:`SimResult`."""
        if self._per_class is None:
            self._per_class = self._class_fn() if self._class_fn else {}
        return self._per_class

    @property
    def per_worker(self) -> Dict[int, SimResult]:
        if self._per_worker is None:
            pc = self.per_class
            self._per_worker = {m: pc[ci]
                                for ci, c in enumerate(self.classes)
                                for m in c.members}
        return self._per_worker


class FoldedClusterGraph:
    """Duck-types :class:`~repro.core.cluster.ClusterGraph` over a folded
    build: the inner graph has one worker slot per :class:`WorkerClass`
    (worker thread ``w<class>/...``), while :attr:`workers` stays the full
    original spec list.  ``simulate``/``retune``/``can_retune``/
    ``simulate_incremental`` match the materialized API so
    :class:`~repro.core.optimize.Scenario` and the analysis layer use
    either interchangeably."""

    def __init__(self, cg: ClusterGraph, classes: Sequence[WorkerClass],
                 specs: Sequence[WorkerSpec],
                 partition_fn: Callable[[Sequence[WorkerSpec]],
                                        Optional[List[WorkerClass]]]) -> None:
        self.cg = cg
        self.classes = list(classes)
        self.workers = list(specs)
        self._partition_fn = partition_fn
        self._class_of = {m: ci for ci, c in enumerate(self.classes)
                          for m in c.members}
        # fold-closed structures (ring legs / hierarchical stages) whose
        # durations are functions of the *original* specs; everything else
        # retunes through the inner graph's own provenance.
        self._fprov: List[Tuple] = []
        self.last_retune_dirty: set = set()

    # ------------------------------------------------------ delegated surface
    @property
    def graph(self) -> DependencyGraph:
        return self.cg.graph

    @property
    def schedule(self) -> Optional[ScheduleFn]:
        return self.cg.schedule

    @property
    def cost(self) -> CostModel:
        return self.cg.cost

    @property
    def collective_mode(self) -> str:
        return self.cg.collective_mode

    @property
    def num_classes(self) -> int:
        return len(self.classes)

    @property
    def retunable(self) -> bool:
        return True

    # -------------------------------------------------------------- folding
    def _orig_link_bandwidth(self, i: int, j: int,
                             specs: Optional[Sequence[WorkerSpec]] = None
                             ) -> float:
        """Link bandwidth between *original* workers i and j — the same
        expression as ``ClusterGraph._link_bandwidth`` evaluated against
        the unfolded spec list, so folded durations are bit-identical to
        materialized ones."""
        w = self.workers if specs is None else specs
        wi, wj = w[i], w[j]
        bw = self.cg.cost.link_bandwidth(
            "dcn" if wi.pod != wj.pod else "ici")
        return bw * max(min(wi.bandwidth_scale, wj.bandwidth_scale), 1e-12)

    def _fold_collective(self, op: str, members: List[Task],
                         group_size: int) -> None:
        """Close one matched collective over the class representatives —
        the fold counterpart of ``ClusterGraph.wire_collective_group``.
        ``members[ci]`` is class ci's cloned collective task;
        ``group_size`` is the original member count the algebra closes
        over."""
        cg = self.cg
        cg._gid += 1
        mode = cg.collective_mode
        if mode == "hierarchical" and op == "all-reduce":
            self._fold_hierarchical(members)
        elif mode in ("ring", "hierarchical") and op in _RING_ROUNDS:
            # valid only for a fully uniform single-pod group (the caller
            # guarantees it): every member's chain is identical, so each
            # class representative keeps its own full leg chain and the
            # cross-worker ring edges — which provably never bind for
            # uniform legs — are dropped
            for rc in members:
                self._fold_ring(op, rc, group_size, (0, 1))
        else:
            cg._fused_sync(members)

    def _fold_ring(self, op: str, rc: Task, n: int,
                   link: Tuple[int, int]) -> None:
        """One representative's ring-leg chain for a uniform n-member
        group; ``link`` is an adjacent pair of *original* worker ids whose
        (uniform) link sets every leg's duration."""
        cg = self.cg
        rounds = _RING_ROUNDS[op] * (n - 1)
        payload = max(rc.comm_bytes, 0.0)
        parents, children = cg._detach(rc)
        i0, i1 = link
        leg_dur = (payload / n) / self._orig_link_bandwidth(i0, i1) \
            + cg.cost.collectives.hop_latency
        prev: Optional[Task] = None
        for k in range(rounds):
            leg = rc.clone()
            leg.name = f"{rc.name}:leg{k}"
            leg.duration = leg_dur
            leg.comm_bytes = payload / n
            leg.attrs = dict(rc.attrs, ring_round=k, coll_gid=cg._gid)
            self._fprov.append(("ring", leg, n, payload, i0, i1))
            cg.graph.add_task(leg, link_lane=False)
            for p in (parents if prev is None else [prev]):
                cg.graph.add_edge(p, leg)
            prev = leg
        for ch in children:
            cg.graph.add_edge(prev, ch)

    def _fold_hierarchical(self, members: List[Task]) -> None:
        """BlueConnect closure over (pod, role) classes: same barrier
        skeleton as ``ClusterGraph._hierarchical_decompose`` but with one
        reduce-scatter/all-gather per class instead of per worker; stage
        durations are computed from the original pod memberships."""
        cg = self.cg
        coll = cg.cost.collectives
        payload = max(max(m.comm_bytes for m in members), 0.0)
        cname = members[0].name
        pods: Dict[int, List[int]] = {}
        for w, s in enumerate(self.workers):
            pods.setdefault(s.pod, []).append(w)
        pod_classes: Dict[int, List[int]] = {}
        for ci, c in enumerate(self.classes):
            pod_classes.setdefault(c.spec.pod, []).append(ci)
        pod_ids = sorted(pods)
        num_pods = len(pod_ids)

        bounds = {ci: cg._detach(m) for ci, m in enumerate(members)}

        leaders_bar = cg._barrier(f"{cname}:leaders-barrier")
        for p in pod_ids:
            pod_members = tuple(pods[p])
            m = len(pod_members)
            scale = min(self.workers[i].bandwidth_scale for i in pod_members)
            rs_dur = coll.axis_time("reduce-scatter", payload, m, "ici")
            rs_dur /= max(scale, 1e-12)
            bar = cg._barrier(f"{cname}:pod{p}:rs-barrier")
            rs_tasks = []
            for ci in pod_classes[p]:
                parents, _ = bounds[ci]
                for par in parents:
                    cg.graph.add_edge(par, bar)
                rs = cg._add_comm(ci, members[ci], f"pod{p}:reduce-scatter",
                                  rs_dur, payload)
                self._fprov.append(("hrs", rs, pod_members, payload))
                cg.graph.add_edge(bar, rs)
                rs_tasks.append(rs)
            for rs in rs_tasks:
                cg.graph.add_edge(rs, leaders_bar)

        if num_pods > 1:
            gather_bar = cg._barrier(f"{cname}:gather-barrier")
            for p in pod_ids:
                pod_members = pods[p]
                leader = pod_members[0]
                ci = self._class_of[leader]
                shard = payload / max(len(pod_members), 1)
                cross_dur = coll.axis_time("all-reduce", shard, num_pods,
                                           "dcn")
                cross_dur /= max(self.workers[leader].bandwidth_scale, 1e-12)
                cross = cg._add_comm(ci, members[ci],
                                     f"pod{p}:cross-all-reduce",
                                     cross_dur, shard)
                self._fprov.append(("hcross", cross, leader, shard,
                                    num_pods))
                cg.graph.add_edge(leaders_bar, cross)
                cg.graph.add_edge(cross, gather_bar)
            gate = gather_bar
        else:
            gate = leaders_bar
        for p in pod_ids:
            pod_members = tuple(pods[p])
            m = len(pod_members)
            scale = min(self.workers[i].bandwidth_scale for i in pod_members)
            ag_dur = coll.axis_time("all-gather", payload, m, "ici")
            ag_dur /= max(scale, 1e-12)
            for ci in pod_classes[p]:
                ag = cg._add_comm(ci, members[ci], f"pod{p}:all-gather",
                                  ag_dur, payload)
                self._fprov.append(("hag", ag, pod_members, payload))
                cg.graph.add_edge(gate, ag)
                _, children = bounds[ci]
                for ch in children:
                    cg.graph.add_edge(ag, ch)

    # --------------------------------------------------------------- retune
    def can_retune(self, workers: Union[int, Sequence[WorkerSpec]]) -> bool:
        """True when ``workers`` keeps the fold partition: same worker
        count, same members per class (specs may change freely within
        that).  A partition-changing what-if (perturbing one member of a
        uniform ring) needs a rebuild — ``Scenario.sweep`` handles the
        fallback."""
        try:
            specs = _as_specs(workers)
        except GraphError:
            return False
        if len(specs) != len(self.workers):
            return False
        new = self._partition_fn(specs)
        if new is None or len(new) != len(self.classes):
            return False
        return all(a.members == b.members and a.role == b.role
                   for a, b in zip(new, self.classes))

    def retune(self, workers: Union[int, Sequence[WorkerSpec]]
               ) -> "FoldedClusterGraph":
        """Re-parameterize the folded build in place (same contract as
        :meth:`ClusterGraph.retune`, plus the partition-stability
        requirement of :meth:`can_retune`)."""
        specs = _as_specs(workers)
        if not self.can_retune(specs):
            raise GraphError(
                "retune would change the fold partition (different worker "
                "count or class membership); rebuild — Scenario.sweep does "
                "this automatically")
        self.workers = list(specs)
        self.classes = self._partition_fn(specs)
        with _obs_span("cluster.fold_retune", workers=len(specs),
                       classes=len(self.classes)) as sp:
            self.cg.retune([c.spec for c in self.classes])
            dirty = set(self.cg.last_retune_dirty)
            dirty |= self._retune_fold_records(specs)
            self.last_retune_dirty = dirty
            sp.note(dirty=len(dirty))
        return self

    def _retune_fold_records(self, specs: Sequence[WorkerSpec]) -> set:
        coll = self.cg.cost.collectives
        hop = coll.hop_latency
        link_bw: Dict[Tuple[int, int], float] = {}
        pod_scale: Dict[Tuple[int, ...], float] = {}
        dirty: set = set()

        def bw(i: int, j: int) -> float:
            b = link_bw.get((i, j))
            if b is None:
                b = link_bw[(i, j)] = self._orig_link_bandwidth(i, j, specs)
            return b

        for rec in self._fprov:
            kind, t = rec[0], rec[1]
            if kind == "ring":
                _, _, n, payload, i0, i1 = rec
                d = (payload / n) / bw(i0, i1) + hop
            elif kind in ("hrs", "hag"):
                _, _, pod_members, payload = rec
                op = "reduce-scatter" if kind == "hrs" else "all-gather"
                scale = pod_scale.get(pod_members)
                if scale is None:
                    scale = pod_scale[pod_members] = \
                        min(specs[i].bandwidth_scale for i in pod_members)
                d = coll.axis_time(op, payload, len(pod_members),
                                   "ici") / max(scale, 1e-12)
            else:               # hcross
                _, _, leader, shard, num_pods = rec
                d = coll.axis_time("all-reduce", shard, num_pods,
                                   "dcn") \
                    / max(specs[leader].bandwidth_scale, 1e-12)
            if d != t.duration:
                t.duration = d
                dirty.add(t.uid)
        return dirty

    # ------------------------------------------------------------- simulate
    def _wrap(self, res: SimResult) -> FoldedClusterResult:
        cg = self.cg
        snap = {t.uid: (t.duration, t.gap) for t in cg.graph.tasks()}
        return FoldedClusterResult(
            makespan=res.makespan, global_result=res,
            workers=list(self.workers), classes=list(self.classes),
            _class_fn=lambda: cg._split_result(res, snap))

    def simulate(self, schedule: Optional[ScheduleFn] = None, *,
                 record_binding: bool = False) -> FoldedClusterResult:
        res = simulate(self.cg.graph, schedule or self.cg.schedule,
                       record_binding=record_binding)
        return self._wrap(res)

    def simulate_incremental(self, prev: ClusterResult,
                             dirty: Optional[set] = None,
                             schedule: Optional[ScheduleFn] = None
                             ) -> Optional[FoldedClusterResult]:
        """Cone replay over the folded graph (see
        :meth:`ClusterGraph.simulate_incremental`); the two optimizations
        compose — a sweep point replays a small cone of an
        O(classes)-sized graph."""
        if dirty is None:
            dirty = self.last_retune_dirty
        res = simulate_incremental(self.cg.graph, prev.global_result, dirty,
                                   schedule or self.cg.schedule)
        if res is None:
            return None
        return self._wrap(res)


def fold_cluster(base: DependencyGraph,
                 workers: Union[int, Sequence[WorkerSpec]],
                 *, cost: Optional[CostModel] = None,
                 collective_mode: str = "ring",
                 schedule: Optional[ScheduleFn] = None
                 ) -> Optional[FoldedClusterGraph]:
    """Folded counterpart of :meth:`ClusterGraph.build`.

    Returns ``None`` when the (specs, mode, base) combination admits no
    exact fold — same-signature fallback to ``ClusterGraph.build`` is the
    caller's job (``Scenario`` does it automatically).  Raises exactly
    where ``build`` would raise (invalid mode / pod layout), so swapping
    the two never changes error behavior.
    """
    specs = _as_specs(workers)
    ClusterGraph._check_mode(collective_mode, specs)
    cost = cost or CostModel()
    n = len(specs)
    classes = partition_workers(specs, collective_mode)
    if classes is None or len(classes) >= n:
        return None
    if collective_mode == "hierarchical" and len({s.pod for s in specs}) > 1:
        # a bare reduce-scatter / all-gather keeps ring legs even in
        # hierarchical mode, and a multi-pod ring cannot fold
        for c in base.tasks():
            op = c.attrs.get("collective")
            if c.kind == TaskKind.COLLECTIVE and op \
                    and op != "all-reduce" and op in _RING_ROUNDS:
                return None
    with _obs_span("cluster.fold", workers=n, classes=len(classes),
                   base_tasks=len(base), mode=collective_mode):
        g = DependencyGraph()
        cg = ClusterGraph(g, [c.spec for c in classes], cost, schedule,
                          collective_mode)
        fg = FoldedClusterGraph(
            cg, classes, specs,
            partition_fn=lambda s: partition_workers(s, collective_mode))
        replicas = [cg._clone_worker(ci, c.spec, base)
                    for ci, c in enumerate(classes)]
        for c in base.tasks():
            if c.kind == TaskKind.COLLECTIVE and c.attrs.get("collective"):
                fg._fold_collective(c.attrs["collective"],
                                    [remap[c.uid] for remap in replicas], n)
        # push/pull pairs: one aggregation barrier over the class
        # representatives (the barrier max over identical members is the
        # max over representatives)
        cg._sync_push_pull(
            [[(remap[push.uid], [remap[v.uid] for v in pulls])
              for remap in replicas]
             for ((push, pulls),) in match_push_pull_groups([base])])
        cg._finish()
        fg._fprov = [r for r in fg._fprov if r[1] in g]
        return fg


def fold_plan(plan, workers: Optional[Union[int, Sequence[WorkerSpec]]]
              = None, *, cost: Optional[CostModel] = None,
              collective_mode: str = "ring",
              sched_fn: Optional[ScheduleFn] = None,
              templates: Optional[Sequence[DependencyGraph]] = None
              ) -> Optional[FoldedClusterGraph]:
    """Folded counterpart of :meth:`ParallelPlan.place` for hybrid PP x DP.

    Folds each stage's ``dp`` data-parallel replicas into one class (one
    worker slot per *stage*) when every stage is internally spec-uniform:
    stage-boundary p2p hops wire representative-to-representative (replica
    r's hop is identical to replica 0's), and each stage's gradient ring
    closes as a representative leg chain over the original ``dp``.
    Returns ``None`` — fall back to ``place()`` — for ``dp < 2``,
    hierarchical mode (a folded stage cannot host a per-pod
    decomposition), non-uniform stages, or malformed templates (``place``
    then raises the proper error).
    """
    S, M, dp = plan.num_stages, plan.microbatches, plan.dp
    if dp < 2 or collective_mode == "hierarchical":
        return None
    specs = [WorkerSpec() for _ in range(plan.num_workers)] \
        if workers is None else _as_specs(workers)
    if len(specs) != plan.num_workers:
        return None

    def part(s: Sequence[WorkerSpec]) -> Optional[List[WorkerClass]]:
        s = list(s)
        if len(s) != S * dp:
            return None
        out = []
        for st in range(S):
            grp = s[st * dp:(st + 1) * dp]
            if any(x != grp[0] for x in grp[1:]):
                return None
            out.append(WorkerClass(members=tuple(range(st * dp,
                                                       (st + 1) * dp)),
                                   spec=grp[0], role="stage"))
        return out

    classes = part(specs)
    if classes is None:
        return None
    cost = cost or CostModel()
    tmpls = list(templates) if templates is not None \
        else plan.stage_templates(cost)
    if len(tmpls) != S:
        return None
    with _obs_span("cluster.fold_plan", workers=len(specs), classes=S,
                   stages=S, dp=dp):
        cg = ClusterGraph(DependencyGraph(), [c.spec for c in classes],
                          cost, sched_fn, collective_mode)
        fg = FoldedClusterGraph(cg, classes, specs, partition_fn=part)
        remaps = [cg._clone_worker(s, classes[s].spec, tmpls[s],
                                   comm_prov=False) for s in range(S)]
        # index each template's schedule tasks by role/microbatch — the
        # same discipline as ParallelPlan.place
        fwds: List[Dict[int, Task]] = []
        bwds: List[Dict[int, Task]] = []
        acts: List[Dict[int, Task]] = []
        grads: List[Dict[int, Task]] = []
        ars: List[Optional[Task]] = []
        for g in tmpls:
            f: Dict[int, Task] = {}
            b: Dict[int, Task] = {}
            a: Dict[int, Task] = {}
            gr: Dict[int, Task] = {}
            ar: Optional[Task] = None
            for t in g.tasks():
                m = t.attrs.get("microbatch")
                if t.kind == TaskKind.COMM and t.attrs.get("p2p_role"):
                    (a if t.attrs["p2p_role"] == "act" else gr)[m] = t
                elif t.kind == TaskKind.COLLECTIVE \
                        and t.attrs.get("collective") \
                        and "stage" in t.attrs:
                    ar = t
                elif t.phase == "fwd" and m is not None:
                    f[m] = t
                elif t.phase == "bwd" and m is not None:
                    b[m] = t
            fwds.append(f)
            bwds.append(b)
            acts.append(a)
            grads.append(gr)
            ars.append(ar)
        for s in range(S):
            if any(m not in fwds[s] or m not in bwds[s] for m in range(M)) \
                    or (s < S - 1 and len(acts[s]) != M) \
                    or (s > 0 and len(grads[s]) != M) or ars[s] is None:
                return None     # malformed template: place() raises properly
        for s in range(S - 1):
            for m in range(M):
                cg.wire_p2p(None, remaps[s + 1][fwds[s + 1][m].uid],
                            s, s + 1, leg=remaps[s][acts[s][m].uid])
        for s in range(1, S):
            for m in range(M):
                cg.wire_p2p(None, remaps[s - 1][bwds[s - 1][m].uid],
                            s, s - 1, leg=remaps[s][grads[s][m].uid])
        for s in range(S):
            op = ars[s].attrs["collective"]
            rc = remaps[s][ars[s].uid]
            cg._gid += 1
            if collective_mode == "ring" and op in _RING_ROUNDS:
                fg._fold_ring(op, rc, dp, (s * dp, s * dp + 1))
            else:               # "fused" (or a non-ring op): barrier + rep
                cg._fused_sync([rc])
        cg._finish()
        fg._fprov = [r for r in fg._fprov if r[1] in cg.graph]
        return fg
