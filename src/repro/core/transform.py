"""Graph-transformation primitives (paper §4.4).

The paper's what-if interface is a small set of primitives over the dependency
graph — ``Select``, ``Scale``/``Shrink``, ``Insert``, ``Remove``, and overriding
the simulator's ``Schedule`` policy.  :class:`GraphTransform` packages them as a
fluent API used by every optimization model in :mod:`repro.core.whatif`.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Iterable, List, Optional, Sequence

from .graph import DependencyGraph
from .simulate import ScheduleFn, make_priority_schedule, simulate, SimResult
from .task import Task, TaskKind, DEVICE_STREAM, HOST_THREAD

Predicate = Callable[[Task], bool]


# ---------------------------------------------------------------- selectors
def by_kind(*kinds: TaskKind) -> Predicate:
    ks = set(kinds)
    return lambda t: t.kind in ks


def by_name(pattern: str) -> Predicate:
    """Select by keyword/regex in task names (paper: 'sgemm' / 'elementwise')."""
    rx = re.compile(pattern)
    return lambda t: bool(rx.search(t.name))


def by_layer(pattern: str) -> Predicate:
    """Select by the task->layer mapping (paper: select-by-layer)."""
    rx = re.compile(pattern)
    return lambda t: t.layer is not None and bool(rx.search(t.layer))


def by_phase(*phases: str) -> Predicate:
    ps = set(phases)
    return lambda t: t.phase in ps


def on_device(t: Task) -> bool:
    return t.thread == DEVICE_STREAM


def all_of(*preds: Predicate) -> Predicate:
    return lambda t: all(p(t) for p in preds)


def any_of(*preds: Predicate) -> Predicate:
    return lambda t: any(p(t) for p in preds)


class GraphTransform:
    """Mutable what-if session over a *copy* of a baseline graph.

    Usage (paper Algorithm 3, AMP):

        tf = GraphTransform(baseline)
        tf.scale(all_of(on_device, by_name("dot|conv")), 1/3)
        tf.scale(all_of(on_device, by_name("fusion|elementwise")), 1/2)
        result = tf.simulate()
    """

    def __init__(self, graph: DependencyGraph, *, copy: bool = True) -> None:
        self.graph = graph.copy() if copy else graph
        self.schedule: Optional[ScheduleFn] = None

    # ------------------------------------------------------------ primitives
    def select(self, pred: Predicate) -> List[Task]:
        return self.graph.select(pred)

    def scale(self, pred: Predicate, factor: float) -> int:
        """Multiply matching task durations by ``factor`` (shrink if < 1)."""
        n = 0
        for t in self.select(pred):
            t.duration *= factor
            n += 1
        return n

    def shrink(self, pred: Predicate, factor: float) -> int:
        """Paper's shrink: divide durations by ``factor`` (e.g. 2x faster)."""
        return self.scale(pred, 1.0 / factor)

    def set_duration(self, pred: Predicate, seconds: float) -> int:
        n = 0
        for t in self.select(pred):
            t.duration = seconds
            n += 1
        return n

    def insert_after(self, anchor: Task, task: Task,
                     extra_parents: Sequence[Task] = (),
                     extra_children: Sequence[Task] = ()) -> Task:
        """Insert ``task`` into its thread lane right after ``anchor`` if they
        share a thread, otherwise append to the task's lane and add the
        dependency edge anchor->task (paper Fig. 4 'insert a GPU task': the
        companion host launch task is the caller's responsibility — helpers in
        whatif.py add it when modeling launch-bound inserts)."""
        if anchor.thread == task.thread:
            self.graph.add_task(task, after=anchor)
        else:
            self.graph.add_task(task)
            self.graph.add_edge(anchor, task)
        for p in extra_parents:
            self.graph.add_edge(p, task)
        for c in extra_children:
            self.graph.add_edge(task, c)
        return task

    def insert_before(self, anchor: Task, task: Task,
                      extra_parents: Sequence[Task] = (),
                      extra_children: Sequence[Task] = ()) -> Task:
        """Splice ``task`` into the lane right before ``anchor`` (same thread)."""
        if anchor.thread != task.thread:
            raise ValueError("insert_before requires same-thread anchor")
        lane = self.graph.lanes[anchor.thread]
        idx = lane.index(anchor.uid)
        if idx == 0:
            # becomes new lane head: add without lane link, wire to anchor
            self.graph.add_task(task, link_lane=False)
            lane.remove(task.uid)
            lane.insert(0, task.uid)
            self.graph.add_edge(task, anchor)
        else:
            prev = self.graph.get(lane[idx - 1])
            self.graph.add_task(task, after=prev)
        for p in extra_parents:
            self.graph.add_edge(p, task)
        for c in extra_children:
            self.graph.add_edge(task, c)
        return task

    def append(self, task: Task, parents: Sequence[Task] = (),
               children: Sequence[Task] = ()) -> Task:
        self.graph.add_task(task)
        for p in parents:
            self.graph.add_edge(p, task)
        for c in children:
            self.graph.add_edge(task, c)
        return task

    def remove(self, pred_or_task) -> int:
        """Remove matching tasks, bridging parents to children (paper Fig. 4)."""
        if isinstance(pred_or_task, Task):
            self.graph.remove_task(pred_or_task)
            return 1
        n = 0
        for t in self.select(pred_or_task):
            self.graph.remove_task(t)
            n += 1
        return n

    def override_schedule(self, schedule: ScheduleFn) -> None:
        self.schedule = schedule

    def prioritize(self, priority: Callable[[Task], float]) -> None:
        """Convenience: schedule override by a priority function (P3-style)."""
        self.schedule = make_priority_schedule(priority)

    # ------------------------------------------------------------- execution
    def simulate(self) -> SimResult:
        return simulate(self.graph, self.schedule)

    def cluster(self, workers, **kwargs):
        """Replicate the transformed graph across ``workers`` and return the
        :class:`repro.core.cluster.ClusterGraph` (schedule carried over)."""
        from .cluster import ClusterGraph
        kwargs.setdefault("schedule", self.schedule)
        return ClusterGraph.build(self.graph, workers, **kwargs)


def predicted_speedup(baseline: DependencyGraph,
                      build: Callable[[GraphTransform], None],
                      schedule: Optional[ScheduleFn] = None) -> float:
    """Simulate baseline vs a transformed copy; return predicted speedup."""
    base = simulate(baseline)
    tf = GraphTransform(baseline)
    build(tf)
    if schedule is not None:
        tf.override_schedule(schedule)
    opt = tf.simulate()
    return base.makespan / opt.makespan
