"""HLO-text parsing: compiled XLA modules -> Daydream tasks.

This is the TPU-side replacement for CUPTI (DESIGN.md §2).  The compiled HLO of
a jitted step function is the ground-truth "kernel schedule": every instruction
in the entry computation (with ``is_scheduled=true``, text order *is* the
device execution order) becomes one task.  ``while`` bodies (``lax.scan`` over
layers / microbatches) are expanded by their ``known_trip_count`` so FLOP and
byte accounting is exact — XLA's own ``cost_analysis()`` visits loop bodies
once and undercounts them (verified; see tests/test_hlo.py).

Two consumers:
  * :func:`extract_graph`  — full dependency graph for Daydream simulation.
  * :func:`aggregate_costs` — fast trip-count-aware aggregation for roofline.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .costmodel import CostModel
from .graph import DependencyGraph
from .task import Task, TaskKind, DEVICE_STREAM, HOST_THREAD, ici_channel

# ----------------------------------------------------------------- shapes
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> float:
    total = 0.0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


# ------------------------------------------------------------- instructions
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
    "add-dependency",
}
# memory-movement opcodes (bytes-bound, zero useful flops)
_MEMORY_OPS = {
    "copy", "copy-start", "copy-done", "transpose", "reshape", "broadcast",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "gather", "scatter", "reverse", "convert", "iota", "copy-to-host",
    "copy-from-host",
}

_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z][\w\[\]\{\},\s]*?))\s+"
    r"([\w\-]+)\(")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_IOTA_RG_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RG_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


@dataclasses.dataclass
class HloInstr:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    raw: str
    op_name: str = ""
    is_root: bool = False

    @property
    def out_bytes(self) -> float:
        return _shape_bytes(self.type_str)

    @property
    def out_elems(self) -> float:
        return _shape_elems(self.type_str)

    def called(self) -> List[str]:
        return _CALLS_RE.findall(self.raw)

    def cond(self) -> Optional[str]:
        m = _COND_RE.search(self.raw)
        return m.group(1) if m else None

    def branches(self) -> List[str]:
        m = _BRANCHES_RE.search(self.raw)
        if not m:
            return []
        return [b.strip().lstrip("%") for b in m.group(1).split(",")]

    def trip_count(self) -> Optional[int]:
        m = _TRIP_RE.search(self.raw)
        return int(m.group(1)) if m else None

    def replica_groups(self) -> Optional[np.ndarray]:
        """Return (num_groups, group_size) array of device ids, or None."""
        m = _IOTA_RG_RE.search(self.raw)
        if m:
            dims = [int(d) for d in m.group(1).split(",")]
            src = [int(d) for d in m.group(2).split(",")]
            n = int(np.prod(src))
            ids = np.arange(n).reshape(src)
            if m.group(3):
                perm = [int(d) for d in m.group(3).split(",")]
                ids = ids.transpose(perm)
            return ids.reshape(dims[0], -1)
        m = _EXPLICIT_RG_RE.search(self.raw)
        if m:
            groups = re.findall(r"\{([\d,\s]*)\}", "{" + m.group(1) + "}")
            parsed = [[int(x) for x in g.split(",") if x.strip()] for g in groups
                      if g.strip()]
            if parsed and all(len(p) == len(parsed[0]) for p in parsed):
                return np.asarray(parsed)
        return None


@dataclasses.dataclass
class HloComputation:
    name: str
    instrs: List[HloInstr]

    def by_name(self) -> Dict[str, HloInstr]:
        return {i.name: i for i in self.instrs}


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, HloComputation]
    entry: str
    num_partitions: int

    @property
    def entry_computation(self) -> HloComputation:
        return self.computations[self.entry]


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def parse_hlo_module(text: str) -> HloModule:
    computations: Dict[str, HloComputation] = {}
    entry: Optional[str] = None
    cur: Optional[HloComputation] = None
    num_partitions = 1
    m = re.search(r"num_partitions=(\d+)", text)
    if m:
        num_partitions = int(m.group(1))
    for line in text.splitlines():
        if cur is None:
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr:
                cur = HloComputation(hdr.group(2), [])
                if hdr.group(1):
                    entry = hdr.group(2)
            continue
        stripped = line.strip()
        if stripped == "}":
            computations[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        is_root, name, type_str, opcode = (
            bool(im.group(1)), im.group(2), im.group(3).strip(), im.group(4))
        # operands: %tokens inside the first balanced paren group after opcode
        rest = line[im.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[1:end] if end else ""
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        md = _METADATA_RE.search(line)
        cur.instrs.append(HloInstr(
            name=name, opcode=opcode, type_str=type_str, operands=operands,
            raw=line, op_name=md.group(1) if md else "", is_root=is_root))
    if entry is None:
        # fall back: last computation is the entry in XLA dumps
        entry = list(computations)[-1]
    return HloModule(computations, entry, num_partitions)


# ----------------------------------------------------------------- costing
def _dot_flops(instr: HloInstr, operand_types: Dict[str, str]) -> float:
    """2 * prod(output dims) * prod(contracting dims of lhs)."""
    out = instr.out_elems
    lhs_type = operand_types.get(instr.operands[0], "") if instr.operands else ""
    lhs_dims = _first_dims(lhs_type)
    m = _CONTRACT_RE.search(instr.raw)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2.0 * out * contract


def _operand_bytes(instr: HloInstr, operand_types: Dict[str, str]) -> float:
    return sum(_shape_bytes(operand_types.get(o, "")) for o in instr.operands)


def _conv_flops(instr: HloInstr, operand_types: Dict[str, str]) -> float:
    # rough: 2 * out_elems * kernel_elems / out_channels
    out = instr.out_elems
    if len(instr.operands) >= 2:
        k = _shape_elems(operand_types.get(instr.operands[1], ""))
        kd = _first_dims(operand_types.get(instr.operands[1], ""))
        oc = kd[-1] if kd else 1
        return 2.0 * out * max(k / max(oc, 1), 1.0)
    return 2.0 * out


class _CostVisitor:
    """Shared per-instruction flops/bytes/collective classification."""

    def __init__(self, module: HloModule, cost: CostModel,
                 devices_per_pod: Optional[int] = None) -> None:
        self.module = module
        self.cost = cost
        self.devices_per_pod = devices_per_pod
        self._fusion_cache: Dict[str, float] = {}
        self._traffic_cache: Dict[str, float] = {}

    def fusion_traffic(self, comp_name: str, depth: int = 0) -> float:
        """HBM bytes a fusion actually moves.

        Fusion operands are *not* charged wholesale: a body ``dynamic-slice``
        of a parameter (the scan-over-layers stacked-weight pattern) touches
        only the slice; an in-place root ``dynamic-update-slice`` writes only
        the update.  Without this, every layer iteration would be charged the
        full stacked parameter buffer (observed 800x bytes overcount).
        """
        if comp_name in self._traffic_cache:
            return self._traffic_cache[comp_name]
        comp = self.module.computations.get(comp_name)
        if comp is None or depth > 24:
            return 0.0
        types = {i.name: i.type_str for i in comp.instrs}
        by_name = {i.name: i for i in comp.instrs}
        params = {i.name: _shape_bytes(i.type_str) for i in comp.instrs
                  if i.opcode == "parameter"}

        _PASSTHRU = {"convert", "bitcast", "copy", "reshape"}

        def resolve(name: str, lim: int = 8) -> str:
            """Follow convert/bitcast/copy chains back to the origin value."""
            while lim > 0:
                i = by_name.get(name)
                if i is None or i.opcode not in _PASSTHRU or not i.operands:
                    return name
                name = i.operands[0]
                lim -= 1
            return name

        # dynamic-update-slice carries (scan stacking) are in-place on TPU:
        # the carried buffer (even through convert/bitcast wrappers, which
        # XLA:CPU materializes but TPU fuses) is charged the UPDATE size,
        # not the full buffer.
        dus_carry: Dict[str, float] = {}
        dus_names = set()
        for i in comp.instrs:
            if i.opcode == "dynamic-update-slice":
                dus_names.add(i.name)
                ub = _shape_bytes(types.get(i.operands[1], ""))
                src = resolve(i.operands[0])
                if src in params:
                    dus_carry[src] = max(dus_carry.get(src, 0.0), ub)

        touched: Dict[str, float] = {p: 0.0 for p in params}
        extra = 0.0
        root_bytes = 0.0
        for i in comp.instrs:
            if i.opcode == "parameter":
                continue
            for o in i.operands:
                if o in params:
                    if o in dus_carry:
                        touched[o] = max(touched[o], dus_carry[o])
                    elif i.opcode in ("dynamic-slice", "gather", "slice"):
                        touched[o] = max(touched[o], i.out_bytes)
                    elif (i.opcode == "dynamic-update-slice"
                          and o == i.operands[0]):
                        ub = _shape_bytes(types.get(i.operands[1], ""))
                        touched[o] = max(touched[o], ub)
                    else:
                        touched[o] = params[o]
            if i.opcode == "fusion":
                for c in i.called():
                    extra += self.fusion_traffic(c, depth + 1)
            if i.is_root:
                if resolve(i.name) in dus_names \
                        or i.opcode == "dynamic-update-slice":
                    ref = by_name.get(resolve(i.name), i)
                    ops = ref.operands if ref.opcode == "dynamic-update-slice" \
                        else i.operands
                    root_bytes = _shape_bytes(types.get(ops[1], "")) \
                        if len(ops) > 1 else i.out_bytes
                else:
                    root_bytes = i.out_bytes
        total = sum(touched.values()) + extra + root_bytes
        self._traffic_cache[comp_name] = total
        return total

    def fusion_flops(self, comp_name: str) -> float:
        if comp_name in self._fusion_cache:
            return self._fusion_cache[comp_name]
        comp = self.module.computations.get(comp_name)
        total = 0.0
        if comp is not None:
            types = {i.name: i.type_str for i in comp.instrs}
            for i in comp.instrs:
                if i.opcode == "dot":
                    total += _dot_flops(i, types)
                elif i.opcode == "convolution":
                    total += _conv_flops(i, types)
                elif i.opcode in _SKIP_OPS or i.opcode in _MEMORY_OPS:
                    continue
                elif i.opcode == "fusion":
                    for c in i.called():
                        total += self.fusion_flops(c)
                else:
                    total += i.out_elems   # 1 flop/elem for elementwise/reduce
        self._fusion_cache[comp_name] = total
        return total

    def classify(self, instr: HloInstr,
                 operand_types: Dict[str, str]) -> Optional[Dict]:
        """Return task descriptor dict or None for zero-cost bookkeeping ops."""
        op = instr.opcode
        base = op[:-6] if op.endswith("-start") else op
        if base.endswith("-done"):
            return None
        if op in _SKIP_OPS:
            return None
        if base in COLLECTIVE_OPS:
            groups = instr.replica_groups()
            group_size = int(groups.shape[1]) if groups is not None else (
                self.module.num_partitions)
            crosses_pod = False
            if groups is not None and self.devices_per_pod:
                pods = groups // self.devices_per_pod
                crosses_pod = bool((pods != pods[:, :1]).any())
            if base == "all-gather":
                payload = instr.out_bytes       # full gathered output
            else:
                payload = _operand_bytes(instr, operand_types)
            dur = self.cost.collective_time(base, payload, group_size, crosses_pod)
            return dict(kind=TaskKind.COLLECTIVE, flops=0.0,
                        bytes=payload + instr.out_bytes, comm_bytes=payload,
                        duration=dur, group_size=group_size,
                        crosses_pod=crosses_pod, collective=base)
        inb = _operand_bytes(instr, operand_types)
        outb = instr.out_bytes
        if op == "dot":
            f = _dot_flops(instr, operand_types)
            return dict(kind=TaskKind.COMPUTE, flops=f, bytes=inb + outb,
                        duration=self.cost.compute_time(f, inb + outb))
        if op == "convolution":
            f = _conv_flops(instr, operand_types)
            return dict(kind=TaskKind.COMPUTE, flops=f, bytes=inb + outb,
                        duration=self.cost.compute_time(f, inb + outb))
        if op == "fusion":
            f = sum(self.fusion_flops(c) for c in instr.called())
            b = sum(self.fusion_traffic(c) for c in instr.called())
            kind = TaskKind.COMPUTE if f > b else TaskKind.MEMORY
            return dict(kind=kind, flops=f, bytes=b,
                        duration=self.cost.compute_time(f, b))
        if op == "custom-call":
            # opaque kernel (e.g. Pallas): bandwidth-bound estimate unless the
            # caller re-costs it via attrs
            return dict(kind=TaskKind.COMPUTE, flops=0.0, bytes=inb + outb,
                        duration=self.cost.compute_time(0.0, inb + outb))
        if op in ("dynamic-slice", "gather", "slice"):
            b = 2.0 * outb                      # touched slice read + write
            return dict(kind=TaskKind.MEMORY, flops=0.0, bytes=b,
                        duration=self.cost.compute_time(0.0, b))
        if op == "dynamic-update-slice":
            ub = (_shape_bytes(operand_types.get(instr.operands[1], ""))
                  if len(instr.operands) > 1 else outb)
            b = 2.0 * ub                        # in-place update region
            return dict(kind=TaskKind.MEMORY, flops=0.0, bytes=b,
                        duration=self.cost.compute_time(0.0, b))
        if op == "scatter":
            ub = (_shape_bytes(operand_types.get(instr.operands[2], ""))
                  if len(instr.operands) > 2 else outb)
            b = 3.0 * ub                        # read-modify-write of updates
            return dict(kind=TaskKind.MEMORY, flops=0.0, bytes=b,
                        duration=self.cost.compute_time(0.0, b))
        if op in _MEMORY_OPS:
            return dict(kind=TaskKind.MEMORY, flops=0.0, bytes=inb + outb,
                        duration=self.cost.compute_time(0.0, inb + outb))
        # generic elementwise / reduce / compare / select / rng ...
        f = instr.out_elems
        if op in ("reduce", "reduce-window"):
            f = max(f, _shape_elems(operand_types.get(instr.operands[0], ""))
                    if instr.operands else f)
        return dict(kind=TaskKind.COMPUTE, flops=f, bytes=inb + outb,
                    duration=self.cost.compute_time(f, inb + outb))


# ------------------------------------------------------------ aggregation
def aggregate_costs(module: HloModule, cost: Optional[CostModel] = None,
                    devices_per_pod: Optional[int] = None) -> Dict[str, float]:
    """Trip-count-aware totals (per device): flops, bytes, collective payloads.

    Returns the inputs of the §Roofline terms plus per-collective breakdowns.
    """
    cost = cost or CostModel()
    visitor = _CostVisitor(module, cost, devices_per_pod)
    totals = {
        "flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
        "collective_s": 0.0, "compute_ops": 0.0, "memory_ops": 0.0,
        "collective_ops": 0.0, "device_time_s": 0.0,
    }
    per_coll: Dict[str, float] = {}

    def walk(comp_name: str, mult: float, depth: int = 0) -> None:
        comp = module.computations.get(comp_name)
        if comp is None or depth > 24:
            return
        types = {i.name: i.type_str for i in comp.instrs}
        for instr in comp.instrs:
            if instr.opcode == "while":
                n = instr.trip_count() or 1
                for body in instr.called():
                    walk(body, mult * n, depth + 1)
                continue
            if instr.opcode in ("call", "async-start"):
                for c in instr.called():
                    walk(c, mult, depth + 1)
                continue
            if instr.opcode == "conditional":
                branches = instr.branches() or instr.called()
                if branches:           # cost of the heaviest branch
                    walk(branches[0], mult, depth + 1)
                continue
            desc = visitor.classify(instr, types)
            if desc is None:
                continue
            totals["flops"] += mult * desc["flops"]
            totals["bytes"] += mult * desc["bytes"]
            totals["device_time_s"] += mult * desc["duration"]
            if desc["kind"] == TaskKind.COLLECTIVE:
                totals["collective_bytes"] += mult * desc["comm_bytes"]
                totals["collective_s"] += mult * desc["duration"]
                totals["collective_ops"] += mult
                key = desc["collective"]
                per_coll[key] = per_coll.get(key, 0.0) + mult * desc["comm_bytes"]
            elif desc["kind"] == TaskKind.COMPUTE:
                totals["compute_ops"] += mult
            else:
                totals["memory_ops"] += mult

    walk(module.entry, 1.0)
    for k, v in per_coll.items():
        totals[f"bytes_{k}"] = v
    return totals


# ------------------------------------------------------- graph extraction
def extract_graph(module: HloModule, cost: Optional[CostModel] = None,
                  *, overlap_collectives: bool = False,
                  devices_per_pod: Optional[int] = None,
                  max_tasks: int = 60_000,
                  include_host: bool = True) -> DependencyGraph:
    """Expand the entry computation into a Daydream dependency graph.

    ``overlap_collectives=False`` (default) keeps collectives on the device
    stream — faithful to the synchronous compiled program.  ``True`` moves them
    to per-group ICI channel lanes with data edges, modeling an async-collective
    runtime (a what-if in itself).

    ``while`` bodies are expanded ``known_trip_count`` times until the task
    budget is reached; beyond it, one representative iteration is emitted with
    durations scaled by the remaining trip count (aggregate-exact).
    """
    cost = cost or CostModel()
    visitor = _CostVisitor(module, cost, devices_per_pod)
    g = DependencyGraph()

    if include_host:
        dispatch = Task(name="host:dispatch", kind=TaskKind.HOST,
                        thread=HOST_THREAD, duration=cost.host_dispatch_time())
        g.add_task(dispatch)
    else:
        dispatch = None

    budget = [max_tasks]

    def emit(comp_name: str, env: Dict[str, Task], mult: float,
             depth: int) -> Dict[str, Task]:
        comp = module.computations.get(comp_name)
        if comp is None or depth > 24:
            return env
        types = {i.name: i.type_str for i in comp.instrs}
        local: Dict[str, Task] = dict(env)

        def producer(opname: str) -> Optional[Task]:
            return local.get(opname)

        for instr in comp.instrs:
            if instr.opcode == "while":
                n = instr.trip_count() or 1
                bodies = instr.called()
                body = bodies[0] if bodies else None
                if body is None:
                    continue
                body_size = len(module.computations[body].instrs)
                full_iters = n
                scale_tail = 0
                if body_size * n > budget[0]:
                    full_iters = max(1, budget[0] // max(body_size, 1))
                    scale_tail = n - full_iters
                inner = dict(local)
                for it in range(full_iters):
                    m = mult * (1 + scale_tail) if it == full_iters - 1 else mult
                    inner = emit(body, inner, m, depth + 1)
                local.update(inner)
                # while result aliases the body root env; leave names resolved
                continue
            if instr.opcode in ("call", "async-start"):
                for c in instr.called():
                    local = emit(c, local, mult, depth + 1)
                continue
            if instr.opcode == "conditional":
                branches = instr.branches() or instr.called()
                if branches:
                    local = emit(branches[0], local, mult, depth + 1)
                continue
            desc = visitor.classify(instr, types)
            if desc is None:
                # bookkeeping op: alias to its first produced operand task
                for o in instr.operands:
                    if o in local:
                        local[instr.name] = local[o]
                        break
                continue
            if budget[0] <= 0 and desc["kind"] != TaskKind.COLLECTIVE:
                continue
            budget[0] -= 1
            thread = DEVICE_STREAM
            if desc["kind"] == TaskKind.COLLECTIVE and overlap_collectives:
                thread = ici_channel(
                    "dcn" if desc.get("crosses_pod") else "ici")
            layer, phase = split_op_name(instr.op_name)
            t = Task(
                name=f"{instr.opcode}:{instr.name}",
                kind=desc["kind"], thread=thread,
                duration=desc["duration"] * mult,
                flops=desc["flops"] * mult,
                bytes_accessed=desc["bytes"] * mult,
                comm_bytes=desc.get("comm_bytes", 0.0) * mult,
                layer=layer, phase=phase,
                attrs={"opcode": instr.opcode,
                       "group_size": desc.get("group_size"),
                       "collective": desc.get("collective"),
                       "crosses_pod": desc.get("crosses_pod", False)},
            )
            g.add_task(t)
            for o in instr.operands:
                p = producer(o)
                if p is not None and p.uid != t.uid:
                    g.add_edge(p, t)
            if dispatch is not None and not g.parents(t) and thread != HOST_THREAD:
                g.add_edge(dispatch, t)
            local[instr.name] = t
        return local

    env = emit(module.entry, {}, 1.0, 0)

    if include_host:
        done = Task(name="host:sync", kind=TaskKind.SYNC, thread=HOST_THREAD,
                    duration=1e-6)
        g.add_task(done)
        # device completion -> host sync (dependency type 4)
        lane = g.lane_tasks(DEVICE_STREAM)
        if lane:
            g.add_edge(lane[-1], done)
    return g


# --------------------------------------------------------------- layer map
_PHASE_PATTERNS = (
    (re.compile(r"transpose\(jvp"), "bwd"),
    (re.compile(r"jvp\("), "fwd"),
    (re.compile(r"(^|/)update(/|$)"), "update"),
    (re.compile(r"(^|/)bwd(/|$)"), "bwd"),
    (re.compile(r"(^|/)fwd(/|$)"), "fwd"),
)
_NOISE = re.compile(
    r"(jit\([\w\.]*\)/|while/body/|while/cond/|closed_call/|checkpoint/|"
    r"remat\d*/|transpose\(jvp\(|jvp\(|\)+)")


def split_op_name(op_name: str) -> Tuple[Optional[str], Optional[str]]:
    """metadata op_name -> (layer, phase): the synchronization-free mapping."""
    if not op_name:
        return None, None
    phase = None
    for rx, ph in _PHASE_PATTERNS:
        if rx.search(op_name):
            phase = ph
            break
    cleaned = _NOISE.sub("", op_name)
    parts = [p for p in cleaned.split("/") if p]
    layer = "/".join(parts[:-1]) if len(parts) > 1 else None
    return layer or None, phase
