"""Analytical per-task cost model for the target hardware (TPU v5e-class).

Daydream needs a duration for every task.  On GPU the paper reads durations from
CUPTI; with no TPU in the loop we derive durations from first principles, the
same way the paper derives *new* task durations (communication formulas, §4.2.1
"Duration"; NCCL ring formulas, §6.5):

  - compute/memory ops:  max(FLOPs / peak_FLOPs, bytes / HBM_bw) + issue overhead
  - collectives:         ring / bidirectional-ring formulas over the mesh axes
  - host dispatch:       fixed per-program enqueue cost
  - data loading:        bytes / host IO bandwidth

A *calibrated* mode replaces the hardware constants with CPU-measured ones
(:mod:`repro.core.calibrate`) so that simulated makespans can be validated
against wall-clock ground truth in this container.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .task import HardwareSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Physical interpretation of mesh axes for the collective model.

    ``axis_kind`` maps each mesh axis to the interconnect it travels over:
    ``ici`` (intra-pod torus links) or ``dcn`` (cross-pod data-centre network).
    """

    axis_sizes: Dict[str, int]
    axis_kind: Dict[str, str]

    @staticmethod
    def single_pod(data: int = 16, model: int = 16) -> "MeshTopology":
        return MeshTopology({"data": data, "model": model},
                            {"data": "ici", "model": "ici"})

    @staticmethod
    def multi_pod(pods: int = 2, data: int = 16, model: int = 16) -> "MeshTopology":
        return MeshTopology({"pod": pods, "data": data, "model": model},
                            {"pod": "dcn", "data": "ici", "model": "ici"})

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.axis_sizes.values():
            n *= s
        return n


class CollectiveModel:
    """Time model for mesh collectives (paper §6.5 / NCCL-tests formulas [56]).

    Ring algorithms on a bidirectional torus axis of size ``n``:

      all-reduce      : 2 * (n-1)/n * bytes / bw     (reduce-scatter + all-gather)
      reduce-scatter  :     (n-1)/n * bytes / bw
      all-gather      :     (n-1)/n * bytes / bw     (bytes = full output size)
      all-to-all      :     (n-1)/n * bytes / bw     (each device keeps 1/n)
      permute         :           bytes / bw

    ``bytes`` is the per-device payload.  A per-hop latency term models the
    (n-1) link traversals.  BlueConnect-style axis decomposition falls out of
    running the formula per mesh axis (DESIGN.md §2).
    """

    # Default seconds per ring step (link + switch latency).  Kept as a class
    # constant for the analytical TPU model; pass ``hop_latency`` (or set
    # ``CostModel.hop_latency``) to use a *measured* value — calibration
    # (:func:`repro.core.calibrate.calibrated_cost_model`) derives it from
    # tiny-payload collectives the same way compute durations are calibrated
    # from measured FLOP rates.
    HOP_LATENCY = 1e-6

    def __init__(self, hw: HardwareSpec = TPU_V5E,
                 topo: Optional[MeshTopology] = None,
                 hop_latency: Optional[float] = None,
                 ici_factor: float = 1.0,
                 dcn_factor: float = 1.0) -> None:
        self.hw = hw
        self.topo = topo or MeshTopology.single_pod()
        self.hop_latency = (self.HOP_LATENCY if hop_latency is None
                            else hop_latency)
        self.ici_factor = ici_factor
        self.dcn_factor = dcn_factor

    def _axis_bw(self, kind: str) -> float:
        if kind == "dcn":
            return self.hw.dcn_bandwidth * self.dcn_factor
        return self.hw.ici_bandwidth * self.hw.ici_links_per_axis \
            * self.ici_factor

    def axis_time(self, op: str, payload_bytes: float, axis_size: int,
                  kind: str = "ici") -> float:
        if axis_size <= 1 or payload_bytes <= 0:
            return 0.0
        bw = self._axis_bw(kind)
        frac = (axis_size - 1) / axis_size
        steps = axis_size - 1
        if op == "all-reduce":
            return 2 * frac * payload_bytes / bw + 2 * steps * self.hop_latency
        if op in ("reduce-scatter", "all-gather", "all-to-all"):
            return frac * payload_bytes / bw + steps * self.hop_latency
        if op == "collective-permute":
            return payload_bytes / bw + self.hop_latency
        raise ValueError(f"unknown collective {op!r}")

    def p2p_time(self, payload_bytes: float, bandwidth: float) -> float:
        """One point-to-point hop over a link of ``bandwidth`` bytes/s.

        The primitive under both ring legs and pipeline-parallel
        activation/gradient hops: payload transfer plus the per-hop
        link/switch latency.  Zero payload is a pure synchronization edge
        and costs nothing (matching :meth:`axis_time`'s empty-collective
        contract).
        """
        if payload_bytes <= 0:
            return 0.0
        return payload_bytes / bandwidth + self.hop_latency

    def group_time(self, op: str, payload_bytes: float, group_size: int,
                   crosses_pod: bool = False) -> float:
        """Time for one collective over an opaque replica group.

        Used when the HLO replica groups don't align with a single mesh axis:
        treat the group as one ring over the slowest link it crosses.
        """
        kind = "dcn" if crosses_pod else "ici"
        return self.axis_time(op, payload_bytes, group_size, kind)

    def hierarchical_all_reduce(self, payload_bytes: float,
                                axes: Sequence[str]) -> float:
        """BlueConnect / TPU-hierarchical decomposition over multiple axes:
        reduce-scatter along each axis in turn, then all-gather in reverse.
        Payload shrinks by the axis size after each reduce-scatter."""
        t = 0.0
        p = payload_bytes
        for ax in axes:
            n = self.topo.axis_sizes[ax]
            t += self.axis_time("reduce-scatter", p, n, self.topo.axis_kind[ax])
            p /= max(n, 1)
        for ax in reversed(list(axes)):
            n = self.topo.axis_sizes[ax]
            p *= max(n, 1)
            t += self.axis_time("all-gather", p, n, self.topo.axis_kind[ax])
        return t


@dataclasses.dataclass(frozen=True)
class FittableConstant:
    """One CostModel constant the trace-fit loop may adjust.

    ``name`` is the key :meth:`CostModel.with_constants` accepts
    (``"kind_scale:<task-kind>"``, ``"ici_factor"``, ``"dcn_factor"``,
    ``"hop_latency"``); ``lo``/``hi`` bound the search, ``log`` says the
    constant lives on a multiplicative scale (search in log-space), and
    ``kind`` names the task kind a per-kind scale applies to (None for
    link-level constants).
    """

    name: str
    value: float
    lo: float
    hi: float
    log: bool = True
    kind: Optional[str] = None


# Task kinds whose traced/cloned durations a per-kind scale multiplies
# (collective/comm durations are bandwidth-derived instead — fit those
# through ici_factor/dcn_factor/hop_latency).
SCALED_KINDS: Tuple[str, ...] = ("compute", "memory", "host", "data",
                                 "offload")


@dataclasses.dataclass
class CostModel:
    """Duration assignment for HLO-derived tasks."""

    hw: HardwareSpec = dataclasses.field(default_factory=lambda: TPU_V5E)
    topo: MeshTopology = dataclasses.field(
        default_factory=MeshTopology.single_pod)
    # Calibration multipliers (1.0 = analytical model; calibrate.py overrides).
    compute_scale: float = 1.0
    memory_scale: float = 1.0
    collective_scale: float = 1.0
    # Per-ring-step latency override (None = CollectiveModel.HOP_LATENCY);
    # calibrate.py measures it from tiny-payload local collectives.
    hop_latency: Optional[float] = None
    # Trace-fit constants (repro.analysis.calibrate): per-task-kind duration
    # multipliers applied to traced/cloned durations on the cluster routes,
    # and link-bandwidth factors multiplying the ICI / DCN hardware
    # bandwidths everywhere they are read (ring legs, p2p hops, analytical
    # collective formulas).  All default to 1.0 == the uncalibrated model.
    kind_scales: Dict[str, float] = dataclasses.field(default_factory=dict)
    ici_factor: float = 1.0
    dcn_factor: float = 1.0

    def __post_init__(self) -> None:
        self.collectives = CollectiveModel(self.hw, self.topo,
                                           hop_latency=self.hop_latency,
                                           ici_factor=self.ici_factor,
                                           dcn_factor=self.dcn_factor)

    # ------------------------------------------------------- trace-fit API
    def kind_scale(self, kind) -> float:
        """Duration multiplier for one task kind (TaskKind or value string);
        1.0 unless calibration set one."""
        return self.kind_scales.get(getattr(kind, "value", kind), 1.0)

    def link_bandwidth(self, link: str) -> float:
        """Effective bandwidth of one ``"ici"`` / ``"dcn"`` link, the
        calibration factors applied — the single source the cluster ring /
        p2p wiring and the analytical collective formulas share."""
        return self.collectives._axis_bw(link)

    def fittable_constants(self, kinds: Optional[Sequence[str]] = None
                           ) -> List[FittableConstant]:
        """The typed list of constants the trace-fit loop may adjust.

        ``kinds`` restricts the per-kind scales (default:
        :data:`SCALED_KINDS`).  Bounds are generous-but-physical: duration
        and bandwidth multipliers within 20x either way, hop latency
        between 10ns and 1ms.
        """
        out = [FittableConstant(f"kind_scale:{k}", self.kind_scale(k),
                                0.05, 20.0, kind=k)
               for k in (SCALED_KINDS if kinds is None else kinds)]
        out.append(FittableConstant("ici_factor", self.ici_factor,
                                    0.05, 20.0))
        out.append(FittableConstant("dcn_factor", self.dcn_factor,
                                    0.05, 20.0))
        out.append(FittableConstant(
            "hop_latency",
            self.collectives.hop_latency, 1e-8, 1e-3))
        return out

    def with_constants(self, mapping: Dict[str, float]) -> "CostModel":
        """A copy of this model with fittable constants overridden;
        ``mapping`` keys are :class:`FittableConstant` names."""
        ks = dict(self.kind_scales)
        kwargs: Dict[str, float] = {}
        for name, val in mapping.items():
            if name.startswith("kind_scale:"):
                ks[name.split(":", 1)[1]] = float(val)
            elif name in ("ici_factor", "dcn_factor", "hop_latency"):
                kwargs[name] = float(val)
            else:
                raise ValueError(f"unknown fittable constant {name!r}")
        return dataclasses.replace(self, kind_scales=ks, **kwargs)

    # ------------------------------------------------------------- durations
    def compute_time(self, flops: float, bytes_accessed: float) -> float:
        t_flops = self.compute_scale * flops / self.hw.peak_flops
        t_bytes = self.memory_scale * bytes_accessed / self.hw.hbm_bandwidth
        return max(t_flops, t_bytes) + self.hw.op_overhead

    def collective_time(self, op: str, payload_bytes: float, group_size: int,
                        crosses_pod: bool = False) -> float:
        t = self.collectives.group_time(op, payload_bytes, group_size, crosses_pod)
        return self.collective_scale * t + self.hw.op_overhead

    def host_dispatch_time(self) -> float:
        return self.hw.host_dispatch

    def offload_time(self, bytes_moved: float) -> float:
        return bytes_moved / self.hw.pcie_bandwidth + self.hw.op_overhead

    # --------------------------------------------------------------- roofline
    def roofline_terms(self, flops_per_device: float, bytes_per_device: float,
                       collective_seconds: float) -> Dict[str, float]:
        """The three §Roofline terms, in seconds (per device ≡ per chip)."""
        compute = flops_per_device / self.hw.peak_flops
        memory = bytes_per_device / self.hw.hbm_bandwidth
        terms = {
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": collective_seconds,
        }
        dom = max(terms, key=terms.get)
        terms["bound"] = dom.replace("_s", "")   # type: ignore[assignment]
        return terms
