"""Task -> layer mapping utilities (paper §4.3).

The mapping itself is synchronization-free by construction on this stack:
``jax.named_scope`` survives lowering into per-instruction HLO metadata and
:func:`repro.core.hlo.split_op_name` turns it into (layer, phase) tags at parse
time.  This module provides the query side: grouping, per-layer rollups, and
the layer->bucket mapping used when injecting communication tasks (the paper's
gradient-bucketing instrumentation for PyTorch DDP, §4.2.1 "Communication").
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import DependencyGraph
from .task import Task, TaskKind


@dataclasses.dataclass
class LayerProfile:
    layer: str
    duration_s: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    tasks: int = 0
    phases: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float))


class LayerMap:
    """Per-layer rollup over a dependency graph."""

    def __init__(self, graph: DependencyGraph) -> None:
        self.graph = graph
        self.profiles: Dict[str, LayerProfile] = {}
        for t in graph.tasks():
            key = t.layer or "<unmapped>"
            p = self.profiles.setdefault(key, LayerProfile(key))
            p.duration_s += t.duration
            p.flops += t.flops
            p.bytes_accessed += t.bytes_accessed
            p.tasks += 1
            if t.phase:
                p.phases[t.phase] += t.duration

    def layers(self) -> List[str]:
        return sorted(k for k in self.profiles if k != "<unmapped>")

    def mapped_fraction(self) -> float:
        total = sum(p.duration_s for p in self.profiles.values())
        unmapped = self.profiles.get("<unmapped>", LayerProfile("")).duration_s
        return 1.0 - (unmapped / total) if total > 0 else 0.0

    def tasks_for(self, layer_pattern: str) -> List[Task]:
        import re
        rx = re.compile(layer_pattern)
        return [t for t in self.graph.tasks()
                if t.layer is not None and rx.search(t.layer)]

    def phase_tasks(self, phase: str) -> List[Task]:
        return [t for t in self.graph.tasks() if t.phase == phase]

    def top_layers(self, n: int = 10) -> List[LayerProfile]:
        return sorted(self.profiles.values(), key=lambda p: -p.duration_s)[:n]


def bucket_layers(layer_grad_bytes: Dict[str, float],
                  bucket_bytes: float = 25 * 1024 * 1024,
                  reverse_order: Optional[Sequence[str]] = None,
                  ) -> List[Tuple[List[str], float]]:
    """Group per-layer gradients into communication buckets.

    Mirrors PyTorch DDP's 25MB gradient bucketing that the paper instruments
    (§4.2.1): gradients become ready in reverse layer order during the backward
    pass; consecutive ready gradients are coalesced until ``bucket_bytes``.
    Returns [(layers, payload_bytes), ...] in ready order.
    """
    order = list(reverse_order) if reverse_order is not None else (
        list(reversed(list(layer_grad_bytes))))
    buckets: List[Tuple[List[str], float]] = []
    cur: List[str] = []
    cur_bytes = 0.0
    for layer in order:
        b = layer_grad_bytes[layer]
        cur.append(layer)
        cur_bytes += b
        if cur_bytes >= bucket_bytes:
            buckets.append((cur, cur_bytes))
            cur, cur_bytes = [], 0.0
    if cur:
        buckets.append((cur, cur_bytes))
    return buckets
