"""Trace acquisition: jitted JAX step functions -> Daydream dependency graphs.

Daydream Phase 1 (paper §4.1).  Two acquisition modes:

* :func:`trace_compiled` — AOT: lower+compile the step (optionally under a
  sharded mesh with ShapeDtypeStruct inputs — zero allocation), parse the HLO,
  assign analytical durations.  This is the mode every dry-run / roofline /
  what-if query uses, and needs no hardware at all.

* :func:`trace_measured` — runs the compiled step on the *local* backend and
  rescales the analytical graph so total device time matches measured
  wall-clock (host-calibrated).  Used by the validation benchmarks that compare
  predicted vs ground-truth speedups on CPU, mirroring the paper's
  predict -> implement -> compare methodology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax

from .costmodel import CostModel, MeshTopology
from .graph import DependencyGraph
from .hlo import aggregate_costs, extract_graph, parse_hlo_module, HloModule
from .simulate import simulate, SimResult
from .task import Task, TaskKind, DEVICE_STREAM


@dataclasses.dataclass
class TraceBundle:
    """Everything Daydream knows about one step function."""

    graph: DependencyGraph
    module: HloModule
    aggregates: Dict[str, float]
    cost: CostModel
    compiled: Any = None
    measured_step_s: Optional[float] = None

    def simulate(self, schedule=None) -> SimResult:
        return simulate(self.graph, schedule)

    def xla_cost_analysis(self) -> Dict[str, float]:
        if self.compiled is None:
            return {}
        from repro.compat import cost_analysis_dict
        return cost_analysis_dict(self.compiled)

    def memory_analysis(self):
        if self.compiled is None:
            return None
        try:
            return self.compiled.memory_analysis()
        except Exception:
            return None

    def export_chrome(self, path: str,
                      result: Optional[SimResult] = None) -> Dict[str, Any]:
        """Export the (simulated) step timeline as Chrome trace-event JSON.

        Opens in Perfetto / ``chrome://tracing``; re-importable via
        :mod:`repro.traceio` (the round-trip reproduces the simulated
        makespan).  ``result`` defaults to a fresh :meth:`simulate`.
        """
        from repro.traceio import export_graph_trace
        return export_graph_trace(self.graph, result or self.simulate(),
                                  path)


def lower_and_compile(fn: Callable, *args, mesh=None, in_shardings=None,
                      out_shardings=None, donate_argnums=(), static_argnums=(),
                      **kwargs):
    jitted = jax.jit(fn, in_shardings=in_shardings, out_shardings=out_shardings,
                     donate_argnums=donate_argnums, static_argnums=static_argnums)
    if mesh is not None:
        with mesh:
            lowered = jitted.lower(*args, **kwargs)
            return lowered, lowered.compile()
    lowered = jitted.lower(*args, **kwargs)
    return lowered, lowered.compile()


def trace_compiled(fn: Callable, *args, cost: Optional[CostModel] = None,
                   mesh=None, in_shardings=None, out_shardings=None,
                   donate_argnums=(), static_argnums=(),
                   overlap_collectives: bool = False,
                   devices_per_pod: Optional[int] = None,
                   max_tasks: int = 60_000, **kwargs) -> TraceBundle:
    """AOT trace: compile, parse HLO, build graph + aggregates."""
    cost = cost or CostModel()
    _, compiled = lower_and_compile(
        fn, *args, mesh=mesh, in_shardings=in_shardings,
        out_shardings=out_shardings, donate_argnums=donate_argnums,
        static_argnums=static_argnums, **kwargs)
    module = parse_hlo_module(compiled.as_text())
    graph = extract_graph(module, cost, overlap_collectives=overlap_collectives,
                          devices_per_pod=devices_per_pod, max_tasks=max_tasks)
    agg = aggregate_costs(module, cost, devices_per_pod)
    return TraceBundle(graph=graph, module=module, aggregates=agg, cost=cost,
                       compiled=compiled)


def measure_wallclock(fn: Callable, *args, iters: int = 10, warmup: int = 3,
                      **kwargs) -> float:
    """Median wall-clock of a jitted callable (blocks on outputs)."""
    jitted = jax.jit(fn) if not hasattr(fn, "lower") else fn
    times = []
    for i in range(warmup + iters):
        t0 = time.perf_counter()
        out = jitted(*args, **kwargs)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if i >= warmup:
            times.append(dt)
    times.sort()
    return times[len(times) // 2]


def trace_measured(fn: Callable, *args, cost: Optional[CostModel] = None,
                   iters: int = 10, max_tasks: int = 60_000,
                   **kwargs) -> TraceBundle:
    """Compiled trace rescaled so simulated device time == measured wall-clock.

    This mirrors the paper's use of *profiled* durations: the graph topology
    comes from the compiled program, per-task durations keep their analytical
    *relative* weights, and the absolute scale is pinned by measurement.  The
    simulated baseline therefore matches ground truth by construction and every
    what-if perturbs from a measured starting point (paper §4.1 Phase 1).
    """
    bundle = trace_compiled(fn, *args, cost=cost, max_tasks=max_tasks, **kwargs)
    wall = measure_wallclock(fn, *args, iters=iters, **kwargs)
    sim = bundle.simulate()
    device_time = sum(t.duration for t in bundle.graph.tasks()
                      if t.thread == DEVICE_STREAM)
    host_time = sim.makespan - device_time if sim.makespan > device_time else 0.0
    target_device = max(wall - host_time, 1e-9)
    scale = target_device / max(device_time, 1e-12)
    for t in bundle.graph.tasks():
        if t.thread == DEVICE_STREAM:
            t.duration *= scale
    # calibrate the cost model so *new* task durations (insertions in
    # what-ifs) land in the same wall-clock units as the rescaled trace
    base = bundle.cost
    bundle.cost = dataclasses.replace(
        base, compute_scale=base.compute_scale * scale,
        memory_scale=base.memory_scale * scale)
    bundle.measured_step_s = wall
    return bundle
