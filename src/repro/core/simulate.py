"""Daydream's runtime simulation — paper Algorithm 1, two engines.

:func:`simulate` is a heap-based *event-driven* engine: ready tasks live in a
priority queue keyed by effective start time, so each scheduling decision is
O(log V) instead of the naive frontier scan's O(F) (plus an O(F)
``list.remove``).  Total cost is O(E log V) on lane-ordered graphs, which is
what lets the cluster simulator (:mod:`repro.core.cluster`) run global graphs
with hundreds of thousands of tasks.  :func:`simulate_reference` keeps the
original O(V·F) frontier-scan loop verbatim as the equivalence oracle used by
the property tests and the benchmark harness.

Engine invariants (relied on by tests/test_engine_equivalence.py):

* Effective start times are monotone: a task's ``max(thread progress,
  dependency-ready time)`` only ever grows, so a heap entry's key is a valid
  *lower bound* and stale entries can be lazily re-keyed on pop.
* With the default policy, popping the minimum ``(eff, ready, uid)`` entry
  reproduces :func:`default_schedule`'s tie-breaking exactly — both engines
  produce bit-identical start times and makespans.
* A pluggable :data:`ScheduleFn` must be *eff-minimal*: it returns a task
  whose effective start is within ``SCHED_EPS`` of the frontier minimum.
  Both built-ins (:func:`default_schedule`, :func:`make_priority_schedule`)
  satisfy this; a policy that deliberately idles a resource should use
  :func:`simulate_reference`, which passes the entire frontier.

The ``schedule`` function that picks among ready tasks is pluggable exactly
as in the paper (§4.4 "Schedule"): the default picks the task with the
earliest effective start time; what-ifs like P3 override it with priority
policies.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DependencyGraph
from .task import Task, TaskKind, DEVICE_STREAM, HOST_THREAD

# schedule(frontier, progress, earliest_start) -> chosen task
ScheduleFn = Callable[[List[Task], Dict[str, float], Dict[int, float]], Task]

# Tie window inside which a custom schedule may reorder ready tasks; matches
# make_priority_schedule's candidate filter so both engines see the same set.
SCHED_EPS = 1e-12


def default_schedule(frontier: List[Task], progress: Dict[str, float],
                     earliest: Dict[int, float]) -> Task:
    """Paper default: pick the ready task with the earliest effective start.

    Effective start = max(thread progress, task's dependency-ready time).
    Ties break on dependency-ready time then uid for determinism.
    """
    def key(t: Task) -> Tuple[float, float, int]:
        eff = max(progress.get(t.thread, 0.0), earliest[t.uid])
        return (eff, earliest[t.uid], t.uid)
    return min(frontier, key=key)


def make_priority_schedule(priority: Callable[[Task], float]) -> ScheduleFn:
    """Priority override used by P3-style what-ifs (paper Algorithm 7).

    Among the tasks tied for earliest effective start, prefer the one with the
    highest ``priority(task)``.
    """
    def sched(frontier: List[Task], progress: Dict[str, float],
              earliest: Dict[int, float]) -> Task:
        def eff(t: Task) -> float:
            return max(progress.get(t.thread, 0.0), earliest[t.uid])
        best_eff = min(eff(t) for t in frontier)
        candidates = [t for t in frontier if eff(t) <= best_eff + SCHED_EPS]
        return max(candidates, key=lambda t: (priority(t), -t.uid))
    return sched


@dataclasses.dataclass
class SimResult:
    makespan: float
    start: Dict[int, float]                  # uid -> start time (paper output)
    finish: Dict[int, float]                 # uid -> start + duration (no gap)
    thread_busy: Dict[str, float]            # per-thread busy seconds
    _breakdown: Optional[Dict[str, float]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _breakdown_fn: Optional[Callable[[], Dict[str, float]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _binding: Optional[Dict[int, Optional[int]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _binding_fn: Optional[Callable[[], Dict[int, Optional[int]]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    # incremental-replay carry: per-thread busy intervals, per-thread final
    # completion (finish + gap of the lane's last task), and per-thread uid
    # execution order.  simulate_incremental() reads them off ``prev`` to
    # freeze clean lanes in O(threads) instead of re-deriving them in O(V),
    # and writes them on its merged result so sweep chains stay cheap.
    _intervals: Optional[Dict[str, List[Tuple[float, float]]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _lane_done: Optional[Dict[str, float]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _lanes: Optional[Dict[str, List[int]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _lanes_fn: Optional[Callable[[], Dict[str, List[int]]]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def breakdown(self) -> Dict[str, float]:
        """Paper Fig. 6 runtime breakdown: host-only / device-only /
        parallel / idle seconds.

        Materialized lazily on first access (the :attr:`binding` pattern):
        the interval unions behind it are O(V log V) and most sweep points
        never read them — deferring keeps both the engine and the
        incremental replay path free of the cost.
        """
        if self._breakdown is None and self._breakdown_fn is not None:
            self._breakdown = self._breakdown_fn()
            self._breakdown_fn = None    # drop: pins the interval lists
        return self._breakdown or {}

    @property
    def lane_order(self) -> Optional[Dict[str, List[int]]]:
        """Per-thread uids in execution order, or ``None`` when this
        result cannot provide them (hand-built instances).  Derived
        lazily from the engine's pop order and cached."""
        if self._lanes is None and self._lanes_fn is not None:
            self._lanes = self._lanes_fn()
            self._lanes_fn = None
        return self._lanes

    @property
    def binding(self) -> Optional[Dict[int, Optional[int]]]:
        """uid -> uid of the *binding predecessor* (the task whose
        completion set this task's effective start: the lane predecessor
        when the thread was the constraint, the last-finishing dependency
        otherwise; None for tasks that started at t=0).

        Available only from ``simulate(record_binding=True)`` —
        :mod:`repro.analysis` walks it to extract the makespan-defining
        critical path.  Materialized lazily on first access (the
        ``ClusterResult.per_worker`` pattern): the engine's hot loop only
        stores one conditional observation per released edge, and the
        O(V log V) map derivation runs here, outside the simulation —
        which is what keeps the instrumented run within the
        ``bench_sim.py`` 10% gate.
        """
        if self._binding is None and self._binding_fn is not None:
            self._binding = self._binding_fn()
            # drop the closure: it pins the engine's O(V) working dicts
            self._binding_fn = None
        return self._binding

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan / self.makespan if self.makespan > 0 else float("inf")


# Busy-interval math lives in repro.obs.timeline (one implementation for
# the engine breakdown, serving lane reports, and counter timelines); the
# historical names stay importable from here.
from repro.obs.timeline import interval_overlap as _overlap          # noqa: E402
from repro.obs.timeline import interval_union as _interval_union     # noqa: E402
from repro.obs.timeline import lane_utilization                      # noqa: E402,F401


def _host_device_breakdown(busy_intervals: Dict[str, List[Tuple[float, float]]],
                           makespan: float,
                           is_host: Callable[[str], bool]) -> Dict[str, float]:
    """Paper Fig. 6 runtime breakdown: host-only / device-only / parallel."""
    host_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if is_host(th) for iv in ivs])
    dev_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if not is_host(th) for iv in ivs])
    host_busy = sum(e - s for s, e in host_iv)
    dev_busy = sum(e - s for s, e in dev_iv)
    par = _overlap(host_iv, dev_iv)
    return {
        "host_only_s": host_busy - par,
        "device_only_s": dev_busy - par,
        "parallel_s": par,
        "idle_s": max(0.0, makespan - (host_busy + dev_busy - par)),
    }


def _assemble(graph: DependencyGraph, executed: int,
              progress: Dict[str, float], start: Dict[int, float],
              finish: Dict[int, float], busy: Dict[str, float],
              busy_intervals: Dict[str, List[Tuple[float, float]]],
              binding_fn: Optional[Callable[[], Dict[int, Optional[int]]]]
              = None) -> SimResult:
    if executed != len(graph):
        raise RuntimeError(
            f"simulation deadlock: executed {executed}/{len(graph)} tasks (cycle?)")
    makespan = max(progress.values(), default=0.0)
    ivs = dict(busy_intervals)
    lane_done = dict(progress)
    by_uid = graph._tasks

    def lanes_fn() -> Dict[str, List[int]]:
        # ``start`` insertion order is the engine's pop order, so one
        # grouping pass recovers each lane's execution order
        lanes: Dict[str, List[int]] = {th: [] for th in lane_done}
        for uid in start:
            lanes[by_uid[uid].thread].append(uid)
        return lanes

    return SimResult(makespan=makespan, start=start, finish=finish,
                     thread_busy=dict(busy),
                     _breakdown_fn=lambda: _host_device_breakdown(
                         ivs, makespan, lambda th: th == HOST_THREAD),
                     _binding_fn=binding_fn,
                     _intervals=ivs, _lane_done=lane_done,
                     _lanes_fn=lanes_fn)


def _derive_binding(by_uid: Dict[int, Task], start: Dict[int, float],
                    finish: Dict[int, float], earliest: Dict[int, float],
                    dep_binder: Dict[int, int]) -> Dict[int, Optional[int]]:
    """Binding predecessors, derived *after* the simulation loop.

    A task's effective start is ``max(thread progress, dependency-ready)``.
    When the thread was the constraint (``start > earliest``) the binder is
    the thread task that completed (``finish + gap``) exactly at our start;
    otherwise the dependency that last raised the ready time
    (``dep_binder``, the only thing the hot loop records), or None for a
    t=0 start.

    Per-thread execution order is recovered by sorting on ``(start, uid)``:
    thread progress is monotone, so start order matches execution order
    except among same-instant ties, where the backward scan for the exact
    completion time picks the true constraint (completion times here are
    bitwise reproductions of the progress values the engine compared
    against, so ``==`` is the right test).  The scan is bounded by the
    same-instant run plus one earlier-start task — tasks with a strictly
    earlier start all executed before us, so the first one reached is the
    latest of them.
    """
    lanes: Dict[str, List[Tuple[float, int]]] = collections.defaultdict(list)
    for uid, s in start.items():
        lanes[by_uid[uid].thread].append((s, uid))
    binding: Dict[int, Optional[int]] = {}
    get_dep = dep_binder.get
    for lane in lanes.values():
        lane.sort()
        for i, (s, u) in enumerate(lane):
            if s <= earliest[u]:
                binding[u] = get_dep(u)
                continue
            b = lane[i - 1][1] if i > 0 else None
            j = i - 1
            while j >= 0:
                sc, c = lane[j]
                if finish[c] + by_uid[c].gap == s:
                    b = c
                    break
                if sc < s:
                    break
                j -= 1
            binding[u] = b
    return binding


def simulate(graph: DependencyGraph, schedule: Optional[ScheduleFn] = None,
             *, record_binding: bool = False) -> SimResult:
    """Event-driven engine (default): paper Algorithm 1 semantics in O(E log V).

    Ready tasks sit in a min-heap keyed by ``(effective start, ready time,
    uid)``.  Keys are lower bounds (effective starts only grow), so a popped
    entry whose key is stale is re-pushed with its current effective start;
    a fresh minimum is executed directly.  When a custom ``schedule`` is
    supplied, every entry within ``SCHED_EPS`` of the minimum is popped and
    handed to the policy — the same candidate set the legacy loop's built-in
    policies select from — and the losers are re-pushed.

    ``record_binding=True`` additionally makes :attr:`SimResult.binding`
    available — each task's binding predecessor, what
    :mod:`repro.analysis` walks for critical paths.  The recording is
    designed to be free when off (the child-release loop is duplicated so
    the disabled path runs the byte-identical original body) and cheap
    when on: the hot loop stores exactly one observation per released edge
    that raises a ready time (``dep_binder``), and the full binding map is
    derived lazily on first ``.binding`` access (:func:`_derive_binding`).
    ``benchmarks/bench_sim.py`` gates the instrumented run within 10% of
    the plain run.
    """
    # direct adjacency access (uid sets) — the engine is the hottest loop in
    # the system and per-call Task-list materialization doubles its cost
    by_uid = graph._tasks
    children_of = graph._children
    parents_of = graph._parents
    ref: Dict[int, int] = {}
    earliest: Dict[int, float] = {}          # "u.start" accumulator of Algorithm 1
    heap: List[Tuple[float, float, int]] = []
    for uid in by_uid:
        n = len(parents_of[uid]) if uid in parents_of else 0
        ref[uid] = n
        earliest[uid] = 0.0
        if n == 0:
            heap.append((0.0, 0.0, uid))
    heapq.heapify(heap)

    progress: Dict[str, float] = collections.defaultdict(float)   # P
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = collections.defaultdict(float)
    busy_intervals: Dict[str, List[Tuple[float, float]]] = collections.defaultdict(list)
    executed = 0
    dep_binder: Dict[int, int] = {}

    heappush, heappop = heapq.heappush, heapq.heappop
    while heap:
        eff_key, _, uid = heappop(heap)
        u = by_uid[uid]
        e = earliest[uid]
        p = progress[u.thread]
        eff = p if p > e else e
        if eff > eff_key:                     # stale lower bound: re-key
            heappush(heap, (eff, e, uid))
            continue
        if schedule is not None:
            candidates = [u]
            spill: List[Tuple[float, float, int]] = []
            while heap and heap[0][0] <= eff_key + SCHED_EPS:
                _, _, uid2 = heapq.heappop(heap)
                t2 = by_uid[uid2]
                eff2 = max(progress[t2.thread], earliest[uid2])
                if eff2 <= eff_key + SCHED_EPS:
                    candidates.append(t2)
                else:
                    spill.append((eff2, earliest[uid2], uid2))
            if len(candidates) > 1:
                u = schedule(candidates, progress, earliest)
                for t2 in candidates:
                    if t2.uid != u.uid:
                        eff2 = max(progress[t2.thread], earliest[t2.uid])
                        spill.append((eff2, earliest[t2.uid], t2.uid))
            for item in spill:
                heapq.heappush(heap, item)

        th = u.thread
        uu = u.uid
        e = earliest[uu]
        p = progress[th]
        s = p if p > e else e
        start[uu] = s
        end = s + u.duration
        finish[uu] = end
        done = end + u.gap
        progress[th] = done
        busy[th] += u.duration
        if u.duration > 0:
            busy_intervals[th].append((s, end))
        executed += 1
        if uu in children_of:
            if not record_binding:
                for cuid in children_of[uu]:
                    r = ref[cuid] - 1
                    ref[cuid] = r
                    if earliest[cuid] < done:
                        earliest[cuid] = done
                    if r == 0:
                        ec = earliest[cuid]
                        pc = progress[by_uid[cuid].thread]
                        heappush(heap, (pc if pc > ec else ec, ec, cuid))
            else:
                for cuid in children_of[uu]:
                    r = ref[cuid] - 1
                    ref[cuid] = r
                    if earliest[cuid] < done:
                        earliest[cuid] = done
                        dep_binder[cuid] = uu
                    if r == 0:
                        ec = earliest[cuid]
                        pc = progress[by_uid[cuid].thread]
                        heappush(heap, (pc if pc > ec else ec, ec, cuid))

    binding_fn = (lambda: _derive_binding(by_uid, start, finish, earliest,
                                          dep_binder)) \
        if record_binding else None
    return _assemble(graph, executed, progress, start, finish, busy,
                     busy_intervals, binding_fn)


def simulate_incremental(graph: DependencyGraph, prev: SimResult,
                         dirty, schedule: Optional[ScheduleFn] = None,
                         *, max_cone_frac: float = 0.75
                         ) -> Optional[SimResult]:
    """Re-simulate only the downstream *cone* of ``dirty`` tasks.

    ``prev`` is the result of simulating ``graph`` before the durations/gaps
    of the ``dirty`` task uids were changed in place (a
    :meth:`~repro.core.cluster.ClusterGraph.retune` records exactly that
    set).  Everything outside the cone — the dependency-closure of ``dirty``
    unioned with each affected lane's execution-order suffix — kept its
    start/finish times, so only the cone is replayed through the heap
    engine, seeded with the frozen boundary: per-lane progress resumes from
    the last clean task and ready times come from clean parents' previous
    completion times.  On sweeps that touch a small fraction of the graph
    this is the difference between O(cone) and O(E log V) per point.

    Returns a :class:`SimResult` **bit-identical** to a full
    :func:`simulate` replay, or ``None`` when incremental replay cannot
    guarantee that and the caller must fall back to :func:`simulate`:

    * a custom ``schedule`` is supplied (its SCHED_EPS tie window may
      reorder tasks across the frozen boundary),
    * ``prev`` does not cover this graph's task set,
    * the cone exceeds ``max_cone_frac`` of the graph (replay would not
      pay for the merge),
    * a cone task's new ready time falls *before* its previous start AND
      at-or-before the last frozen task's start on its lane — the re-tune
      could legally reorder that lane, so the frozen prefix is no longer
      trustworthy.  (Either condition alone keeps the previous order
      under the default policy: a ready time ``>=`` the previous start
      means the heap key ``(eff, ready, uid)`` only ever grew, and a
      ready time strictly after every prefix start means the prefix pops
      first regardless — heap pop times are nondecreasing.)

    An empty ``dirty`` set returns ``prev`` unchanged.
    """
    if schedule is not None:
        return None
    by_uid = graph._tasks
    dirty = {u for u in dirty if u in by_uid}
    if not dirty:
        return prev
    start_prev, finish_prev = prev.start, prev.finish
    if len(start_prev) != len(by_uid) or \
            any(u not in start_prev for u in dirty):
        return None

    # per-lane execution order: results straight off the engine (and
    # merged incremental results, which maintain the carry) expose it as
    # ``prev.lane_order`` — position indices are then built only for the
    # lanes the cone actually reaches.  Hand-built results fall back to a
    # one-pass membership scan; a scanned lane whose recorded order is
    # non-monotone in start (cone entries of an in-place-merged dict keep
    # stale insertion positions) is re-sorted by (start, uid) — starts are
    # monotone per lane and same-instant ties are zero-duration runs where
    # any order is equivalent
    prev_lanes = prev.lane_order
    members: Optional[Dict[str, List[int]]] = None
    if prev_lanes is None:
        members = collections.defaultdict(list)
        for uid in start_prev:
            members[by_uid[uid].thread].append(uid)
    lanes: Dict[str, List[int]] = {}
    pos: Dict[int, int] = {}

    def lane_of(th: str) -> List[int]:
        lane = lanes.get(th)
        if lane is None:
            if prev_lanes is not None:
                lane = prev_lanes[th]
            else:
                lane = members[th]
                last = float("-inf")
                for u in lane:
                    s = start_prev[u]
                    if s < last:
                        lane = sorted(lane,
                                      key=lambda u: (start_prev[u], u))
                        break
                    last = s
            lanes[th] = lane
            for i, u in enumerate(lane):
                pos[u] = i
        return lane

    # cone closure: dependency children + lane successors
    children_of = graph._children
    parents_of = graph._parents
    cone = set()
    stack = list(dirty)
    while stack:
        u = stack.pop()
        if u in cone:
            continue
        cone.add(u)
        lane = lane_of(by_uid[u].thread)
        i = pos[u]
        if i + 1 < len(lane) and lane[i + 1] not in cone:
            stack.append(lane[i + 1])
        for c in children_of.get(u, ()):
            if c not in cone:
                stack.append(c)
    if len(cone) > max_cone_frac * len(by_uid):
        return None

    # frozen boundary per affected lane: progress resumes from the last
    # clean task (the cone's lane slice is an execution-order suffix)
    first_cone: Dict[str, int] = {}
    for u in cone:
        th = by_uid[u].thread
        i = pos[u]
        if i < first_cone.get(th, len(lanes[th])):
            first_cone[th] = i
    # lane completion is not monotone under the (start, uid) sort inside a
    # zero-duration same-instant tie run, so boundaries are maxes, not
    # last-element reads
    progress: Dict[str, float] = {}
    bound_start: Dict[str, float] = {}
    for th, i in first_cone.items():
        p = 0.0
        if i > 0:
            lane = lanes[th]
            bs = start_prev[lane[i - 1]]    # latest frozen-prefix start
            bound_start[th] = bs
            # completion (finish + gap) is nondecreasing along execution
            # order except inside a same-instant tie run, and every task
            # before the trailing tie run completed at or before ``bs``
            # (itself <= any tie-run completion) — so the boundary max
            # only needs the tie run, not the whole prefix
            j = i - 1
            while j >= 0 and start_prev[lane[j]] == bs:
                u = lane[j]
                d = finish_prev[u] + by_uid[u].gap
                if d > p:
                    p = d
                j -= 1
        progress[th] = p

    # seed ready times from clean parents' previous completions; replay
    # releases propagate the in-cone ones
    earliest: Dict[int, float] = {}
    ref: Dict[int, int] = {}
    heap: List[Tuple[float, float, int]] = []
    for u in cone:
        e = 0.0
        r = 0
        for pu in parents_of.get(u, ()):
            if pu in cone:
                r += 1
            else:
                d = finish_prev[pu] + by_uid[pu].gap
                if d > e:
                    e = d
            # a clean task's children are all clean by closure, so every
            # parent of a cone task is either in the cone or frozen
        earliest[u] = e
        ref[u] = r
        if r == 0:
            p = progress[by_uid[u].thread]
            heap.append((p if p > e else e, e, u))
    heapq.heapify(heap)

    start = dict(start_prev)
    finish = dict(finish_prev)
    exec_seq: Dict[str, List[int]] = {th: [] for th in first_cone}
    executed = 0
    heappush, heappop = heapq.heappush, heapq.heappop
    while heap:
        eff_key, _, uid = heappop(heap)
        u = by_uid[uid]
        th = u.thread
        e = earliest[uid]
        p = progress[th]
        eff = p if p > e else e
        if eff > eff_key:                     # stale lower bound: re-key
            heappush(heap, (eff, e, uid))
            continue
        if first_cone[th] > 0 and e < start_prev[uid] \
                and e <= bound_start[th]:
            # this task became ready before its old start AND at-or-before
            # the last frozen-prefix start on its lane: a full replay
            # could slot it ahead of the frozen prefix — bail out.  Either
            # disjunct alone is safe: e >= old start keeps the previous
            # heap order (the (eff, ready, uid) key only grew), and
            # e > every prefix start means the prefix pops first anyway
            # (pop times are nondecreasing)
            return None
        start[uid] = eff
        end = eff + u.duration
        finish[uid] = end
        done = end + u.gap
        progress[th] = done
        exec_seq[th].append(uid)
        executed += 1
        for cuid in children_of.get(uid, ()):
            r = ref[cuid] - 1
            ref[cuid] = r
            if earliest[cuid] < done:
                earliest[cuid] = done
            if r == 0:
                ec = earliest[cuid]
                pc = progress[by_uid[cuid].thread]
                heappush(heap, (pc if pc > ec else ec, ec, cuid))
    if executed != len(cone):
        raise RuntimeError(
            f"incremental simulation deadlock: executed {executed}/"
            f"{len(cone)} cone task(s) (cycle?)")

    # merge: clean lanes keep their previous totals verbatim; affected
    # lanes re-fold busy/intervals in execution order (frozen prefix, then
    # replay order) so the sums are bit-identical to a full replay.  With
    # the ``prev`` carry (intervals / lane finals / lane order) the clean
    # side is O(threads) dict copies sharing prev's per-lane lists;
    # without it, a one-pass fallback over the membership scan.
    fast = (prev_lanes is not None and prev._intervals is not None
            and prev._lane_done is not None)
    if fast:
        busy = dict(prev.thread_busy)
        busy_intervals = dict(prev._intervals)
        lane_done = dict(prev._lane_done)
        res_lanes: Optional[Dict[str, List[int]]] = dict(prev_lanes)
    else:
        busy = {}
        busy_intervals = {}
        lane_done = {}
        res_lanes = None
    for th in first_cone:
        order = lanes[th][:first_cone[th]] + exec_seq[th]
        acc = 0.0
        ivs: List[Tuple[float, float]] = []
        for u in order:
            d = by_uid[u].duration
            acc += d
            if d > 0:
                ivs.append((start[u], finish[u]))
        busy[th] = acc
        busy_intervals[th] = ivs
        lane_done[th] = progress[th]
        if res_lanes is not None:
            res_lanes[th] = order
    if not fast:
        if members is None:
            members = collections.defaultdict(list)
            for uid in start_prev:
                members[by_uid[uid].thread].append(uid)
        for th, mem in members.items():
            if th in first_cone:
                continue
            busy[th] = prev.thread_busy.get(th, 0.0)
            lane_done[th] = max(finish_prev[u] + by_uid[u].gap
                                for u in mem)
            # membership order is fine: _host_device_breakdown re-sorts
            busy_intervals[th] = [(start_prev[u], finish_prev[u])
                                  for u in mem if by_uid[u].duration > 0]
    makespan = max(lane_done.values(), default=0.0)
    return SimResult(makespan=makespan, start=start, finish=finish,
                     thread_busy=busy,
                     _breakdown_fn=lambda: _host_device_breakdown(
                         busy_intervals, makespan,
                         lambda th: th == HOST_THREAD),
                     _intervals=busy_intervals, _lane_done=lane_done,
                     _lanes=res_lanes)


def simulate_reference(graph: DependencyGraph,
                       schedule: Optional[ScheduleFn] = None,
                       *, record_binding: bool = False) -> SimResult:
    """Legacy frontier-scan loop (paper Algorithm 1 verbatim) — the oracle.

    Maintains the frontier ``F`` of dependency-ready tasks and per-thread
    progress ``P``; each iteration picks ``u = schedule(F)``, sets
    ``u.start = max(P[t], u.start)`` and advances
    ``P[t] = u.start + u.duration + u.gap``, then releases children whose
    remaining-parent refcount hits zero, propagating ready times.  O(V·F) —
    kept for arbitrary (non-eff-minimal) schedules and as the equivalence
    oracle for :func:`simulate`.
    """
    sched = schedule or default_schedule
    ref: Dict[int, int] = {}
    earliest: Dict[int, float] = {}
    frontier: List[Task] = []
    for t in graph.tasks():
        ref[t.uid] = len(graph.parents(t))
        earliest[t.uid] = 0.0
        if ref[t.uid] == 0:
            frontier.append(t)

    progress: Dict[str, float] = collections.defaultdict(float)
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = collections.defaultdict(float)
    busy_intervals: Dict[str, List[Tuple[float, float]]] = collections.defaultdict(list)
    executed = 0
    dep_binder: Dict[int, int] = {}

    while frontier:
        u = sched(frontier, progress, earliest)
        frontier.remove(u)
        t = u.thread
        s = max(progress[t], earliest[u.uid])
        start[u.uid] = s
        end = s + u.duration
        finish[u.uid] = end
        progress[t] = end + u.gap
        busy[t] += u.duration
        if u.duration > 0:
            busy_intervals[t].append((s, end))
        executed += 1
        done = end + u.gap
        for c in graph.children(u):
            ref[c.uid] -= 1
            if earliest[c.uid] < done:
                earliest[c.uid] = done
                if record_binding:
                    dep_binder[c.uid] = u.uid
            if ref[c.uid] == 0:
                frontier.append(c)

    binding_fn = (lambda: _derive_binding(
        {t.uid: t for t in graph.tasks()}, start, finish, earliest,
        dep_binder)) if record_binding else None
    return _assemble(graph, executed, progress, start, finish, busy,
                     busy_intervals, binding_fn)
