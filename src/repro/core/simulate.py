"""Daydream's runtime simulation — paper Algorithm 1, two engines.

:func:`simulate` is a heap-based *event-driven* engine: ready tasks live in a
priority queue keyed by effective start time, so each scheduling decision is
O(log V) instead of the naive frontier scan's O(F) (plus an O(F)
``list.remove``).  Total cost is O(E log V) on lane-ordered graphs, which is
what lets the cluster simulator (:mod:`repro.core.cluster`) run global graphs
with hundreds of thousands of tasks.  :func:`simulate_reference` keeps the
original O(V·F) frontier-scan loop verbatim as the equivalence oracle used by
the property tests and the benchmark harness.

Engine invariants (relied on by tests/test_engine_equivalence.py):

* Effective start times are monotone: a task's ``max(thread progress,
  dependency-ready time)`` only ever grows, so a heap entry's key is a valid
  *lower bound* and stale entries can be lazily re-keyed on pop.
* With the default policy, popping the minimum ``(eff, ready, uid)`` entry
  reproduces :func:`default_schedule`'s tie-breaking exactly — both engines
  produce bit-identical start times and makespans.
* A pluggable :data:`ScheduleFn` must be *eff-minimal*: it returns a task
  whose effective start is within ``SCHED_EPS`` of the frontier minimum.
  Both built-ins (:func:`default_schedule`, :func:`make_priority_schedule`)
  satisfy this; a policy that deliberately idles a resource should use
  :func:`simulate_reference`, which passes the entire frontier.

The ``schedule`` function that picks among ready tasks is pluggable exactly
as in the paper (§4.4 "Schedule"): the default picks the task with the
earliest effective start time; what-ifs like P3 override it with priority
policies.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DependencyGraph
from .task import Task, TaskKind, DEVICE_STREAM, HOST_THREAD

# schedule(frontier, progress, earliest_start) -> chosen task
ScheduleFn = Callable[[List[Task], Dict[str, float], Dict[int, float]], Task]

# Tie window inside which a custom schedule may reorder ready tasks; matches
# make_priority_schedule's candidate filter so both engines see the same set.
SCHED_EPS = 1e-12


def default_schedule(frontier: List[Task], progress: Dict[str, float],
                     earliest: Dict[int, float]) -> Task:
    """Paper default: pick the ready task with the earliest effective start.

    Effective start = max(thread progress, task's dependency-ready time).
    Ties break on dependency-ready time then uid for determinism.
    """
    def key(t: Task) -> Tuple[float, float, int]:
        eff = max(progress.get(t.thread, 0.0), earliest[t.uid])
        return (eff, earliest[t.uid], t.uid)
    return min(frontier, key=key)


def make_priority_schedule(priority: Callable[[Task], float]) -> ScheduleFn:
    """Priority override used by P3-style what-ifs (paper Algorithm 7).

    Among the tasks tied for earliest effective start, prefer the one with the
    highest ``priority(task)``.
    """
    def sched(frontier: List[Task], progress: Dict[str, float],
              earliest: Dict[int, float]) -> Task:
        def eff(t: Task) -> float:
            return max(progress.get(t.thread, 0.0), earliest[t.uid])
        best_eff = min(eff(t) for t in frontier)
        candidates = [t for t in frontier if eff(t) <= best_eff + SCHED_EPS]
        return max(candidates, key=lambda t: (priority(t), -t.uid))
    return sched


@dataclasses.dataclass
class SimResult:
    makespan: float
    start: Dict[int, float]                  # uid -> start time (paper output)
    finish: Dict[int, float]                 # uid -> start + duration (no gap)
    thread_busy: Dict[str, float]            # per-thread busy seconds
    breakdown: Dict[str, float]              # paper Fig.6: host-only / device-only / parallel
    _binding: Optional[Dict[int, Optional[int]]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _binding_fn: Optional[Callable[[], Dict[int, Optional[int]]]] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def binding(self) -> Optional[Dict[int, Optional[int]]]:
        """uid -> uid of the *binding predecessor* (the task whose
        completion set this task's effective start: the lane predecessor
        when the thread was the constraint, the last-finishing dependency
        otherwise; None for tasks that started at t=0).

        Available only from ``simulate(record_binding=True)`` —
        :mod:`repro.analysis` walks it to extract the makespan-defining
        critical path.  Materialized lazily on first access (the
        ``ClusterResult.per_worker`` pattern): the engine's hot loop only
        stores one conditional observation per released edge, and the
        O(V log V) map derivation runs here, outside the simulation —
        which is what keeps the instrumented run within the
        ``bench_sim.py`` 10% gate.
        """
        if self._binding is None and self._binding_fn is not None:
            self._binding = self._binding_fn()
            # drop the closure: it pins the engine's O(V) working dicts
            self._binding_fn = None
        return self._binding

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan / self.makespan if self.makespan > 0 else float("inf")


# Busy-interval math lives in repro.obs.timeline (one implementation for
# the engine breakdown, serving lane reports, and counter timelines); the
# historical names stay importable from here.
from repro.obs.timeline import interval_overlap as _overlap          # noqa: E402
from repro.obs.timeline import interval_union as _interval_union     # noqa: E402
from repro.obs.timeline import lane_utilization                      # noqa: E402,F401


def _host_device_breakdown(busy_intervals: Dict[str, List[Tuple[float, float]]],
                           makespan: float,
                           is_host: Callable[[str], bool]) -> Dict[str, float]:
    """Paper Fig. 6 runtime breakdown: host-only / device-only / parallel."""
    host_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if is_host(th) for iv in ivs])
    dev_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if not is_host(th) for iv in ivs])
    host_busy = sum(e - s for s, e in host_iv)
    dev_busy = sum(e - s for s, e in dev_iv)
    par = _overlap(host_iv, dev_iv)
    return {
        "host_only_s": host_busy - par,
        "device_only_s": dev_busy - par,
        "parallel_s": par,
        "idle_s": max(0.0, makespan - (host_busy + dev_busy - par)),
    }


def _assemble(graph: DependencyGraph, executed: int,
              progress: Dict[str, float], start: Dict[int, float],
              finish: Dict[int, float], busy: Dict[str, float],
              busy_intervals: Dict[str, List[Tuple[float, float]]],
              binding_fn: Optional[Callable[[], Dict[int, Optional[int]]]]
              = None) -> SimResult:
    if executed != len(graph):
        raise RuntimeError(
            f"simulation deadlock: executed {executed}/{len(graph)} tasks (cycle?)")
    makespan = max(progress.values(), default=0.0)
    breakdown = _host_device_breakdown(busy_intervals, makespan,
                                       lambda th: th == HOST_THREAD)
    return SimResult(makespan=makespan, start=start, finish=finish,
                     thread_busy=dict(busy), breakdown=breakdown,
                     _binding_fn=binding_fn)


def _derive_binding(by_uid: Dict[int, Task], start: Dict[int, float],
                    finish: Dict[int, float], earliest: Dict[int, float],
                    dep_binder: Dict[int, int]) -> Dict[int, Optional[int]]:
    """Binding predecessors, derived *after* the simulation loop.

    A task's effective start is ``max(thread progress, dependency-ready)``.
    When the thread was the constraint (``start > earliest``) the binder is
    the thread task that completed (``finish + gap``) exactly at our start;
    otherwise the dependency that last raised the ready time
    (``dep_binder``, the only thing the hot loop records), or None for a
    t=0 start.

    Per-thread execution order is recovered by sorting on ``(start, uid)``:
    thread progress is monotone, so start order matches execution order
    except among same-instant ties, where the backward scan for the exact
    completion time picks the true constraint (completion times here are
    bitwise reproductions of the progress values the engine compared
    against, so ``==`` is the right test).  The scan is bounded by the
    same-instant run plus one earlier-start task — tasks with a strictly
    earlier start all executed before us, so the first one reached is the
    latest of them.
    """
    lanes: Dict[str, List[Tuple[float, int]]] = collections.defaultdict(list)
    for uid, s in start.items():
        lanes[by_uid[uid].thread].append((s, uid))
    binding: Dict[int, Optional[int]] = {}
    get_dep = dep_binder.get
    for lane in lanes.values():
        lane.sort()
        for i, (s, u) in enumerate(lane):
            if s <= earliest[u]:
                binding[u] = get_dep(u)
                continue
            b = lane[i - 1][1] if i > 0 else None
            j = i - 1
            while j >= 0:
                sc, c = lane[j]
                if finish[c] + by_uid[c].gap == s:
                    b = c
                    break
                if sc < s:
                    break
                j -= 1
            binding[u] = b
    return binding


def simulate(graph: DependencyGraph, schedule: Optional[ScheduleFn] = None,
             *, record_binding: bool = False) -> SimResult:
    """Event-driven engine (default): paper Algorithm 1 semantics in O(E log V).

    Ready tasks sit in a min-heap keyed by ``(effective start, ready time,
    uid)``.  Keys are lower bounds (effective starts only grow), so a popped
    entry whose key is stale is re-pushed with its current effective start;
    a fresh minimum is executed directly.  When a custom ``schedule`` is
    supplied, every entry within ``SCHED_EPS`` of the minimum is popped and
    handed to the policy — the same candidate set the legacy loop's built-in
    policies select from — and the losers are re-pushed.

    ``record_binding=True`` additionally makes :attr:`SimResult.binding`
    available — each task's binding predecessor, what
    :mod:`repro.analysis` walks for critical paths.  The recording is
    designed to be free when off (the child-release loop is duplicated so
    the disabled path runs the byte-identical original body) and cheap
    when on: the hot loop stores exactly one observation per released edge
    that raises a ready time (``dep_binder``), and the full binding map is
    derived lazily on first ``.binding`` access (:func:`_derive_binding`).
    ``benchmarks/bench_sim.py`` gates the instrumented run within 10% of
    the plain run.
    """
    # direct adjacency access (uid sets) — the engine is the hottest loop in
    # the system and per-call Task-list materialization doubles its cost
    by_uid = graph._tasks
    children_of = graph._children
    parents_of = graph._parents
    ref: Dict[int, int] = {}
    earliest: Dict[int, float] = {}          # "u.start" accumulator of Algorithm 1
    heap: List[Tuple[float, float, int]] = []
    for uid in by_uid:
        n = len(parents_of[uid]) if uid in parents_of else 0
        ref[uid] = n
        earliest[uid] = 0.0
        if n == 0:
            heap.append((0.0, 0.0, uid))
    heapq.heapify(heap)

    progress: Dict[str, float] = collections.defaultdict(float)   # P
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = collections.defaultdict(float)
    busy_intervals: Dict[str, List[Tuple[float, float]]] = collections.defaultdict(list)
    executed = 0
    dep_binder: Dict[int, int] = {}

    heappush, heappop = heapq.heappush, heapq.heappop
    while heap:
        eff_key, _, uid = heappop(heap)
        u = by_uid[uid]
        e = earliest[uid]
        p = progress[u.thread]
        eff = p if p > e else e
        if eff > eff_key:                     # stale lower bound: re-key
            heappush(heap, (eff, e, uid))
            continue
        if schedule is not None:
            candidates = [u]
            spill: List[Tuple[float, float, int]] = []
            while heap and heap[0][0] <= eff_key + SCHED_EPS:
                _, _, uid2 = heapq.heappop(heap)
                t2 = by_uid[uid2]
                eff2 = max(progress[t2.thread], earliest[uid2])
                if eff2 <= eff_key + SCHED_EPS:
                    candidates.append(t2)
                else:
                    spill.append((eff2, earliest[uid2], uid2))
            if len(candidates) > 1:
                u = schedule(candidates, progress, earliest)
                for t2 in candidates:
                    if t2.uid != u.uid:
                        eff2 = max(progress[t2.thread], earliest[t2.uid])
                        spill.append((eff2, earliest[t2.uid], t2.uid))
            for item in spill:
                heapq.heappush(heap, item)

        th = u.thread
        uu = u.uid
        e = earliest[uu]
        p = progress[th]
        s = p if p > e else e
        start[uu] = s
        end = s + u.duration
        finish[uu] = end
        done = end + u.gap
        progress[th] = done
        busy[th] += u.duration
        if u.duration > 0:
            busy_intervals[th].append((s, end))
        executed += 1
        if uu in children_of:
            if not record_binding:
                for cuid in children_of[uu]:
                    r = ref[cuid] - 1
                    ref[cuid] = r
                    if earliest[cuid] < done:
                        earliest[cuid] = done
                    if r == 0:
                        ec = earliest[cuid]
                        pc = progress[by_uid[cuid].thread]
                        heappush(heap, (pc if pc > ec else ec, ec, cuid))
            else:
                for cuid in children_of[uu]:
                    r = ref[cuid] - 1
                    ref[cuid] = r
                    if earliest[cuid] < done:
                        earliest[cuid] = done
                        dep_binder[cuid] = uu
                    if r == 0:
                        ec = earliest[cuid]
                        pc = progress[by_uid[cuid].thread]
                        heappush(heap, (pc if pc > ec else ec, ec, cuid))

    binding_fn = (lambda: _derive_binding(by_uid, start, finish, earliest,
                                          dep_binder)) \
        if record_binding else None
    return _assemble(graph, executed, progress, start, finish, busy,
                     busy_intervals, binding_fn)


def simulate_reference(graph: DependencyGraph,
                       schedule: Optional[ScheduleFn] = None,
                       *, record_binding: bool = False) -> SimResult:
    """Legacy frontier-scan loop (paper Algorithm 1 verbatim) — the oracle.

    Maintains the frontier ``F`` of dependency-ready tasks and per-thread
    progress ``P``; each iteration picks ``u = schedule(F)``, sets
    ``u.start = max(P[t], u.start)`` and advances
    ``P[t] = u.start + u.duration + u.gap``, then releases children whose
    remaining-parent refcount hits zero, propagating ready times.  O(V·F) —
    kept for arbitrary (non-eff-minimal) schedules and as the equivalence
    oracle for :func:`simulate`.
    """
    sched = schedule or default_schedule
    ref: Dict[int, int] = {}
    earliest: Dict[int, float] = {}
    frontier: List[Task] = []
    for t in graph.tasks():
        ref[t.uid] = len(graph.parents(t))
        earliest[t.uid] = 0.0
        if ref[t.uid] == 0:
            frontier.append(t)

    progress: Dict[str, float] = collections.defaultdict(float)
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = collections.defaultdict(float)
    busy_intervals: Dict[str, List[Tuple[float, float]]] = collections.defaultdict(list)
    executed = 0
    dep_binder: Dict[int, int] = {}

    while frontier:
        u = sched(frontier, progress, earliest)
        frontier.remove(u)
        t = u.thread
        s = max(progress[t], earliest[u.uid])
        start[u.uid] = s
        end = s + u.duration
        finish[u.uid] = end
        progress[t] = end + u.gap
        busy[t] += u.duration
        if u.duration > 0:
            busy_intervals[t].append((s, end))
        executed += 1
        done = end + u.gap
        for c in graph.children(u):
            ref[c.uid] -= 1
            if earliest[c.uid] < done:
                earliest[c.uid] = done
                if record_binding:
                    dep_binder[c.uid] = u.uid
            if ref[c.uid] == 0:
                frontier.append(c)

    binding_fn = (lambda: _derive_binding(
        {t.uid: t for t in graph.tasks()}, start, finish, earliest,
        dep_binder)) if record_binding else None
    return _assemble(graph, executed, progress, start, finish, busy,
                     busy_intervals, binding_fn)
