"""Daydream's runtime simulation — a faithful implementation of paper Algorithm 1.

The simulator traverses the dependency graph, dispatching each frontier task to
its execution thread and advancing per-thread progress including the task's
trailing ``gap`` (the paper's mechanism for untraced host time).  The
``schedule`` function that picks among ready tasks is pluggable exactly as in
the paper (§4.4 "Schedule"): the default picks the task with the earliest
effective start time; what-ifs like P3 override it with priority policies.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import DependencyGraph
from .task import Task, TaskKind, DEVICE_STREAM, HOST_THREAD

# schedule(frontier, progress, earliest_start) -> chosen task
ScheduleFn = Callable[[List[Task], Dict[str, float], Dict[int, float]], Task]


def default_schedule(frontier: List[Task], progress: Dict[str, float],
                     earliest: Dict[int, float]) -> Task:
    """Paper default: pick the ready task with the earliest effective start.

    Effective start = max(thread progress, task's dependency-ready time).
    Ties break on dependency-ready time then uid for determinism.
    """
    def key(t: Task) -> Tuple[float, float, int]:
        eff = max(progress.get(t.thread, 0.0), earliest[t.uid])
        return (eff, earliest[t.uid], t.uid)
    return min(frontier, key=key)


def make_priority_schedule(priority: Callable[[Task], float]) -> ScheduleFn:
    """Priority override used by P3-style what-ifs (paper Algorithm 7).

    Among the tasks tied for earliest effective start, prefer the one with the
    highest ``priority(task)``.
    """
    def sched(frontier: List[Task], progress: Dict[str, float],
              earliest: Dict[int, float]) -> Task:
        def eff(t: Task) -> float:
            return max(progress.get(t.thread, 0.0), earliest[t.uid])
        best_eff = min(eff(t) for t in frontier)
        candidates = [t for t in frontier if eff(t) <= best_eff + 1e-12]
        return max(candidates, key=lambda t: (priority(t), -t.uid))
    return sched


@dataclasses.dataclass
class SimResult:
    makespan: float
    start: Dict[int, float]                  # uid -> start time (paper output)
    finish: Dict[int, float]                 # uid -> start + duration (no gap)
    thread_busy: Dict[str, float]            # per-thread busy seconds
    breakdown: Dict[str, float]              # paper Fig.6: host-only / device-only / parallel

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan / self.makespan if self.makespan > 0 else float("inf")


def _interval_union(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    if not intervals:
        return []
    intervals = sorted(intervals)
    out = [intervals[0]]
    for s, e in intervals[1:]:
        ls, le = out[-1]
        if s <= le:
            out[-1] = (ls, max(le, e))
        else:
            out.append((s, e))
    return out


def _overlap(a: List[Tuple[float, float]], b: List[Tuple[float, float]]) -> float:
    i = j = 0
    tot = 0.0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if e > s:
            tot += e - s
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return tot


def simulate(graph: DependencyGraph, schedule: Optional[ScheduleFn] = None) -> SimResult:
    """Paper Algorithm 1.

    Maintains the frontier ``F`` of dependency-ready tasks and per-thread
    progress ``P``; each iteration picks ``u = schedule(F)``, sets
    ``u.start = max(P[t], u.start)`` and advances
    ``P[t] = u.start + u.duration + u.gap``, then releases children whose
    remaining-parent refcount hits zero, propagating ready times.
    """
    sched = schedule or default_schedule
    ref: Dict[int, int] = {}
    earliest: Dict[int, float] = {}          # "u.start" accumulator of Algorithm 1
    frontier: List[Task] = []
    for t in graph.tasks():
        ref[t.uid] = len(graph.parents(t))
        earliest[t.uid] = 0.0
        if ref[t.uid] == 0:
            frontier.append(t)

    progress: Dict[str, float] = collections.defaultdict(float)   # P
    start: Dict[int, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = collections.defaultdict(float)
    busy_intervals: Dict[str, List[Tuple[float, float]]] = collections.defaultdict(list)
    executed = 0

    while frontier:
        u = sched(frontier, progress, earliest)
        frontier.remove(u)
        t = u.thread
        s = max(progress[t], earliest[u.uid])
        start[u.uid] = s
        end = s + u.duration
        finish[u.uid] = end
        progress[t] = end + u.gap
        busy[t] += u.duration
        if u.duration > 0:
            busy_intervals[t].append((s, end))
        executed += 1
        done = end + u.gap
        for c in graph.children(u):
            ref[c.uid] -= 1
            earliest[c.uid] = max(earliest[c.uid], done)
            if ref[c.uid] == 0:
                frontier.append(c)

    if executed != len(graph):
        raise RuntimeError(
            f"simulation deadlock: executed {executed}/{len(graph)} tasks (cycle?)")

    makespan = max(progress.values(), default=0.0)

    # Paper Fig. 6 runtime breakdown: host-only / device-only / host+device parallel.
    host_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if th == HOST_THREAD for iv in ivs])
    dev_iv = _interval_union(
        [iv for th, ivs in busy_intervals.items() if th != HOST_THREAD for iv in ivs])
    host_busy = sum(e - s for s, e in host_iv)
    dev_busy = sum(e - s for s, e in dev_iv)
    par = _overlap(host_iv, dev_iv)
    breakdown = {
        "host_only_s": host_busy - par,
        "device_only_s": dev_busy - par,
        "parallel_s": par,
        "idle_s": max(0.0, makespan - (host_busy + dev_busy - par)),
    }
    return SimResult(makespan=makespan, start=start, finish=finish,
                     thread_busy=dict(busy), breakdown=breakdown)
