"""Task and resource model for Daydream's kernel-granularity dependency graph.

Paper mapping (Daydream §4.2.1): tasks are GPU kernels / CPU calls / data loading /
communication primitives, each bound to an *execution thread* (CPU process, GPU
stream, or communication channel).  On the TPU/JAX side the resources are:

  - ``host``        : the host Python/runtime thread that feeds steps (CPU tasks)
  - ``device``      : the TPU core's compute stream (one XLA program executes
                      HLO ops in schedule order — the analogue of a CUDA stream)
  - ``ici:<axis>``  : one communication channel per mesh axis (collectives)
  - ``dma``         : HBM<->host DMA engine (offload / infeed / outfeed copies)
  - ``data``        : the data-loading pipeline thread

Every task carries a ``gap`` — Daydream's mechanism (§4.2.1 "Gap") for the
untraced runtime between consecutive tasks on the same thread — and an optional
``layer`` tag produced by the task->layer mapping (§4.3).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional, Tuple


class TaskKind(enum.Enum):
    """Coarse task taxonomy used by selection predicates and what-ifs."""

    COMPUTE = "compute"            # dots / convolutions / fusions on the device stream
    MEMORY = "memory"              # copies, transposes, dynamic-update-slice, bitcasts
    COLLECTIVE = "collective"      # all-reduce / all-gather / reduce-scatter / all-to-all / permute
    COMM = "comm"                  # point-to-point send/recv legs (pipeline hops, ppermute)
    HOST = "host"                  # host-side dispatch, callbacks, optimizer driver logic
    DATA = "data"                  # data loading (one task per micro/mini-batch)
    SYNC = "sync"                  # device->host completion events / blocking copies
    OFFLOAD = "offload"            # HBM<->host DMA traffic (vDNN-style what-ifs insert these)


# Resource (execution-thread) name constants.
HOST_THREAD = "host"
DEVICE_STREAM = "device"
DATA_THREAD = "data"
DMA_CHANNEL = "dma"


def ici_channel(axis: str) -> str:
    """Communication channel resource for a mesh axis (e.g. ``ici:data``)."""
    return f"ici:{axis}"


def p2p_channel(dst: int) -> str:
    """Channel resource of the point-to-point link *towards* worker ``dst``.

    Pipeline-parallel activation/gradient hops serialize per link: every
    send from one worker to the same destination shares this channel, so
    back-to-back microbatch hops queue exactly like ring legs on an ICI
    link do.
    """
    return f"ici:p2p>w{dst}"


def _json_safe(v: Any) -> bool:
    """Whether ``v`` survives a JSON round-trip unchanged (trace records)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return True
    if isinstance(v, (list, tuple)):
        return all(_json_safe(x) for x in v)
    if isinstance(v, dict):
        return all(isinstance(k, str) and _json_safe(x) for k, x in v.items())
    return False


def worker_thread(worker: int, thread: str) -> str:
    """Thread name of a worker-local resource inside a cluster graph.

    The cluster simulator (:mod:`repro.core.cluster`) replicates a
    single-worker graph; each replica's resources are namespaced as
    ``w<i>/<thread>`` so one global simulation can model N workers.
    """
    return f"w{worker}/{thread}"


def split_worker_thread(thread: str) -> Tuple[Optional[int], str]:
    """Inverse of :func:`worker_thread`: ``(worker or None, local thread)``."""
    if thread.startswith("w") and "/" in thread:
        head, rest = thread.split("/", 1)
        if head[1:].isdigit():
            return int(head[1:]), rest
    return None, thread


@dataclasses.dataclass
class Task:
    """One node of the dependency graph (paper §4.2.1).

    Attributes mirror the paper's task record: execution thread, duration, gap,
    and layer.  ``flops``/``bytes`` let the analytical cost model re-derive
    duration after transformations (e.g. precision what-ifs halve bytes).
    """

    name: str
    kind: TaskKind
    thread: str
    duration: float                 # seconds
    gap: float = 0.0                # seconds of untraced follow-on host time (§4.2.1)
    layer: Optional[str] = None     # task->layer mapping (§4.3); None == unmapped
    phase: Optional[str] = None     # fwd / bwd / update / comm (derived from layer scope)
    flops: float = 0.0
    bytes_accessed: float = 0.0
    comm_bytes: float = 0.0         # payload bytes for collectives
    comm_axes: Tuple[str, ...] = () # mesh axes the collective spans
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- simulation state (reset by the simulator) -------------------------
    uid: int = -1                   # assigned by the graph; stable identity

    def clone(self) -> "Task":
        t = dataclasses.replace(self)
        t.attrs = dict(self.attrs)
        return t

    def is_on_device(self) -> bool:
        return self.thread == DEVICE_STREAM

    def is_collective(self) -> bool:
        return self.kind == TaskKind.COLLECTIVE

    def is_comm(self) -> bool:
        """Any communication task: group collective or point-to-point leg.

        Bandwidth-style what-ifs act on this superset — a pipeline hop is as
        much network traffic as an all-reduce leg.
        """
        return self.kind in (TaskKind.COLLECTIVE, TaskKind.COMM)

    # ------------------------------------------------------- trace records
    def to_record(self) -> Dict[str, Any]:
        """JSON-safe dict of the task's trace-facing fields.

        This is the per-event schema of the native JSONL trace format
        (:mod:`repro.traceio`): ``dur``/``gap`` in seconds, ``kind`` as the
        :class:`TaskKind` value string, byte counts under ``bytes`` /
        ``comm_bytes``.  ``gap`` is always written (even 0.0) so importers
        never re-infer gaps for records we produced; zero/empty optional
        fields are dropped.  Non-JSON-safe ``attrs`` values are skipped.
        """
        rec: Dict[str, Any] = {"name": self.name, "kind": self.kind.value,
                               "thread": self.thread, "dur": self.duration,
                               "gap": self.gap}
        if self.layer:
            rec["layer"] = self.layer
        if self.phase:
            rec["phase"] = self.phase
        if self.flops:
            rec["flops"] = self.flops
        if self.bytes_accessed:
            rec["bytes"] = self.bytes_accessed
        if self.comm_bytes:
            rec["comm_bytes"] = self.comm_bytes
        if self.comm_axes:
            rec["comm_axes"] = list(self.comm_axes)
        attrs = {k: v for k, v in self.attrs.items() if _json_safe(v)}
        if attrs:
            rec["attrs"] = attrs
        return rec

    @staticmethod
    def from_record(rec: Dict[str, Any]) -> "Task":
        """Inverse of :meth:`to_record` (missing fields take defaults)."""
        return Task(
            name=str(rec.get("name", "?")),
            kind=TaskKind(rec.get("kind", "compute")),
            thread=str(rec.get("thread", DEVICE_STREAM)),
            duration=float(rec.get("dur", 0.0)),
            gap=float(rec.get("gap", 0.0) or 0.0),
            layer=rec.get("layer"),
            phase=rec.get("phase"),
            flops=float(rec.get("flops", 0.0)),
            bytes_accessed=float(rec.get("bytes", 0.0)),
            comm_bytes=float(rec.get("comm_bytes", 0.0)),
            comm_axes=tuple(rec.get("comm_axes", ())),
            attrs=dict(rec.get("attrs", {})))

    def __repr__(self) -> str:  # keep graphs printable
        lay = f" layer={self.layer}" if self.layer else ""
        return (f"Task#{self.uid}({self.name!r}, {self.kind.value}, {self.thread}, "
                f"{self.duration * 1e6:.2f}us{lay})")


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Target-hardware constants (TPU v5e-class chip unless overridden).

    These are the constants the roofline and the analytical cost model share.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    ici_bandwidth: float = 50e9         # bytes/s per link per direction
    ici_links_per_axis: int = 1         # torus links usable per mesh axis
    dcn_bandwidth: float = 25e9         # bytes/s cross-pod (data-centre network)
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024 * 1024 * 1024
    op_overhead: float = 0.5e-6         # fixed per-HLO-op issue overhead (seconds)
    host_dispatch: float = 20e-6        # host enqueue of one device program
    pcie_bandwidth: float = 32e9        # host<->device DMA for offload what-ifs

    def matmul_time(self, flops: float, bytes_accessed: float) -> float:
        return max(flops / self.peak_flops, bytes_accessed / self.hbm_bandwidth)


TPU_V5E = HardwareSpec()
