"""Roofline-term derivation from a compiled dry-run artifact (§Roofline).

Terms (seconds, per chip — the compiled HLO is the per-device program):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = sum over collective ops of ring-model time on the mesh links

plus MODEL_FLOPS (6*N*D train / 2*N*D prefill / 2*N*B decode) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips) which catches
remat/redundancy waste.  Sources: trip-count-aware ``aggregate_costs`` over
the parsed HLO (XLA's own cost_analysis visits while bodies once and
undercounts; both are reported).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from .costmodel import CostModel
from .task import HardwareSpec, TPU_V5E


def model_flops(kind: str, n_active_params: float, seq_len: int,
                global_batch: int) -> float:
    tokens = seq_len * global_batch
    if kind == "train":
        return 6.0 * n_active_params * tokens
    if kind == "prefill":
        return 2.0 * n_active_params * tokens
    if kind == "decode":
        return 2.0 * n_active_params * global_batch   # one new token per seq
    raise ValueError(kind)


def roofline_report(agg: Dict[str, float], *, chips: int, kind: str,
                    n_active_params: float, seq_len: int, global_batch: int,
                    hw: HardwareSpec = TPU_V5E,
                    xla_cost: Optional[Dict[str, float]] = None,
                    memory_stats: Optional[Any] = None) -> Dict[str, Any]:
    compute_s = agg["flops"] / hw.peak_flops
    memory_s = agg["bytes"] / hw.hbm_bandwidth
    collective_s = agg["collective_s"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(kind, n_active_params, seq_len, global_batch)
    hlo_total = agg["flops"] * chips
    step_s = max(compute_s, memory_s, collective_s)     # perfect-overlap bound
    ideal_s = mf / (chips * hw.peak_flops)
    report = {
        **terms,
        "bound": bound,
        "chips": chips,
        "hlo_flops_per_device": agg["flops"],
        "hlo_bytes_per_device": agg["bytes"],
        "collective_bytes_per_device": agg["collective_bytes"],
        "model_flops": mf,
        "useful_compute_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": ideal_s / step_s if step_s > 0 else 0.0,
        "step_time_lower_bound_s": step_s,
        "arithmetic_intensity": (agg["flops"] / agg["bytes"]
                                 if agg["bytes"] else 0.0),
    }
    for k, v in agg.items():
        if k.startswith("bytes_"):
            report[k] = v
    if xla_cost:
        report["xla_flops"] = xla_cost.get("flops", 0.0)
        report["xla_bytes"] = xla_cost.get("bytes accessed", 0.0)
    if memory_stats is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            report[f"mem_{f}"] = getattr(memory_stats, f, 0)
        report["hbm_bytes_per_device"] = (
            report["mem_argument_size_in_bytes"]
            + report["mem_output_size_in_bytes"]
            + report["mem_temp_size_in_bytes"]
            - report["mem_alias_size_in_bytes"])
        report["fits_hbm"] = bool(report["hbm_bytes_per_device"]
                                  <= hw.hbm_bytes)
    return report


def format_row(arch: str, shape: str, mesh: str, r: Dict[str, Any]) -> str:
    return (f"{arch:24s} {shape:12s} {mesh:6s} "
            f"comp={r['compute_s']*1e3:9.3f}ms "
            f"mem={r['memory_s']*1e3:9.3f}ms "
            f"coll={r['collective_s']*1e3:9.3f}ms "
            f"bound={r['bound']:10s} "
            f"useful={r['useful_compute_ratio']:5.2f} "
            f"roofline={r['roofline_fraction']:5.2f}")
