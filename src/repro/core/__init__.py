"""Daydream core: dependency-graph what-if performance prediction for DNN
training/serving on TPU-class hardware (paper: Zhu et al., USENIX ATC 2020).

Public surface:

    from repro.core import (
        Task, TaskKind, DependencyGraph, simulate, GraphTransform,
        trace_compiled, trace_measured, CostModel, whatif,
        ClusterGraph, WorkerSpec,          # N-worker global-graph simulation
        Optimization, Scenario, Stack, Prediction,   # unified what-if API
        register, get_optimization,        # the optimization registry
    )

The unified what-if API (:mod:`repro.core.optimize`) is the preferred
surface: optimizations are registered, typed, composable via ``|``, and
``Scenario.sweep`` evaluates parameter grids reusing one ClusterGraph
build.  The ``whatif.what_if_*`` functions remain as thin wrappers.
``Scenario(trace_dir=...)`` (and ``ClusterGraph.from_traces``) runs the
same registry on *real* per-worker profiler traces imported via
:mod:`repro.traceio` (Chrome trace-event JSON / native JSONL, dPRO-style
clock alignment, asymmetric per-worker graphs).

Simulation engines: :func:`simulate` is the O(E log V) event-driven heap
engine; :func:`simulate_reference` keeps the paper's Algorithm 1 frontier
scan as the equivalence oracle.  :class:`ClusterGraph` replicates a profiled
single-worker graph across N (possibly heterogeneous) workers with
cross-worker collective edges (ring / hierarchical / fused) and returns a
per-worker :class:`SimResult` breakdown — see :mod:`repro.core.cluster`.
"""

from .task import (Task, TaskKind, HardwareSpec, TPU_V5E, HOST_THREAD,
                   DEVICE_STREAM, DATA_THREAD, DMA_CHANNEL, ici_channel,
                   p2p_channel, worker_thread, split_worker_thread)
from .graph import DependencyGraph, GraphError
from .simulate import (simulate, simulate_incremental, simulate_reference,
                       SimResult, default_schedule, lane_utilization,
                       make_priority_schedule)
from .cluster import (ClusterGraph, ClusterResult, WorkerSpec,
                      match_collective_gid_groups, match_collective_groups,
                      match_push_pull_groups, match_wired_p2p)
from .fold import (FoldedClusterGraph, FoldedClusterResult, WorkerClass,
                   fold_cluster, fold_plan, partition_workers)
from .transform import (GraphTransform, predicted_speedup, by_kind, by_name,
                        by_layer, by_phase, on_device, all_of, any_of)
from .costmodel import CostModel, CollectiveModel, MeshTopology
from .hlo import parse_hlo_module, extract_graph, aggregate_costs, split_op_name
from .layermap import LayerMap, LayerProfile, bucket_layers
from .trace import (TraceBundle, trace_compiled, trace_measured,
                    measure_wallclock, lower_and_compile)
from .optimize import (Optimization, OptimizationError, PipelineParallel,
                       Prediction, Scenario, Stack, available,
                       get_optimization, greedy_search, parse_stack,
                       register)
from . import optimize
from . import whatif

__all__ = [
    "Task", "TaskKind", "HardwareSpec", "TPU_V5E",
    "HOST_THREAD", "DEVICE_STREAM", "DATA_THREAD", "DMA_CHANNEL", "ici_channel",
    "p2p_channel", "worker_thread", "split_worker_thread",
    "DependencyGraph", "GraphError",
    "simulate", "simulate_incremental", "simulate_reference", "SimResult",
    "default_schedule", "lane_utilization", "make_priority_schedule",
    "ClusterGraph", "ClusterResult", "WorkerSpec",
    "match_collective_gid_groups", "match_collective_groups",
    "match_push_pull_groups", "match_wired_p2p",
    "FoldedClusterGraph", "FoldedClusterResult", "WorkerClass",
    "fold_cluster", "fold_plan", "partition_workers",
    "GraphTransform", "predicted_speedup",
    "by_kind", "by_name", "by_layer", "by_phase", "on_device", "all_of", "any_of",
    "CostModel", "CollectiveModel", "MeshTopology",
    "parse_hlo_module", "extract_graph", "aggregate_costs", "split_op_name",
    "LayerMap", "LayerProfile", "bucket_layers",
    "TraceBundle", "trace_compiled", "trace_measured", "measure_wallclock",
    "lower_and_compile",
    "Optimization", "OptimizationError", "PipelineParallel", "Prediction",
    "Scenario", "Stack",
    "available", "get_optimization", "greedy_search", "parse_stack",
    "register",
    "optimize", "whatif",
]
