"""Daydream core: dependency-graph what-if performance prediction for DNN
training/serving on TPU-class hardware (paper: Zhu et al., USENIX ATC 2020).

Public surface:

    from repro.core import (
        Task, TaskKind, DependencyGraph, simulate, GraphTransform,
        trace_compiled, trace_measured, CostModel, whatif,
    )
"""

from .task import (Task, TaskKind, HardwareSpec, TPU_V5E, HOST_THREAD,
                   DEVICE_STREAM, DATA_THREAD, DMA_CHANNEL, ici_channel)
from .graph import DependencyGraph, GraphError
from .simulate import simulate, SimResult, default_schedule, make_priority_schedule
from .transform import (GraphTransform, predicted_speedup, by_kind, by_name,
                        by_layer, by_phase, on_device, all_of, any_of)
from .costmodel import CostModel, CollectiveModel, MeshTopology
from .hlo import parse_hlo_module, extract_graph, aggregate_costs, split_op_name
from .layermap import LayerMap, LayerProfile, bucket_layers
from .trace import (TraceBundle, trace_compiled, trace_measured,
                    measure_wallclock, lower_and_compile)
from . import whatif

__all__ = [
    "Task", "TaskKind", "HardwareSpec", "TPU_V5E",
    "HOST_THREAD", "DEVICE_STREAM", "DATA_THREAD", "DMA_CHANNEL", "ici_channel",
    "DependencyGraph", "GraphError",
    "simulate", "SimResult", "default_schedule", "make_priority_schedule",
    "GraphTransform", "predicted_speedup",
    "by_kind", "by_name", "by_layer", "by_phase", "on_device", "all_of", "any_of",
    "CostModel", "CollectiveModel", "MeshTopology",
    "parse_hlo_module", "extract_graph", "aggregate_costs", "split_op_name",
    "LayerMap", "LayerProfile", "bucket_layers",
    "TraceBundle", "trace_compiled", "trace_measured", "measure_wallclock",
    "lower_and_compile",
    "whatif",
]
