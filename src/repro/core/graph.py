"""Dependency graph construction (paper §4.2).

The graph is a DAG over :class:`repro.core.task.Task` nodes.  Edges come from the
paper's five dependency types, re-grounded for the XLA/TPU stack (DESIGN.md §2):

  1. host-thread program order          (paper: CPU same-thread order)
  2. device-stream program order        (paper: same-CUDA-stream order)
  3. dispatch: host enqueue -> device   (paper: cudaLaunchKernel correlation)
  4. synchronization: device -> host    (paper: cudaDeviceSynchronize etc.)
  5. communication: grad-ready -> collective -> consumer (wait-free backprop)

Program-order edges (types 1 and 2) are implied by thread membership and are
added explicitly between consecutive same-thread tasks at build time so that the
simulator and the transformation primitives can treat all dependencies uniformly
while insert/remove only needs local splicing (paper Fig. 4).
"""

from __future__ import annotations

import collections
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .task import Task, TaskKind


class GraphError(RuntimeError):
    pass


class DependencyGraph:
    """Mutable task DAG with thread-ordered lanes.

    Nodes are Tasks (uid-keyed); edges are stored as adjacency sets.  Same-thread
    program order is maintained as per-thread ordered lists (``lanes``), which is
    what makes insert/remove constant-time local operations, mirroring the
    paper's "appending a node to a linked list" description (§4.4).
    """

    def __init__(self) -> None:
        self._tasks: Dict[int, Task] = {}
        self._children: Dict[int, Set[int]] = collections.defaultdict(set)
        self._parents: Dict[int, Set[int]] = collections.defaultdict(set)
        self.lanes: Dict[str, List[int]] = collections.defaultdict(list)
        self._next_uid = 0

    # ------------------------------------------------------------------ nodes
    def add_task(self, task: Task, *, after: Optional[Task] = None,
                 link_lane: bool = True) -> Task:
        """Add ``task`` to its thread lane.

        If ``after`` is given the task is spliced into the lane right after it
        (program-order edges re-wired); otherwise it is appended to the lane
        tail.  ``link_lane=False`` adds the node without program-order edges
        (used while bulk-loading traces that add edges separately).
        """
        task.uid = self._next_uid
        self._next_uid += 1
        self._tasks[task.uid] = task
        lane = self.lanes[task.thread]
        if not link_lane:
            lane.append(task.uid)
            return task
        if after is None:
            if lane:
                self.add_edge(self._tasks[lane[-1]], task)
            lane.append(task.uid)
        else:
            if after.thread != task.thread:
                raise GraphError(
                    f"cannot splice {task.name} after {after.name}: different threads")
            idx = lane.index(after.uid)
            nxt = lane[idx + 1] if idx + 1 < len(lane) else None
            if nxt is not None:
                self.remove_edge(after, self._tasks[nxt])
                self.add_edge(task, self._tasks[nxt])
            self.add_edge(after, task)
            lane.insert(idx + 1, task.uid)
        return task

    def remove_task(self, task: Task, *, bridge: bool = True) -> None:
        """Remove a task (paper Fig. 4).

        With ``bridge=True`` (default) every parent is connected to every child
        so downstream work keeps its transitive dependencies — this is what
        "removing a kernel" means in the paper's fusion what-ifs.
        """
        uid = task.uid
        if uid not in self._tasks:
            raise GraphError(f"task {task} not in graph")
        parents = list(self._parents[uid])
        children = list(self._children[uid])
        if bridge:
            for p in parents:
                for c in children:
                    if p != c:
                        self._children[p].add(c)
                        self._parents[c].add(p)
        for p in parents:
            self._children[p].discard(uid)
        for c in children:
            self._parents[c].discard(uid)
        del self._parents[uid]
        del self._children[uid]
        lane = self.lanes[task.thread]
        lane.remove(uid)
        del self._tasks[uid]

    # ------------------------------------------------------------------ edges
    def add_edge(self, src: Task, dst: Task) -> None:
        if src.uid == dst.uid:
            raise GraphError(f"self-edge on {src}")
        self._children[src.uid].add(dst.uid)
        self._parents[dst.uid].add(src.uid)

    def remove_edge(self, src: Task, dst: Task) -> None:
        self._children[src.uid].discard(dst.uid)
        self._parents[dst.uid].discard(src.uid)

    # ------------------------------------------------------------ accessors
    def tasks(self) -> List[Task]:
        return list(self._tasks.values())

    def __len__(self) -> int:
        return len(self._tasks)

    def __contains__(self, task: Task) -> bool:
        return task.uid in self._tasks

    def get(self, uid: int) -> Task:
        return self._tasks[uid]

    def children(self, task: Task) -> List[Task]:
        return [self._tasks[c] for c in self._children[task.uid]]

    def parents(self, task: Task) -> List[Task]:
        return [self._tasks[p] for p in self._parents[task.uid]]

    def lane_tasks(self, thread: str) -> List[Task]:
        return [self._tasks[u] for u in self.lanes.get(thread, [])]

    def threads(self) -> List[str]:
        return [t for t, lane in self.lanes.items() if lane]

    def select(self, pred: Callable[[Task], bool]) -> List[Task]:
        """The paper's Select primitive (§4.4)."""
        return [t for t in self._tasks.values() if pred(t)]

    # -------------------------------------------------------------- analysis
    def toposort(self) -> List[Task]:
        indeg = {u: len(self._parents[u]) for u in self._tasks}
        queue = collections.deque(u for u, d in indeg.items() if d == 0)
        order: List[Task] = []
        while queue:
            u = queue.popleft()
            order.append(self._tasks[u])
            for c in self._children[u]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    queue.append(c)
        if len(order) != len(self._tasks):
            raise GraphError("dependency graph contains a cycle")
        return order

    def validate(self) -> None:
        """Invariants: acyclic; lanes consistent; edge symmetry."""
        self.toposort()
        for thread, lane in self.lanes.items():
            for uid in lane:
                t = self._tasks.get(uid)
                if t is None or t.thread != thread:
                    raise GraphError(f"lane {thread} references bad task {uid}")
        for u, cs in self._children.items():
            for c in cs:
                if u not in self._parents[c]:
                    raise GraphError(f"asymmetric edge {u}->{c}")

    def critical_path(self) -> float:
        """Longest duration(+gap) path — lower bound on any simulated makespan."""
        finish: Dict[int, float] = {}
        for t in self.toposort():
            start = max((finish[p.uid] for p in self.parents(t)), default=0.0)
            finish[t.uid] = start + t.duration + t.gap
        return max(finish.values(), default=0.0)

    def total_work(self) -> float:
        return sum(t.duration + t.gap for t in self._tasks.values())

    def copy(self) -> "DependencyGraph":
        g = DependencyGraph()
        remap: Dict[int, Task] = {}
        for thread, lane in self.lanes.items():
            for uid in lane:
                nt = self._tasks[uid].clone()
                g.add_task(nt, link_lane=False)
                remap[uid] = nt
        for u, cs in self._children.items():
            for c in cs:
                g.add_edge(remap[u], remap[c])
        return g

    def stats(self) -> Dict[str, float]:
        by_kind: Dict[str, float] = collections.defaultdict(float)
        for t in self._tasks.values():
            by_kind[t.kind.value] += t.duration
        return {
            "num_tasks": float(len(self._tasks)),
            "num_edges": float(sum(len(c) for c in self._children.values())),
            "critical_path_s": self.critical_path(),
            "total_work_s": self.total_work(),
            **{f"dur_{k}_s": v for k, v in sorted(by_kind.items())},
        }
