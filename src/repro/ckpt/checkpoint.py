"""Sharded checkpointing: atomic commit, async save, elastic re-shard restore.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json     tree structure, shapes, dtypes, logical axes, step
        <flat.key>.npy    one array per leaf (host-gathered values)
        COMMIT            written last — a checkpoint without it is invalid

Design points for the 1000+-node posture (DESIGN.md §6):
  * **atomic commit** — writers stage into ``step_X.tmp`` and rename; readers
    only trust directories containing COMMIT, so a mid-save crash can never
    corrupt restore state.
  * **elastic re-shard** — arrays are saved in *logical* (unsharded) form with
    their logical axis names; ``restore_checkpoint(mesh=...)`` re-places them
    onto any mesh shape via NamedSharding, so a 512-chip checkpoint restores
    onto 256 chips (or vice versa) without conversion tools.
  * **async** — ``CheckpointManager.save_async`` snapshots to host memory
    (jax.device_get) synchronously and writes in a background thread, keeping
    the save off the training critical path.
  * On a real multi-host fleet each host would write only its addressable
    shards; in this container the single process owns everything, and the
    format is already per-leaf so the extension is a filename suffix.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "."

# dtypes np.save writes as-is; anything else (bf16/fp8/...) rides a float32
# carrier (lossless upcast) — shared by save_checkpoint and checkpoint_bytes
_SAVED_DTYPES = (np.float32, np.float64, np.int32, np.int64, np.int8,
                 np.uint8, np.bool_, np.float16, np.uint16, np.uint32)


def _carrier_dtype(dtype) -> np.dtype:
    dt = np.dtype(dtype)
    return dt if dt in (np.dtype(d) for d in _SAVED_DTYPES) \
        else np.dtype(np.float32)


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Write one atomic checkpoint; returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "leaves": {},
                                "extra": extra_meta or {}}
    for key, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        carrier = _carrier_dtype(arr.dtype)
        if arr.dtype != carrier:
            arr = arr.astype(carrier)
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": orig_dtype}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def checkpoint_bytes(tree: Any) -> int:
    """Deterministic on-disk payload size of ``save_checkpoint(tree)``.

    Sums leaf ``shape x carrier-dtype`` over the tree using the same
    dtype-carrier rules as the save path (exotic dtypes ride a float32
    carrier), without materializing or transferring any array — abstract
    values (``jax.ShapeDtypeStruct``, ``jax.eval_shape`` outputs) size the
    same as concrete ones.  Manifest/COMMIT bookkeeping is excluded: this
    is the number the fault simulator's RecoveryModel turns into restore
    seconds over the host DMA bandwidth.
    """
    total = 0
    for _, leaf in _flatten(tree):
        shape = getattr(leaf, "shape", None)
        if shape is None:
            shape = np.shape(leaf)
        n = 1
        for d in shape:
            n *= int(d)
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            dtype = np.asarray(leaf).dtype
        total += n * _carrier_dtype(dtype).itemsize
    return total


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       mesh=None, shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``.

    ``shardings``: optional NamedSharding tree (elastic re-shard: place each
    restored array onto the *current* mesh regardless of the mesh it was
    saved from).  Returns (tree, step).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"checkpoint {path} is uncommitted")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = [k for k, _ in _flatten(like)]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(keys))
    out = []
    for key, ref, sh in zip(keys, leaves_like, shard_leaves):
        arr = np.load(os.path.join(path, key + ".npy"))
        want_dtype = getattr(ref, "dtype", arr.dtype)
        jarr = jax.numpy.asarray(arr).astype(want_dtype)
        if sh is not None:
            out.append(jax.device_put(jarr, sh))
        else:
            out.append(jarr)
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Keep-last-k manager with async save."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, **meta) -> str:
        path = save_checkpoint(self.directory, step, tree, meta or None)
        self._gc()
        return path

    def save_async(self, step: int, tree: Any, **meta) -> None:
        """Snapshot to host synchronously, write in the background.

        One save in flight at a time: joins the previous one first, so a
        failed background write surfaces *here* (or in :meth:`wait`) as
        its exception rather than being dropped with the worker thread.
        """
        self.wait()                      # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = self._pool.submit(self.save, step, host_tree, **meta)

    def wait(self) -> None:
        """Join the in-flight save, re-raising its exception exactly once.

        The pending future is cleared *before* ``result()`` can raise:
        a failed save must not wedge the manager by re-raising forever
        and blocking every later ``save_async``.
        """
        with self._lock:
            if self._pending is not None:
                fut, self._pending = self._pending, None
                fut.result()

    def restore_latest(self, like, mesh=None, shardings=None):
        return restore_checkpoint(self.directory, like, mesh=mesh,
                                  shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory)) if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
