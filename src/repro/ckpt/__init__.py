from .checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                         checkpoint_bytes, CheckpointManager)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "checkpoint_bytes", "CheckpointManager"]
