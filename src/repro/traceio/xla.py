"""XLA profiler (``jax.profiler``) capture import.

``jax.profiler.trace(logdir)`` / ``jax.profiler.start_trace(logdir)``
write a TensorBoard-style profile directory::

    logdir/plugins/profile/<run-timestamp>/<host>.trace.json.gz
    logdir/plugins/profile/<run-timestamp>/<host>.xplane.pb

The ``.trace.json.gz`` file is gzipped Chrome trace-event JSON, one per
host, with every device/host stream of that host as a ``(pid, tid)`` pair:
device processes (or, on CPU-backed captures, the XLA runtime threads of
the ``/host:CPU`` process) carry HLO-op slices tagged with
``args.hlo_op`` / ``args.hlo_module``; the python host thread carries the
profiler's nested call-stack flames; ``jax.profiler.StepTraceAnnotation``
shows up as slices carrying ``args.step_num``.

This reader maps those captures onto the lane model the rest of
:mod:`repro.traceio` uses (one non-overlapping event sequence per thread):

* **step slicing** — with step annotations present, only events inside the
  selected step's window are kept (``step="last"`` by default: the last —
  warmed-up — step; an int selects a specific ``step_num``; ``None``
  keeps the whole capture);
* **leaf extraction** — profiler flames nest (a python frame contains its
  callees; an HLO module slice contains its ops), which violates the lane
  model, so each ``(pid, tid)`` keeps only its *leaf* slices — the frames
  where time is actually spent — and residual overlaps are clipped;
* **lane naming** — threads holding HLO-op slices (or XLA-runtime thread
  names) become ``device`` lanes, python/host threads become ``host``
  lanes, anything else keeps a sanitized thread name;
* **kinds** — from the lane plus the usual name classification
  (:func:`repro.traceio.events.classify`), so HLO collectives
  (``all-reduce.N`` ...) land as :data:`TaskKind.COLLECTIVE` with their
  lane order preserved.

XLA's Chrome export carries no flow events on these captures, so
cross-thread dependencies are not recoverable: the imported graph has
per-lane program order only, which preserves every duration (what
calibration fits against) but lets a simulation compact inter-lane idle
time.

One *worker* per device process — or per host file when the capture is
CPU-backed (single ``/host:CPU`` process).  Multi-host captures are
clock-aligned through matched collectives like any other trace set.
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .align import ClockAlignment, align_traces, apply_alignment
from .events import TraceEvent, TraceImportError, WorkerTrace
from .importer import ImportedCluster, graph_from_events

_US = 1e6     # Chrome microseconds -> seconds

# XLA runtime execution threads (device streams on CPU-backed captures).
_DEVICE_THREAD = re.compile(
    r"XLATfrtCpuClient|XlaLauncher|StreamExecutor|TpuDriver|/device:", re.I)
_HOST_THREAD = re.compile(r"^python$|main_thread|^host", re.I)
# Background service threads that are not part of the training step.
_NOISE_THREAD = re.compile(r"llvm-codegen|compile|Profiler|pthread", re.I)


def find_xla_trace_files(path: str) -> List[str]:
    """Per-host ``.trace.json(.gz)`` files of an XLA profile capture.

    ``path`` may be the profiler logdir (the newest run under
    ``plugins/profile/`` wins), one run directory, or one trace file.
    Returns ``[]`` when ``path`` holds no XLA capture — the signal
    :func:`repro.traceio.load_trace_dir` keys its format detection on.
    """
    if os.path.isfile(path):
        return [path] if path.endswith((".trace.json", ".trace.json.gz")) \
            else []
    runs = sorted(glob.glob(os.path.join(path, "plugins", "profile", "*")))
    in_run_dir = bool(runs)
    candidates = [runs[-1]] if runs else [path]
    for cand in candidates:
        files = sorted(glob.glob(os.path.join(cand, "*.trace.json.gz"))
                       + glob.glob(os.path.join(cand, "*.trace.json")))
        if not in_run_dir:
            # a bare directory of worker<N>.trace.json files is this
            # package's *native* Chrome export, not an XLA capture —
            # claiming it would bypass the provenance-aware importer
            files = [f for f in files
                     if not re.match(r"worker\d+\.trace\.json$",
                                     os.path.basename(f))]
        if files:
            return files
    return []


def _read_trace_json(path: str) -> Dict[str, Any]:
    opener = gzip.open if path.endswith(".gz") else open
    try:
        with opener(path, "rt") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise TraceImportError(f"{path}: not a readable Chrome trace "
                               f"({e})") from e
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise TraceImportError(
            f"{path}: expected a Chrome trace object with 'traceEvents'")
    return doc


def _leaf_slices(evs: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Leaves of one thread's flame stack, in time order.

    Nested profiler slices (python frames over their callees, HLO module
    slices over their ops) attribute the same wall time at every depth;
    the lane model needs each instant counted once, so only slices that
    contain no other slice survive.
    """
    evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
    out: List[Dict[str, Any]] = []
    stack: List[List[Any]] = []          # [event, end, is_leaf]
    for e in evs:
        while stack and e["ts"] >= stack[-1][1]:
            top = stack.pop()
            if top[2]:
                out.append(top[0])
        if stack:
            stack[-1][2] = False
        stack.append([e, e["ts"] + e["dur"], True])
    while stack:
        top = stack.pop()
        if top[2]:
            out.append(top[0])
    return sorted(out, key=lambda e: e["ts"])


def _clip_overlaps(evs: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Force strictly sequential slices (tiny profiler-rounding overlaps
    between adjacent leaves get clipped, zero-length remnants dropped)."""
    out: List[Dict[str, Any]] = []
    cursor = float("-inf")
    for e in evs:
        ts, dur = e["ts"], e["dur"]
        if ts < cursor:
            dur -= cursor - ts
            ts = cursor
        if dur <= 0:
            continue
        e = dict(e, ts=ts, dur=dur)
        cursor = ts + dur
        out.append(e)
    return out


def _step_window(events: List[Dict[str, Any]],
                 step: Union[str, int, None]
                 ) -> Optional[Tuple[float, float]]:
    """Resolve one annotated step's [start, end] window over a whole file.

    ``jax.profiler.StepTraceAnnotation`` slices carry ``args.step_num`` —
    but only on the annotating (host) thread, so the window must be
    computed file-wide and then applied to *every* thread, device lanes
    included.  ``step="last"`` picks the highest step number (steady
    state), an int picks that step, ``None`` keeps everything.  Returns
    ``None`` (keep everything) for unannotated captures.
    """
    if step is None:
        return None
    markers: Dict[int, Tuple[float, float]] = {}
    for e in events:
        num = (e.get("args") or {}).get("step_num")
        if num is None:
            continue
        lo, hi = markers.get(int(num), (float("inf"), float("-inf")))
        markers[int(num)] = (min(lo, e["ts"]),
                             max(hi, e["ts"] + e["dur"]))
    if not markers:
        return None
    if step == "last":
        chosen = max(markers)
    else:
        chosen = int(step)
        if chosen not in markers:
            raise TraceImportError(
                f"step {chosen} not in capture (annotated steps: "
                f"{sorted(markers)})")
    return markers[chosen]


def _select_step(events: List[Dict[str, Any]],
                 window: Optional[Tuple[float, float]]
                 ) -> List[Dict[str, Any]]:
    """Restrict one thread's X events to a :func:`_step_window` (marker
    slices themselves are dropped — they are annotations, not work)."""
    if window is None:
        return events
    lo, hi = window
    return [e for e in events
            if e["ts"] >= lo and e["ts"] + e["dur"] <= hi
            and (e.get("args") or {}).get("step_num") is None]


def _lane_name(thread_name: str, has_hlo: bool, used: Dict[str, int]) -> str:
    """Map one profiler thread onto a lane name (``device`` / ``host`` /
    sanitized), deduplicated with ``:<k>`` suffixes.  Host-name patterns
    win over HLO presence: CPU-backed captures can run small HLO programs
    inline on the python thread, which is still host time."""
    if _HOST_THREAD.search(thread_name):
        base = "host"
    elif has_hlo or _DEVICE_THREAD.search(thread_name):
        base = "device"
    else:
        base = re.sub(r"[^\w.-]+", "_", thread_name).strip("_") or "aux"
    used[base] = used.get(base, 0) + 1
    return base if used[base] == 1 else f"{base}:{used[base]}"


def read_xla_trace(path: str, *, step: Union[str, int, None] = "last"
                   ) -> List[WorkerTrace]:
    """Read one per-host ``.trace.json(.gz)`` file into worker traces.

    One worker per device process; CPU-backed captures (a single
    ``/host:CPU`` process) yield one worker.  Worker numbering here is
    file-local — :func:`load_xla_profile` renumbers across hosts.
    """
    doc = _read_trace_json(path)
    proc_names: Dict[Any, str] = {}
    thread_names: Dict[Tuple[Any, Any], str] = {}
    by_thread: Dict[Tuple[Any, Any], List[Dict[str, Any]]] = {}
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict):
            continue
        ph = ev.get("ph")
        if ph == "M":
            args = ev.get("args") or {}
            if ev.get("name") == "process_name":
                proc_names[ev.get("pid")] = str(args.get("name", ""))
            elif ev.get("name") == "thread_name":
                thread_names[(ev.get("pid"), ev.get("tid"))] = \
                    str(args.get("name", ""))
        elif ph == "X":
            key = (ev.get("pid"), ev.get("tid"))
            by_thread.setdefault(key, []).append(
                {"name": str(ev.get("name", "")),
                 "ts": float(ev.get("ts", 0.0)),
                 "dur": float(ev.get("dur", 0.0)),
                 "args": ev.get("args") or {}})
    if not by_thread:
        raise TraceImportError(f"{path}: capture has no complete (ph=X) "
                               f"events")

    window = _step_window(
        [e for evs in by_thread.values() for e in evs], step)
    traces: List[WorkerTrace] = []
    for pid in sorted({k[0] for k in by_thread}, key=str):
        threads = sorted((k for k in by_thread if k[0] == pid),
                         key=lambda k: str(k[1]))
        proc_is_device = "/device:" in proc_names.get(pid, "")
        used: Dict[str, int] = {}
        events: List[TraceEvent] = []
        for key in threads:
            tname = thread_names.get(key, f"tid{key[1]}")
            if _NOISE_THREAD.search(tname):
                continue
            evs = _select_step(by_thread[key], window)
            evs = _clip_overlaps(_leaf_slices(evs))
            if not evs:
                continue
            has_hlo = any("hlo_op" in e["args"] for e in evs)
            lane = _lane_name(tname, has_hlo or (
                proc_is_device and not _HOST_THREAD.search(tname)), used)
            for e in evs:
                args = e["args"]
                attrs = {k: v for k, v in args.items()
                         if isinstance(v, (str, int, float, bool))}
                attrs["xla_thread"] = tname
                events.append(TraceEvent(
                    name=str(args.get("hlo_op") or e["name"]),
                    thread=lane, ts=e["ts"] / _US, dur=e["dur"] / _US,
                    eid=len(events), attrs=attrs))
        if events:
            traces.append(WorkerTrace(worker=len(traces), events=events,
                                      source=f"{path}#pid={pid}"))
    if not traces:
        raise TraceImportError(
            f"{path}: no usable worker events after step slicing "
            f"(step={step!r})")
    return traces


def load_xla_profile(path: str, *, step: Union[str, int, None] = "last",
                     infer_gaps: str = "host") -> ImportedCluster:
    """Load a ``jax.profiler`` capture into an :class:`ImportedCluster`.

    ``path`` is the profiler logdir, one run directory, or one trace file
    (see :func:`find_xla_trace_files`).  Workers from one host file share
    that host's clock (identity alignment); multi-host captures are
    aligned through matched collectives like native trace sets.
    """
    files = find_xla_trace_files(path)
    if not files:
        raise TraceImportError(
            f"{path!r} holds no XLA profile (*.trace.json[.gz] under "
            f"plugins/profile/<run>/)")
    traces: List[WorkerTrace] = []
    file_of: List[int] = []
    for fi, f in enumerate(files):
        for tr in read_xla_trace(f, step=step):
            tr.worker = len(traces)
            traces.append(tr)
            file_of.append(fi)
    if len(set(file_of)) > 1:
        alignments = align_traces(traces)
        for tr, al in zip(traces, alignments):
            apply_alignment(tr, al)
    else:
        alignments = [ClockAlignment() for _ in traces]
    firsts = [tr.first_ts() for tr in traces]
    t0 = min(firsts, default=0.0)
    start_skews = [max(0.0, f - t0) for f in firsts]
    graphs = [graph_from_events(tr, infer_gaps=infer_gaps) for tr in traces]
    return ImportedCluster(graphs=graphs, traces=traces,
                           alignments=alignments, start_skews=start_skews)
