"""Trace events and the native JSONL per-worker trace format.

This module is the *format contract* of the trace I/O subsystem
(Daydream §4.1: the dependency graph is built from low-level traces).  A
trace set is a directory with **one file per worker**; workers are ordered
by the first integer in the file name (``worker0.jsonl``, ``worker1.json``,
...), falling back to lexicographic order.

Native JSONL format (``*.jsonl``)
---------------------------------

One JSON object per line; blank lines and lines whose object carries a
``"trace"`` key (file metadata) are ignored.  Event fields:

``name``        task name (required)
``thread``      execution stream — ``device`` / ``host`` / ``ici:<axis>`` /
                ``dma`` / ``data`` (required; free-form threads allowed)
``ts``          start time in **seconds**, worker-local clock (required)
``dur``         duration in seconds (required)
``id``          event id referenced by ``deps`` (default: line ordinal)
``deps``        explicit dependency event ids (cross-thread edges; same-
                thread program order is implied by ``ts`` order per thread)
``kind``        :class:`~repro.core.task.TaskKind` value string; inferred
                from the name/thread when absent
``gap``         Daydream §4.2.1 untraced follow-on time in seconds.  When
                absent, the importer *infers* it from the idle time to the
                next same-thread event (host threads only by default) —
                records written by this repo always carry it explicitly.
``layer`` / ``phase`` / ``flops`` / ``bytes`` / ``comm_bytes``
                optional task metadata (see :meth:`repro.core.task.Task
                .to_record`)
``collective``  collective op (``all-reduce`` | ``reduce-scatter`` |
                ``all-gather`` | ``all-to-all`` | ``collective-permute``);
                inferred from the name when absent.  Collectives are what
                :func:`repro.core.cluster.match_collective_groups` matches
                across workers and what clock alignment anchors on.
``group_size``  collective group size as captured (informational)
``attrs``       free-form JSON-safe dict merged into ``Task.attrs``

Chrome trace-event JSON (``*.json``) is read by :mod:`repro.traceio.chrome`
and normalized into the same :class:`TraceEvent` records.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.core.task import Task, TaskKind, HOST_THREAD, DATA_THREAD, \
    DMA_CHANNEL


class TraceImportError(RuntimeError):
    """A trace file/set that cannot be turned into a simulation graph."""


# Collective-op inference from task/kernel names (covers XLA HLO names,
# NCCL kernel names, and our own exports).
_COLLECTIVE_PATTERNS = [
    ("all-reduce", re.compile(r"all[-_ ]?reduce|ncclAllReduce", re.I)),
    ("reduce-scatter", re.compile(r"reduce[-_ ]?scatter|ncclReduceScatter",
                                  re.I)),
    ("all-gather", re.compile(r"all[-_ ]?gather|ncclAllGather", re.I)),
    ("all-to-all", re.compile(r"all[-_ ]?to[-_ ]?all|ncclAllToAll", re.I)),
    ("collective-permute", re.compile(r"collective[-_ ]?permute|"
                                      r"ncclSend|ncclRecv", re.I)),
]


def infer_collective(name: str) -> Optional[str]:
    """Canonical collective op named by ``name``, or None."""
    for op, rx in _COLLECTIVE_PATTERNS:
        if rx.search(name):
            return op
    return None


def classify(name: str, thread: str,
             collective: Optional[str] = None) -> TaskKind:
    """Default task-kind classification for events without an explicit kind.

    Collective names win; otherwise the thread decides (Daydream binds kinds
    to execution threads: host/data/DMA streams carry host/data/offload
    tasks, everything else is device compute).
    """
    if collective or infer_collective(name):
        return TaskKind.COLLECTIVE
    local = thread.rsplit("/", 1)[-1]
    if local == HOST_THREAD or local.startswith("host"):
        return TaskKind.HOST
    if local == DATA_THREAD:
        return TaskKind.DATA
    if local == DMA_CHANNEL:
        return TaskKind.OFFLOAD
    if local.startswith("ici"):
        return TaskKind.COLLECTIVE
    return TaskKind.COMPUTE


@dataclasses.dataclass
class TraceEvent:
    """One profiled event, normalized across trace formats.

    ``ts``/``dur``/``gap`` are seconds in the *worker-local* clock until
    :func:`repro.traceio.align.apply_alignment` rescales them.  ``deps``
    are event ids (explicit cross-thread dependencies); same-thread program
    order comes from per-thread ``ts`` order.
    """

    name: str
    thread: str
    ts: float
    dur: float
    eid: int = -1
    deps: List[int] = dataclasses.field(default_factory=list)
    kind: Optional[str] = None          # TaskKind value string
    gap: Optional[float] = None         # None => importer may infer
    layer: Optional[str] = None
    phase: Optional[str] = None
    flops: float = 0.0
    bytes_accessed: float = 0.0
    comm_bytes: float = 0.0
    collective: Optional[str] = None
    group_size: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def resolved_collective(self) -> Optional[str]:
        return self.collective or infer_collective(self.name)

    def to_task(self) -> Task:
        """Materialize the event as a graph :class:`Task` (no deps/ts)."""
        coll = self.resolved_collective()
        kind = TaskKind(self.kind) if self.kind \
            else classify(self.name, self.thread, coll)
        attrs = dict(self.attrs)
        if coll and kind == TaskKind.COLLECTIVE:
            attrs.setdefault("collective", coll)
            if self.group_size:
                attrs.setdefault("group_size", self.group_size)
        return Task(name=self.name, kind=kind, thread=self.thread,
                    duration=self.dur, gap=self.gap or 0.0, layer=self.layer,
                    phase=self.phase, flops=self.flops,
                    bytes_accessed=self.bytes_accessed,
                    comm_bytes=self.comm_bytes, attrs=attrs)

    def to_json(self) -> Dict[str, Any]:
        """The native JSONL line for this event (see module docstring)."""
        rec: Dict[str, Any] = {"name": self.name, "thread": self.thread,
                               "ts": self.ts, "dur": self.dur,
                               "id": self.eid}
        if self.deps:
            rec["deps"] = list(self.deps)
        if self.kind:
            rec["kind"] = self.kind
        if self.gap is not None:
            rec["gap"] = self.gap
        for key, val in (("layer", self.layer), ("phase", self.phase)):
            if val:
                rec[key] = val
        for key, val in (("flops", self.flops),
                         ("bytes", self.bytes_accessed),
                         ("comm_bytes", self.comm_bytes)):
            if val:
                rec[key] = val
        if self.collective:
            rec["collective"] = self.collective
        if self.group_size:
            rec["group_size"] = self.group_size
        if self.attrs:
            rec["attrs"] = self.attrs
        return rec

    @staticmethod
    def from_json(rec: Dict[str, Any], default_eid: int) -> "TraceEvent":
        try:
            name = str(rec["name"])
            thread = str(rec["thread"])
            ts = float(rec["ts"])
            dur = float(rec["dur"])
        except KeyError as e:
            raise TraceImportError(
                f"trace event missing required field {e.args[0]!r}: {rec!r}"
            ) from e
        gap = rec.get("gap")
        return TraceEvent(
            name=name, thread=thread, ts=ts, dur=dur,
            eid=int(rec.get("id", default_eid)),
            deps=[int(d) for d in rec.get("deps", ())],
            kind=rec.get("kind"),
            gap=None if gap is None else float(gap),
            layer=rec.get("layer"), phase=rec.get("phase"),
            flops=float(rec.get("flops", 0.0)),
            bytes_accessed=float(rec.get("bytes", 0.0)),
            comm_bytes=float(rec.get("comm_bytes", 0.0)),
            collective=rec.get("collective"),
            group_size=int(rec.get("group_size") or 0),
            attrs=dict(rec.get("attrs", {})))


@dataclasses.dataclass
class WorkerTrace:
    """One worker's captured events plus bookkeeping."""

    worker: int
    events: List[TraceEvent]
    source: str = ""

    def collectives(self) -> List[TraceEvent]:
        return [e for e in self.events if e.resolved_collective()]

    def first_ts(self) -> float:
        return min((e.ts for e in self.events), default=0.0)


def read_jsonl(path_or_lines: Union[str, Iterable[str]],
               worker: int = 0) -> WorkerTrace:
    """Read a native JSONL worker trace (path, open file, or line iterable)."""
    source = path_or_lines if isinstance(path_or_lines, str) else "<lines>"
    if isinstance(path_or_lines, str):
        fh: Any = open(path_or_lines, "r")
        close = True
    else:
        fh, close = path_or_lines, False
    events: List[TraceEvent] = []
    try:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceImportError(
                    f"{source}:{lineno}: not valid JSON: {e}") from e
            if not isinstance(rec, dict) or "trace" in rec:
                continue                    # metadata line
            events.append(TraceEvent.from_json(rec, default_eid=len(events)))
    finally:
        if close:
            fh.close()
    eids = [e.eid for e in events]
    if len(set(eids)) != len(eids):
        raise TraceImportError(f"{source}: duplicate event ids")
    return WorkerTrace(worker=worker, events=events, source=source)


def write_jsonl(events: Sequence[TraceEvent],
                path: Optional[str] = None, *,
                meta: Optional[Dict[str, Any]] = None) -> List[str]:
    """Write events as native JSONL; returns the lines (also when ``path``
    is None, for in-memory round-trips)."""
    header = {"trace": "repro-jsonl", "version": 1, **(meta or {})}
    lines = [json.dumps(header)]
    lines += [json.dumps(e.to_json()) for e in events]
    if path is not None:
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
    return lines
