"""Clock alignment across independently-captured per-worker traces.

Each worker's profiler stamps events with its *own* clock, so N traces of
one training step disagree by a per-worker offset (clocks started at
different times) and drift (oscillators tick at slightly different rates).
dPRO (arXiv:2205.02473) aligns them by anchoring on communication: a
synchronous collective *ends* at (physically) the same instant on every
participant, so matched collective end times are observations of one global
timestamp through each worker's clock.

:func:`align_traces` matches collectives across traces by (name,
occurrence) — the same contract :func:`repro.core.cluster
.match_collective_groups` uses on graphs — takes worker 0's clock as the
reference timeline, and least-squares fits a per-worker affine map
``t_ref ≈ scale * t_local + offset`` over the anchor pairs:

* >= 2 anchors: full offset+drift fit (closed-form simple linear
  regression);
* exactly 1 anchor: offset only (``scale = 1``);
* no anchors (single worker, or no matched collectives): identity, flagged
  by ``anchors == 0`` so callers can warn.

Real oscillator drift is parts-per-million; a fitted scale far from 1 (or
non-positive, which would *negate* every duration downstream) can only
come from a degenerate anchor set — collinear-in-time anchors, mismatched
collectives, or a noise-dominated fit.  Fits with scale outside
``[SCALE_MIN, SCALE_MAX]`` therefore fall back to an offset-only map
(``scale = 1``) with :attr:`ClockAlignment.fallback` set so callers can
flag the anchors.

:func:`apply_alignment` rescales a trace in place: timestamps map through
the affine fit; durations and gaps are *intervals*, so they scale by the
drift term only.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from .events import TraceEvent, WorkerTrace

# Sanity bounds on the fitted drift term.  Physical clock drift is ppm-
# scale; anything outside a factor of 2 is a degenerate/noise-dominated
# fit, and a non-positive scale would negate durations and gaps outright.
SCALE_MIN = 0.5
SCALE_MAX = 2.0


@dataclasses.dataclass(frozen=True)
class ClockAlignment:
    """Affine map from one worker's clock to the reference timeline."""

    scale: float = 1.0       # drift correction (reference seconds per local)
    offset: float = 0.0      # seconds
    anchors: int = 0         # matched collective ends the fit used
    residual: float = 0.0    # RMS fit residual, seconds
    fallback: bool = False   # drift fit rejected -> offset-only map

    def apply_time(self, ts: float) -> float:
        return self.scale * ts + self.offset

    @property
    def is_identity(self) -> bool:
        return self.scale == 1.0 and self.offset == 0.0


def collective_end_anchors(traces: Sequence[WorkerTrace]
                           ) -> List[List[float]]:
    """Matched collective end times, one row per anchor, one column per
    worker (rows ordered by worker 0's timeline).  Collectives are matched
    by (name, occurrence); only keys present in *every* trace anchor —
    alignment is best-effort, the importer's graph-level matching raises on
    real inconsistencies."""
    keyed: List[Dict[Tuple[str, int], TraceEvent]] = []
    for tr in traces:
        seen: Dict[str, int] = collections.defaultdict(int)
        d: Dict[Tuple[str, int], TraceEvent] = {}
        # occurrence numbering must scan in the exact order the graph-level
        # matcher will (sorted thread, then per-thread time order), or
        # same-named collectives on different channels could anchor
        # physically different operations onto each other
        for ev in sorted(tr.collectives(), key=lambda e: (e.thread, e.ts)):
            key = (ev.name, seen[ev.name])
            seen[ev.name] += 1
            d[key] = ev
        keyed.append(d)
    if not keyed:
        return []
    common = set(keyed[0])
    for d in keyed[1:]:
        common &= set(d)
    ordered = sorted(common, key=lambda k: keyed[0][k].ts)
    return [[d[k].end for d in keyed] for k in ordered]


def _fit(xs: Sequence[float], ys: Sequence[float]) -> Tuple[float, float]:
    """Least-squares ``y ≈ a*x + b`` (a pinned to 1 when x is degenerate)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    var = sum((x - mx) ** 2 for x in xs)
    if var <= 1e-24:
        return 1.0, my - mx
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    a = cov / var
    return a, my - a * mx


def align_traces(traces: Sequence[WorkerTrace],
                 ) -> List[ClockAlignment]:
    """Per-worker clock alignments onto worker 0's timeline (see module
    docstring).  Does not mutate the traces — pair with
    :func:`apply_alignment`."""
    n = len(traces)
    if n == 0:
        return []
    anchors = collective_end_anchors(traces)
    out = [ClockAlignment(anchors=len(anchors))]     # worker 0 == reference
    for i in range(1, n):
        xs = [row[i] for row in anchors]
        ys = [row[0] for row in anchors]
        if not xs:
            out.append(ClockAlignment(anchors=0))
            continue
        fallback = False
        if len(xs) == 1:
            a, b = 1.0, ys[0] - xs[0]
        else:
            a, b = _fit(xs, ys)
            if not (math.isfinite(a) and SCALE_MIN <= a <= SCALE_MAX):
                # degenerate anchors (noise/mismatch): a wildly-off or
                # non-positive drift would corrupt every duration, so keep
                # the clock rate and fit the offset alone
                a = 1.0
                b = sum(y - x for x, y in zip(xs, ys)) / len(xs)
                fallback = True
        rss = sum((a * x + b - y) ** 2 for x, y in zip(xs, ys))
        out.append(ClockAlignment(scale=a, offset=b, anchors=len(xs),
                                  residual=math.sqrt(rss / len(xs)),
                                  fallback=fallback))
    return out


def apply_alignment(trace: WorkerTrace, alignment: ClockAlignment) -> None:
    """Rescale a trace's events onto the reference timeline, in place."""
    if alignment.is_identity:
        return
    a = alignment.scale
    for ev in trace.events:
        ev.ts = alignment.apply_time(ev.ts)
        ev.dur *= a
        if ev.gap is not None:
            ev.gap *= a
