"""Chrome trace-event JSON: reader and exporters.

The Chrome trace-event format is what the JAX/XLA profiler, TensorBoard's
trace viewer, and most GPU profilers emit, and what Perfetto / ``chrome://
tracing`` open.  This module reads the subset needed to reconstruct a
dependency graph, and writes predictions back out so simulated timelines
open in the same viewers.

Reader contract (:func:`read_chrome`)
-------------------------------------

* The file is either ``{"traceEvents": [...]}`` or a bare event list.
* ``ph == "X"`` complete events become :class:`~repro.traceio.events
  .TraceEvent`\\ s; ``ts``/``dur`` are microseconds (per the spec) and are
  converted to seconds.  Task metadata is taken from ``args`` when present
  (``kind``, ``gap``, ``layer``, ``phase``, ``flops``, ``bytes``,
  ``comm_bytes``, ``collective``, ``group_size``, ``id``) and inferred from
  the event name/thread otherwise.
* ``ph == "M"`` ``thread_name``/``process_name`` metadata names the
  threads; unnamed tids become ``t<tid>`` (prefixed ``p<pid>/`` when the
  file contains several pids).
* ``ph == "C"`` counter events (the tracks our exporters emit — see
  below) are *skipped*: they describe derived series, not tasks, so a
  counter-carrying file imports byte-identically to its counter-free twin.
* Dependencies: flow events (``ph`` in ``s``/``t``/``f``) keyed by
  ``(cat, id)``.  A flow binds to the slice named by ``args.bind`` (our
  export extension: the X event's ``args.id``); foreign traces fall back to
  timestamp binding — ``s`` to the latest slice on its (pid, tid) starting
  at or before ``ts``, ``t``/``f`` to the earliest slice starting at or
  after ``ts``.  Each ``t``/``f`` depends on the closest preceding ``s`` of
  its flow id.  Events sharing ``args.correlation`` (GPU launch/kernel
  correlation ids) are also linked earliest-to-rest.

Exporters
---------

:func:`events_from_graph` turns a simulated graph into events (explicit
cross-thread deps; same-thread order is carried by timestamps), and
:func:`export_graph_trace` / :func:`export_cluster_traces` write Chrome
JSON — the latter writes **one file per worker**, collapsing cross-worker
collective structures (ring legs / hierarchical stages, tagged with
``attrs["coll_gid"]`` at build time) back into one per-worker collective
event spanning first-leg start to last-leg finish, exactly what a real
per-worker profiler would have captured.  Cross-worker edges are dropped —
each file stands alone, which is what makes the export → import round trip
a real test of trace *matching* rather than graph serialization.  What
does survive is *provenance*: collapsed collectives carry their
``coll_gid``, and point-to-point hop legs carry ``args.p2p`` (src/dst
worker) plus the ``p2p_gid`` mirrored in the receiver's ``p2p_in`` — which
is how re-import (:func:`repro.core.cluster.match_wired_p2p`) re-wires
pipeline stage boundaries and :mod:`repro.analysis.diff` matches hops
task-by-task.  :func:`predicted_worker_events` exposes the collapsed
per-worker timelines without writing files.

Both exporters also emit Perfetto **counter tracks** (``counters=True``):
phase-``"C"`` events sampling each worker's :class:`repro.obs.TimelineSet`
at every change point — ``utilization`` (busy-lane fraction, 0..1),
``ready_queue`` (dependency-ready tasks not yet dispatched),
``comm_bytes_in_flight``, and ``memory_bytes`` (live activation+gradient
bytes, present when byte maps are passed).  The reader skips them (above),
so the round-trip invariant is untouched.
"""

from __future__ import annotations

import bisect
import collections
import json
import os
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import _RING_ROUNDS
from repro.core.graph import DependencyGraph
from repro.core.simulate import SimResult, simulate
from repro.core.task import Task, TaskKind, split_worker_thread, _json_safe
from repro.obs.timeline import (TimelineSet, check_result_fresh,
                                compute_timelines)

from .events import TraceEvent, TraceImportError, WorkerTrace

_US = 1e6     # seconds -> Chrome microseconds

_LEG_SUFFIX = re.compile(r":leg\d+$")


# ================================================================== reading
def read_chrome(path: str, worker: int = 0) -> WorkerTrace:
    """Read one worker's Chrome trace-event JSON file (contract above)."""
    with open(path) as f:
        try:
            data = json.load(f)
        except json.JSONDecodeError as e:
            raise TraceImportError(f"{path}: not valid JSON: {e}") from e
    raw = data.get("traceEvents") if isinstance(data, dict) else data
    if not isinstance(raw, list):
        raise TraceImportError(
            f"{path}: expected a traceEvents list, got {type(raw).__name__}")

    thread_names: Dict[Tuple[Any, Any], str] = {}
    xs: List[Tuple[Dict[str, Any], TraceEvent]] = []
    pids = set()
    for ev in raw:
        if not isinstance(ev, dict):
            continue
        ph = ev.get("ph")
        if ph == "M" and ev.get("name") == "thread_name":
            thread_names[(ev.get("pid"), ev.get("tid"))] = \
                str(ev.get("args", {}).get("name", ""))
        elif ph == "X":
            pids.add(ev.get("pid"))

    def thread_of(ev: Dict[str, Any]) -> str:
        key = (ev.get("pid"), ev.get("tid"))
        name = thread_names.get(key) or f"t{ev.get('tid')}"
        if len(pids) > 1:
            name = f"p{ev.get('pid')}/{name}"
        return name

    by_eid: Dict[int, TraceEvent] = {}
    for ev in raw:
        if not isinstance(ev, dict) or ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        gap = args.get("gap")
        te = TraceEvent(
            name=str(ev.get("name", "?")), thread=thread_of(ev),
            ts=float(ev.get("ts", 0.0)) / _US,
            dur=float(ev.get("dur", 0.0)) / _US,
            eid=int(args["id"]) if "id" in args else len(xs),
            kind=args.get("kind"),
            gap=None if gap is None else float(gap),
            layer=args.get("layer"), phase=args.get("phase"),
            flops=float(args.get("flops", 0.0)),
            bytes_accessed=float(args.get("bytes", 0.0)),
            comm_bytes=float(args.get("comm_bytes", 0.0)),
            collective=args.get("collective"),
            group_size=int(args.get("group_size") or 0),
            attrs={k: v for k, v in args.items()
                   if k not in ("id", "kind", "gap", "layer", "phase",
                                "flops", "bytes", "comm_bytes", "collective",
                                "group_size", "correlation") and _json_safe(v)})
        if te.eid in by_eid:
            raise TraceImportError(f"{path}: duplicate event id {te.eid}")
        by_eid[te.eid] = te
        xs.append((ev, te))

    _bind_flows(path, raw, xs, by_eid)
    _link_correlations(xs)
    events = [te for _, te in xs]
    return WorkerTrace(worker=worker, events=events, source=path)


def _bind_flows(path: str, raw: List[Any],
                xs: List[Tuple[Dict[str, Any], TraceEvent]],
                by_eid: Dict[int, TraceEvent]) -> None:
    """Turn flow events into TraceEvent.deps per the reader contract."""
    # per-(pid, tid) slice starts, sorted, for timestamp binding
    slices: Dict[Tuple[Any, Any], List[Tuple[float, TraceEvent]]] = \
        collections.defaultdict(list)
    for ev, te in xs:
        slices[(ev.get("pid"), ev.get("tid"))].append(
            (float(ev.get("ts", 0.0)), te))
    for lst in slices.values():
        lst.sort(key=lambda p: p[0])
    starts = {k: [p[0] for p in v] for k, v in slices.items()}

    def bind(ev: Dict[str, Any]) -> Optional[TraceEvent]:
        args = ev.get("args") or {}
        if "bind" in args:
            te = by_eid.get(int(args["bind"]))
            if te is None:
                raise TraceImportError(
                    f"{path}: flow event binds to unknown event id "
                    f"{args['bind']}")
            return te
        key = (ev.get("pid"), ev.get("tid"))
        if key not in starts:
            return None
        ts = float(ev.get("ts", 0.0))
        if ev.get("ph") == "s":
            idx = bisect.bisect_right(starts[key], ts) - 1
        else:
            idx = bisect.bisect_left(starts[key], ts)
        if 0 <= idx < len(slices[key]):
            return slices[key][idx][1]
        return None

    flows: Dict[Tuple[Any, Any], List[Tuple[float, str, Dict[str, Any]]]] = \
        collections.defaultdict(list)
    for ev in raw:
        if isinstance(ev, dict) and ev.get("ph") in ("s", "t", "f"):
            flows[(ev.get("cat"), ev.get("id"))].append(
                (float(ev.get("ts", 0.0)), ev.get("ph"), ev))
    for group in flows.values():
        group.sort(key=lambda p: (p[0], p[1] != "s"))
        srcs: List[Tuple[float, TraceEvent]] = []
        for ts, ph, ev in group:
            te = bind(ev)
            if te is None:
                continue
            if ph == "s":
                srcs.append((ts, te))
            elif srcs:
                src = max((s for s in srcs if s[0] <= ts),
                          default=srcs[0], key=lambda s: s[0])[1]
                if src.eid != te.eid:
                    te.deps.append(src.eid)


def _link_correlations(xs: List[Tuple[Dict[str, Any], TraceEvent]]) -> None:
    corr: Dict[Any, List[TraceEvent]] = collections.defaultdict(list)
    for ev, te in xs:
        args = ev.get("args") or {}
        cid = args.get("correlation", args.get("correlation_id"))
        if cid is not None:
            corr[cid].append(te)
    for group in corr.values():
        if len(group) < 2:
            continue
        group.sort(key=lambda t: t.ts)
        first = group[0]
        for te in group[1:]:
            if first.eid != te.eid:
                te.deps.append(first.eid)


# ================================================================ exporting
def _event_from_task(t: Task, ts: float, eid: int) -> TraceEvent:
    attrs = {k: v for k, v in t.attrs.items()
             if k not in ("collective", "group_size") and _json_safe(v)}
    return TraceEvent(
        name=t.name, thread=t.thread, ts=ts, dur=t.duration, eid=eid,
        kind=t.kind.value, gap=t.gap, layer=t.layer, phase=t.phase,
        flops=t.flops, bytes_accessed=t.bytes_accessed,
        comm_bytes=t.comm_bytes, collective=t.attrs.get("collective"),
        group_size=int(t.attrs.get("group_size") or 0), attrs=attrs)


def events_from_graph(graph: DependencyGraph,
                      result: Optional[SimResult] = None
                      ) -> List[TraceEvent]:
    """Turn a (simulated) graph into trace events.

    Timestamps come from ``result`` (simulated on the spot when omitted);
    gaps are written explicitly from the tasks, so re-importing never
    infers.  Cross-thread edges become explicit ``deps``; same-thread
    edges are implied by per-thread timestamp order (the stream-order
    contract), which every lane-consistent simulation satisfies.
    """
    result = result or simulate(graph)
    events: List[TraceEvent] = []
    eid_of: Dict[int, int] = {}
    for thread, lane in graph.lanes.items():
        pos = {uid: i for i, uid in enumerate(lane)}
        for uid in sorted(lane, key=lambda u: (result.start[u], pos[u])):
            t = graph.get(uid)
            ev = _event_from_task(t, result.start[uid], len(events))
            eid_of[uid] = ev.eid
            events.append(ev)
    for t in graph.tasks():
        for c in graph.children(t):
            if c.thread != t.thread:
                events[eid_of[c.uid]].deps.append(eid_of[t.uid])
    for ev in events:
        ev.deps = sorted(set(ev.deps))
    return events


def counter_track_events(timelines: TimelineSet, *,
                         worker: Optional[int] = None,
                         pid: int = 0) -> List[Dict[str, Any]]:
    """Phase-``"C"`` Chrome counter events sampling ``timelines``.

    One sample per change point plus a closing sample at the makespan —
    exactly the piecewise-constant series, no resampling.  ``worker``
    selects one worker's tracks under plain names (the per-worker cluster
    export); ``None`` emits every worker, prefixing names with ``w<i>/``
    when the set spans several workers (the single-file export).
    """
    from repro.obs.timeline import Timeline
    workers = timelines.workers if worker is None else [worker]
    prefix_names = worker is None and len(workers) > 1
    flat = Timeline((), (), timelines.makespan)
    out: List[Dict[str, Any]] = []
    for w in workers:
        prefix = f"w{w}/" if prefix_names else ""
        # utilization/ready_queue always (a flat-zero queue is a finding:
        # nothing ever waited); memory only when byte maps sized it, comm
        # only when the worker communicated — absence is meaningful there
        tracks = (("utilization", timelines.utilization.get(w, flat)),
                  ("memory_bytes", timelines.memory.get(w)),
                  ("ready_queue", timelines.queue_depth.get(w, flat)),
                  ("comm_bytes_in_flight", timelines.comm_bytes.get(w)))
        for name, tl in tracks:
            if tl is None or (not len(tl)
                              and name not in ("utilization",
                                               "ready_queue")):
                continue
            for t, v in tl.samples():
                out.append({"ph": "C", "name": prefix + name, "pid": pid,
                            "tid": 0, "ts": t * _US, "args": {"value": v}})
    return out


def chrome_trace_dict(events: Sequence[TraceEvent], *, pid: int = 0,
                      process_name: str = "worker0",
                      counters: Optional[Sequence[Dict[str, Any]]] = None
                      ) -> Dict[str, Any]:
    """Chrome trace-event JSON object for ``events`` (one process).

    ``counters`` are pre-built phase-``"C"`` dicts
    (:func:`counter_track_events`) appended after the slices; the reader
    skips them on re-import.
    """
    tids: Dict[str, int] = {}
    out: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": process_name}}]
    for ev in events:
        if ev.thread not in tids:
            tids[ev.thread] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tids[ev.thread],
                        "args": {"name": ev.thread}})
    for ev in events:
        # free-form attrs first; the reserved metadata keys (the ones
        # read_chrome strips back out of args) must win over any
        # same-named attr, else an attr called "id"/"gap" would corrupt
        # flow binding and gap handling on re-import
        args: Dict[str, Any] = dict(ev.attrs)
        args.update({"id": ev.eid, "kind": ev.kind,
                     "gap": 0.0 if ev.gap is None else ev.gap})
        for key, val in (("layer", ev.layer), ("phase", ev.phase),
                         ("collective", ev.collective)):
            if val:
                args[key] = val
        for key, val in (("flops", ev.flops), ("bytes", ev.bytes_accessed),
                         ("comm_bytes", ev.comm_bytes),
                         ("group_size", ev.group_size)):
            if val:
                args[key] = val
        out.append({"ph": "X", "name": ev.name, "cat": ev.kind or "task",
                    "pid": pid, "tid": tids[ev.thread],
                    "ts": ev.ts * _US, "dur": ev.dur * _US, "args": args})
    fid = 0
    by_eid = {ev.eid: ev for ev in events}
    for ev in events:
        for dep in ev.deps:
            src = by_eid[dep]
            fid += 1
            out.append({"ph": "s", "cat": "dep", "name": "dep", "id": fid,
                        "pid": pid, "tid": tids[src.thread],
                        "ts": src.ts * _US, "args": {"bind": src.eid}})
            out.append({"ph": "f", "cat": "dep", "name": "dep", "id": fid,
                        "bp": "e", "pid": pid, "tid": tids[ev.thread],
                        "ts": ev.ts * _US, "args": {"bind": ev.eid}})
    if counters:
        out.extend(counters)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_graph_trace(graph: DependencyGraph,
                       result: Optional[SimResult] = None,
                       path: Optional[str] = None, *,
                       process_name: str = "worker0",
                       counters: bool = True,
                       activation_bytes: Optional[Dict[str, float]] = None,
                       layer_grad_bytes: Optional[Dict[str, float]] = None
                       ) -> Dict[str, Any]:
    """Export one graph's simulated timeline as Chrome trace JSON.

    Returns the trace dict; writes it to ``path`` when given.  Open the
    file in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
    ``counters=True`` adds utilization/queue/comm counter tracks (plus
    live ``memory_bytes`` when byte maps are passed — the schema in the
    module docstring); the reader skips them, so re-import is unchanged.
    """
    result = result or simulate(graph)
    cevents = None
    if counters:
        cevents = counter_track_events(compute_timelines(
            graph, result, activation_bytes=activation_bytes,
            layer_grad_bytes=layer_grad_bytes))
    trace = chrome_trace_dict(events_from_graph(graph, result),
                              process_name=process_name, counters=cevents)
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


# ------------------------------------------------- cluster per-worker export
def predicted_worker_events(cluster_graph, result
                            ) -> List[List[TraceEvent]]:
    """Per-worker predicted timelines, exactly as the cluster exporter
    writes them.

    ``result`` is a :class:`~repro.core.cluster.ClusterResult` (or its
    global :class:`~repro.core.simulate.SimResult`).  One event list per
    worker: ordinary tasks as-is, wired collective structures collapsed
    back into one per-worker event carrying its ``coll_gid``, p2p hop legs
    with their ``p2p``/``p2p_gid`` provenance, thread names localized.
    This is the *predicted* side of :mod:`repro.analysis.diff` — diffing
    against a captured trace compares like with like, because both sides
    are per-worker profiler-shaped timelines.

    Raises when ``result`` no longer matches the graph's durations (a
    sweep retuned the shared build in place after this result was
    simulated): events would otherwise silently mix one point's
    timestamps with another point's durations.
    """
    res = getattr(result, "global_result", result)
    check_result_fresh(cluster_graph.graph, res)
    partition = cluster_graph._worker_partition()
    return [_collapse_worker(cluster_graph, res, i, partition.get(i, []))[0]
            for i in range(len(cluster_graph.workers))]


def _collective_origin(t: Task) -> Optional[str]:
    """Base collective name of a wired piece (ring leg / hierarchical
    stage), or None for ordinary tasks."""
    if "ring_round" in t.attrs:
        return _LEG_SUFFIX.sub("", t.name)
    stage = t.attrs.get("stage")
    if stage and t.name.endswith(":" + stage):
        return t.name[: -len(stage) - 1]
    return None


def _collapse_worker(cluster_graph, res: SimResult,
                     worker: int, tasks: Sequence[Task]
                     ) -> Tuple[List[TraceEvent], Dict[int, int]]:
    """Worker ``i``'s local events: ordinary tasks as-is, collective pieces
    collapsed back into one event per wired collective (by ``coll_gid``)."""
    n = len(cluster_graph.workers)
    singles: List[Task] = []
    groups: Dict[int, List[Task]] = collections.defaultdict(list)
    for t in tasks:
        if t.thread.endswith("trace/skew"):
            continue          # import artifact; skew is carried by the ts
        gid = t.attrs.get("coll_gid")
        if gid is not None and _collective_origin(t) is not None:
            groups[gid].append(t)
        else:
            singles.append(t)

    drafts: List[Tuple[float, TraceEvent, List[int]]] = []
    unit_of: Dict[int, int] = {}       # task uid -> draft index
    for t in singles:
        ev = _event_from_task(t, res.start[t.uid], -1)
        unit_of[t.uid] = len(drafts)
        drafts.append((ev.ts, ev, [t.uid]))
    for gid in sorted(groups):
        pieces = groups[gid]
        ts = min(res.start[p.uid] for p in pieces)
        end = max(res.finish[p.uid] for p in pieces)
        proto = min(pieces, key=lambda p: res.start[p.uid])
        payload = max(p.comm_bytes for p in pieces)
        if any("ring_round" in p.attrs for p in pieces):
            # legs carry payload/k chunks where k is the *group's* member
            # count — a per-stage DDP ring spans a worker subset, so the
            # cluster-wide count would inflate the payload.  k follows
            # from the leg count: rounds = _RING_ROUNDS[op] * (k - 1).
            mult = _RING_ROUNDS.get(proto.attrs.get("collective"), 1)
            k = len(pieces) // mult + 1
            payload *= k
        else:
            k = int(proto.attrs.get("group_size") or n)
        ev = TraceEvent(
            name=_collective_origin(proto) or proto.name,
            thread=proto.thread, ts=ts, dur=end - ts, eid=-1,
            kind=TaskKind.COLLECTIVE.value, gap=0.0, phase="comm",
            comm_bytes=payload, collective=proto.attrs.get("collective"),
            group_size=k, attrs={"coll_gid": gid})
        idx = len(drafts)
        drafts.append((ts, ev, [p.uid for p in pieces]))
        for p in pieces:
            unit_of[p.uid] = idx

    # order per thread by ts (stable), assign eids, localize thread names
    order = sorted(range(len(drafts)), key=lambda i: (drafts[i][0], i))
    events: List[TraceEvent] = []
    eid_of_unit: Dict[int, int] = {}
    for i in order:
        _, ev, _ = drafts[i]
        ev.eid = len(events)
        ev.thread = split_worker_thread(ev.thread)[1]
        eid_of_unit[i] = ev.eid
        events.append(ev)

    # project global edges onto worker-local event deps (one-step bridge
    # across the zero-duration cluster/sync barriers; cross-worker edges
    # are dropped — each worker's file stands alone)
    g = cluster_graph.graph
    for t in tasks:
        if t.uid not in unit_of:
            continue
        dst = unit_of[t.uid]
        parents: List[Task] = []
        for p in g.parents(t):
            w, _ = split_worker_thread(p.thread)
            if w == worker:
                parents.append(p)
            elif w is None:                       # barrier: bridge one step
                parents.extend(pp for pp in g.parents(p)
                               if split_worker_thread(pp.thread)[0] == worker)
        for p in parents:
            src = unit_of.get(p.uid)
            if src is None or src == dst:
                continue
            if events[eid_of_unit[src]].thread != events[eid_of_unit[dst]].thread:
                events[eid_of_unit[dst]].deps.append(events[eid_of_unit[src]].eid)
    for ev in events:
        ev.deps = sorted(set(ev.deps))
    return events, eid_of_unit


def export_cluster_traces(cluster_graph, result, out_dir: str, *,
                          stem: str = "worker",
                          counters: bool = True,
                          activation_bytes: Optional[Dict[str, float]] = None,
                          layer_grad_bytes: Optional[Dict[str, float]] = None
                          ) -> List[str]:
    """Export a simulated cluster as N per-worker Chrome trace files.

    ``result`` is the :class:`~repro.core.cluster.ClusterResult` of
    ``cluster_graph.simulate()``.  Writes ``<stem><i>.trace.json`` per
    worker into ``out_dir`` and returns the paths.  The files re-import via
    :meth:`ClusterGraph.from_traces` — the round-trip invariant the test
    suite anchors on: a uniform cluster's re-import reproduces the
    predicted makespan.

    ``counters=True`` adds each worker's utilization/queue/comm counter
    tracks (plus live ``memory_bytes`` when byte maps are passed), computed
    once on the global graph and sliced per worker; the reader skips them,
    so the round-trip invariant is untouched.
    """
    os.makedirs(out_dir, exist_ok=True)
    timelines = None
    if counters:
        timelines = compute_timelines(
            cluster_graph.graph, result, activation_bytes=activation_bytes,
            layer_grad_bytes=layer_grad_bytes)
    paths: List[str] = []
    for i, events in enumerate(predicted_worker_events(cluster_graph,
                                                       result)):
        cevents = counter_track_events(timelines, worker=i, pid=i) \
            if timelines is not None else None
        trace = chrome_trace_dict(events, pid=i, process_name=f"worker{i}",
                                  counters=cevents)
        path = os.path.join(out_dir, f"{stem}{i}.trace.json")
        with open(path, "w") as f:
            json.dump(trace, f)
        paths.append(path)
    return paths
