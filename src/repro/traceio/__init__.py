"""Trace I/O: real per-worker profiler traces <-> simulation graphs.

Daydream's premise (§4.1) is that the dependency graph comes from
*low-level traces*; this package supplies that path for the cluster
simulator.  It turns N independently-captured per-worker traces into the
asymmetric global graph :meth:`repro.core.cluster.ClusterGraph
.from_worker_graphs` simulates, and exports predictions back out so they
open in Perfetto.

Pipeline::

    trace_dir/worker*.{jsonl,json}
        │  readers: native JSONL (events.read_jsonl) and Chrome
        │  trace-event JSON (chrome.read_chrome) -> TraceEvent streams
        ▼
    align.align_traces       dPRO-style clock alignment: least-squares
        │                    per-worker offset+drift, anchored on matched
        ▼                    collective end times
    importer.graph_from_events
        │                    tasks + stream-order lanes + flow/correlation
        ▼                    cross-thread edges, host-gap inference
    ClusterGraph.from_traces / Scenario(trace_dir=...)
        │                    matched collectives -> ring / hierarchical /
        ▼                    fused cross-worker structures
    chrome.export_graph_trace / export_cluster_traces
                             predictions -> Chrome JSON (Perfetto);
                             re-importable (round-trip invariant)

Format contract: :mod:`repro.traceio.events` (native JSONL) and
:mod:`repro.traceio.chrome` (Chrome trace-event subset).  Real
``jax.profiler`` / XLA-profiler captures (TensorBoard profile logdirs with
``plugins/profile/<run>/*.trace.json.gz``) are detected by
:func:`load_trace_dir` and imported through :mod:`repro.traceio.xla`
(device/step annotations mapped onto the lane model).  Synthetic trace
sets for tests/benchmarks: :mod:`repro.traceio.synthetic`.

Gap inference modes (``infer_gaps`` on :func:`load_trace_dir` /
:func:`graph_from_events`) — Daydream §4.2.1's *gap* is untraced runtime
between consecutive tasks on one thread:

* ``"host"`` (default): infer missing gaps from inter-event idle time on
  host threads only.  Device/channel idle is dependency *waiting*, which
  the graph already expresses; baking it into gaps would pin what-if
  predictions to the captured timeline.
* ``"all"``: infer on every thread — use when a capture has no
  dependency information at all and the timeline should replay as-is.
* ``"none"``: never infer; only explicitly recorded gaps survive.

Clock alignment guards: degenerate anchor sets fall back to offset-only
fits (:data:`repro.traceio.align.SCALE_MIN` / ``SCALE_MAX`` bounds on the
drift term), and multi-worker sets that cannot be anchored at all warn by
default — pass ``align="strict"`` to :func:`load_trace_dir` to make both
conditions raise instead.

Counter-track schema (``counters=True`` on the exporters, default): each
worker's :class:`repro.obs.TimelineSet` is emitted as phase-``"C"``
Chrome counter events — ``{"ph": "C", "name": <track>, "pid": <worker>,
"tid": 0, "ts": <µs>, "args": {"value": <v>}}``, one sample per change
point plus a closing sample at the makespan.  Tracks per worker:
``utilization`` (busy-lane fraction, 0..1), ``ready_queue``
(dependency-ready tasks awaiting dispatch; both always emitted),
``memory_bytes`` (live activation+gradient bytes — present when the
Scenario byte maps are passed through) and ``comm_bytes_in_flight``
(present when the worker communicates).  Single-file exports of
multi-worker graphs prefix track names with ``w<i>/``.  Every reader in
this package (``read_chrome``, ``read_xla_trace``) skips ``"C"`` events,
so counter-carrying files import byte-identically to counter-free ones
and the round-trip invariant is untouched.

Self-instrumentation: the import pipeline itself emits JSONL spans
(``traceio.load_trace_dir`` and downstream ``cluster.from_worker_graphs``)
when ``REPRO_TELEMETRY=<path>`` is set or a launch CLI passes
``--telemetry PATH`` — see :mod:`repro.obs.spans`.

User surface: ``Scenario(trace_dir=...)`` runs any registered optimization
stack on imported traces; ``python -m repro.launch.perf_report --trace-dir
DIR [--what-if STACK] [--export-trace OUT]`` is the CLI form, and
``python -m repro.launch.calibrate --trace-dir DIR`` fits the CostModel to
the capture (:mod:`repro.analysis.calibrate`).
"""

from .events import (TraceEvent, TraceImportError, WorkerTrace, classify,
                     infer_collective, read_jsonl, write_jsonl)
from .chrome import (chrome_trace_dict, counter_track_events,
                     events_from_graph, export_cluster_traces,
                     export_graph_trace, predicted_worker_events,
                     read_chrome)
from .align import (ClockAlignment, align_traces, apply_alignment,
                    collective_end_anchors)
from .importer import (ImportedCluster, find_worker_files, graph_from_events,
                       load_trace_dir, load_worker_trace)
from .synthetic import synthetic_cluster_traces, write_synthetic_trace_dir
from .xla import find_xla_trace_files, load_xla_profile, read_xla_trace

__all__ = [
    "TraceEvent", "TraceImportError", "WorkerTrace",
    "classify", "infer_collective", "read_jsonl", "write_jsonl",
    "chrome_trace_dict", "counter_track_events", "events_from_graph",
    "export_cluster_traces", "export_graph_trace",
    "predicted_worker_events", "read_chrome",
    "ClockAlignment", "align_traces", "apply_alignment",
    "collective_end_anchors",
    "ImportedCluster", "find_worker_files", "graph_from_events",
    "load_trace_dir", "load_worker_trace",
    "synthetic_cluster_traces", "write_synthetic_trace_dir",
    "find_xla_trace_files", "load_xla_profile", "read_xla_trace",
]
