"""Trace import: per-worker event streams -> simulation-ready graphs.

This is Daydream Phase 1 (§4.1) for *captured* traces: every event becomes
a :class:`~repro.core.task.Task`, dependencies are reconstructed from

1. **stream order** — events on one thread execute in timestamp order, so
   each per-thread lane is chained in program order (the graph's lane
   edges), and
2. **explicit deps** — flow/correlation ids (Chrome) or ``deps`` lists
   (native JSONL) become cross-thread edges,

and Daydream's *gap* (§4.2.1, untraced runtime between consecutive tasks
on one thread) is inferred from idle time on host threads when the trace
does not record it explicitly.

:func:`load_trace_dir` is the directory-level entry point: one trace file
per worker (see :mod:`repro.traceio.events` for ordering and formats),
clock-aligned (:mod:`repro.traceio.align`) and turned into one
:class:`~repro.core.graph.DependencyGraph` per worker plus the per-worker
start skews.  Feed the result to
:meth:`repro.core.cluster.ClusterGraph.from_traces` /
``Scenario(trace_dir=...)``.
"""

from __future__ import annotations

import dataclasses
import glob
import os
import re
import warnings
from typing import Dict, List, Optional, Sequence, Union

from repro.core.graph import DependencyGraph, GraphError
from repro.core.task import HOST_THREAD
from repro.obs.spans import span as _obs_span

from .align import ClockAlignment, align_traces, apply_alignment
from .chrome import read_chrome
from .events import TraceEvent, TraceImportError, WorkerTrace, read_jsonl

_NUM = re.compile(r"(\d+)")


@dataclasses.dataclass
class ImportedCluster:
    """A loaded trace set: aligned events, per-worker graphs, start skews."""

    graphs: List[DependencyGraph]
    traces: List[WorkerTrace]
    alignments: List[ClockAlignment]
    start_skews: List[float]

    @property
    def num_workers(self) -> int:
        return len(self.graphs)

    def first_ts(self) -> float:
        """Earliest (aligned) timestamp across all workers — the capture's
        time origin."""
        return min((tr.first_ts() for tr in self.traces), default=0.0)

    def worker_events(self, *, rebase: bool = True
                      ) -> List[List["TraceEvent"]]:
        """Per-worker aligned event streams; with ``rebase`` (default) all
        timestamps shift so the earliest event across workers sits at t=0 —
        the same origin a simulated timeline uses, which is what
        :mod:`repro.analysis.diff` compares against.  Events are copies;
        the stored traces are never mutated."""
        t0 = self.first_ts() if rebase else 0.0
        return [[dataclasses.replace(ev, ts=ev.ts - t0,
                                     deps=list(ev.deps),
                                     attrs=dict(ev.attrs))
                 for ev in tr.events] for tr in self.traces]


def graph_from_events(trace: WorkerTrace, *,
                      infer_gaps: str = "host") -> DependencyGraph:
    """Reconstruct one worker's dependency graph from its events.

    ``infer_gaps``: ``"host"`` (default) infers missing gaps from
    inter-event idle time on host threads only — device/channel idle is
    dependency waiting, which the graph already expresses, and baking it
    into gaps would pin what-if predictions to the captured timeline;
    ``"all"`` infers on every thread; ``"none"`` never infers.
    """
    if infer_gaps not in ("host", "all", "none"):
        raise ValueError(f"infer_gaps must be host|all|none, "
                         f"got {infer_gaps!r}")
    g = DependencyGraph()
    lanes: Dict[str, List[TraceEvent]] = {}
    for ev in trace.events:
        lanes.setdefault(ev.thread, []).append(ev)
    task_of: Dict[int, object] = {}
    for thread, evs in lanes.items():
        evs.sort(key=lambda e: e.ts)          # stable: ties keep file order
        infer = infer_gaps == "all" or (
            infer_gaps == "host"
            and thread.rsplit("/", 1)[-1] == HOST_THREAD)
        for i, ev in enumerate(evs):
            t = ev.to_task()
            if ev.gap is None and infer and i + 1 < len(evs):
                t.gap = max(0.0, evs[i + 1].ts - ev.end)
            if ev.eid in task_of:
                raise TraceImportError(
                    f"{trace.source}: duplicate event id {ev.eid}")
            task_of[ev.eid] = g.add_task(t)   # lane-linked program order
    for ev in trace.events:
        dst = task_of[ev.eid]
        for dep in ev.deps:
            src = task_of.get(dep)
            if src is None:
                raise TraceImportError(
                    f"{trace.source}: event {ev.eid} ({ev.name!r}) depends "
                    f"on unknown event id {dep}")
            if src is not dst:
                g.add_edge(src, dst)
    try:
        g.validate()
    except GraphError as e:
        raise TraceImportError(
            f"{trace.source}: imported events do not form a DAG ({e}); "
            f"check flow/deps ids against the stream order") from e
    return g


def find_worker_files(trace_dir: str) -> List[str]:
    """Per-worker trace files in ``trace_dir``, in worker order.

    Accepts ``*.jsonl`` (native) and ``*.json`` (Chrome trace-event) files;
    order is by the first integer in the file name, then lexicographic —
    ``worker0.jsonl``, ``worker1.jsonl``, ... as written by the exporters.
    """
    paths = sorted(glob.glob(os.path.join(trace_dir, "*.jsonl"))
                   + glob.glob(os.path.join(trace_dir, "*.json")))
    def order(p: str):
        m = _NUM.search(os.path.basename(p))
        return (int(m.group(1)) if m else float("inf"),
                os.path.basename(p))
    return sorted(paths, key=order)


def load_worker_trace(path: str, worker: int = 0) -> WorkerTrace:
    """Read one worker trace file, dispatching on the extension."""
    if path.endswith(".jsonl"):
        return read_jsonl(path, worker)
    if path.endswith(".json"):
        return read_chrome(path, worker)
    raise TraceImportError(
        f"{path}: unknown trace format (expected .jsonl or .json)")


def _check_alignment_quality(alignments: Sequence[ClockAlignment],
                             strict: bool, source: str) -> None:
    """Flag multi-worker alignments that could not actually align.

    ``anchors == 0`` means a worker shares no matched collective with the
    set and kept its own clock verbatim (identity map); ``fallback`` means
    the drift fit was degenerate and only the offset was corrected.  Either
    way the diff/calibration downstream compares against possibly-skewed
    clocks, so warn by default and raise under ``align="strict"``.
    """
    unanchored = [i for i, al in enumerate(alignments) if al.anchors == 0]
    fallbacks = [i for i, al in enumerate(alignments) if al.fallback]
    if not unanchored and not fallbacks:
        return
    parts = []
    if unanchored:
        parts.append(f"worker(s) {unanchored} share no matched collectives "
                     f"with the set (identity clock map)")
    if fallbacks:
        parts.append(f"worker(s) {fallbacks} had a degenerate drift fit "
                     f"(offset-only fallback)")
    msg = (f"{source}: clock alignment is unreliable — " + "; ".join(parts)
           + "; timestamps may be cross-worker skewed")
    if strict:
        raise TraceImportError(msg)
    warnings.warn(msg, stacklevel=3)


def load_trace_dir(trace_dir: str, *,
                   align: Union[bool, str] = True,
                   infer_gaps: str = "host") -> ImportedCluster:
    """Load a per-worker trace directory into an :class:`ImportedCluster`.

    Reads every worker file, clock-aligns the traces (``align=True``; see
    :mod:`repro.traceio.align`), reconstructs one graph per worker, and
    computes each worker's *start skew* — how much later than the earliest
    worker it began its step on the aligned timeline.  The skews become
    zero-duration gate tasks in
    :meth:`~repro.core.cluster.ClusterGraph.from_worker_graphs`, so a
    worker that genuinely started late stays late in the simulation.

    ``align`` is ``True`` (align, warn when a multi-worker set cannot be
    anchored), ``False`` (keep local clocks), or ``"strict"`` (align, raise
    :class:`TraceImportError` when any worker has no anchors or needed the
    offset-only fallback).

    XLA profiler captures (``jax.profiler`` log directories holding
    ``plugins/profile/<run>/*.trace.json.gz``) are detected and routed
    through :func:`repro.traceio.xla.load_xla_profile`.
    """
    if align not in (True, False, "strict"):
        raise ValueError(f"align must be True, False or 'strict', "
                         f"got {align!r}")
    if not os.path.isdir(trace_dir):
        raise TraceImportError(f"trace dir {trace_dir!r} does not exist")
    from .xla import find_xla_trace_files, load_xla_profile
    with _obs_span("traceio.load_trace_dir", dir=trace_dir) as sp:
        if find_xla_trace_files(trace_dir):
            sp.note(format="xla")
            return load_xla_profile(trace_dir, infer_gaps=infer_gaps)
        files = find_worker_files(trace_dir)
        if not files:
            raise TraceImportError(
                f"trace dir {trace_dir!r} has no *.jsonl / *.json worker "
                f"files")
        traces = [load_worker_trace(f, i) for i, f in enumerate(files)]
        if align and len(traces) > 1:
            alignments = align_traces(traces)
            _check_alignment_quality(alignments, align == "strict",
                                     trace_dir)
            for tr, al in zip(traces, alignments):
                apply_alignment(tr, al)
        else:
            alignments = [ClockAlignment() for _ in traces]
        firsts = [tr.first_ts() for tr in traces]
        t0 = min(firsts, default=0.0)
        start_skews = [max(0.0, f - t0) for f in firsts]
        graphs = [graph_from_events(tr, infer_gaps=infer_gaps)
                  for tr in traces]
        sp.note(format="native", workers=len(graphs),
                events=sum(len(tr.events) for tr in traces))
        return ImportedCluster(graphs=graphs, traces=traces,
                               alignments=alignments,
                               start_skews=start_skews)
