"""Synthetic per-worker traces of a data-parallel training step.

Shared by the traceio tests, ``benchmarks/bench_traceio.py``, and
``examples/trace_import.py``: generates what a per-worker profiler *would*
capture from an N-worker DDP step — per-layer forward/backward/update
compute on the device stream, one gradient all-reduce per layer on a
communication channel, host dispatch/sync — with three kinds of controlled
imperfection:

* ``compute_scales``: per-worker compute slowdowns (stragglers).  The
  collective *end* times are computed globally (a synchronous all-reduce
  finishes when the slowest participant is done), so each worker's
  collective events include their real blocking time — exactly how a
  profiler sees a straggler from a fast worker's side.
* ``clock_offsets`` / ``clock_drifts``: each worker's events are stamped
  through its own skewed clock (``ts_local = ts_true * drift + offset``),
  which the alignment pass must undo.
* explicit ``gap = 0`` everywhere, so imports never infer gaps and the
  generated step is exactly reproducible.
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence

from repro.core.costmodel import CostModel
from repro.core.task import DEVICE_STREAM, HOST_THREAD, ici_channel

from .events import TraceEvent, WorkerTrace, write_jsonl

GRAD_CHANNEL = ici_channel("grad")


def synthetic_cluster_traces(n_workers: int = 4, *, layers: int = 6,
                             fwd: float = 2e-3, bwd: float = 4e-3,
                             upd: float = 1e-3, dispatch: float = 20e-6,
                             grad_bytes: float = 30e6,
                             compute_scales: Optional[Sequence[float]] = None,
                             clock_offsets: Optional[Sequence[float]] = None,
                             clock_drifts: Optional[Sequence[float]] = None,
                             cost: Optional[CostModel] = None
                             ) -> List[WorkerTrace]:
    """Generate N per-worker traces of one DDP training step (see module
    docstring).  Event counts are ``4 * layers + 2`` per worker."""
    scales = list(compute_scales or [1.0] * n_workers)
    offsets = list(clock_offsets or [0.0] * n_workers)
    drifts = list(clock_drifts or [1.0] * n_workers)
    if not (len(scales) == len(offsets) == len(drifts) == n_workers):
        raise ValueError("per-worker parameter lists must have n_workers "
                         "entries")
    cost = cost or CostModel()
    coll_dur = cost.collectives.group_time("all-reduce", grad_bytes,
                                           n_workers) if n_workers > 1 \
        else 0.0

    # -- true-time schedule per worker, collectives synchronized globally --
    evs: List[List[TraceEvent]] = [[] for _ in range(n_workers)]
    eid = [0] * n_workers

    def emit(w: int, **kw) -> TraceEvent:
        ev = TraceEvent(eid=eid[w], gap=0.0, **kw)
        eid[w] += 1
        evs[w].append(ev)
        return ev

    dev_cursor = [0.0] * n_workers
    disp = [emit(w, name="host:dispatch", thread=HOST_THREAD, ts=0.0,
                 dur=dispatch, kind="host") for w in range(n_workers)]
    for w in range(n_workers):
        dev_cursor[w] = dispatch
    for l in range(layers):
        for w in range(n_workers):
            e = emit(w, name=f"fwd:l{l}", thread=DEVICE_STREAM,
                     ts=dev_cursor[w], dur=fwd * scales[w], kind="compute",
                     layer=f"l{l}", phase="fwd",
                     deps=[disp[w].eid] if l == 0 else [])
            dev_cursor[w] += e.dur
    bwd_end = [[0.0] * layers for _ in range(n_workers)]
    bwd_eid = [[0] * layers for _ in range(n_workers)]
    for l in reversed(range(layers)):
        for w in range(n_workers):
            e = emit(w, name=f"bwd:l{l}", thread=DEVICE_STREAM,
                     ts=dev_cursor[w], dur=bwd * scales[w], kind="compute",
                     layer=f"l{l}", phase="bwd")
            dev_cursor[w] += e.dur
            bwd_end[w][l] = e.end
            bwd_eid[w][l] = e.eid
    # per-layer all-reduce in backward-completion order; everyone blocks
    # until the slowest participant's gradients are ready
    comm_cursor = [0.0] * n_workers
    coll_end = [0.0] * layers
    coll_eid = [[0] * layers for _ in range(n_workers)]
    for l in reversed(range(layers)):
        ready = [max(bwd_end[w][l], comm_cursor[w])
                 for w in range(n_workers)]
        end = max(ready) + coll_dur
        coll_end[l] = end
        for w in range(n_workers):
            e = emit(w, name=f"allreduce:l{l}", thread=GRAD_CHANNEL,
                     ts=ready[w], dur=end - ready[w], kind="collective",
                     layer=f"l{l}", phase="comm", comm_bytes=grad_bytes,
                     collective="all-reduce", group_size=n_workers,
                     deps=[bwd_eid[w][l]])
            comm_cursor[w] = end
            coll_eid[w][l] = e.eid
    for l in range(layers):
        for w in range(n_workers):
            ts = max(dev_cursor[w], coll_end[l] if n_workers > 1
                     else dev_cursor[w])
            e = emit(w, name=f"upd:l{l}", thread=DEVICE_STREAM, ts=ts,
                     dur=upd * scales[w], kind="compute", layer=f"l{l}",
                     phase="update", deps=[coll_eid[w][l]]
                     if n_workers > 1 else [])
            dev_cursor[w] = e.end
    for w in range(n_workers):
        emit(w, name="host:sync", thread=HOST_THREAD, ts=dev_cursor[w],
             dur=1e-6, kind="sync", deps=[evs[w][-1].eid])

    # -- stamp through each worker's skewed local clock --
    for w in range(n_workers):
        d, o = drifts[w], offsets[w]
        if d == 1.0 and o == 0.0:
            continue
        for ev in evs[w]:
            ev.ts = ev.ts * d + o
            ev.dur *= d
    return [WorkerTrace(worker=w, events=evs[w], source=f"<synthetic:{w}>")
            for w in range(n_workers)]


def write_synthetic_trace_dir(trace_dir: str, n_workers: int = 4,
                              **kwargs) -> List[str]:
    """Write a synthetic trace set as native JSONL worker files; returns
    the file paths (``worker<i>.jsonl``)."""
    os.makedirs(trace_dir, exist_ok=True)
    paths = []
    for tr in synthetic_cluster_traces(n_workers, **kwargs):
        path = os.path.join(trace_dir, f"worker{tr.worker}.jsonl")
        write_jsonl(tr.events, path, meta={"worker": tr.worker})
        paths.append(path)
    return paths
