"""Pipeline parallelism: GPipe schedule over a mesh axis + its Daydream model.

Two pieces:

* :func:`gpipe_spmd` — a real SPMD GPipe wavefront, written for
  ``shard_map`` over a ``stage`` mesh axis (the multi-pod layout's ``pod``
  axis is the natural stage axis: cross-pod links are the slowest, and PP
  crosses them once per microbatch instead of every layer).  Stage s runs
  microbatch m at wavefront step t = s + m; activations hop stages with
  ``ppermute``.

* :func:`pipeline_graph` — the same schedule as a Daydream dependency graph
  (one lane per stage, cross-stage edges), so the simulator predicts the
  bubble fraction before anyone commits to a stage split.  The classic
  closed form for balanced stages — makespan = (M + S - 1) * t_stage — is
  asserted against the simulator in tests/test_pipeline.py, a nice
  independent validation of paper Algorithm 1 on a known schedule.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core.graph import DependencyGraph
from repro.core.task import Task, TaskKind


# ------------------------------------------------------------- SPMD GPipe
def gpipe_spmd(stage_fn: Callable[[jax.Array], jax.Array],
               x_microbatches: jax.Array, *, n_microbatches: int,
               axis_name: str = "stage") -> jax.Array:
    """Run a GPipe wavefront inside ``shard_map`` over ``axis_name``.

    ``stage_fn`` is this device's stage (parameters closed over, already
    stage-sharded).  ``x_microbatches``: (M, mb, ...) — read by stage 0;
    other stages receive activations via ppermute.  Returns (M, mb, ...)
    outputs as produced by the LAST stage (valid on every device for
    simplicity; callers slice).
    """
    S = jax.lax.psum(1, axis_name)
    sid = jax.lax.axis_index(axis_name)
    M = n_microbatches
    mb_shape = x_microbatches.shape[1:]
    perm = [(i, i + 1) for i in range(S - 1)]

    def step(carry, t):
        buf_in, outputs = carry
        m = t - sid                                # this stage's microbatch
        active = (m >= 0) & (m < M)
        fresh = x_microbatches[jnp.clip(t, 0, M - 1)]
        inp = jnp.where(sid == 0, fresh, buf_in)
        out = stage_fn(inp)
        out = jnp.where(active, out, jnp.zeros_like(out))
        # the last stage emits a finished microbatch at row m
        is_last = sid == S - 1
        row = jnp.clip(m, 0, M - 1)
        emitted = jnp.where(active & is_last, out, outputs[row])
        outputs = jax.lax.dynamic_update_slice_in_dim(
            outputs, emitted[None], row, axis=0)
        nxt = jax.lax.ppermute(out, axis_name, perm)
        return (nxt, outputs), None

    buf0 = jnp.zeros(mb_shape, x_microbatches.dtype)
    out0 = jnp.zeros((M,) + mb_shape, x_microbatches.dtype)
    # scan carries diverge per stage: mark them varying over the mesh axis
    if hasattr(jax.lax, "pvary"):
        buf0 = jax.lax.pvary(buf0, (axis_name,))
        out0 = jax.lax.pvary(out0, (axis_name,))
    (_, outputs), _ = jax.lax.scan(step, (buf0, out0),
                                   jnp.arange(M + S - 1))
    # broadcast the last stage's outputs to every device so callers can
    # read them uniformly (psum of one-hot contribution)
    contrib = jnp.where(sid == S - 1, outputs, jnp.zeros_like(outputs))
    return jax.lax.psum(contrib, axis_name)


# --------------------------------------------------------- Daydream model
def pipeline_graph(stage_times_s: Sequence[float], n_microbatches: int,
                   hop_time_s: float = 0.0) -> DependencyGraph:
    """GPipe schedule as a Daydream graph: lanes = stages, edges = deps.

    Task (s, m) depends on (s-1, m) [activation arrival] and its own lane's
    program order handles (s, m-1).  ``hop_time_s`` models the ppermute as
    the producing task's trailing gap.
    """
    g = DependencyGraph()
    tasks: Dict[tuple, Task] = {}
    for m in range(n_microbatches):
        for s, dt in enumerate(stage_times_s):
            t = Task(name=f"stage{s}/mb{m}", kind=TaskKind.COMPUTE,
                     thread=f"stage{s}", duration=float(dt),
                     gap=float(hop_time_s), layer=f"stage{s}", phase="fwd")
            g.add_task(t)
            tasks[(s, m)] = t
            if s > 0:
                g.add_edge(tasks[(s - 1, m)], t)
    return g


def gpipe_bubble_fraction(stage_times_s: Sequence[float],
                          n_microbatches: int) -> float:
    """Analytic GPipe bubble for balanced stages: (S-1) / (M + S - 1)."""
    S = len(stage_times_s)
    M = n_microbatches
    return (S - 1) / (M + S - 1)
