"""Pipeline parallelism: GPipe schedule over a mesh axis + its Daydream model.

Two pieces:

* :func:`gpipe_spmd` — a real SPMD GPipe wavefront, written for
  ``shard_map`` over a ``stage`` mesh axis (the multi-pod layout's ``pod``
  axis is the natural stage axis: cross-pod links are the slowest, and PP
  crosses them once per microbatch instead of every layer).  Stage s runs
  microbatch m at wavefront step t = s + m; activations hop stages with
  ``ppermute``.

* :func:`pipeline_graph` — the same schedule as a Daydream dependency graph
  (one lane per stage, cross-stage edges), so the simulator predicts the
  bubble fraction before anyone commits to a stage split.  The classic
  closed form for balanced stages — makespan = (M + S - 1) * t_stage — is
  asserted against the simulator in tests/test_pipeline.py, a nice
  independent validation of paper Algorithm 1 on a known schedule.
  Rebuilt (PR 4) on :mod:`repro.parallel.plan`'s scheduling core: fwd+bwd
  schedules (GPipe / 1F1B) and real COMM hop tasks.  For placement onto
  actual workers — per-stage WorkerSpecs, DCN-aware retunable hops, hybrid
  PP x DP — use :class:`repro.parallel.plan.ParallelPlan`; the registered
  ``pipeline`` optimization (:mod:`repro.core.optimize`) is the what-if
  surface.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.graph import DependencyGraph
from repro.core.task import Task, TaskKind


# ------------------------------------------------------------- SPMD GPipe
def gpipe_spmd(stage_fn: Callable[[Any], Any],
               x_microbatches: Any, *, n_microbatches: int,
               axis_name: str = "stage") -> Any:
    """Run a GPipe wavefront inside ``shard_map`` over ``axis_name``.

    ``stage_fn`` is this device's stage (parameters closed over, already
    stage-sharded).  ``x_microbatches``: (M, mb, ...) — read by stage 0;
    other stages receive activations via ppermute.  Returns (M, mb, ...)
    outputs as produced by the LAST stage (valid on every device for
    simplicity; callers slice).
    """
    import jax
    import jax.numpy as jnp
    S = jax.lax.psum(1, axis_name)
    sid = jax.lax.axis_index(axis_name)
    M = n_microbatches
    mb_shape = x_microbatches.shape[1:]
    perm = [(i, i + 1) for i in range(S - 1)]

    def step(carry, t):
        buf_in, outputs = carry
        m = t - sid                                # this stage's microbatch
        active = (m >= 0) & (m < M)
        fresh = x_microbatches[jnp.clip(t, 0, M - 1)]
        inp = jnp.where(sid == 0, fresh, buf_in)
        out = stage_fn(inp)
        out = jnp.where(active, out, jnp.zeros_like(out))
        # the last stage emits a finished microbatch at row m
        is_last = sid == S - 1
        row = jnp.clip(m, 0, M - 1)
        emitted = jnp.where(active & is_last, out, outputs[row])
        outputs = jax.lax.dynamic_update_slice_in_dim(
            outputs, emitted[None], row, axis=0)
        nxt = jax.lax.ppermute(out, axis_name, perm)
        return (nxt, outputs), None

    buf0 = jnp.zeros(mb_shape, x_microbatches.dtype)
    out0 = jnp.zeros((M,) + mb_shape, x_microbatches.dtype)
    # scan carries diverge per stage: mark them varying over the mesh axis
    if hasattr(jax.lax, "pvary"):
        buf0 = jax.lax.pvary(buf0, (axis_name,))
        out0 = jax.lax.pvary(out0, (axis_name,))
    (_, outputs), _ = jax.lax.scan(step, (buf0, out0),
                                   jnp.arange(M + S - 1))
    # broadcast the last stage's outputs to every device so callers can
    # read them uniformly (psum of one-hot contribution)
    contrib = jnp.where(sid == S - 1, outputs, jnp.zeros_like(outputs))
    return jax.lax.psum(contrib, axis_name)


# --------------------------------------------------------- Daydream model
def pipeline_graph(stage_times_s: Sequence[float], n_microbatches: int,
                   hop_time_s: float = 0.0, *,
                   bwd_stage_times_s: Optional[Sequence[float]] = None,
                   schedule: str = "gpipe",
                   hop_bytes: float = 0.0) -> DependencyGraph:
    """Pipeline schedule as a Daydream graph: lanes = stages, edges = deps.

    Rebuilt on the plan layer's scheduling core
    (:func:`repro.parallel.plan.schedule_order`): task (s, m) depends on
    (s-1, m) [activation arrival] and its own lane's program order encodes
    the microbatch schedule.  The ppermute hop is a real
    :data:`~repro.core.task.TaskKind.COMM` task on a per-link channel
    carrying ``hop_bytes`` — visible to bandwidth/overlap what-ifs, unlike
    the old model that buried it in the producing task's trailing gap.

    The legacy fwd-only analytic form is the default; pass
    ``bwd_stage_times_s`` for the full fwd+bwd step under ``schedule``
    ("gpipe" | "1f1b").  For cluster placement (per-stage WorkerSpecs,
    retunable hops, hybrid PP x DP) use
    :class:`repro.parallel.plan.ParallelPlan` instead — this graph is the
    single-timeline analytic view.
    """
    from .plan import schedule_order
    S = len(stage_times_s)
    M = n_microbatches
    bwd = list(bwd_stage_times_s) if bwd_stage_times_s is not None else None
    g = DependencyGraph()
    fwd_tasks: Dict[tuple, Task] = {}
    bwd_tasks: Dict[tuple, Task] = {}

    def hop(src: Task, s_from: int, s_to: int, m: int) -> Task:
        h = Task(name=f"hop:s{s_from}>s{s_to}/mb{m}", kind=TaskKind.COMM,
                 thread=f"link:s{s_from}>s{s_to}", duration=float(hop_time_s),
                 comm_bytes=float(hop_bytes), phase="comm",
                 attrs={"p2p_role": "act" if s_to > s_from else "grad",
                        "microbatch": m})
        g.add_task(h)
        g.add_edge(src, h)
        return h

    for s in range(S):
        order = schedule_order(S, s, M, schedule) if bwd is not None \
            else [("F", m) for m in range(M)]
        for op, m in order:
            if op == "F":
                t = Task(name=f"stage{s}/mb{m}", kind=TaskKind.COMPUTE,
                         thread=f"stage{s}", duration=float(stage_times_s[s]),
                         layer=f"stage{s}", phase="fwd")
                g.add_task(t)
                fwd_tasks[(s, m)] = t
            else:
                t = Task(name=f"stage{s}/bwd/mb{m}", kind=TaskKind.COMPUTE,
                         thread=f"stage{s}", duration=float(bwd[s]),
                         layer=f"stage{s}", phase="bwd")
                g.add_task(t)
                g.add_edge(fwd_tasks[(s, m)], t)
                bwd_tasks[(s, m)] = t
    for s in range(S):
        for m in range(M):
            if s > 0:
                src = fwd_tasks[(s - 1, m)]
                dst = fwd_tasks[(s, m)]
                if hop_time_s > 0 or hop_bytes > 0:
                    g.add_edge(hop(src, s - 1, s, m), dst)
                else:
                    g.add_edge(src, dst)
            if bwd is not None and s < S - 1:
                src = bwd_tasks[(s + 1, m)]
                dst = bwd_tasks[(s, m)]
                if hop_time_s > 0 or hop_bytes > 0:
                    g.add_edge(hop(src, s + 1, s, m), dst)
                else:
                    g.add_edge(src, dst)
    return g


def gpipe_bubble_fraction(stage_times_s: Sequence[float],
                          n_microbatches: int) -> float:
    """Analytic GPipe bubble for balanced stages: (S-1) / (M + S - 1)."""
    S = len(stage_times_s)
    M = n_microbatches
    return (S - 1) / (M + S - 1)
