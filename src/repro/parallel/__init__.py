from .pipeline import gpipe_spmd, pipeline_graph, gpipe_bubble_fraction

__all__ = ["gpipe_spmd", "pipeline_graph", "gpipe_bubble_fraction"]
