from .pipeline import gpipe_spmd, pipeline_graph, gpipe_bubble_fraction
from .plan import (ParallelPlan, StageProfile, partition_stages,
                   schedule_order, SCHEDULES)

__all__ = ["gpipe_spmd", "pipeline_graph", "gpipe_bubble_fraction",
           "ParallelPlan", "StageProfile", "partition_stages",
           "schedule_order", "SCHEDULES"]
