"""Parallelism plans: pipeline/hybrid parallelism through the real simulator.

Daydream's claim is that one dependency graph plus graph-transformation
primitives models "a wide variety of optimizations" — and a *parallelism
plan* is just another graph construction.  This module closes the gap the
old analytic pipeline toy left open: instead of a fwd-only closed-form
schedule, a :class:`ParallelPlan` places real per-stage subgraphs onto
:class:`~repro.core.cluster.WorkerSpec` workers and wires them with the
cluster simulator's comm primitives, so pipeline questions route through
the same machinery as every other what-if (heterogeneous pods, skewed
links, retunable sweeps, per-worker breakdowns).

Three pieces:

* :func:`partition_stages` — split a profiled single-worker graph by layer
  into S contiguous stage profiles, balanced by per-layer device time
  (fwd+bwd), with activation/gradient payloads drawn from the scenario's
  layer byte maps.
* :func:`schedule_order` — the per-stage microbatch op order for GPipe
  (all forwards, then all backwards) and 1F1B (warmup forwards, steady
  one-forward-one-backward, cooldown backwards).  The order *is* the
  schedule: each stage's device lane chains its ops in program order, and
  the simulator does the rest.
* :meth:`ParallelPlan.place` — build the global
  :class:`~repro.core.cluster.ClusterGraph`: one worker per (stage,
  replica), cross-stage activation/gradient hops as point-to-point COMM
  legs (:meth:`~repro.core.cluster.ClusterGraph.wire_p2p` — duration from
  the placed link's bandwidth, pods -> DCN, retunable), and, when
  ``dp > 1``, a per-stage gradient all-reduce wired over just that stage's
  replicas (:meth:`~repro.core.cluster.ClusterGraph.wire_collective_group`)
  — hybrid PP x DP.

The classic closed forms fall out of the simulation instead of being baked
in: balanced-stage GPipe makespan is ``(M + S - 1) * t_stage`` (asserted
to float precision in tests/test_plan.py), and the bubble fraction is
``(S - 1) / (M + S - 1)``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.cluster import ClusterGraph, WorkerSpec, _as_specs
from repro.core.costmodel import CostModel
from repro.core.graph import DependencyGraph, GraphError
from repro.core.simulate import ScheduleFn
from repro.core.task import (Task, TaskKind, DEVICE_STREAM, ici_channel)

SCHEDULES = ("gpipe", "1f1b")

# Worker-local channel resources for the cross-stage hops: activations flow
# stage s -> s+1, gradients s -> s-1, on independent (bidirectional-link)
# channels, so consecutive microbatch hops serialize per direction exactly
# like ring legs on an ICI link.
ACT_CHANNEL = ici_channel("pp:act")
GRAD_CHANNEL = ici_channel("pp:grad")
# Per-stage data-parallel gradient ring (hybrid PP x DP).
DP_CHANNEL = ici_channel("dp:grad")


@dataclasses.dataclass(frozen=True)
class StageProfile:
    """One pipeline stage's aggregate profile (per minibatch).

    ``fwd_s``/``bwd_s``/``update_s`` are the stage's summed device times by
    phase; flops/bytes aggregates let roofline-style what-ifs (AMP) classify
    the stage's microbatch tasks.  ``act_bytes`` is the activation payload
    *leaving* this stage (the byte-map entry of its last layer);
    ``grad_bytes`` is the stage's parameter-gradient payload (the per-stage
    DDP ring's traffic).
    """

    index: int
    layers: Tuple[str, ...]
    fwd_s: float
    bwd_s: float
    update_s: float = 0.0
    fwd_flops: float = 0.0
    fwd_bytes: float = 0.0
    bwd_flops: float = 0.0
    bwd_bytes: float = 0.0
    update_flops: float = 0.0
    update_bytes: float = 0.0
    act_bytes: float = 0.0
    grad_bytes: float = 0.0


def partition_stages(graph: DependencyGraph, num_stages: int, *,
                     activation_bytes: Optional[Dict[str, float]] = None,
                     layer_grad_bytes: Optional[Dict[str, float]] = None
                     ) -> List[StageProfile]:
    """Split a profiled single-worker graph into S contiguous stage profiles.

    Layers are taken in device-lane forward order (first appearance) and
    assigned greedily so cumulative per-layer weight (fwd + bwd device
    time) tracks the balanced target — the standard contiguous-partition
    heuristic.  Only layer-mapped device-lane compute/memory tasks are
    counted: collectives are dropped (the plan wires its own communication)
    and host/data lanes stay behind — the plan models the *device program*,
    so on a host-dispatch-bound profile the predicted pipeline makespan
    omits that bottleneck (compare against a DP baseline, not wall clock).
    Raises when the profile maps fewer layers than stages.
    """
    if num_stages < 1:
        raise GraphError(f"pipeline needs >= 1 stage, got {num_stages}")
    acts = activation_bytes or {}
    grads = layer_grad_bytes or {}
    order: List[str] = []
    agg: Dict[str, Dict[str, float]] = {}
    for t in graph.lane_tasks(DEVICE_STREAM):
        if t.layer is None or t.kind in (TaskKind.COLLECTIVE, TaskKind.COMM):
            continue
        if t.layer not in agg:
            order.append(t.layer)
            agg[t.layer] = {"fwd_s": 0.0, "bwd_s": 0.0, "update_s": 0.0,
                            "fwd_flops": 0.0, "fwd_bytes": 0.0,
                            "bwd_flops": 0.0, "bwd_bytes": 0.0,
                            "update_flops": 0.0, "update_bytes": 0.0}
        a = agg[t.layer]
        phase = t.phase if t.phase in ("bwd", "update") else "fwd"
        a[f"{phase}_s"] += t.duration
        a[f"{phase}_flops"] += t.flops
        a[f"{phase}_bytes"] += t.bytes_accessed
    if not order:
        raise GraphError(
            "cannot partition: the profile has no layer-mapped device "
            "tasks (see repro.core.layermap)")
    if len(order) < num_stages:
        raise GraphError(
            f"cannot split {len(order)} mapped layer(s) into {num_stages} "
            f"pipeline stages")
    weight = {l: agg[l]["fwd_s"] + agg[l]["bwd_s"] for l in order}
    total = sum(weight.values())
    target = total / num_stages
    stages: List[List[str]] = [[]]
    cum = 0.0
    remaining = len(order)
    for l in order:
        s = len(stages) - 1
        # close the stage once it reaches its balanced share, as long as
        # every remaining stage can still get >= 1 layer
        if (stages[-1] and cum >= target * len(stages)
                and len(stages) < num_stages
                and remaining >= num_stages - s):
            stages.append([])
        stages[-1].append(l)
        cum += weight[l]
        remaining -= 1
    while len(stages) < num_stages:      # degenerate weights: pad from tail
        for i in range(len(stages) - 1, -1, -1):
            if len(stages[i]) > 1:
                stages.insert(i + 1, [stages[i].pop()])
                break
    profiles = []
    for s, layers in enumerate(stages):
        tot = {k: sum(agg[l][k] for l in layers) for k in agg[layers[0]]}
        profiles.append(StageProfile(
            index=s, layers=tuple(layers),
            act_bytes=acts.get(layers[-1], 0.0),
            grad_bytes=sum(grads.get(l, 0.0) for l in layers), **tot))
    return profiles


def schedule_order(num_stages: int, stage: int, microbatches: int,
                   schedule: str = "gpipe") -> List[Tuple[str, int]]:
    """Per-stage op order: ``[("F"|"B", microbatch), ...]``.

    ``"gpipe"`` runs every forward then every backward; ``"1f1b"``
    (PipeDream-flush / Megatron) runs ``min(S - 1 - stage, M)`` warmup
    forwards, then alternates one forward / one backward, then drains the
    remaining backwards.  Same work, same bubble on balanced stages —
    1F1B's win is activation memory — but the simulated orders differ and
    unbalanced stages separate them.
    """
    S, M = num_stages, microbatches
    if schedule == "gpipe":
        return [("F", m) for m in range(M)] + [("B", m) for m in range(M)]
    if schedule == "1f1b":
        warmup = min(max(S - 1 - stage, 0), M)
        order = [("F", m) for m in range(warmup)]
        f, b = warmup, 0
        while b < M:
            if f < M:
                order.append(("F", f))
                f += 1
            order.append(("B", b))
            b += 1
        return order
    raise GraphError(
        f"unknown pipeline schedule {schedule!r}; expected one of "
        f"{SCHEDULES}")


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """A placement of S pipeline stages x ``dp`` data-parallel replicas.

    Worker ``stage * dp + replica`` runs stage ``stage``'s microbatch
    schedule; :meth:`place` builds the global cluster graph.  The plan is
    frozen so sweeps can cache partitions and rebuild only the O(S * M)
    schedule graph per point.
    """

    profiles: Tuple[StageProfile, ...]
    microbatches: int
    schedule: str = "gpipe"
    dp: int = 1

    def __post_init__(self) -> None:
        if not self.profiles:
            raise GraphError("ParallelPlan needs >= 1 stage profile")
        if self.microbatches < 1:
            raise GraphError(
                f"pipeline needs >= 1 microbatch, got {self.microbatches}")
        if self.dp < 1:
            raise GraphError(f"pipeline needs dp >= 1, got {self.dp}")
        if self.schedule not in SCHEDULES:
            raise GraphError(
                f"unknown pipeline schedule {self.schedule!r}; expected "
                f"one of {SCHEDULES}")

    @classmethod
    def from_profile(cls, graph: DependencyGraph, stages: int,
                     microbatches: int, *, schedule: str = "gpipe",
                     dp: int = 1,
                     activation_bytes: Optional[Dict[str, float]] = None,
                     layer_grad_bytes: Optional[Dict[str, float]] = None
                     ) -> "ParallelPlan":
        """Partition ``graph`` into ``stages`` and wrap it in a plan."""
        return cls(tuple(partition_stages(
            graph, stages, activation_bytes=activation_bytes,
            layer_grad_bytes=layer_grad_bytes)), microbatches, schedule, dp)

    # ------------------------------------------------------------- layout
    @property
    def num_stages(self) -> int:
        return len(self.profiles)

    @property
    def num_workers(self) -> int:
        return len(self.profiles) * self.dp

    def worker_index(self, stage: int, replica: int) -> int:
        return stage * self.dp + replica

    # ---------------------------------------------------------- templates
    def stage_templates(self, cost: Optional[CostModel] = None
                        ) -> List[DependencyGraph]:
        """One single-worker graph per stage, lane-ordered by the schedule.

        Each template is an ordinary :class:`DependencyGraph`: device-lane
        F/B microbatch tasks in :func:`schedule_order`, per-microbatch COMM
        hop tasks on the act/grad channels (payloads from the stage
        profile; durations are filled in at placement from the real link),
        the weight update, and — when ``dp > 1`` — the stage's gradient
        all-reduce.  Because templates are plain graphs, registered
        optimizations apply to them unchanged (``pipeline | amp | dgc``)
        before :meth:`place` wires them across workers.
        """
        cost = cost or CostModel()
        S, M = self.num_stages, self.microbatches
        out: List[DependencyGraph] = []
        for p in self.profiles:
            s = p.index
            g = DependencyGraph()
            fwd: Dict[int, Task] = {}
            for op, m in schedule_order(S, s, M, self.schedule):
                if op == "F":
                    t = g.add_task(Task(
                        name=f"stage{s}:fwd:mb{m}", kind=TaskKind.COMPUTE,
                        thread=DEVICE_STREAM, duration=p.fwd_s / M,
                        layer=f"stage{s}", phase="fwd", flops=p.fwd_flops / M,
                        bytes_accessed=p.fwd_bytes / M,
                        attrs={"stage": s, "microbatch": m}))
                    fwd[m] = t
                    if s < S - 1:
                        send = g.add_task(Task(
                            name=f"stage{s}:act:mb{m}", kind=TaskKind.COMM,
                            thread=ACT_CHANNEL, duration=0.0,
                            comm_bytes=p.act_bytes / M, phase="comm",
                            attrs={"p2p_role": "act", "stage": s,
                                   "microbatch": m}))
                        g.add_edge(fwd[m], send)
                else:
                    b = g.add_task(Task(
                        name=f"stage{s}:bwd:mb{m}", kind=TaskKind.COMPUTE,
                        thread=DEVICE_STREAM, duration=p.bwd_s / M,
                        layer=f"stage{s}", phase="bwd", flops=p.bwd_flops / M,
                        bytes_accessed=p.bwd_bytes / M,
                        attrs={"stage": s, "microbatch": m}))
                    g.add_edge(fwd[m], b)        # stashed-activation dep
                    if s > 0:
                        send = g.add_task(Task(
                            name=f"stage{s}:grad:mb{m}", kind=TaskKind.COMM,
                            thread=GRAD_CHANNEL, duration=0.0,
                            comm_bytes=self.profiles[s - 1].act_bytes / M,
                            phase="comm",
                            attrs={"p2p_role": "grad", "stage": s,
                                   "microbatch": m}))
                        g.add_edge(b, send)
            last_bwd = g.lane_tasks(DEVICE_STREAM)[-1]
            upd = g.add_task(Task(
                name=f"stage{s}:update", kind=TaskKind.COMPUTE,
                thread=DEVICE_STREAM, duration=p.update_s,
                layer=f"stage{s}", phase="update", flops=p.update_flops,
                bytes_accessed=p.update_bytes, attrs={"stage": s}))
            if self.dp > 1:
                ar = g.add_task(Task(
                    name=f"stage{s}:allreduce", kind=TaskKind.COLLECTIVE,
                    thread=DP_CHANNEL,
                    duration=cost.collectives.group_time(
                        "all-reduce", p.grad_bytes, self.dp),
                    comm_bytes=p.grad_bytes, phase="comm",
                    attrs={"collective": "all-reduce",
                           "group_size": self.dp, "stage": s}))
                g.add_edge(last_bwd, ar)
                g.add_edge(ar, upd)
            out.append(g)
        return out

    # ------------------------------------------------------------ placing
    def place(self, workers: Optional[Union[int, Sequence[WorkerSpec]]]
              = None, *, cost: Optional[CostModel] = None,
              collective_mode: str = "ring",
              sched_fn: Optional[ScheduleFn] = None,
              templates: Optional[Sequence[DependencyGraph]] = None
              ) -> ClusterGraph:
        """Place the plan onto workers and return the global cluster graph.

        ``workers`` must provide one :class:`WorkerSpec` per (stage,
        replica) slot — ``stages * dp`` total (default: uniform).  Stage
        boundaries become provenance-carrying point-to-point COMM legs
        (DCN when the placed link crosses pods), per-stage gradient
        all-reduces become scoped cross-worker structures in
        ``collective_mode``, and the whole build retunes like any other
        :class:`ClusterGraph` — bandwidth/straggler sweeps reuse it.
        Pass ``templates`` (e.g. transformed by a what-if stack) to place
        pre-built stage graphs; they must match this plan's layout.
        """
        specs = [WorkerSpec() for _ in range(self.num_workers)] \
            if workers is None else _as_specs(workers)
        if len(specs) != self.num_workers:
            raise GraphError(
                f"plan places {self.num_stages} stage(s) x {self.dp} "
                f"replica(s) = {self.num_workers} worker(s), got "
                f"{len(specs)} WorkerSpec(s)")
        if collective_mode not in ("ring", "hierarchical", "fused"):
            raise GraphError(f"unknown collective_mode {collective_mode!r}")
        cost = cost or CostModel()
        S, M, dp = self.num_stages, self.microbatches, self.dp
        tmpls = list(templates) if templates is not None \
            else self.stage_templates(cost)
        if len(tmpls) != S:
            raise GraphError(
                f"plan has {S} stage(s) but {len(tmpls)} template(s)")
        cg = ClusterGraph(DependencyGraph(), specs, cost, sched_fn,
                          collective_mode)
        remaps = [cg._clone_worker(w, specs[w], tmpls[w // dp],
                                   comm_prov=False)
                  for w in range(self.num_workers)]
        # index each template's schedule tasks by role/microbatch
        fwds: List[Dict[int, Task]] = []
        bwds: List[Dict[int, Task]] = []
        acts: List[Dict[int, Task]] = []
        grads: List[Dict[int, Task]] = []
        ars: List[Optional[Task]] = []
        for g in tmpls:
            f: Dict[int, Task] = {}
            b: Dict[int, Task] = {}
            a: Dict[int, Task] = {}
            gr: Dict[int, Task] = {}
            ar: Optional[Task] = None
            for t in g.tasks():
                m = t.attrs.get("microbatch")
                if t.kind == TaskKind.COMM and t.attrs.get("p2p_role"):
                    (a if t.attrs["p2p_role"] == "act" else gr)[m] = t
                elif t.kind == TaskKind.COLLECTIVE \
                        and t.attrs.get("collective") \
                        and "stage" in t.attrs:
                    # the template's own gradient ring ("stage" attr), not a
                    # collective a post-placement what-if stack inserted
                    ar = t
                elif t.phase == "fwd" and m is not None:
                    f[m] = t
                elif t.phase == "bwd" and m is not None:
                    b[m] = t
            fwds.append(f)
            bwds.append(b)
            acts.append(a)
            grads.append(gr)
            ars.append(ar)
        for s in range(S):
            missing = [m for m in range(M)
                       if m not in fwds[s] or m not in bwds[s]]
            if missing or (s < S - 1 and len(acts[s]) != M) \
                    or (s > 0 and len(grads[s]) != M):
                raise GraphError(
                    f"stage {s} template does not cover all {M} "
                    f"microbatch(es) of this plan")
        for r in range(dp):
            for s in range(S - 1):
                src_w = self.worker_index(s, r)
                dst_w = self.worker_index(s + 1, r)
                for m in range(M):
                    cg.wire_p2p(None, remaps[dst_w][fwds[s + 1][m].uid],
                                src_w, dst_w,
                                leg=remaps[src_w][acts[s][m].uid])
            for s in range(1, S):
                src_w = self.worker_index(s, r)
                dst_w = self.worker_index(s - 1, r)
                for m in range(M):
                    cg.wire_p2p(None, remaps[dst_w][bwds[s - 1][m].uid],
                                src_w, dst_w,
                                leg=remaps[src_w][grads[s][m].uid])
        if dp > 1:
            for s in range(S):
                if ars[s] is None:
                    raise GraphError(
                        f"stage {s} template lost its gradient all-reduce; "
                        f"dp={dp} placement cannot wire the stage ring")
                ids = [self.worker_index(s, r) for r in range(dp)]
                cg.wire_collective_group(
                    "all-reduce", [remaps[w][ars[s].uid] for w in ids],
                    worker_ids=ids)
        return cg._finish()

    def fold_place(self, workers: Optional[Union[int, Sequence[WorkerSpec]]]
                   = None, *, cost: Optional[CostModel] = None,
                   collective_mode: str = "ring",
                   sched_fn: Optional[ScheduleFn] = None,
                   templates: Optional[Sequence[DependencyGraph]] = None):
        """Symmetry-folded :meth:`place`: one representative per stage.

        When every replica of a stage shares an identical
        :class:`WorkerSpec`, the ``dp`` data-parallel replicas are
        equivalence classes — folding materializes ``stages`` workers
        instead of ``stages * dp`` and closes the gradient rings
        algebraically over the class size.  Returns ``None`` whenever the
        exactness contract does not hold (``dp < 2``, hierarchical mode,
        non-uniform stage replicas); callers fall back to :meth:`place`.
        """
        from repro.core.fold import fold_plan
        return fold_plan(self, workers, cost=cost,
                         collective_mode=collective_mode,
                         sched_fn=sched_fn, templates=templates)
