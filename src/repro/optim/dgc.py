"""Deep Gradient Compression (Lin et al., paper §5.2 / Algorithm 12).

Top-k gradient sparsification with local error feedback: each step transmits
only the largest-magnitude ``ratio`` fraction of gradient entries; the residual
accumulates locally and is added back next step.  The Daydream what-if
(``core/whatif.py::what_if_dgc``) predicts its efficacy; this module is the
runnable implementation the prediction can be validated against, and the
Pallas ``dgc_topk`` kernel is its TPU-tiled selection stage.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DGCState:
    residual: Any      # error-feedback accumulator (same tree as grads)


def dgc_init(grads_like) -> DGCState:
    return DGCState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def dgc_compress(g: jax.Array, ratio: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """Dense top-|k| selection on one leaf: returns (values, int32 indices).

    k = max(1, round(ratio * size)).  Ties resolve arbitrarily (jax.lax.top_k).
    """
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(round(ratio * flat.size)))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def dgc_decompress(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for d in shape:
        size *= d
    out = jnp.zeros((size,), jnp.float32).at[idx].set(values)
    return out.reshape(shape)


def dgc_step(grads, state: DGCState, ratio: float = 0.01
             ) -> Tuple[Any, DGCState]:
    """One DGC round on a gradient tree: returns (sparse-equivalent dense
    gradients as transmitted, new state with residuals)."""
    def leaf(g, r):
        acc = g.astype(jnp.float32) + r
        vals, idx = dgc_compress(acc, ratio)
        sent = dgc_decompress(vals, idx, acc.shape)
        return sent.astype(g.dtype), acc - sent

    out = jax.tree.map(leaf, grads, state.residual)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, DGCState(residual=resid)
