"""Learning-rate schedules (pure functions of the step count)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup_steps, 1)
        prog = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn
