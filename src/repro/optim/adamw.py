"""AdamW with f32 moments over (possibly bf16) params — pure pytree functions.

Spec-mode aware: ``init`` over a SpecLeaf tree yields SpecLeaf moments with the
same logical sharding, so the dry-run can lower ``train_step`` with the full
(params, opt_state) structure and zero allocation.

Two update paths:
  * ``apply``       — standard per-leaf tree_map update (XLA fuses decently).
  * ``apply_fused`` — flattens every leaf into one contiguous vector and runs a
    single fused update (the FusedAdam of paper §6.3; the Pallas kernel in
    ``repro/kernels/fused_adam.py`` is the TPU-tiled version of this op).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models.paramdecl import SpecLeaf

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros((), jnp.float32)


def _f32_like(tree):
    def leaf(l):
        if isinstance(l, SpecLeaf):
            return SpecLeaf(l.shape, jnp.dtype(jnp.float32), l.logical)
        return jnp.zeros(l.shape, jnp.float32)
    return jax.tree.map(leaf, tree, is_leaf=lambda x: isinstance(x, SpecLeaf))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Schedule = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    fused: bool = False

    # ------------------------------------------------------------------ init
    def init(self, params) -> Dict[str, Any]:
        leaves = jax.tree.leaves(params,
                                 is_leaf=lambda x: isinstance(x, SpecLeaf))
        spec_mode = leaves and isinstance(leaves[0], SpecLeaf)
        scalar = (lambda: SpecLeaf((), jnp.dtype(jnp.float32), ())) if \
            spec_mode else (lambda: jnp.zeros((), jnp.float32))
        count = (SpecLeaf((), jnp.dtype(jnp.int32), ()) if spec_mode
                 else jnp.zeros((), jnp.int32))
        return {"m": _f32_like(params), "v": _f32_like(params),
                "count": count, "gnorm": scalar()}

    # ---------------------------------------------------------------- update
    def _lr(self, count: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(count), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)

    def apply(self, grads, state, params) -> Tuple[Any, Dict[str, Any]]:
        if self.fused:
            return self.apply_fused(grads, state, params)
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.where(
            gnorm > self.grad_clip, self.grad_clip / jnp.maximum(gnorm, 1e-12),
            1.0) if self.grad_clip else jnp.ones((), jnp.float32)
        lr = self._lr(count)
        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            step = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return newp, m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        # unzip the (p, m, v) leaf tuples
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "count": count, "gnorm": gnorm}

    def apply_fused(self, grads, state, params) -> Tuple[Any, Dict[str, Any]]:
        """Single fused update over one flattened vector (FusedAdam)."""
        count = state["count"] + 1
        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = jax.tree.leaves(grads)
        leaves_m = jax.tree.leaves(state["m"])
        leaves_v = jax.tree.leaves(state["v"])
        sizes = [l.size for l in leaves_p]
        shapes = [l.shape for l in leaves_p]
        dtypes = [l.dtype for l in leaves_p]
        flat = lambda ls, dt: jnp.concatenate(
            [l.reshape(-1).astype(dt) for l in ls])
        p = flat(leaves_p, jnp.float32)
        g = flat(leaves_g, jnp.float32)
        m = flat(leaves_m, jnp.float32)
        v = flat(leaves_v, jnp.float32)
        gnorm = jnp.sqrt(jnp.sum(jnp.square(g)))
        scale = jnp.where(gnorm > self.grad_clip,
                          self.grad_clip / jnp.maximum(gnorm, 1e-12), 1.0) \
            if self.grad_clip else jnp.ones((), jnp.float32)
        g = g * scale
        lr = self._lr(count)
        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)
        from repro.kernels import ops as kops
        p, m, v = kops.fused_adam(p, g, m, v, lr=lr, b1=self.b1, b2=self.b2,
                                  eps=self.eps, wd=self.weight_decay,
                                  c1=c1, c2=c2)
        outs, ms, vs = [], [], []
        off = 0
        for size, shp, dt in zip(sizes, shapes, dtypes):
            outs.append(p[off:off + size].reshape(shp).astype(dt))
            ms.append(m[off:off + size].reshape(shp))
            vs.append(v[off:off + size].reshape(shp))
            off += size
        newp = jax.tree.unflatten(treedef, outs)
        newm = jax.tree.unflatten(treedef, ms)
        newv = jax.tree.unflatten(treedef, vs)
        return newp, {"m": newm, "v": newv, "count": count, "gnorm": gnorm}

    @staticmethod
    def last_grad_norm(state) -> jax.Array:
        return state["gnorm"]


def adamw(lr: Schedule = 3e-4, **kw) -> AdamW:
    return AdamW(lr=lr, **kw)
