from .adamw import AdamW, adamw, global_norm
from .schedules import constant, warmup_cosine
from .dgc import dgc_compress, dgc_decompress, DGCState, dgc_init, dgc_step

__all__ = ["AdamW", "adamw", "global_norm", "constant", "warmup_cosine",
           "dgc_compress", "dgc_decompress", "DGCState", "dgc_init",
           "dgc_step"]
