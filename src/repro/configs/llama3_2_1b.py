"""llama3.2-1b — Llama-3.2 1B dense (tied embeddings).

[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256.
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=500000.0,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16)
