"""mamba2-2.7b — Mamba-2 SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  64L d_model=2560 ssm_state=128 vocab=50280.
d_inner = 2*d_model = 5120 -> 80 SSD heads of dim 64.  Sub-quadratic: runs
the ``long_500k`` decode cell (O(1)-per-token recurrent state).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,            # d_inner / 64 (accounting only; SSD derives it)
    n_kv_heads=80,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    sub_quadratic=True,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, vocab=512, ssm_state=16)
