"""Per-model ServingCostModel defaults for the architecture registry.

Every assigned arch gets an analytic :class:`repro.serving.ServingCostModel`
derived from its exact :class:`~repro.models.model.ModelConfig` shape
(:meth:`ServingCostModel.from_model_config`); archs that have been run
through the :mod:`repro.serving.measure` timing harness additionally carry
fitted constants in :data:`SERVING_COSTS` — the mapping the harness's
``with_constants({...})`` reuse line pastes into.

:func:`serving_cost` is the one-stop lookup the serving CLIs use; it
accepts CLI-style underscore names (``llama3_405b``) as well as the
registry's canonical dashed ids (``llama3-405b``).
"""

from __future__ import annotations

from typing import Dict

from .registry import ARCHS, _module

# arch -> fitted {prefill_scale, decode_scale, step_overhead} from
# `python -m repro.serving.measure --arch <id> --smoke`.  Measured on the
# CPU smoke configs against TPU-v5e rooflines, hence the large scales —
# re-run the harness on real hardware to re-seed; archs absent here use
# the pure analytic model.
SERVING_COSTS: Dict[str, Dict[str, float]] = {
    "tinyllama-1.1b": {"prefill_scale": 3667.11, "decode_scale": 676.663,
                       "step_overhead": 2e-05},
}


def normalize_arch(name: str) -> str:
    """Map a CLI-style name (``llama3_405b``, ``llama3.2-1b``…) to the
    registry's canonical arch id, via the same dash/dot folding the
    config-module loader uses."""
    if name in ARCHS:
        return name
    folded = _module(name)
    for arch in ARCHS:
        if _module(arch) == folded:
            return arch
    raise KeyError(f"unknown architecture {name!r}; known: {ARCHS}")


def serving_cost(name: str, hw=None, *, smoke: bool = False,
                 fitted: bool = True):
    """The arch's :class:`repro.serving.ServingCostModel`: analytic shape
    math plus (``fitted=True``) any harness-measured constants.

    ``smoke=True`` prices the reduced smoke config instead (what the
    measure harness actually ran on CPU).
    """
    from repro.core.task import TPU_V5E
    from repro.serving.costs import ServingCostModel
    from .registry import get_config, get_smoke_config
    arch = normalize_arch(name)
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = ServingCostModel.from_model_config(cfg, hw or TPU_V5E)
    consts = SERVING_COSTS.get(arch) if fitted else None
    if consts:
        model = model.with_constants(consts)
    return model
