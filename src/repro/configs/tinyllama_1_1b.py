"""tinyllama-1.1b — TinyLlama (llama2-architecture small).

[arXiv:2401.02385; hf]  22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Also the backbone of the end-to-end training example (reduced).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    rope_theta=10000.0,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512)
