"""llama3-405b — Llama-3.1 405B dense.

[arXiv:2407.21783; unverified]  126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256.

Capacity note (DESIGN.md): training this on a single 256-chip v5e pod is
over-capacity (params+optimizer ~4 TB); the dry-run reports the honest
bytes/device and the multi-pod (512-chip) run halves them.
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=500000.0,
    layout="dp",        # §Perf iter: beats 16-way TP on every roofline term
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512)
