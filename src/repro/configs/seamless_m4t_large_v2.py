"""seamless-m4t-large-v2 — SeamlessM4T v2 large (enc-dec, multimodal).

[arXiv:2308.11596; hf]  24L (24 enc + 24 dec) d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206.  The speech frontend (w2v-BERT feature extractor) is
a STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings at d_model.  Classic post-LN transformer FFN (non-gated ReLU).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    gated_mlp=False,
    activation="relu",
    norm="layernorm",
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, enc_layers=2, dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512)
