"""internvl2-1b — InternVL2-1B backbone (InternLM2-style GQA decoder).

[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The InternViT frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (n_patches=256).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    n_patches=256,
    rope_theta=1000000.0,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=112, vocab=512,
    n_patches=4)
