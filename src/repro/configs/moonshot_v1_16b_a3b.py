"""moonshot-v1-16b-a3b — Moonlight-16B-A3B (DeepSeek-V3-style MoE).

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64 experts top-6 (+2 shared).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    d_ff_expert=1408,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    vocab=163840,
    rope_theta=50000.0,
    activation="silu",
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff_expert=32,
    n_experts=8, top_k=2, n_shared_experts=1, vocab=512)
