"""deepseek-v2-236b — DeepSeek-V2 (MLA + fine-grained MoE).

[arXiv:2405.04434; hf]  60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536,
qk_nope=128, qk_rope=64, v=128), d_ff=1536 per routed expert, vocab=102400,
160 routed experts top-6 + 2 shared.
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="mla_moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=0,
    d_ff_expert=1536,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    vocab=102400,
    q_lora=1536,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head_dim=128,
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff_expert=32,
    n_experts=8, top_k=2, n_shared_experts=1, vocab=512,
    q_lora=48, kv_lora=32, qk_nope=16, qk_rope=8, v_head_dim=16)
