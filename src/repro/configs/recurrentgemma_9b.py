"""recurrentgemma-9b — Griffin (RG-LRU + local attention, 2:1 pattern).

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, window=2048, lru width 4096.  38 layers = 12 x (rec,rec,attn)
groups + 2 tail recurrent layers.  Sub-quadratic: runs ``long_500k``
(bounded window cache + O(1) recurrent state).
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    window=2048,
    d_rnn=4096,
    activation="gelu",
    rope_theta=10000.0,
    sub_quadratic=True,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab=512, window=8, d_rnn=64)
