"""Architecture registry: the ten assigned (architecture x shape) pools.

Every assigned arch has ``src/repro/configs/<id>.py`` exporting ``CONFIG``
(exact numbers from the public pool) and ``SMOKE`` (reduced same-family config
for CPU smoke tests).  The dry-run iterates ``cells()``.

Shape semantics (assignment block):
  train_4k     seq 4,096   global_batch 256   lowers ``train_step``
  prefill_32k  seq 32,768  global_batch 32    lowers ``prefill_step``
  decode_32k   seq 32,768  global_batch 128   lowers ``serve_step`` (1 new tok)
  long_500k    seq 524,288 global_batch 1     serve_step; sub-quadratic archs
               only (mamba2, recurrentgemma) — full-attention archs SKIP
               (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.model import ModelConfig

ARCHS = [
    "moonshot-v1-16b-a3b",
    "deepseek-v2-236b",
    "internvl2-1b",
    "tinyllama-1.1b",
    "llama3-405b",
    "llama3.2-1b",
    "command-r-35b",
    "mamba2-2.7b",
    "recurrentgemma-9b",
    "seamless-m4t-large-v2",
]


def _module(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def list_archs() -> List[str]:
    return list(ARCHS)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module(arch)}")
    return mod.SMOKE


def runnable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for a cell, per the assignment rules."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention): 500k dense-KV decode inapplicable"
    if spec.kind == "decode" and not cfg.decode_supported:
        return False, "SKIP(no-decoder)"
    return True, ""


def cells(include_skipped: bool = False) -> List[Tuple[str, str]]:
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, _ = runnable(a, s)
            if ok or include_skipped:
                out.append((a, s))
    return out
