from .registry import (get_config, get_smoke_config, list_archs, SHAPES,
                       ShapeSpec, cells, runnable)
from .serving import SERVING_COSTS, normalize_arch, serving_cost

__all__ = ["get_config", "get_smoke_config", "list_archs", "SHAPES",
           "ShapeSpec", "cells", "runnable",
           "SERVING_COSTS", "normalize_arch", "serving_cost"]
