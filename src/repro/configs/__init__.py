from .registry import (get_config, get_smoke_config, list_archs, SHAPES,
                       ShapeSpec, cells, runnable)

__all__ = ["get_config", "get_smoke_config", "list_archs", "SHAPES",
           "ShapeSpec", "cells", "runnable"]
