"""command-r-35b — Cohere Command-R (GQA, no-bias, 256k vocab).

[hf:CohereForAI/c4ai-command-r-v01; unverified]  40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000.  LayerNorm (no bias via attn_bias=False),
non-gated-style large vocab — the vocab-sharded embedding stress case.
"""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    attn_bias=False,
    rope_theta=8000000.0,
    layout="dp",        # §Perf: no-TP DP+FSDP (small/linear arch)
    serve_fsdp=False,   # weights fit replicated-over-data at serve time
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_ff=128, vocab=512)
