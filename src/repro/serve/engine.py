"""Batched serving engine: prefill + decode over a shared KV cache.

A deliberately small but real engine: fixed-size batch slots, greedy decode,
per-request max-token budgets, and cache reuse across the decode loop (the
decode step is the same jitted ``serve_step`` the dry-run lowers at the
decode_32k / long_500k cells).  Requests shorter than the batch's prompt
length are left-padded; finished slots keep decoding into a scratch token
(classic static-batch serving) until the whole batch drains.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import (ModelConfig, build_model, make_prefill_step,
                                make_serve_step)


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    tokens: List[int]              # generated continuation (greedy)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int = 256) -> None:
        if cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "engine demo drives token-only families; vlm/encdec prefill "
                "requires frontend embeddings via model.prefill directly")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.model = build_model(cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(make_serve_step(cfg))

    def generate(self, requests: List[Request]) -> List[Result]:
        B = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt     # left-pad
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        # grow cache to max_seq for attention families (prefill cache is plen)
        cache = self._grow_cache(cache, plen)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        budget = max(r.max_new_tokens for r in requests)
        out = [nxt]
        pos = plen
        for _ in range(min(budget - 1, self.max_seq - plen - 1)):
            nxt, cache = self._decode(self.params, cache, nxt,
                                      jnp.asarray(pos, jnp.int32))
            out.append(nxt)
            pos += 1
        gen = np.concatenate([np.asarray(t) for t in out], axis=1)
        return [Result(tokens=list(gen[i, :requests[i].max_new_tokens]))
                for i in range(B)]

    def _grow_cache(self, cache, plen: int):
        """Pad seq-dim KV caches from prefill length to max_seq."""
        target = self.max_seq

        def grow(x):
            if x.ndim == 4 and x.shape[1] == plen and plen < target and \
                    not self.cfg.window:
                pad = [(0, 0)] * x.ndim
                pad[1] = (0, target - plen)
                return jnp.pad(x, pad)
            if x.ndim == 3 and x.shape[1] == plen and plen < target and \
                    self.cfg.family == "mla_moe":
                pad = [(0, 0)] * x.ndim
                pad[1] = (0, target - plen)
                return jnp.pad(x, pad)
            return x
        return jax.tree.map(grow, cache)
