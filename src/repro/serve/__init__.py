from .engine import ServeEngine, Request, Result

__all__ = ["ServeEngine", "Request", "Result"]
